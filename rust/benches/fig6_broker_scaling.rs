//! Fig. 6: scaling of the Workload-generator → Message-broker setup.
//!
//! The paper's first experiment: generator(s) + Kafka (4 partitions),
//! loads stepped upward; the result is a 1:1 linear relationship between
//! offered load and broker throughput, with broker latency scaling
//! linearly as load intensifies.
//!
//! Here: the pass-through scenario at increasing offered rates.  The
//! harness fits broker-out vs offered throughput (slope ≈ 1, R² ≈ 1)
//! and reports broker ingest latency per load step.

use sprobench::bench::{scenarios, Bencher, Measurement};
use sprobench::coordinator::run_wall;
use sprobench::metrics::MeasurementPoint;
use sprobench::util::stats::linear_fit;

fn main() {
    let mut b = Bencher::new("fig6_broker_scaling");
    let rates = [50_000u64, 100_000, 200_000, 400_000, 800_000];
    let mut offered = Vec::new();
    let mut through = Vec::new();
    let mut latencies = Vec::new();

    for &rate in &rates {
        let cfg = scenarios::fig6(rate);
        let (summary, _) = run_wall(&cfg, None).expect("fig6 run");
        let broker_lat = summary
            .latency_at(MeasurementPoint::BrokerIn)
            .expect("broker latency recorded");
        offered.push(summary.offered_rate);
        through.push(summary.processed_rate);
        latencies.push(broker_lat.mean);
        b.record(Measurement {
            name: format!("offered {}K ev/s", rate / 1000),
            times: vec![summary.elapsed_micros as f64 / 1e6],
            units_per_iter: summary.processed as f64,
            extras: vec![
                ("offered_eps".into(), summary.offered_rate),
                ("broker_out_eps".into(), summary.processed_rate),
                ("broker_lat_mean_us".into(), broker_lat.mean),
                ("broker_lat_p99_us".into(), broker_lat.p99 as f64),
            ],
        });
    }
    b.finish();

    // The paper's claims: 1:1 linear throughput, linear-ish latency trend.
    let fit = linear_fit(&offered, &through);
    println!(
        "fig6 fit: broker_out = {:.4} * offered + {:.0}   (R^2 = {:.5})",
        fit.slope, fit.intercept, fit.r2
    );
    assert!(
        (fit.slope - 1.0).abs() < 0.05,
        "Fig 6 claim violated: slope {:.4} deviates from 1:1",
        fit.slope
    );
    assert!(fit.r2 > 0.99, "Fig 6 claim violated: R^2 {:.4} not linear", fit.r2);
    let lat_fit = linear_fit(&offered, &latencies);
    println!(
        "fig6 latency trend: {:.4} us per K ev/s (R^2 = {:.3})",
        lat_fit.slope * 1000.0,
        lat_fit.r2
    );
    println!("fig6 mean broker latency by load step: {latencies:?}");
    assert!(
        lat_fit.slope > 0.0,
        "broker latency must grow with load: slope {}",
        lat_fit.slope
    );
    let (first, last) = (latencies[0], latencies[latencies.len() - 1]);
    assert!(
        last > first,
        "broker latency at top load ({last:.0}us) must exceed bottom load ({first:.0}us)"
    );
    println!("CLAIMS OK: 1:1 broker scaling with load-increasing broker latency");
}
