//! Fig. 7: parallelism vs throughput (a) and latency (b, c).
//!
//! Paper setup: CPU-intensive pipeline, parallelism {1, 2, 4, 8, 16},
//! constant workloads 0.5–8 M ev/s.  Findings: near-linear throughput
//! scaling that plateaus at high parallelism; latency grows with
//! parallelism (the optimisation tradeoff the paper highlights).
//!
//! Wall mode runs the grid scaled ~10× down for one box; sim mode then
//! replays the paper-scale grid on the calibrated model.  Shape checks:
//! monotone speedup with diminishing returns, and p50 latency at
//! P=16 > P=1 under fixed load.

use sprobench::bench::{scenarios, Bencher, Measurement};
use sprobench::coordinator::{run_wall, simrun};
use sprobench::metrics::MeasurementPoint;
use sprobench::runtime::RuntimeFactory;

fn main() {
    let mut b = Bencher::new("fig7_parallelism");
    let rtf = RuntimeFactory::default_dir();
    let use_hlo = rtf.available();
    if !use_hlo {
        eprintln!("NOTE: artifacts not built; wall grid runs native compute");
    }
    // Physical parallelism of this box. The paper's near-linear scaling
    // needs real cores; on small hosts the wall grid is recorded for
    // reference and the *shape* claims are carried by the calibrated sim
    // grid (see DESIGN.md §1, scale substitution).
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let assert_wall = cores >= 2 * 16;
    let wall_grid: Vec<u32> = scenarios::PARALLELISM_GRID
        .iter()
        .copied()
        .filter(|&p| assert_wall || p <= (2 * cores as u32).max(2))
        .collect();
    println!("host cores: {cores}; wall grid {wall_grid:?} (shape asserted on {})",
        if assert_wall { "wall + sim" } else { "sim" });

    // --- Wall mode (scaled-down grid, saturating load) -------------------
    let saturating = 400_000u64;
    let mut wall_rates = Vec::new();
    let mut wall_p50 = Vec::new();
    for &p in &wall_grid {
        let mut cfg = scenarios::fig7(p, saturating, use_hlo);
        cfg.bench.duration_micros = 1_500_000;
        let (summary, _) =
            run_wall(&cfg, use_hlo.then(|| rtf.clone())).expect("fig7 wall run");
        let e2e = summary
            .latency_at(MeasurementPoint::EndToEnd)
            .expect("e2e latency");
        wall_rates.push(summary.processed_rate);
        wall_p50.push(e2e.p50 as f64);
        b.record(Measurement {
            name: format!("wall P={p}"),
            times: vec![summary.elapsed_micros as f64 / 1e6],
            units_per_iter: summary.processed as f64,
            extras: vec![
                ("proc_eps".into(), summary.processed_rate),
                ("e2e_p50_us".into(), e2e.p50 as f64),
                ("e2e_p99_us".into(), e2e.p99 as f64),
                ("proc_p50_us".into(), summary.latency_at(MeasurementPoint::ProcOut).map(|h| h.p50 as f64).unwrap_or(0.0)),
            ],
        });
    }

    // --- Sim mode (paper-scale grid) -------------------------------------
    let model = simrun::SimModel::default();
    for &p in &scenarios::PARALLELISM_GRID {
        for &rate in &scenarios::PAPER_RATE_GRID {
            let (summary, _) = simrun::run_sim(&scenarios::fig7_sim(p, rate), &model);
            let e2e = summary
                .latency_at(MeasurementPoint::EndToEnd)
                .expect("sim e2e");
            b.record(Measurement {
                name: format!("sim P={p} load={}M", rate / 1_000_000),
                times: vec![summary.elapsed_micros as f64 / 1e6],
                units_per_iter: summary.processed as f64,
                extras: vec![
                    ("proc_eps".into(), summary.processed_rate),
                    ("e2e_p50_us".into(), e2e.p50 as f64),
                    ("e2e_p99_us".into(), e2e.p99 as f64),
                ],
            });
        }
    }
    b.finish();

    // --- Shape assertions --------------------------------------------------
    println!("fig7 wall throughput by parallelism: {wall_rates:?}");
    println!("fig7 wall latency p50 by parallelism: {wall_p50:?}");
    if assert_wall {
        // (a) throughput grows with parallelism, then flattens.
        assert!(
            wall_rates.windows(2).all(|w| w[1] > w[0] * 0.95),
            "throughput not monotone-ish: {wall_rates:?}"
        );
        let early = wall_rates[1] / wall_rates[0];
        let late = wall_rates[4] / wall_rates[3];
        assert!(late < early, "no plateau at high parallelism: {wall_rates:?}");
        // (b) latency grows with parallelism at fixed offered load.
        assert!(
            wall_p50[4] > wall_p50[0],
            "latency did not rise with parallelism: {wall_p50:?}"
        );
    }
    // Sim grid shapes hold regardless of host size (the paper-scale path).
    let sat = 50_000_000u64;
    let sim_rates: Vec<f64> = scenarios::PARALLELISM_GRID
        .iter()
        .map(|&p| {
            let mut cfg = scenarios::fig7_sim(p, sat);
            cfg.generators.max_instances = 1024;
            simrun::run_sim(&cfg, &model).0.processed_rate
        })
        .collect();
    let sim_p50: Vec<f64> = scenarios::PARALLELISM_GRID
        .iter()
        .map(|&p| {
            simrun::run_sim(&scenarios::fig7_sim(p, 500_000), &model)
                .0
                .latency_at(MeasurementPoint::EndToEnd)
                .expect("sim e2e")
                .p50 as f64
        })
        .collect();
    println!("fig7 sim throughput by parallelism (saturating): {sim_rates:?}");
    println!("fig7 sim latency p50 by parallelism (0.5M ev/s): {sim_p50:?}");
    assert!(
        sim_rates.windows(2).all(|w| w[1] > w[0]),
        "sim throughput not monotone: {sim_rates:?}"
    );
    let early = sim_rates[1] / sim_rates[0];
    let late = sim_rates[4] / sim_rates[3];
    assert!(late < early, "sim plateau missing: {sim_rates:?}");
    assert!(
        sim_p50[4] > sim_p50[0],
        "sim latency did not rise with parallelism: {sim_p50:?}"
    );
    println!("CLAIMS OK: near-linear scaling with plateau; latency rises with parallelism");
}
