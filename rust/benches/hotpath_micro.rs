//! Hot-path microbenchmarks — the §Perf profile targets.
//!
//! Per-component cost of everything on the request path: event
//! serialization/parsing, broker append/poll, channel transfer, latency
//! recording, HLO dispatch per batch size, native-vs-HLO pipeline compute,
//! and the fused-vs-separate dispatch ablation (DESIGN.md) — plus the
//! **data-plane comparison**: a full produce → consume → parse → process
//! loop on the per-record plane vs the batch-first plane (`RecordBatch`
//! end-to-end), which is the number the batching refactor is accountable
//! to, the chained operator preset, the event-time window case
//! (disordered stream → watermarked window) whose surcharge is tracked
//! as `data_plane.event_vs_chained`, and the checkpoint/restore smoke
//! pair: the chained loop with an aligned snapshot + file-commit cycle
//! on the path (`data_plane.checkpoint_eps`) and warm-restore vs
//! cold-replay recovery (`data_plane.restore_vs_cold`).
//!
//! Run `cargo bench --bench hotpath_micro` for the full profile, or
//! `-- --quick` for a reduced run (CI smoke).  Either way the data-plane
//! comparison is written to `BENCH_hotpath.json` at the repo root so every
//! change leaves a perf data point (schema documented in README.md).

use std::sync::Arc;

use sprobench::bench::{scenarios, Bencher, Measurement};
use sprobench::broker::{Broker, BrokerConfig, PartitionedBatchBuilder, Record, Topic};
use sprobench::engine::{Checkpoint, CheckpointStore, EventBatch, TaskPart};
use sprobench::metrics::{LatencyRecorder, MeasurementPoint};
use sprobench::pipelines::{LockstepExchange, PipelineStep, StepFactory};
use sprobench::runtime::{Input, RuntimeFactory};
use sprobench::util::clock;
use sprobench::util::json::Json;
use sprobench::util::rng::Pcg32;
use sprobench::wgen::{EventFormat, EventSerializer, SensorEvent};

/// One produce → consume → parse → process pass over the **per-record**
/// plane: per-record appends (one lock/condvar handshake each), records
/// materialized from the poll, per-event latency samples.
fn e2e_per_record(
    broker: &Arc<Broker>,
    topic: &Arc<Topic>,
    group: &Arc<sprobench::broker::ConsumerGroup>,
    payloads: &[Vec<u8>],
    events: u64,
    lat: &LatencyRecorder,
) -> f64 {
    for i in 0..events {
        let p = &payloads[(i % 1000) as usize];
        broker
            .produce(topic, Record::new(i as u32, p.clone(), i))
            .unwrap();
    }
    let mut seen = 0u64;
    let mut parsed = EventBatch::with_capacity(4096);
    while seen < events {
        if let Ok(Some(b)) = group.poll(0, 4096) {
            let records = b.to_records();
            seen += records.len() as u64;
            parsed.clear();
            parsed.extend_from_records(&records);
            for &append_ts in &parsed.append_ts {
                lat.record(MeasurementPoint::ProcIn, 0, append_ts);
            }
            let alerts = parsed.temps.iter().filter(|&&t| t * 1.8 + 32.0 > 80.0).count();
            std::hint::black_box(alerts);
            group.commit(b.partition, b.next_offset);
        }
    }
    events as f64
}

/// The same pass over the **batch-first** plane: chunked serialization
/// into per-partition arenas, whole-batch appends and polls, payload-view
/// parsing, one bulk latency group per batch.
fn e2e_batched(
    broker: &Arc<Broker>,
    topic: &Arc<Topic>,
    group: &Arc<sprobench::broker::ConsumerGroup>,
    payloads: &[Vec<u8>],
    events: u64,
    lat: &LatencyRecorder,
) -> f64 {
    let mut sent = 0u64;
    while sent < events {
        let chunk = 512.min(events - sent);
        let mut pb = PartitionedBatchBuilder::new(topic.partition_count());
        for i in 0..chunk {
            let key = (sent + i) as u32;
            pb.push(
                topic.partition_for_key(key),
                key,
                &payloads[((sent + i) % 1000) as usize],
                sent + i,
            );
        }
        broker.produce_batches(topic, pb.finish()).unwrap();
        sent += chunk;
    }
    let mut seen = 0u64;
    let mut parsed = EventBatch::with_capacity(4096);
    while seen < events {
        if let Ok(Some(b)) = group.poll(0, 4096) {
            seen += b.record_count() as u64;
            parsed.clear();
            parsed.extend_from_batches(&b.batches);
            lat.record_groups(
                MeasurementPoint::ProcIn,
                0,
                b.batches.iter().map(|rb| (rb.append_ts_micros, rb.len() as u64)),
            );
            let alerts = parsed.temps.iter().filter(|&&t| t * 1.8 + 32.0 > 80.0).count();
            std::hint::black_box(alerts);
            group.commit(b.partition, b.next_offset);
        }
    }
    events as f64
}

/// The batched pass with a full operator chain processing each poll:
/// `filter → keyby → window(mean) → topk → emit_aggregates` (the
/// `chained_filter_topk` preset, native compute).  The delta against
/// `e2e data plane batched` is the operator-chain overhead.
fn e2e_chained(
    broker: &Arc<Broker>,
    topic: &Arc<Topic>,
    group: &Arc<sprobench::broker::ConsumerGroup>,
    payloads: &[Vec<u8>],
    events: u64,
) -> f64 {
    let cfg = scenarios::chained_filter_topk();
    let factory = StepFactory::new(&cfg, None);
    let mut step = factory.create(0).expect("compile chain");
    let mut sent = 0u64;
    while sent < events {
        let chunk = 512.min(events - sent);
        let mut pb = PartitionedBatchBuilder::new(topic.partition_count());
        for i in 0..chunk {
            let key = (sent + i) as u32;
            pb.push(
                topic.partition_for_key(key),
                key,
                &payloads[((sent + i) % 1000) as usize],
                sent + i,
            );
        }
        broker.produce_batches(topic, pb.finish()).unwrap();
        sent += chunk;
    }
    let mut seen = 0u64;
    let mut parsed = EventBatch::with_capacity(4096);
    let mut out = Vec::new();
    while seen < events {
        if let Ok(Some(b)) = group.poll(0, 4096) {
            seen += b.record_count() as u64;
            parsed.clear();
            parsed.extend_from_batches(&b.batches);
            out.clear();
            // Virtual clock at 100 µs/event so the 500 ms slide keeps
            // crossing boundaries (and topk + emit stay on the path).
            step.process(seen * 100, &[], &parsed, &mut out).unwrap();
            std::hint::black_box(out.len());
            group.commit(b.partition, b.next_offset);
        }
    }
    let mut tail = Vec::new();
    step.finish(seen * 100 + 1_000_000, &mut tail).unwrap();
    std::hint::black_box(tail.len());
    events as f64
}

/// The batched pass through an **event-time** window chain over a
/// disorder-injected stream: virtual event time advances 100µs/event,
/// emission order is shuffled in 32-event blocks (≤3.1ms displacement,
/// inside the 5ms watermark bound), and the chain is
/// `window(event, mean, merge_if_open) → emit_aggregates`.  The delta
/// against `e2e data plane chained` is the event-time surcharge
/// (watermark bookkeeping + data-dependent pane assignment).
fn e2e_event_time(
    broker: &Arc<Broker>,
    topic: &Arc<Topic>,
    group: &Arc<sprobench::broker::ConsumerGroup>,
    events: u64,
) -> f64 {
    use sprobench::config::{OpSpec, PipelineSpec};
    use sprobench::engine::{AggKind, LatePolicy, WindowTime};
    let mut cfg = scenarios::wall_base("hotpath-event-time");
    cfg.engine.use_hlo = false;
    cfg.engine.pipeline_spec = Some(PipelineSpec {
        ops: vec![
            OpSpec::Window {
                agg: AggKind::Mean,
                window_micros: 100_000,
                slide_micros: 50_000,
                time: WindowTime::Event,
                allowed_lateness_micros: 10_000,
                late_policy: LatePolicy::MergeIfOpen,
                watermark_micros: 5_000,
            },
            OpSpec::EmitAggregates,
        ],
    });
    let factory = StepFactory::new(&cfg, None);
    let mut step = factory.create(0).expect("compile event-time chain");

    let mut serializer = EventSerializer::new(EventFormat::Csv, 27);
    let mut wire = Vec::new();
    let mut sent = 0u64;
    while sent < events {
        let chunk = 512.min(events - sent);
        let mut pb = PartitionedBatchBuilder::new(topic.partition_count());
        let mut idx: Vec<u64> = (sent..sent + chunk).collect();
        for block in idx.chunks_mut(32) {
            block.reverse();
        }
        for &i in &idx {
            let ev = SensorEvent {
                ts_micros: i * 100,
                sensor_id: (i % 1024) as u32,
                temp_c: 20.0 + (i % 40) as f32,
            };
            serializer.serialize(&ev, &mut wire);
            // Everything on partition 0: the whole stream is produced
            // before consumption starts, and per-partition polling would
            // otherwise interleave ~seconds of event-time skew across
            // partitions — blowing past the watermark bound and turning
            // the case into a drop-path measurement instead of real
            // watermark bookkeeping + pane assignment.
            pb.push(0, ev.sensor_id, &wire, ev.ts_micros);
        }
        broker.produce_batches(topic, pb.finish()).unwrap();
        sent += chunk;
    }
    let mut seen = 0u64;
    let mut parsed = EventBatch::with_capacity(4096);
    let mut out = Vec::new();
    while seen < events {
        if let Ok(Some(b)) = group.poll(0, 4096) {
            seen += b.record_count() as u64;
            parsed.clear();
            parsed.extend_from_batches(&b.batches);
            out.clear();
            step.process(seen * 100, &[], &parsed, &mut out).unwrap();
            std::hint::black_box(out.len());
            group.commit(b.partition, b.next_offset);
        }
    }
    let mut tail = Vec::new();
    step.finish(seen * 100 + 1_000_000, &mut tail).unwrap();
    std::hint::black_box(tail.len());
    events as f64
}

/// [`e2e_chained`] with an aligned checkpoint cycle on the hot path:
/// every 8th poll the chain snapshots its operator state and commits a
/// CRC-stamped checkpoint file (temp-then-rename) through a real
/// [`CheckpointStore`].  The delta against `e2e data plane chained` is
/// the checkpointing surcharge, tracked as
/// `data_plane.checkpoint_vs_chained`.
fn e2e_checkpointed(
    broker: &Arc<Broker>,
    topic: &Arc<Topic>,
    group: &Arc<sprobench::broker::ConsumerGroup>,
    payloads: &[Vec<u8>],
    events: u64,
    store: &CheckpointStore,
) -> f64 {
    let cfg = scenarios::chained_filter_topk();
    let factory = StepFactory::new(&cfg, None);
    let mut step = factory.create(0).expect("compile chain");
    let mut sent = 0u64;
    while sent < events {
        let chunk = 512.min(events - sent);
        let mut pb = PartitionedBatchBuilder::new(topic.partition_count());
        for i in 0..chunk {
            let key = (sent + i) as u32;
            pb.push(
                topic.partition_for_key(key),
                key,
                &payloads[((sent + i) % 1000) as usize],
                sent + i,
            );
        }
        broker.produce_batches(topic, pb.finish()).unwrap();
        sent += chunk;
    }
    let mut seen = 0u64;
    let mut parsed = EventBatch::with_capacity(4096);
    let mut out = Vec::new();
    let mut rounds = 0u64;
    let mut epoch = 0u64;
    while seen < events {
        if let Ok(Some(b)) = group.poll(0, 4096) {
            seen += b.record_count() as u64;
            parsed.clear();
            parsed.extend_from_batches(&b.batches);
            out.clear();
            step.process(seen * 100, &[], &parsed, &mut out).unwrap();
            std::hint::black_box(out.len());
            group.commit(b.partition, b.next_offset);
            rounds += 1;
            if rounds % 8 == 0 {
                epoch += 1;
                let state = step.snapshot().expect("chain snapshots");
                store
                    .write(&Checkpoint {
                        epoch,
                        tasks: vec![TaskPart {
                            offsets: vec![(0, seen)],
                            events_in: seen,
                            parse_failures: 0,
                            state,
                        }],
                    })
                    .expect("checkpoint commit");
            }
        }
    }
    let mut tail = Vec::new();
    step.finish(seen * 100 + 1_000_000, &mut tail).unwrap();
    std::hint::black_box(tail.len());
    events as f64
}

/// Synthetic event batches shared by the shuffle case and its
/// task-local baseline: `total` rows per round split across `ways`
/// batches, ids sweeping a 1024-key space, `now` advancing 1ms/round so
/// the 500ms slide keeps crossing boundaries.
fn shuffle_round_batches(sent: u64, ways: usize, per_way: usize, now: u64) -> Vec<EventBatch> {
    (0..ways)
        .map(|t| {
            let mut b = EventBatch::with_capacity(per_way);
            for i in 0..per_way {
                let id = ((sent + (t * per_way + i) as u64) % 1024) as u32;
                b.ids.push(id);
                b.temps.push(20.0 + (i % 40) as f32);
                b.gen_ts.push(now);
                b.append_ts.push(now);
            }
            b.payload_bytes = (per_way * 27) as u64;
            b
        })
        .collect()
}

/// The keyed-exchange (shuffle) data plane: the `shuffle_uniform` preset
/// chain (`keyby → window(mean) → topk → emit_aggregates`) staged across
/// 4 task instances and driven in deterministic lockstep rounds — every
/// row crosses the keyby boundary, every window aggregate crosses the
/// global top-k boundary.  The delta against `e2e shuffle task-local`
/// (identical chain, identical synthetic feed, one fused chain instance)
/// is the exchange surcharge.
fn e2e_shuffle(events: u64) -> f64 {
    let mut cfg = scenarios::shuffle_uniform();
    cfg.engine.use_hlo = false;
    let par = cfg.engine.parallelism as usize;
    let mut lx = LockstepExchange::compile(&cfg)
        .expect("compile staged chain")
        .expect("the shuffle preset stages");
    let chunk = 512usize;
    let mut out = Vec::new();
    let mut sent = 0u64;
    let mut now = 0u64;
    while sent < events {
        now += 1_000;
        let batches = shuffle_round_batches(sent, par, chunk, now);
        lx.process_round(now, &batches, &mut out).unwrap();
        std::hint::black_box(out.len());
        out.clear();
        sent += (par * chunk) as u64;
    }
    lx.finish(now + 1_000_000, &mut out).unwrap();
    std::hint::black_box(out.len());
    sent as f64
}

/// The task-local baseline for [`e2e_shuffle`]: the *same* chain over
/// the *same* synthetic rounds, executed as one fused chain instance
/// with no exchange (what `engine.exchange: none` runs per task).
fn e2e_shuffle_local(events: u64) -> f64 {
    let mut cfg = scenarios::shuffle_uniform();
    cfg.engine.use_hlo = false;
    let par = cfg.engine.parallelism as usize;
    let factory = StepFactory::new(&cfg, None);
    let mut step = factory.create(0).expect("compile fused chain");
    let chunk = 512usize;
    let mut out = Vec::new();
    let mut sent = 0u64;
    let mut now = 0u64;
    while sent < events {
        now += 1_000;
        for b in shuffle_round_batches(sent, par, chunk, now) {
            step.process(now, &[], &b, &mut out).unwrap();
        }
        std::hint::black_box(out.len());
        out.clear();
        sent += (par * chunk) as u64;
    }
    step.finish(now + 1_000_000, &mut out).unwrap();
    std::hint::black_box(out.len());
    sent as f64
}

fn eps(m: &[Measurement], name: &str) -> f64 {
    m.iter()
        .find(|m| m.name == name)
        .map(|m| m.throughput())
        .unwrap_or(0.0)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n: u64 = if quick { 40_000 } else { 200_000 };
    let iters = if quick { 2 } else { 5 };

    let mut b = Bencher::new("hotpath_micro");

    // --- Event serialization (generator inner loop) ----------------------
    let mut rng = Pcg32::new(1, 1);
    let mut wire = Vec::with_capacity(64);
    for (label, format, size) in [
        ("serialize csv 27B", EventFormat::Csv, 27usize),
        ("serialize json 64B", EventFormat::Json, 64),
        ("serialize json 256B", EventFormat::Json, 256),
    ] {
        b.measure(label, 1, iters, || -> f64 {
            for _ in 0..n {
                let ev = SensorEvent {
                    ts_micros: 1_714_329_600_000_000,
                    sensor_id: rng.below(1024),
                    temp_c: 20.0 + rng.f32() * 30.0,
                };
                ev.serialize_into(format, size, &mut wire);
                std::hint::black_box(&wire);
            }
            n as f64
        });
    }

    // --- Event parsing (engine source) ------------------------------------
    let mut payloads = Vec::new();
    let mut serializer = EventSerializer::new(EventFormat::Csv, 27);
    for i in 0..1000u32 {
        let ev = SensorEvent {
            ts_micros: 1_714_329_600_000_000 + i as u64,
            sensor_id: i % 1024,
            temp_c: 21.5,
        };
        let mut buf = Vec::new();
        serializer.serialize(&ev, &mut buf);
        payloads.push(buf);
    }
    b.measure("parse csv 27B", 1, iters, || -> f64 {
        for _ in 0..(n / 1000) {
            for p in &payloads {
                std::hint::black_box(SensorEvent::parse(p));
            }
        }
        n as f64
    });

    // --- Broker produce_batch + consume ------------------------------------
    let clk = clock::wall();
    let broker = Broker::new(
        BrokerConfig {
            queue_depth: 1 << 20,
            ..BrokerConfig::default()
        },
        clk.clone(),
    );
    let topic = broker.create_topic("micro");
    let group = broker.subscribe("micro", "g", 1);
    b.measure("broker produce+consume batch=512", 1, iters, || -> f64 {
        let total = n / 2;
        let mut sent = 0;
        while sent < total {
            let records: Vec<Record> = (0..512)
                .map(|i| Record::new(i as u32, payloads[i % 1000].as_slice(), 0))
                .collect();
            broker.produce_batch(&topic, records).unwrap();
            sent += 512;
        }
        let mut seen = 0u64;
        while seen < sent {
            if let Ok(Some(batch)) = group.poll(0, 4096) {
                seen += batch.record_count() as u64;
                group.commit(batch.partition, batch.next_offset);
            }
        }
        sent as f64
    });

    // --- Data-plane comparison: per-record vs RecordBatch end-to-end -------
    // Same event count, same broker config, same parse + native compute;
    // the only variable is the unit moving through the data plane.
    let lat = LatencyRecorder::new();
    {
        let t = broker.create_topic("dp-record");
        let g = broker.subscribe("dp-record", "dpr", 1);
        b.measure("e2e data plane per-record", 1, iters, || {
            e2e_per_record(&broker, &t, &g, &payloads, n / 2, &lat)
        });
    }
    {
        let t = broker.create_topic("dp-batch");
        let g = broker.subscribe("dp-batch", "dpb", 1);
        b.measure("e2e data plane batched", 1, iters, || {
            e2e_batched(&broker, &t, &g, &payloads, n / 2, &lat)
        });
    }
    {
        let t = broker.create_topic("dp-chain");
        let g = broker.subscribe("dp-chain", "dpc", 1);
        b.measure("e2e data plane chained", 1, iters, || {
            e2e_chained(&broker, &t, &g, &payloads, n / 2)
        });
    }
    {
        let t = broker.create_topic("dp-event");
        let g = broker.subscribe("dp-event", "dpe", 1);
        b.measure("e2e data plane event-time", 1, iters, || {
            e2e_event_time(&broker, &t, &g, n / 2)
        });
    }
    b.measure("e2e shuffle task-local", 1, iters, || e2e_shuffle_local(n / 2));
    b.measure("e2e data plane shuffle", 1, iters, || e2e_shuffle(n / 2));

    // --- Checkpoint + recovery smoke (runs in quick mode: CI coverage) -----
    let ckpt_dir = std::env::temp_dir().join(format!(
        "sprobench-hotpath-ckpt-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let store = CheckpointStore::new(&ckpt_dir, 2);
    {
        let t = broker.create_topic("dp-ckpt");
        let g = broker.subscribe("dp-ckpt", "dpk", 1);
        b.measure("e2e data plane checkpointed", 1, iters, || {
            e2e_checkpointed(&broker, &t, &g, &payloads, n / 2, &store)
        });
    }
    // Warm-restore vs cold-replay recovery: a fused chain is run to its
    // midpoint and checkpointed once; "warm" recovery loads + restores
    // that state and replays only the suffix, "cold" replays the whole
    // stream from scratch.  Both cases return the full stream length (the
    // end state they reach), so `warm_eps / cold_eps` is the recovery
    // speedup a checkpoint buys (`data_plane.restore_vs_cold`, > 1 when
    // restoring beats replaying — the restore + file-read overhead is
    // what pulls it below the ideal 2x at a midpoint checkpoint).
    let recovery_rounds: Vec<(u64, EventBatch)> = {
        let chunk = 512usize;
        let mut v = Vec::new();
        let mut sent = 0u64;
        let mut now = 0u64;
        while sent < n / 2 {
            now += 1_000;
            let mut bs = shuffle_round_batches(sent, 1, chunk, now);
            v.push((now, bs.pop().expect("one batch at ways=1")));
            sent += chunk as u64;
        }
        v
    };
    let recovery_total = (recovery_rounds.len() * 512) as f64;
    let mid = recovery_rounds.len() / 2;
    let recovery_cfg = scenarios::chained_filter_topk();
    let recovery_factory = StepFactory::new(&recovery_cfg, None);
    const RECOVERY_EPOCH: u64 = 1_000_000;
    {
        // Run to the midpoint once; commit the checkpoint warm restores read.
        let mut step = recovery_factory.create(0).expect("compile chain");
        let mut out = Vec::new();
        for (now, batch) in &recovery_rounds[..mid] {
            step.process(*now, &[], batch, &mut out).unwrap();
            out.clear();
        }
        let state = step.snapshot().expect("chain snapshots");
        store
            .write(&Checkpoint {
                epoch: RECOVERY_EPOCH,
                tasks: vec![TaskPart {
                    offsets: vec![(0, (mid * 512) as u64)],
                    events_in: (mid * 512) as u64,
                    parse_failures: 0,
                    state,
                }],
            })
            .expect("checkpoint commit");
    }
    b.measure("recover warm from checkpoint", 1, iters, || -> f64 {
        let ckpt = store.load(RECOVERY_EPOCH).expect("recovery checkpoint");
        let mut step = recovery_factory.create(0).expect("compile chain");
        step.restore(&ckpt.tasks[0].state).expect("restore chain state");
        let mut out = Vec::new();
        for (now, batch) in &recovery_rounds[mid..] {
            step.process(*now, &[], batch, &mut out).unwrap();
            out.clear();
        }
        let last_now = recovery_rounds.last().expect("rounds").0;
        step.finish(last_now + 1_000_000, &mut out).unwrap();
        std::hint::black_box(out.len());
        recovery_total
    });
    b.measure("recover cold replay", 1, iters, || -> f64 {
        let mut step = recovery_factory.create(0).expect("compile chain");
        let mut out = Vec::new();
        for (now, batch) in &recovery_rounds {
            step.process(*now, &[], batch, &mut out).unwrap();
            out.clear();
        }
        let last_now = recovery_rounds.last().expect("rounds").0;
        step.finish(last_now + 1_000_000, &mut out).unwrap();
        std::hint::black_box(out.len());
        recovery_total
    });
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    // --- Record construction: per-event alloc vs chunk arena ------------------
    b.measure("record per-event alloc x512", 1, iters, || -> f64 {
        let iters = 200;
        for _ in 0..iters {
            let records: Vec<Record> = (0..512)
                .map(|i| Record::new(i as u32, payloads[i % 1000].as_slice(), 0))
                .collect();
            std::hint::black_box(records);
        }
        (iters * 512) as f64
    });
    b.measure("record batch arena x512", 1, iters, || -> f64 {
        let iters = 200;
        for _ in 0..iters {
            let mut builder =
                sprobench::broker::RecordBatchBuilder::with_capacity(512, 512 * 27);
            for i in 0..512usize {
                builder.push(i as u32, &payloads[i % 1000], 0);
            }
            std::hint::black_box(builder.build());
        }
        (iters * 512) as f64
    });

    // --- Latency recording ---------------------------------------------------
    let lrec = Arc::new(LatencyRecorder::new());
    b.measure("latency record_batch x1024", 1, iters, || -> f64 {
        for _ in 0..(n / 1024) {
            lrec.record_batch(MeasurementPoint::EndToEnd, 0, (0..1024).map(|i| 500 + i));
        }
        n as f64
    });
    b.measure("latency record_groups 2x512", 1, iters, || -> f64 {
        for _ in 0..(n / 1024) {
            lrec.record_groups(
                MeasurementPoint::EndToEnd,
                0,
                [(500u64, 512u64), (900, 512)].into_iter(),
            );
        }
        n as f64
    });

    // --- HLO dispatch cost per batch size (skipped in quick mode) -------------
    let rtf = RuntimeFactory::default_dir();
    if quick {
        eprintln!("NOTE: --quick: skipping HLO microbenches");
    } else if rtf.available() {
        let rt = rtf.create().expect("runtime");
        for batch in [256usize, 1024, 4096] {
            let temps = vec![21.5f32; batch];
            let thresh = [80.0f32];
            let name = format!("cpu_b{batch}");
            // warm the compile cache
            rt.execute_f32(&name, &[Input::F32(&temps), Input::F32(&thresh)])
                .unwrap();
            b.measure(&format!("hlo cpu dispatch b={batch}"), 2, 10, || -> f64 {
                let iters = 200;
                for _ in 0..iters {
                    std::hint::black_box(
                        rt.execute_f32(&name, &[Input::F32(&temps), Input::F32(&thresh)])
                            .unwrap(),
                    );
                }
                (iters * batch) as f64
            });
        }

        // Fused vs separate dispatch ablation.
        let batch = 1024usize;
        let ids = vec![3i32; batch];
        let temps = vec![21.5f32; batch];
        let thresh = [80.0f32];
        let state = vec![0.0f32; 1024];
        rt.execute_f32(
            "fused_b1024_k1024",
            &[
                Input::I32(&ids),
                Input::F32(&temps),
                Input::F32(&thresh),
                Input::F32(&state),
                Input::F32(&state),
            ],
        )
        .unwrap();
        rt.execute_f32("mem_b1024_k1024", &[
            Input::I32(&ids),
            Input::F32(&temps),
            Input::F32(&state),
            Input::F32(&state),
        ])
        .unwrap();
        b.measure("ablation: cpu+mem separate", 2, 10, || -> f64 {
            let iters = 100;
            for _ in 0..iters {
                let out = rt
                    .execute_f32("cpu_b1024", &[Input::F32(&temps), Input::F32(&thresh)])
                    .unwrap();
                std::hint::black_box(
                    rt.execute_f32(
                        "mem_b1024_k1024",
                        &[
                            Input::I32(&ids),
                            Input::F32(&out[0]),
                            Input::F32(&state),
                            Input::F32(&state),
                        ],
                    )
                    .unwrap(),
                );
            }
            (iters * batch) as f64
        });
        b.measure("ablation: fused single dispatch", 2, 10, || -> f64 {
            let iters = 100;
            for _ in 0..iters {
                std::hint::black_box(
                    rt.execute_f32(
                        "fused_b1024_k1024",
                        &[
                            Input::I32(&ids),
                            Input::F32(&temps),
                            Input::F32(&thresh),
                            Input::F32(&state),
                            Input::F32(&state),
                        ],
                    )
                    .unwrap(),
                );
            }
            (iters * batch) as f64
        });
    } else {
        eprintln!("NOTE: artifacts not built; skipping HLO microbenches");
    }

    // --- Native pipeline compute reference -------------------------------------
    let temps: Vec<f32> = (0..4096).map(|i| i as f32 / 40.0).collect();
    b.measure("native cpu transform b=4096", 1, iters, || -> f64 {
        let iters = 500;
        for _ in 0..iters {
            let f: Vec<f32> = temps.iter().map(|t| t * 9.0 / 5.0 + 32.0).collect();
            let a: Vec<f32> = f.iter().map(|&x| if x > 80.0 { 1.0 } else { 0.0 }).collect();
            std::hint::black_box((f, a));
        }
        (iters * 4096) as f64
    });

    // --- BENCH_hotpath.json: the perf trajectory record ------------------------
    // Written at the repo root on every run (full or quick) so CI and the
    // next PR can compare data-plane throughput.  Schema: see README.md
    // §Data plane batching.
    let per_record_eps = eps(b.measurements(), "e2e data plane per-record");
    let batched_eps = eps(b.measurements(), "e2e data plane batched");
    let chained_eps = eps(b.measurements(), "e2e data plane chained");
    let event_time_eps = eps(b.measurements(), "e2e data plane event-time");
    let shuffle_eps = eps(b.measurements(), "e2e data plane shuffle");
    let shuffle_local_eps = eps(b.measurements(), "e2e shuffle task-local");
    let checkpoint_eps = eps(b.measurements(), "e2e data plane checkpointed");
    let restore_warm_eps = eps(b.measurements(), "recover warm from checkpoint");
    let restore_cold_eps = eps(b.measurements(), "recover cold replay");
    let speedup = if per_record_eps > 0.0 {
        batched_eps / per_record_eps
    } else {
        0.0
    };
    // Operator-chain overhead vs the bare batched loop (< 1.0 means the
    // chained preset costs throughput; tracked per PR).
    let chain_vs_batched = if batched_eps > 0.0 {
        chained_eps / batched_eps
    } else {
        0.0
    };
    // Event-time surcharge vs the processing-time chained loop.
    let event_vs_chained = if chained_eps > 0.0 {
        event_time_eps / chained_eps
    } else {
        0.0
    };
    // Keyed-exchange surcharge vs the task-local run of the *same* chain
    // over the same synthetic feed (broker/parse cost excluded on both
    // sides, so the ratio isolates routing + channels + gating).
    let shuffle_vs_local = if shuffle_local_eps > 0.0 {
        shuffle_eps / shuffle_local_eps
    } else {
        0.0
    };
    // Aligned-checkpoint surcharge vs the same chained loop without the
    // snapshot + file-commit cycle.
    let checkpoint_vs_chained = if chained_eps > 0.0 {
        checkpoint_eps / chained_eps
    } else {
        0.0
    };
    // Recovery speedup: warm restore (load + restore + replay the suffix)
    // vs cold replay of the whole stream, both reaching the same end
    // state.  > 1 means the checkpoint pays for itself on restore.
    let restore_vs_cold = if restore_cold_eps > 0.0 {
        restore_warm_eps / restore_cold_eps
    } else {
        0.0
    };
    let mut doc = Json::obj();
    doc.set("schema", Json::Str("sprobench.bench.hotpath/v1".into()));
    doc.set("target", Json::Str("hotpath_micro".into()));
    doc.set("quick", Json::Bool(quick));
    doc.set("events_per_case", Json::Int((n / 2) as i64));
    let mut cases = Vec::new();
    for m in b.measurements() {
        let mut c = Json::obj();
        c.set("name", Json::Str(m.name.clone()));
        c.set("mean_s", Json::Num(m.mean_time()));
        c.set("p50_s", Json::Num(m.p50_time()));
        c.set("p99_s", Json::Num(m.p99_time()));
        c.set("events_per_sec", Json::Num(m.throughput()));
        cases.push(c);
    }
    doc.set("cases", Json::Arr(cases));
    let mut dp = Json::obj();
    dp.set("per_record_eps", Json::Num(per_record_eps));
    dp.set("batched_eps", Json::Num(batched_eps));
    dp.set("speedup", Json::Num(speedup));
    dp.set("chained_eps", Json::Num(chained_eps));
    dp.set("chain_vs_batched", Json::Num(chain_vs_batched));
    dp.set("event_time_eps", Json::Num(event_time_eps));
    dp.set("event_vs_chained", Json::Num(event_vs_chained));
    dp.set("shuffle_eps", Json::Num(shuffle_eps));
    dp.set("shuffle_local_eps", Json::Num(shuffle_local_eps));
    dp.set("shuffle_vs_local", Json::Num(shuffle_vs_local));
    dp.set("checkpoint_eps", Json::Num(checkpoint_eps));
    dp.set("checkpoint_vs_chained", Json::Num(checkpoint_vs_chained));
    dp.set("restore_warm_eps", Json::Num(restore_warm_eps));
    dp.set("restore_cold_eps", Json::Num(restore_cold_eps));
    dp.set("restore_vs_cold", Json::Num(restore_vs_cold));
    doc.set("data_plane", dp);
    match std::fs::write("BENCH_hotpath.json", doc.to_pretty()) {
        Ok(()) => println!("wrote BENCH_hotpath.json (data-plane speedup: {speedup:.2}x)"),
        Err(e) => eprintln!("WARNING: could not write BENCH_hotpath.json: {e}"),
    }

    b.finish();
}
