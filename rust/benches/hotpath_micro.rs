//! Hot-path microbenchmarks — the §Perf profile targets.
//!
//! Per-component cost of everything on the request path: event
//! serialization/parsing, broker append/poll, channel transfer, latency
//! recording, HLO dispatch per batch size, native-vs-HLO pipeline compute,
//! and the fused-vs-separate dispatch ablation (DESIGN.md).

use std::sync::Arc;

use sprobench::bench::Bencher;
use sprobench::broker::{Broker, BrokerConfig, Record};
use sprobench::metrics::{LatencyRecorder, MeasurementPoint};
use sprobench::runtime::{Input, RuntimeFactory};
use sprobench::util::clock;
use sprobench::util::rng::Pcg32;
use sprobench::wgen::{EventFormat, SensorEvent};

const N: u64 = 200_000;

fn main() {
    let mut b = Bencher::new("hotpath_micro");

    // --- Event serialization (generator inner loop) ----------------------
    let mut rng = Pcg32::new(1, 1);
    let mut wire = Vec::with_capacity(64);
    for (label, format, size) in [
        ("serialize csv 27B", EventFormat::Csv, 27usize),
        ("serialize json 64B", EventFormat::Json, 64),
        ("serialize json 256B", EventFormat::Json, 256),
    ] {
        b.measure(label, 1, 5, || -> f64 {
            
            for _ in 0..N {
                let ev = SensorEvent {
                    ts_micros: 1_714_329_600_000_000,
                    sensor_id: rng.below(1024),
                    temp_c: 20.0 + rng.f32() * 30.0,
                };
                ev.serialize_into(format, size, &mut wire);
                std::hint::black_box(&wire);
            }
            N as f64
        });
    }

    // --- Event parsing (engine source) ------------------------------------
    let mut payloads = Vec::new();
    for i in 0..1000u32 {
        let ev = SensorEvent {
            ts_micros: 1_714_329_600_000_000 + i as u64,
            sensor_id: i % 1024,
            temp_c: 21.5,
        };
        let mut buf = Vec::new();
        ev.serialize_into(EventFormat::Csv, 27, &mut buf);
        payloads.push(buf);
    }
    b.measure("parse csv 27B", 1, 5, || -> f64 {
        for _ in 0..(N / 1000) {
            for p in &payloads {
                std::hint::black_box(SensorEvent::parse(p));
            }
        }
        N as f64
    });

    // --- Broker produce_batch + consume ------------------------------------
    let clk = clock::wall();
    let broker = Broker::new(
        BrokerConfig {
            queue_depth: 1 << 20,
            ..BrokerConfig::default()
        },
        clk.clone(),
    );
    let topic = broker.create_topic("micro");
    let group = broker.subscribe("micro", "g", 1);
    b.measure("broker produce+consume batch=512", 1, 5, || -> f64 {
        let total = 100_000u64;
        let mut sent = 0;
        while sent < total {
            let records: Vec<Record> = (0..512)
                .map(|i| Record::new(i as u32, payloads[i % 1000].as_slice(), 0))
                .collect();
            broker.produce_batch(&topic, records).unwrap();
            sent += 512;
        }
        let mut seen = 0u64;
        while seen < sent {
            if let Ok(Some(batch)) = group.poll(0, 4096) {
                seen += batch.records.len() as u64;
                group.commit(batch.partition, batch.next_offset);
            }
        }
        sent as f64
    });

    // --- Record construction: per-event alloc vs chunk arena ------------------
    b.measure("record per-event alloc x512", 1, 5, || -> f64 {
        let iters = 200;
        for _ in 0..iters {
            let records: Vec<Record> = (0..512)
                .map(|i| Record::new(i as u32, payloads[i % 1000].as_slice(), 0))
                .collect();
            std::hint::black_box(records);
        }
        (iters * 512) as f64
    });
    b.measure("record arena views x512", 1, 5, || -> f64 {
        let iters = 200;
        for _ in 0..iters {
            let mut arena: Vec<u8> = Vec::with_capacity(512 * 27);
            let mut slots = Vec::with_capacity(512);
            for i in 0..512usize {
                let p = &payloads[i % 1000];
                slots.push((i as u32, arena.len(), p.len()));
                arena.extend_from_slice(p);
            }
            let arena: std::sync::Arc<[u8]> = arena.into();
            let records: Vec<Record> = slots
                .into_iter()
                .map(|(k, off, n)| Record::from_arena(k, arena.clone(), off, n, 0))
                .collect();
            std::hint::black_box(records);
        }
        (iters * 512) as f64
    });

    // --- Latency recording ---------------------------------------------------
    let lat = Arc::new(LatencyRecorder::new());
    b.measure("latency record_batch x1024", 1, 5, || -> f64 {
        for _ in 0..(N / 1024) {
            lat.record_batch(MeasurementPoint::EndToEnd, 0, (0..1024).map(|i| 500 + i));
        }
        N as f64
    });

    // --- HLO dispatch cost per batch size -------------------------------------
    let rtf = RuntimeFactory::default_dir();
    if rtf.available() {
        let rt = rtf.create().expect("runtime");
        for batch in [256usize, 1024, 4096] {
            let temps = vec![21.5f32; batch];
            let thresh = [80.0f32];
            let name = format!("cpu_b{batch}");
            // warm the compile cache
            rt.execute_f32(&name, &[Input::F32(&temps), Input::F32(&thresh)])
                .unwrap();
            b.measure(&format!("hlo cpu dispatch b={batch}"), 2, 10, || -> f64 {
                let iters = 200;
                for _ in 0..iters {
                    std::hint::black_box(
                        rt.execute_f32(&name, &[Input::F32(&temps), Input::F32(&thresh)])
                            .unwrap(),
                    );
                }
                (iters * batch) as f64
            });
        }

        // Fused vs separate dispatch ablation.
        let batch = 1024usize;
        let ids = vec![3i32; batch];
        let temps = vec![21.5f32; batch];
        let thresh = [80.0f32];
        let state = vec![0.0f32; 1024];
        rt.execute_f32(
            "fused_b1024_k1024",
            &[
                Input::I32(&ids),
                Input::F32(&temps),
                Input::F32(&thresh),
                Input::F32(&state),
                Input::F32(&state),
            ],
        )
        .unwrap();
        rt.execute_f32("mem_b1024_k1024", &[
            Input::I32(&ids),
            Input::F32(&temps),
            Input::F32(&state),
            Input::F32(&state),
        ])
        .unwrap();
        b.measure("ablation: cpu+mem separate", 2, 10, || -> f64 {
            let iters = 100;
            for _ in 0..iters {
                let out = rt
                    .execute_f32("cpu_b1024", &[Input::F32(&temps), Input::F32(&thresh)])
                    .unwrap();
                std::hint::black_box(
                    rt.execute_f32(
                        "mem_b1024_k1024",
                        &[
                            Input::I32(&ids),
                            Input::F32(&out[0]),
                            Input::F32(&state),
                            Input::F32(&state),
                        ],
                    )
                    .unwrap(),
                );
            }
            (iters * batch) as f64
        });
        b.measure("ablation: fused single dispatch", 2, 10, || -> f64 {
            let iters = 100;
            for _ in 0..iters {
                std::hint::black_box(
                    rt.execute_f32(
                        "fused_b1024_k1024",
                        &[
                            Input::I32(&ids),
                            Input::F32(&temps),
                            Input::F32(&thresh),
                            Input::F32(&state),
                            Input::F32(&state),
                        ],
                    )
                    .unwrap(),
                );
            }
            (iters * batch) as f64
        });
    } else {
        eprintln!("NOTE: artifacts not built; skipping HLO microbenches");
    }

    // --- Native pipeline compute reference -------------------------------------
    let temps: Vec<f32> = (0..4096).map(|i| i as f32 / 40.0).collect();
    b.measure("native cpu transform b=4096", 1, 5, || -> f64 {
        let iters = 500;
        for _ in 0..iters {
            let f: Vec<f32> = temps.iter().map(|t| t * 9.0 / 5.0 + 32.0).collect();
            let a: Vec<f32> = f.iter().map(|&x| if x > 80.0 { 1.0 } else { 0.0 }).collect();
            std::hint::black_box((f, a));
        }
        (iters * 4096) as f64
    });

    b.finish();
}
