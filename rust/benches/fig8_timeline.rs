//! Fig. 8: metrics across normalized runtime, per parallelism level.
//!
//! Paper setup: the CPU-intensive pipeline at parallelism {1,2,4,8,16}
//! (coloured lines), metrics sampled over the run and plotted against
//! normalized runtime:
//!   (a) throughput — higher parallelism achieves more,
//!   (b) latency — higher parallelism pays more,
//!   (c) GC (young) — count and duration grow over runtime, faster at
//!       higher parallelism.
//!
//! This bench runs the grid, exports the per-interval series (the same
//! series the coordinator's sampler collects), writes
//! `bench_results/fig8_<metric>.csv` with one column per parallelism, and
//! asserts the three shape claims.

use sprobench::bench::{scenarios, Bencher, Measurement};
use sprobench::coordinator::run_wall;
use sprobench::postprocess::csv_from_rows;
use sprobench::runtime::RuntimeFactory;

fn main() {
    let mut b = Bencher::new("fig8_timeline");
    let rtf = RuntimeFactory::default_dir();
    let use_hlo = rtf.available();
    // Full grid on big hosts; a condensed grid on small ones (the GC
    // mechanism — fixed worker heap divided across slots — shows at any
    // core count, but 16 busy tasks on a tiny box just thrash).
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let grid: Vec<u32> = if cores >= 16 {
        scenarios::PARALLELISM_GRID.to_vec()
    } else {
        vec![1, 2, 4, 8]
    };
    println!("host cores: {cores}; parallelism grid {grid:?}");

    // Saturating offered load so parallelism differences show.
    let mut tp_series: Vec<Vec<(f64, f64)>> = Vec::new();
    let mut lat_series: Vec<Vec<(f64, f64)>> = Vec::new();
    let mut gc_series: Vec<Vec<(f64, f64)>> = Vec::new();
    let mut gc_final = Vec::new();

    for &p in &grid {
        let mut cfg = scenarios::fig7(p, 400_000, use_hlo);
        cfg.bench.name = format!("fig8-p{p}");
        cfg.bench.duration_micros = 2_500_000;
        cfg.metrics.sample_interval_micros = 200_000;
        let (summary, store) = run_wall(&cfg, use_hlo.then(|| rtf.clone())).expect("fig8 run");

        let tp = store
            .get("throughput.proc_out.eps")
            .map(|s| s.normalized())
            .unwrap_or_default();
        let lat = store
            .get("latency.end_to_end.p50_us")
            .map(|s| s.normalized())
            .unwrap_or_default();
        // Aggregate young-GC count across task heaps: sum the per-task
        // cumulative series sample-by-sample.
        let mut gc: Vec<(f64, f64)> = Vec::new();
        for t in 0..p {
            if let Some(s) = store.get(&format!("jvm.engine-task-{t}.gc_young_count")) {
                let n = s.normalized();
                if gc.is_empty() {
                    gc = n;
                } else {
                    for (acc, (_, v)) in gc.iter_mut().zip(n) {
                        acc.1 += v;
                    }
                }
            }
        }
        gc_final.push(summary.gc_young_count as f64);
        b.record(Measurement {
            name: format!("P={p}"),
            times: vec![summary.elapsed_micros as f64 / 1e6],
            units_per_iter: summary.processed as f64,
            extras: vec![
                ("proc_eps".into(), summary.processed_rate),
                (
                    "e2e_p50_us".into(),
                    summary
                        .latency_at(sprobench::metrics::MeasurementPoint::EndToEnd)
                        .map(|h| h.p50 as f64)
                        .unwrap_or(0.0),
                ),
                ("gc_young".into(), summary.gc_young_count as f64),
                ("gc_ms".into(), summary.gc_young_time_micros as f64 / 1e3),
            ],
        });
        tp_series.push(tp);
        lat_series.push(lat);
        gc_series.push(gc);
    }
    b.finish();

    // Export one CSV per sub-figure: column per parallelism level.
    for (metric, series) in [
        ("fig8a_throughput_eps", &tp_series),
        ("fig8b_latency_p50_us", &lat_series),
        ("fig8c_gc_young_count", &gc_series),
    ] {
        let rows_n = series.iter().map(|s| s.len()).max().unwrap_or(0);
        let mut rows = Vec::new();
        for i in 0..rows_n {
            let mut row = vec![format!(
                "{:.3}",
                series
                    .iter()
                    .find_map(|s| s.get(i).map(|&(x, _)| x))
                    .unwrap_or(0.0)
            )];
            for s in series {
                row.push(
                    s.get(i)
                        .map(|&(_, v)| format!("{v:.1}"))
                        .unwrap_or_default(),
                );
            }
            rows.push(row);
        }
        let headers: Vec<String> = std::iter::once("norm_runtime".to_string())
            .chain(grid.iter().map(|p| format!("P{p}")))
            .collect();
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let csv = csv_from_rows(&header_refs, &rows);
        std::fs::create_dir_all("bench_results").ok();
        std::fs::write(format!("bench_results/{metric}.csv"), csv).ok();
        println!("wrote bench_results/{metric}.csv");
    }

    // Shape claims.
    // (c) GC count grows with parallelism (more allocation churn).
    println!("fig8c final young-GC counts by parallelism: {gc_final:?}");
    assert!(
        gc_final[gc_final.len() - 1] >= gc_final[0],
        "GC count did not grow with parallelism: {gc_final:?}"
    );
    // (c) GC series are cumulative (monotone) within each run.
    for (i, s) in gc_series.iter().enumerate() {
        assert!(
            s.windows(2).all(|w| w[1].1 >= w[0].1 - 1e-9),
            "P={} GC series not monotone",
            grid[i]
        );
    }
    println!("CLAIMS OK: GC growth over runtime and with parallelism");
}
