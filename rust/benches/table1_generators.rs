//! Table 1: maximum generator throughput — SProBench vs the seven
//! baseline suites.
//!
//! Reproduces the paper's comparison column "Max Doc. Throughput": each
//! baseline generator model runs under the same harness (rate-capped at
//! its documented peak + its mechanistic inefficiencies); the SProBench
//! generator runs uncapped, single-instance and multi-instance.  The
//! paper's claims checked here:
//!   * single SProBench instance ≈ 0.5 M ev/s *documented capacity*
//!     (measured is higher — Rust vs JVM; the capacity cap is what the
//!     fleet enforces),
//!   * parallel instances exceed every baseline by more than 10×,
//!   * ≈0.5 GB/s on a single node,
//!   * sim-mode cluster scale reaches the 40 M ev/s headline.

use sprobench::baselines::{all_baselines, run_baseline, run_sprobench_generator};
use sprobench::bench::{Bencher, Measurement};
use sprobench::config::PipelineKind;
use sprobench::coordinator::simrun::{run_sim, SimModel};
use sprobench::util::clock;

fn main() {
    let clk = clock::wall();
    let mut b = Bencher::new("table1_generators");

    // Baseline suite models (rate-capped at documented peaks).
    for spec in all_baselines() {
        let budget_events = (spec.doc_rate * 1.5) as u64;
        let r = run_baseline(&spec, budget_events.clamp(200, 2_000_000), 1_500_000, &clk);
        b.record(Measurement {
            name: format!("{} (doc {:.2}M/s)", spec.name, spec.doc_rate / 1e6),
            times: vec![r.elapsed_micros as f64 / 1e6],
            units_per_iter: r.events as f64,
            extras: vec![("doc_rate_eps".into(), spec.doc_rate)],
        });
    }

    // SProBench generator, single instance (pure generation loop).
    let single = run_sprobench_generator(3_000_000, 27, &clk);
    b.record(Measurement {
        name: "SProBench 1 instance".into(),
        times: vec![single.elapsed_micros as f64 / 1e6],
        units_per_iter: single.events as f64,
        extras: vec![("bytes_per_sec".into(), single.bytes as f64 * 1e6 / single.elapsed_micros as f64)],
    });

    // SProBench generator, N parallel instances (one node's worth).
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(16);
    let per_thread = 2_000_000u64;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..threads)
        .map(|_| {
            let clk = clk.clone();
            std::thread::spawn(move || run_sprobench_generator(per_thread, 27, &clk))
        })
        .collect();
    let mut events = 0u64;
    let mut bytes = 0u64;
    for h in handles {
        let r = h.join().expect("generator thread");
        events += r.events;
        bytes += r.bytes;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let parallel_rate = events as f64 / elapsed;
    b.record(Measurement {
        name: format!("SProBench {threads} instances"),
        times: vec![elapsed],
        units_per_iter: events as f64,
        extras: vec![("bytes_per_sec".into(), bytes as f64 / elapsed)],
    });

    // Sim-mode cluster scale: the 40 M ev/s headline on a Barnard slice.
    let mut cfg = sprobench::bench::scenarios::fig7_sim(64, 45_000_000);
    cfg.engine.pipeline = PipelineKind::PassThrough;
    cfg.broker.partitions = 32;
    cfg.slurm.nodes = 16;
    let (sim, _) = run_sim(&cfg, &SimModel::default());
    b.record(Measurement {
        name: "SProBench cluster (sim, 16 nodes)".into(),
        times: vec![sim.elapsed_micros as f64 / 1e6],
        units_per_iter: sim.processed as f64,
        extras: vec![("offered_eps".into(), sim.offered_rate)],
    });

    b.finish();

    // Shape assertions (the paper's comparative claims).
    let best_baseline = all_baselines()
        .iter()
        .map(|s| s.doc_rate)
        .fold(0.0f64, f64::max);
    assert!(
        parallel_rate > 10.0 * best_baseline,
        "Table 1 claim violated: SProBench parallel {parallel_rate:.0} ev/s \
         is not 10x the best baseline ({best_baseline:.0} ev/s)"
    );
    assert!(
        sim.offered_rate >= 40e6,
        "cluster-scale sim below the 40M ev/s headline: {:.1}M",
        sim.offered_rate / 1e6
    );
    println!(
        "CLAIMS OK: parallel generator {:.1}M ev/s (≥10x best baseline {:.1}M), \
         {:.2} GB/s at 27B, sim cluster {:.0}M ev/s",
        parallel_rate / 1e6,
        best_baseline / 1e6,
        bytes as f64 / elapsed / 1e9,
        sim.offered_rate / 1e6
    );
}
