//! Failure injection: the suite must degrade cleanly, never hang, and
//! keep its accounting honest under faults.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sprobench::broker::{Broker, BrokerConfig, Record};
use sprobench::config::{BenchConfig, FaultKind, FaultSpec, PipelineKind};
use sprobench::coordinator::run_recovery;
use sprobench::engine::Engine;
use sprobench::metrics::{LatencyRecorder, ThroughputRecorder};
use sprobench::postprocess::validate_results;
use sprobench::wgen::{EventFormat, SensorEvent};

fn cfg(pipeline: PipelineKind) -> BenchConfig {
    let mut c = BenchConfig::default();
    c.bench.warmup_micros = 0;
    c.engine.pipeline = pipeline;
    c.engine.parallelism = 2;
    c.engine.use_hlo = false;
    c.engine.batch_size = 128;
    c.workload.sensors = 64;
    c
}

fn spawn_drainer(broker: &Arc<Broker>) -> std::thread::JoinHandle<u64> {
    let drain = broker.subscribe("out", "drain", 1);
    std::thread::spawn(move || {
        let mut n = 0u64;
        loop {
            match drain.poll(0, 2048) {
                Ok(Some(b)) => {
                    n += b.record_count() as u64;
                    drain.commit(b.partition, b.next_offset);
                }
                Ok(None) => std::thread::sleep(std::time::Duration::from_millis(1)),
                Err(_) => return n,
            }
        }
    })
}

fn good_record(i: u32, ts: u64) -> Record {
    let ev = SensorEvent {
        ts_micros: ts,
        sensor_id: i % 64,
        temp_c: (i % 90) as f32,
    };
    let mut buf = Vec::new();
    ev.serialize_into(EventFormat::Csv, 27, &mut buf);
    Record::new(ev.sensor_id, buf, ts)
}

#[test]
fn corrupted_payloads_are_counted_not_fatal() {
    let clk = sprobench::util::clock::wall();
    let broker = Broker::new(BrokerConfig::default(), clk.clone());
    let in_topic = broker.create_topic("in");
    let out_topic = broker.create_topic("out");
    let drainer = spawn_drainer(&broker);

    // 10% of the stream is garbage of various shapes.
    let corrupt: [&[u8]; 5] = [
        b"",
        b"not,even",
        b"{\"wrong\":1}",
        b"\xff\xfe\xfd binary",
        b"123,456",
    ];
    let mut records = Vec::new();
    let mut bad = 0u64;
    for i in 0..5_000u32 {
        if i % 10 == 0 {
            records.push(Record::new(i, corrupt[(i as usize / 10) % 5].to_vec(), 0));
            bad += 1;
        } else {
            records.push(good_record(i, clk.now_micros()));
        }
    }
    broker.produce_batch(&in_topic, records).unwrap();
    in_topic.close();

    let config = cfg(PipelineKind::CpuIntensive);
    let tp = Arc::new(ThroughputRecorder::new());
    let lat = Arc::new(LatencyRecorder::new());
    let engine = Engine::new(&config, clk, tp, lat);
    let stop = Arc::new(AtomicBool::new(false));
    let report = engine
        .run(&broker, "in", &out_topic, &stop, 30_000_000, None, None)
        .unwrap();
    broker.shutdown();
    let forwarded = drainer.join().unwrap();

    assert_eq!(report.events_in, 5_000, "all records consumed");
    assert_eq!(report.parse_failures, bad, "every corruption counted");
    assert_eq!(forwarded, 5_000 - bad, "only valid events forwarded");
}

#[test]
fn broker_shutdown_mid_run_exits_cleanly() {
    let clk = sprobench::util::clock::wall();
    let broker = Broker::new(BrokerConfig::default(), clk.clone());
    let in_topic = broker.create_topic("in");
    let out_topic = broker.create_topic("out");
    let drainer = spawn_drainer(&broker);
    broker
        .produce_batch(&in_topic, (0..2_000).map(|i| good_record(i, 0)).collect())
        .unwrap();

    let config = cfg(PipelineKind::PassThrough);
    let tp = Arc::new(ThroughputRecorder::new());
    let lat = Arc::new(LatencyRecorder::new());
    let engine = Engine::new(&config, clk, tp, lat);
    let stop = Arc::new(AtomicBool::new(false));

    // Kill the broker shortly into the run, from another thread.
    let killer = {
        let broker = broker.clone();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            broker.shutdown();
        })
    };
    let t0 = std::time::Instant::now();
    // Must not hang: tasks see Closed on both topics and drain out.
    let result = engine.run(&broker, "in", &out_topic, &stop, 60_000_000, None, None);
    assert!(t0.elapsed().as_secs() < 20, "engine hung after broker death");
    killer.join().unwrap();
    // Either a clean report or a clean egestion error — never a panic.
    match result {
        Ok(report) => assert!(report.events_in <= 2_000),
        Err(e) => assert!(e.contains("egestion"), "unexpected error: {e}"),
    }
    let _ = drainer.join().unwrap();
}

#[test]
fn stop_flag_interrupts_engine_promptly() {
    let clk = sprobench::util::clock::wall();
    let broker = Broker::new(BrokerConfig::default(), clk.clone());
    let _in = broker.create_topic("in");
    let out_topic = broker.create_topic("out");
    let drainer = spawn_drainer(&broker);

    let config = cfg(PipelineKind::PassThrough);
    let tp = Arc::new(ThroughputRecorder::new());
    let lat = Arc::new(LatencyRecorder::new());
    let engine = Engine::new(&config, clk, tp, lat);
    let stop = Arc::new(AtomicBool::new(false));
    let stopper = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(100));
            stop.store(true, Ordering::SeqCst);
        })
    };
    let t0 = std::time::Instant::now();
    // Input stays open and empty: only the stop flag can end this run.
    let report = engine
        .run(&broker, "in", &out_topic, &stop, 3_600_000_000, None, None)
        .unwrap();
    assert!(t0.elapsed().as_secs() < 10, "stop flag ignored");
    assert_eq!(report.events_in, 0);
    stopper.join().unwrap();
    broker.shutdown();
    let _ = drainer.join().unwrap();
}

#[test]
fn window_state_survives_bursty_starvation() {
    // Mem pipeline with long idle gaps between bursts: panes must rotate
    // on time even when no events arrive (the advance-on-idle path).
    let clk = sprobench::util::clock::wall();
    let broker = Broker::new(BrokerConfig::default(), clk.clone());
    let in_topic = broker.create_topic("in");
    let out_topic = broker.create_topic("out");
    let drainer = spawn_drainer(&broker);

    let mut config = cfg(PipelineKind::MemIntensive);
    config.engine.window_micros = 200_000;
    config.engine.slide_micros = 100_000;
    config.engine.parallelism = 1;

    let tp = Arc::new(ThroughputRecorder::new());
    let lat = Arc::new(LatencyRecorder::new());
    let engine = Engine::new(&config, clk.clone(), tp, lat);
    let stop = Arc::new(AtomicBool::new(false));

    let feeder = {
        let broker = broker.clone();
        let in_topic = in_topic.clone();
        let clk = clk.clone();
        std::thread::spawn(move || {
            for burst in 0..3 {
                let records: Vec<Record> = (0..200)
                    .map(|i| good_record(burst * 200 + i, clk.now_micros()))
                    .collect();
                broker.produce_batch(&in_topic, records).unwrap();
                std::thread::sleep(std::time::Duration::from_millis(250)); // > window
            }
            in_topic.close();
        })
    };
    let report = engine
        .run(&broker, "in", &out_topic, &stop, 30_000_000, None, None)
        .unwrap();
    feeder.join().unwrap();
    broker.shutdown();
    let emitted = drainer.join().unwrap();
    assert_eq!(report.events_in, 600);
    // Each burst must land in its own window generation (idle gaps exceed
    // the window): at least 3 distinct emission rounds.
    let emits: u64 = report.tasks.iter().map(|t| t.step.window_emits).sum();
    assert!(emits >= 3, "bursty stream produced only {emits} window emits");
    assert!(emitted > 0);
}

/// Base config for the kill-and-restore degradation tests.
fn recovery_cfg(name: &str) -> BenchConfig {
    let mut c = cfg(PipelineKind::CpuIntensive);
    c.bench.name = name.into();
    c.bench.duration_micros = 1_500_000;
    c.workload.rate = 50_000;
    c.workload.sensors = 128;
    c.engine.batch_size = 256;
    c.metrics.sample_interval_micros = 100_000;
    c.checkpoint.dir = std::env::temp_dir()
        .join(format!("sprobench-fail-{name}-{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    c.fault.kill_task = 1;
    c.fault.kill_after_micros = 500_000;
    c
}

#[test]
fn poison_only_schedule_quarantines_and_conserves() {
    // A poison window and no restart faults: the parse path must
    // quarantine the corrupted records (with a dead-letter sample),
    // exclude them from `processed`, and keep exact conservation —
    // every generated record is processed or quarantined, never both.
    let mut c = recovery_cfg("poison");
    c.fault.kill_after_micros = 0; // no kill: quarantine is the only fault
    c.fault.schedule = vec![FaultSpec {
        kind: FaultKind::PoisonRecords { fraction: 0.2 },
        at_micros: 100_000,
        duration_micros: 0, // rest of the run
        seed: 7,
    }];
    c.checkpoint.interval_micros = 300_000;
    c.validate().unwrap();
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
    let (summary, _) = run_recovery(&c, None).unwrap();
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);

    assert!(summary.quarantined > 0, "poison window quarantined nothing");
    assert_eq!(
        summary.processed + summary.quarantined,
        summary.generated,
        "conservation must hold exactly under quarantine"
    );
    assert!(
        summary.recovery.is_none(),
        "no restart faults means no recovery block"
    );
    let res = summary.resilience.expect("supervised run reports resilience");
    assert_eq!(res.poison_records, summary.quarantined);
    assert_eq!(res.restart_count, 0);
    assert_eq!(res.injected, 1);
    assert_eq!(res.healed, 1, "whole-run windows heal at run end");
    assert!(
        !res.dead_letters.is_empty(),
        "quarantine must sample dead letters"
    );
    let violations = validate_results(&summary.to_json());
    assert!(violations.is_empty(), "{violations:?}");
}

#[test]
fn restore_from_missing_checkpoint_degrades_to_cold_start() {
    // Checkpointing is on but the interval is longer than the whole run:
    // the kill fires before any checkpoint commits, so the restore scan
    // finds nothing and must degrade to a clean cold start — counted in
    // results.json, with conservation still holding.
    let mut c = recovery_cfg("coldstart");
    c.checkpoint.interval_micros = 30_000_000; // never reached
    c.validate().unwrap();
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
    let (summary, _) = run_recovery(&c, None).unwrap();
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);

    let rec = summary.recovery.expect("fault run reports recovery");
    assert!(rec.cold_start, "no committed checkpoint must mean cold start");
    assert_eq!(rec.restored_epoch, 0);
    assert_eq!(rec.checkpoints, 0, "no epoch boundary was ever crossed");
    assert!(rec.replayed_records > 0, "cold start re-reads the whole log");
    assert!(rec.recovery_time_micros > 0);
    // Replays are subtracted: distinct processed records stay conserved.
    assert_eq!(summary.processed, summary.generated, "{rec:?}");
    let violations = validate_results(&summary.to_json());
    assert!(violations.is_empty(), "{violations:?}");
}

#[test]
fn corrupt_latest_checkpoint_falls_back_and_tmp_orphans_are_ignored() {
    // The newest-looking checkpoint file is garbage (a torn disk write)
    // and a `.tmp` orphan simulates a kill mid-checkpoint-write.  The
    // restore must skip the corrupt file (counted), never consider the
    // orphan — temp-then-rename keeps partial files un-observable as
    // "latest" — and warm-restore from the newest valid epoch.
    let mut c = recovery_cfg("corrupt");
    c.checkpoint.interval_micros = 150_000;
    c.validate().unwrap();
    let dir = std::path::PathBuf::from(&c.checkpoint.dir);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // Epoch numbers derive from run time, so 99999999 always sorts newest.
    std::fs::write(dir.join("ckpt-99999999.json"), b"garbage, not a checkpoint").unwrap();
    std::fs::write(dir.join("ckpt-99999998.json.tmp"), b"half a checkp").unwrap();

    let (summary, _) = run_recovery(&c, None).unwrap();
    let _ = std::fs::remove_dir_all(&dir);

    let rec = summary.recovery.expect("fault run reports recovery");
    assert_eq!(
        rec.corrupt_skipped, 1,
        "exactly the corrupt file is skipped; the .tmp orphan is never a \
         candidate: {rec:?}"
    );
    assert!(!rec.cold_start, "a valid older epoch must be restored");
    assert!(rec.restored_epoch >= 1);
    assert!(rec.replayed_records > 0);
    assert_eq!(summary.processed, summary.generated, "{rec:?}");
    let j = summary.to_json();
    assert!(
        j.path(&["recovery", "corrupt_skipped"]).and_then(|v| v.as_i64()) == Some(1),
        "degradation must be counted in results.json"
    );
    let violations = validate_results(&j);
    assert!(violations.is_empty(), "{violations:?}");
}
