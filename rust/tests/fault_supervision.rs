//! Self-healing supervision under declarative fault schedules: the
//! supervisor must detect every scheduled fault, restart within its
//! retry budget, keep the record accounting conserved, and report the
//! full SLO timeline in results.json — all without external
//! orchestration.

use sprobench::config::{BenchConfig, FaultKind, FaultSpec, PipelineKind};
use sprobench::coordinator::run_recovery;
use sprobench::postprocess::validate_results;

/// Base config for supervised chaos runs: short wall run, checkpoints
/// committing every 150ms into a per-test temp dir.
fn chaos_cfg(name: &str) -> BenchConfig {
    let mut c = BenchConfig::default();
    c.bench.name = name.into();
    c.bench.warmup_micros = 0;
    c.bench.duration_micros = 1_500_000;
    c.workload.rate = 50_000;
    c.workload.sensors = 128;
    c.engine.pipeline = PipelineKind::CpuIntensive;
    c.engine.parallelism = 2;
    c.engine.use_hlo = false;
    c.engine.batch_size = 256;
    c.metrics.sample_interval_micros = 100_000;
    c.checkpoint.interval_micros = 150_000;
    c.checkpoint.dir = std::env::temp_dir()
        .join(format!("sprobench-chaos-{name}-{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    c
}

fn kill(task: u32, at: u64) -> FaultSpec {
    FaultSpec {
        kind: FaultKind::KillTask { task },
        at_micros: at,
        duration_micros: 0,
        seed: 0,
    }
}

fn run(c: &BenchConfig) -> sprobench::coordinator::RunSummary {
    c.validate().unwrap();
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
    let out = run_recovery(c, None).unwrap().0;
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
    out
}

#[test]
fn multi_kill_schedule_heals_each_kill() {
    // Two kills in one run: the supervisor must warm-restore twice, and
    // each fault's timeline must close (injected → detected → healed).
    let mut c = chaos_cfg("multikill");
    c.fault.schedule = vec![kill(0, 400_000), kill(1, 900_000)];
    let summary = run(&c);

    let res = summary.resilience.as_ref().expect("supervised run");
    assert_eq!(res.injected, 2, "{res:?}");
    assert_eq!(res.detected, 2, "{res:?}");
    assert_eq!(res.healed, 2, "both kills must self-heal: {res:?}");
    assert_eq!(res.restart_count, 2, "{res:?}");
    assert_eq!(summary.faults.len(), 2);
    for f in &summary.faults {
        assert!(f.healed_at.is_some(), "unhealed fault: {f:?}");
        assert!(f.mttr_micros() > 0, "{f:?}");
        assert!(
            f.mttr_micros() >= f.detect_micros(),
            "heal cannot precede detection: {f:?}"
        );
    }
    // Downtime is the sum of both outage windows.
    let mttr_sum: u64 = summary.faults.iter().map(|f| f.mttr_micros()).sum();
    assert_eq!(res.downtime_micros, mttr_sum, "{res:?}");
    // Replays are subtracted: distinct processed records stay conserved.
    assert_eq!(summary.processed, summary.generated);
    let violations = validate_results(&summary.to_json());
    assert!(violations.is_empty(), "{violations:?}");
}

#[test]
fn hung_task_detected_by_heartbeat_deadline() {
    // The hang never kills the task — it just stops polling and
    // heartbeating — so only the watchdog's deadline can notice.  The
    // stall outlives the run: without supervision the run would wedge.
    let mut c = chaos_cfg("hangdetect");
    c.fault.schedule = vec![FaultSpec {
        kind: FaultKind::HangTask { task: 1 },
        at_micros: 400_000,
        duration_micros: 30_000_000, // longer than the run
        seed: 0,
    }];
    c.fault.heartbeat_timeout_micros = 200_000;
    let summary = run(&c);

    let res = summary.resilience.as_ref().expect("supervised run");
    assert_eq!(res.injected, 1, "{res:?}");
    assert_eq!(res.detected, 1, "watchdog must flag the stale heartbeat: {res:?}");
    assert_eq!(res.healed, 1, "{res:?}");
    assert_eq!(res.restart_count, 1, "{res:?}");
    let f = &summary.faults[0];
    assert!(f.detect_micros() > 0, "{f:?}");
    assert!(f.mttr_micros() >= f.detect_micros(), "{f:?}");
    assert_eq!(summary.processed, summary.generated);
    let violations = validate_results(&summary.to_json());
    assert!(violations.is_empty(), "{violations:?}");
}

#[test]
fn kill_hang_poison_acceptance_run() {
    // The full acceptance schedule: a kill, a hang, and a poison window
    // overlapping the first restart.  The run must self-heal twice,
    // quarantine the malformed records (replayed poison is re-quarantined,
    // never double-counted), and report the complete SLO rollup.
    let mut c = chaos_cfg("acceptance");
    c.fault.schedule = vec![
        kill(0, 350_000),
        FaultSpec {
            kind: FaultKind::HangTask { task: 1 },
            at_micros: 800_000,
            duration_micros: 30_000_000,
            seed: 0,
        },
        FaultSpec {
            kind: FaultKind::PoisonRecords { fraction: 0.05 },
            at_micros: 0,
            duration_micros: 600_000,
            seed: 11,
        },
    ];
    c.fault.heartbeat_timeout_micros = 200_000;
    let summary = run(&c);

    let res = summary.resilience.as_ref().expect("supervised run");
    assert_eq!(res.injected, 3, "{res:?}");
    assert_eq!(res.healed, 3, "every fault must heal in-run: {res:?}");
    assert_eq!(res.restart_count, 2, "{res:?}");
    assert!(res.downtime_micros > 0, "{res:?}");
    assert!(summary.quarantined > 0, "poison window produced no quarantine");
    assert_eq!(res.poison_records, summary.quarantined);
    // Conservation with quarantine: every distinct generated record is
    // either processed or quarantined, exactly once.
    assert_eq!(
        summary.processed + summary.quarantined,
        summary.generated,
        "{res:?}"
    );

    // The acceptance criteria live in results.json, so check the document
    // itself, not just the in-memory summary.
    let j = summary.to_json();
    let geti = |path: &[&str]| j.path(path).and_then(|v| v.as_i64()).unwrap();
    assert_eq!(geti(&["resilience", "restart_count"]), 2);
    assert!(geti(&["resilience", "downtime_us"]) > 0);
    assert!(geti(&["resilience", "detect_us"]) > 0);
    assert!(geti(&["resilience", "mttr_us"]) > 0);
    assert_eq!(
        geti(&["events", "processed"]) + geti(&["events", "quarantined"]),
        geti(&["events", "generated"])
    );
    let faults = j.get("faults").and_then(|f| f.as_arr()).expect("faults[]");
    assert_eq!(faults.len(), 3);
    for f in faults {
        assert_eq!(f.get("injected").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(f.get("healed").and_then(|v| v.as_bool()), Some(true));
        let kind = f.get("kind").and_then(|v| v.as_str()).unwrap();
        if kind != "poison_records" {
            assert!(f.get("detect_us").and_then(|v| v.as_i64()).unwrap() > 0, "{kind}");
            assert!(f.get("mttr_us").and_then(|v| v.as_i64()).unwrap() > 0, "{kind}");
        }
    }
    let violations = validate_results(&j);
    assert!(violations.is_empty(), "{violations:?}");
}

#[test]
fn restart_budget_exhaustion_fails_the_run_loudly() {
    // Three kills against a budget of two: the supervisor must give up
    // with an error naming the budget, not hang or succeed silently.
    let mut c = chaos_cfg("budget");
    c.fault.schedule = vec![kill(0, 250_000), kill(1, 600_000), kill(0, 950_000)];
    c.fault.max_restarts = 2;
    c.validate().unwrap();
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
    let err = run_recovery(&c, None).unwrap_err();
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
    assert!(
        err.contains("restart") || err.contains("budget"),
        "error must name the exhausted budget: {err}"
    );
}

// ---------------------------------------------------------------------------
// Distributed worker death: a vanished or frozen peer must surface as a
// detected fault within a bounded deadline — never a hang — and the
// connect/accept paths must fail loudly when a peer never shows up.
// ---------------------------------------------------------------------------

mod worker_death {
    use sprobench::broker::RecordBatchBuilder;
    use sprobench::config::{FaultKind, FaultSpec};
    use sprobench::engine::{FaultOutcome, TaskMonitor};
    use sprobench::net::frame::{encode_record_batch, kind, role, write_frame};
    use sprobench::net::{
        accept_with_timeout, connect_with_retry, FeedBatch, TcpOptions, TcpTransport, Transport,
    };
    use sprobench::util::clock;
    use std::net::TcpListener;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    /// Serve one BATCH frame on an accepted connection, then run `after`
    /// with the raw stream (the "peer process" body).
    fn one_shot_server(
        listener: TcpListener,
        after: impl FnOnce(std::net::TcpStream) + Send + 'static,
    ) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let (mut stream, peer) =
                accept_with_timeout(&listener, role::BROKER, 5_000_000).unwrap();
            assert_eq!(peer, role::ENGINE);
            let mut b = RecordBatchBuilder::new();
            b.push(7, b"payload", 1_000);
            let mut payload = Vec::new();
            encode_record_batch(0, &b.build(), &mut payload);
            write_frame(&mut stream, kind::BATCH, 0, &payload).unwrap();
            after(stream);
        })
    }

    /// Dial `addr` as the engine with a heartbeat monitor attached.
    fn engine_link(
        addr: &str,
        monitor: &Arc<TaskMonitor>,
    ) -> Arc<TcpTransport<FeedBatch>> {
        let (stream, peer) = connect_with_retry(addr, role::ENGINE, 5_000_000).unwrap();
        assert_eq!(peer, role::BROKER);
        TcpTransport::<FeedBatch>::spawn(
            stream,
            1,
            1,
            TcpOptions {
                monitor: Some((monitor.clone(), 0, clock::wall())),
                ..TcpOptions::default()
            },
        )
        .unwrap()
    }

    fn recv_one(link: &Arc<TcpTransport<FeedBatch>>) -> FeedBatch {
        let mut buf = Vec::new();
        let t0 = Instant::now();
        while link.drain(0, &mut buf, 16) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "feed batch never arrived");
            std::thread::sleep(Duration::from_millis(2));
        }
        buf.remove(0)
    }

    #[test]
    fn peer_death_is_detected_as_a_link_error_within_the_deadline() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        // The "broker" serves one batch then dies abruptly: the socket
        // drops with no FINISH and no EOF frame.
        let server = one_shot_server(listener, |stream| {
            std::thread::sleep(Duration::from_millis(100));
            drop(stream);
        });

        let monitor = Arc::new(TaskMonitor::new(1));
        let link = engine_link(&addr, &monitor);
        let got = recv_one(&link);
        assert_eq!(got.batch.len(), 1);
        assert!(monitor.last_beat(0) > 0, "received frames must beat the monitor");

        // Bounded detection: the reader surfaces the dead peer as a link
        // error well within the supervision deadline.
        let detect_start = Instant::now();
        let err = loop {
            if let Some(e) = link.error() {
                break e;
            }
            assert!(
                detect_start.elapsed() < Duration::from_secs(10),
                "peer death never detected"
            );
            std::thread::sleep(Duration::from_millis(2));
        };
        assert!(err.contains("disconnect"), "unreadable death report: {err}");
        assert!(!link.upstream_done(0), "abrupt death must not read as clean EOF");

        // The engine worker wraps exactly this signal in a detected,
        // unhealed PeerDisconnect fault for results.json.
        let clk = clock::wall();
        let now = clk.now_micros();
        let mut outcome = FaultOutcome::new(FaultSpec {
            kind: FaultKind::PeerDisconnect {
                worker: role::BROKER as u32,
            },
            at_micros: 0,
            duration_micros: 0,
            seed: 0,
        });
        outcome.injected_at = Some(now);
        outcome.detected_at = Some(now);
        assert_eq!(outcome.spec.kind.name(), "peer_disconnect");
        assert!(outcome.healed_at.is_none());
        link.finish_sending();
        link.join();
        server.join().unwrap();
    }

    #[test]
    fn frozen_peer_goes_stale_on_the_heartbeat_monitor() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        // The "broker" freezes: socket stays open, but nothing — not
        // even a ping — is sent after the first batch.
        let (frozen_tx, frozen_rx) = std::sync::mpsc::channel::<()>();
        let server = one_shot_server(listener, move |stream| {
            // Hold the socket open until the client observed staleness.
            let _ = frozen_rx.recv_timeout(Duration::from_secs(10));
            drop(stream);
        });

        let monitor = Arc::new(TaskMonitor::new(1));
        let link = engine_link(&addr, &monitor);
        recv_one(&link);

        let clk = clock::wall();
        let t0 = Instant::now();
        loop {
            if monitor.stale_task(clk.now_micros(), 300_000).is_some() {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "frozen peer never went stale"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        // No link error: the socket is healthy, only the peer is wedged.
        // Staleness is the only signal — exactly why the engine worker
        // watches both.
        frozen_tx.send(()).ok();
        link.finish_sending();
        link.join();
        server.join().unwrap();
    }

    #[test]
    fn connect_and_accept_fail_loudly_within_their_deadlines() {
        // No listener: the dial retries, then reports the last error.
        let t0 = Instant::now();
        let err = connect_with_retry("127.0.0.1:9", role::ENGINE, 400_000).unwrap_err();
        assert!(err.contains("timed out"), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(25), "dial not bounded");

        // No peer: the accept deadline trips instead of blocking forever.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let t1 = Instant::now();
        let err = accept_with_timeout(&listener, role::DRIVER, 300_000).unwrap_err();
        assert!(!err.is_empty());
        assert!(t1.elapsed() < Duration::from_secs(25), "accept not bounded");
    }
}
