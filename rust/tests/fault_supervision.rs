//! Self-healing supervision under declarative fault schedules: the
//! supervisor must detect every scheduled fault, restart within its
//! retry budget, keep the record accounting conserved, and report the
//! full SLO timeline in results.json — all without external
//! orchestration.

use sprobench::config::{BenchConfig, FaultKind, FaultSpec, PipelineKind};
use sprobench::coordinator::run_recovery;
use sprobench::postprocess::validate_results;

/// Base config for supervised chaos runs: short wall run, checkpoints
/// committing every 150ms into a per-test temp dir.
fn chaos_cfg(name: &str) -> BenchConfig {
    let mut c = BenchConfig::default();
    c.bench.name = name.into();
    c.bench.warmup_micros = 0;
    c.bench.duration_micros = 1_500_000;
    c.workload.rate = 50_000;
    c.workload.sensors = 128;
    c.engine.pipeline = PipelineKind::CpuIntensive;
    c.engine.parallelism = 2;
    c.engine.use_hlo = false;
    c.engine.batch_size = 256;
    c.metrics.sample_interval_micros = 100_000;
    c.checkpoint.interval_micros = 150_000;
    c.checkpoint.dir = std::env::temp_dir()
        .join(format!("sprobench-chaos-{name}-{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    c
}

fn kill(task: u32, at: u64) -> FaultSpec {
    FaultSpec {
        kind: FaultKind::KillTask { task },
        at_micros: at,
        duration_micros: 0,
        seed: 0,
    }
}

fn run(c: &BenchConfig) -> sprobench::coordinator::RunSummary {
    c.validate().unwrap();
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
    let out = run_recovery(c, None).unwrap().0;
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
    out
}

#[test]
fn multi_kill_schedule_heals_each_kill() {
    // Two kills in one run: the supervisor must warm-restore twice, and
    // each fault's timeline must close (injected → detected → healed).
    let mut c = chaos_cfg("multikill");
    c.fault.schedule = vec![kill(0, 400_000), kill(1, 900_000)];
    let summary = run(&c);

    let res = summary.resilience.as_ref().expect("supervised run");
    assert_eq!(res.injected, 2, "{res:?}");
    assert_eq!(res.detected, 2, "{res:?}");
    assert_eq!(res.healed, 2, "both kills must self-heal: {res:?}");
    assert_eq!(res.restart_count, 2, "{res:?}");
    assert_eq!(summary.faults.len(), 2);
    for f in &summary.faults {
        assert!(f.healed_at.is_some(), "unhealed fault: {f:?}");
        assert!(f.mttr_micros() > 0, "{f:?}");
        assert!(
            f.mttr_micros() >= f.detect_micros(),
            "heal cannot precede detection: {f:?}"
        );
    }
    // Downtime is the sum of both outage windows.
    let mttr_sum: u64 = summary.faults.iter().map(|f| f.mttr_micros()).sum();
    assert_eq!(res.downtime_micros, mttr_sum, "{res:?}");
    // Replays are subtracted: distinct processed records stay conserved.
    assert_eq!(summary.processed, summary.generated);
    let violations = validate_results(&summary.to_json());
    assert!(violations.is_empty(), "{violations:?}");
}

#[test]
fn hung_task_detected_by_heartbeat_deadline() {
    // The hang never kills the task — it just stops polling and
    // heartbeating — so only the watchdog's deadline can notice.  The
    // stall outlives the run: without supervision the run would wedge.
    let mut c = chaos_cfg("hangdetect");
    c.fault.schedule = vec![FaultSpec {
        kind: FaultKind::HangTask { task: 1 },
        at_micros: 400_000,
        duration_micros: 30_000_000, // longer than the run
        seed: 0,
    }];
    c.fault.heartbeat_timeout_micros = 200_000;
    let summary = run(&c);

    let res = summary.resilience.as_ref().expect("supervised run");
    assert_eq!(res.injected, 1, "{res:?}");
    assert_eq!(res.detected, 1, "watchdog must flag the stale heartbeat: {res:?}");
    assert_eq!(res.healed, 1, "{res:?}");
    assert_eq!(res.restart_count, 1, "{res:?}");
    let f = &summary.faults[0];
    assert!(f.detect_micros() > 0, "{f:?}");
    assert!(f.mttr_micros() >= f.detect_micros(), "{f:?}");
    assert_eq!(summary.processed, summary.generated);
    let violations = validate_results(&summary.to_json());
    assert!(violations.is_empty(), "{violations:?}");
}

#[test]
fn kill_hang_poison_acceptance_run() {
    // The full acceptance schedule: a kill, a hang, and a poison window
    // overlapping the first restart.  The run must self-heal twice,
    // quarantine the malformed records (replayed poison is re-quarantined,
    // never double-counted), and report the complete SLO rollup.
    let mut c = chaos_cfg("acceptance");
    c.fault.schedule = vec![
        kill(0, 350_000),
        FaultSpec {
            kind: FaultKind::HangTask { task: 1 },
            at_micros: 800_000,
            duration_micros: 30_000_000,
            seed: 0,
        },
        FaultSpec {
            kind: FaultKind::PoisonRecords { fraction: 0.05 },
            at_micros: 0,
            duration_micros: 600_000,
            seed: 11,
        },
    ];
    c.fault.heartbeat_timeout_micros = 200_000;
    let summary = run(&c);

    let res = summary.resilience.as_ref().expect("supervised run");
    assert_eq!(res.injected, 3, "{res:?}");
    assert_eq!(res.healed, 3, "every fault must heal in-run: {res:?}");
    assert_eq!(res.restart_count, 2, "{res:?}");
    assert!(res.downtime_micros > 0, "{res:?}");
    assert!(summary.quarantined > 0, "poison window produced no quarantine");
    assert_eq!(res.poison_records, summary.quarantined);
    // Conservation with quarantine: every distinct generated record is
    // either processed or quarantined, exactly once.
    assert_eq!(
        summary.processed + summary.quarantined,
        summary.generated,
        "{res:?}"
    );

    // The acceptance criteria live in results.json, so check the document
    // itself, not just the in-memory summary.
    let j = summary.to_json();
    let geti = |path: &[&str]| j.path(path).and_then(|v| v.as_i64()).unwrap();
    assert_eq!(geti(&["resilience", "restart_count"]), 2);
    assert!(geti(&["resilience", "downtime_us"]) > 0);
    assert!(geti(&["resilience", "detect_us"]) > 0);
    assert!(geti(&["resilience", "mttr_us"]) > 0);
    assert_eq!(
        geti(&["events", "processed"]) + geti(&["events", "quarantined"]),
        geti(&["events", "generated"])
    );
    let faults = j.get("faults").and_then(|f| f.as_arr()).expect("faults[]");
    assert_eq!(faults.len(), 3);
    for f in faults {
        assert_eq!(f.get("injected").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(f.get("healed").and_then(|v| v.as_bool()), Some(true));
        let kind = f.get("kind").and_then(|v| v.as_str()).unwrap();
        if kind != "poison_records" {
            assert!(f.get("detect_us").and_then(|v| v.as_i64()).unwrap() > 0, "{kind}");
            assert!(f.get("mttr_us").and_then(|v| v.as_i64()).unwrap() > 0, "{kind}");
        }
    }
    let violations = validate_results(&j);
    assert!(violations.is_empty(), "{violations:?}");
}

#[test]
fn restart_budget_exhaustion_fails_the_run_loudly() {
    // Three kills against a budget of two: the supervisor must give up
    // with an error naming the budget, not hang or succeed silently.
    let mut c = chaos_cfg("budget");
    c.fault.schedule = vec![kill(0, 250_000), kill(1, 600_000), kill(0, 950_000)];
    c.fault.max_restarts = 2;
    c.validate().unwrap();
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
    let err = run_recovery(&c, None).unwrap_err();
    let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
    assert!(
        err.contains("restart") || err.contains("budget"),
        "error must name the exhausted budget: {err}"
    );
}
