//! Integration tests for `sprobench analyze`: each test seeds one
//! violation into a throwaway fixture tree and asserts the analyzer
//! reports it, and one test runs the full pass suite over the real
//! repository tree and requires zero errors — the same gate CI runs.
//!
//! Fixture sources are written as string literals; the panics pass
//! only scans `rust/src/`, so panic patterns quoted here never count
//! against the real baseline.

use std::fs;
use std::path::{Path, PathBuf};

use sprobench::analysis::{self, AnalyzeOptions, Finding, Report, Severity};

/// A throwaway mini-repository under the system temp dir.
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new(name: &str) -> Fixture {
        let root = std::env::temp_dir().join(format!(
            "sprobench_analysis_{}_{name}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&root).expect("create fixture root");
        Fixture { root }
    }

    fn write(&self, rel: &str, text: &str) -> &Fixture {
        let path = self.root.join(rel);
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent).expect("create fixture dir");
        }
        fs::write(&path, text).expect("write fixture file");
        self
    }

    fn read(&self, rel: &str) -> String {
        fs::read_to_string(self.root.join(rel)).expect("read fixture file")
    }

    fn run(&self, passes: &[&str], bless: bool) -> Report {
        analysis::run(&AnalyzeOptions {
            root: self.root.clone(),
            passes: passes.iter().map(|s| s.to_string()).collect(),
            bless,
            changed_since: None,
        })
        .expect("analysis run")
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

fn errors(report: &Report) -> Vec<&Finding> {
    report
        .findings
        .iter()
        .filter(|f| f.severity == Severity::Error)
        .collect()
}

/// A baseline file with the header but no entries (budget 0 everywhere).
const EMPTY_BASELINE: &str = "# sprobench panic-path baseline (fixture)\n";

// ---------------------------------------------------------------- real tree

/// The committed tree must run every pass clean — this is the CI gate,
/// and it is what makes the seeded-violation tests below meaningful:
/// the same passes that pass here fail there.
#[test]
fn real_tree_runs_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analysis::run(&AnalyzeOptions {
        root: root.to_path_buf(),
        passes: Vec::new(), // all
        bless: false,
        changed_since: None,
    })
    .expect("analysis over the real tree");
    assert_eq!(
        report.error_count(),
        0,
        "real tree has analysis errors:\n{}",
        report.render(false)
    );
    assert_eq!(report.passes.len(), analysis::PASS_NAMES.len());
}

// -------------------------------------------------------- test registration

#[test]
fn unregistered_test_file_is_an_error() {
    let fix = Fixture::new("unregistered");
    fix.write(
        "Cargo.toml",
        "[package]\nname = \"fix\"\n\n[[test]]\nname = \"alpha\"\npath = \"rust/tests/alpha.rs\"\n",
    )
    .write("rust/tests/alpha.rs", "#[test]\nfn t() {}\n")
    .write("rust/tests/beta.rs", "#[test]\nfn t() {}\n");
    let report = fix.run(&["tests"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(errs[0].message.contains("beta"), "{}", errs[0].message);
}

#[test]
fn registration_pointing_at_missing_file_is_an_error() {
    let fix = Fixture::new("missing_file");
    fix.write(
        "Cargo.toml",
        "[package]\nname = \"fix\"\n\n[[test]]\nname = \"gone\"\npath = \"rust/tests/gone.rs\"\n",
    );
    let report = fix.run(&["tests"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(errs[0].message.contains("missing file"), "{}", errs[0].message);
}

/// Acceptance check from the issue: deleting any `[[test]]` entry from
/// the real manifest makes the analyzer exit nonzero.  Replayed against
/// a fixture holding the real manifest text (minus one block) and stub
/// files for every registered test.
#[test]
fn deleting_a_manifest_registration_is_caught() {
    let real_manifest =
        fs::read_to_string(Path::new(env!("CARGO_MANIFEST_DIR")).join("Cargo.toml"))
            .expect("read real Cargo.toml");
    let needle = "[[test]]\nname = \"shuffle_equivalence\"\npath = \"rust/tests/shuffle_equivalence.rs\"\n";
    assert!(
        real_manifest.contains(needle),
        "expected [[test]] block not found in Cargo.toml"
    );
    let broken = real_manifest.replacen(needle, "", 1);

    let fix = Fixture::new("deleted_registration");
    fix.write("Cargo.toml", &broken);
    // Stub out every test file the real manifest registers (including
    // the one whose registration we just deleted).
    for line in real_manifest.lines() {
        if let Some(value) = line.trim().strip_prefix("path = \"") {
            let path = value.trim_end_matches('"');
            if path.starts_with("rust/tests/") {
                fix.write(path, "#[test]\nfn t() {}\n");
            }
        }
    }
    let report = fix.run(&["tests"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("shuffle_equivalence"),
        "{}",
        errs[0].message
    );
}

// ------------------------------------------------------------- panic ratchet

#[test]
fn new_panic_site_beyond_baseline_is_an_error() {
    let fix = Fixture::new("new_panic");
    fix.write("rust/src/analysis/baseline.txt", EMPTY_BASELINE)
        .write(
            "rust/src/lib.rs",
            "pub fn f(v: Option<u32>) -> u32 { v.unwrap() }\n",
        );
    let report = fix.run(&["panics"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("baseline allows 0"),
        "{}",
        errs[0].message
    );
}

#[test]
fn critical_path_panic_is_marked() {
    let fix = Fixture::new("critical_panic");
    fix.write("rust/src/analysis/baseline.txt", EMPTY_BASELINE)
        .write(
            "rust/src/net/transport.rs",
            "pub fn f(v: Option<u32>) -> u32 { v.unwrap() }\n",
        );
    let report = fix.run(&["panics"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("critical path"),
        "{}",
        errs[0].message
    );
}

#[test]
fn stale_baseline_entries_are_errors() {
    let fix = Fixture::new("stale_baseline");
    // Budget above the actual count, plus an entry for a file with no
    // sites at all: both directions of staleness.
    fix.write(
        "rust/src/analysis/baseline.txt",
        "2 rust/src/lib.rs\n1 rust/src/gone.rs\n",
    )
    .write(
        "rust/src/lib.rs",
        "pub fn f(v: Option<u32>) -> u32 { v.unwrap() }\n",
    );
    let report = fix.run(&["panics"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 2, "{}", report.render(true));
    assert!(errs.iter().all(|e| e.message.contains("stale")));
}

#[test]
fn bless_rewrites_the_baseline_and_the_tree_is_then_clean() {
    let fix = Fixture::new("bless");
    // Start from a stale budget; --bless must overwrite it in place.
    fix.write("rust/src/analysis/baseline.txt", "4 rust/src/lib.rs\n")
        .write(
            "rust/src/lib.rs",
            "pub fn f(v: Option<u32>) -> u32 { v.unwrap() }\n",
        );
    let blessed = fix.run(&["panics"], true);
    assert_eq!(errors(&blessed).len(), 0, "{}", blessed.render(true));
    let baseline = fix.read("rust/src/analysis/baseline.txt");
    assert!(baseline.contains("1 rust/src/lib.rs"), "{baseline}");

    let recheck = fix.run(&["panics"], false);
    assert_eq!(errors(&recheck).len(), 0, "{}", recheck.render(true));
}

#[test]
fn test_module_panics_do_not_count() {
    let fix = Fixture::new("test_mod_panic");
    fix.write("rust/src/analysis/baseline.txt", EMPTY_BASELINE)
        .write(
            "rust/src/lib.rs",
            "pub fn f() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { \
             Some(1).unwrap(); }\n}\n",
        );
    let report = fix.run(&["panics"], false);
    assert_eq!(errors(&report).len(), 0, "{}", report.render(true));
}

// ---------------------------------------------------------------- lock order

#[test]
fn lock_order_cycle_is_an_error() {
    let fix = Fixture::new("lock_cycle");
    fix.write(
        "rust/src/net/transport.rs",
        "fn a(&self) { let g = self.peers.lock().expect(\"p\"); \
         let h = self.state.lock().expect(\"p\"); }\n\
         fn b(&self) { let g = self.state.lock().expect(\"p\"); \
         let h = self.peers.lock().expect(\"p\"); }\n",
    );
    let report = fix.run(&["locks"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("lock-order cycle"),
        "{}",
        errs[0].message
    );
    assert!(errs[0].message.contains("transport.peers"));
    assert!(errs[0].message.contains("transport.state"));
}

#[test]
fn blocking_send_under_held_guard_is_an_error() {
    let fix = Fixture::new("send_under_lock");
    fix.write(
        "rust/src/engine/exchange.rs",
        "fn f(&self) { let g = self.state.lock().expect(\"p\"); self.tx.send(1); }\n",
    );
    let report = fix.run(&["locks"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("blocking channel op"),
        "{}",
        errs[0].message
    );
}

#[test]
fn consistent_lock_order_is_clean() {
    let fix = Fixture::new("lock_clean");
    fix.write(
        "rust/src/net/transport.rs",
        "fn a(&self) { let g = self.peers.lock().expect(\"p\"); \
         let h = self.state.lock().expect(\"p\"); }\n\
         fn b(&self) { let g = self.peers.lock().expect(\"p\"); \
         let h = self.state.lock().expect(\"p\"); }\n",
    );
    let report = fix.run(&["locks"], false);
    assert_eq!(errors(&report).len(), 0, "{}", report.render(true));
}

// --------------------------------------------------------------- schema sync

#[test]
fn undocumented_results_key_is_an_error() {
    let fix = Fixture::new("undocumented_key");
    fix.write(
        "rust/src/coordinator/mod.rs",
        "impl R { pub fn to_json(&self) -> Json { let mut j = Json::obj(); \
         j.set(\"mystery_metric\", Json::Int(1)); j } }\n",
    )
    .write("README.md", "No schema documentation here.\n");
    let report = fix.run(&["schema"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("mystery_metric"),
        "{}",
        errs[0].message
    );
}

#[test]
fn ghost_documented_key_is_an_error() {
    let fix = Fixture::new("ghost_key");
    fix.write(
        "rust/src/coordinator/mod.rs",
        "impl R { pub fn to_json(&self) -> Json { let mut j = Json::obj(); \
         j.set(\"real_field\", Json::Int(1)); j } }\n",
    )
    .write(
        "README.md",
        "Both keys prose-mentioned: real_field, phantom_field.\n\n\
         ```json\n{\"real_field\": 1, \"phantom_field\": 2}\n```\n",
    );
    let report = fix.run(&["schema"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("phantom_field"),
        "{}",
        errs[0].message
    );
}

// ------------------------------------------------------ struct exhaustiveness

#[test]
fn functional_update_of_report_struct_is_an_error() {
    let fix = Fixture::new("functional_update");
    fix.write(
        "rust/src/pipelines/report.rs",
        "fn grow(b: StepStats) -> StepStats { StepStats { events_in: 1, ..b } }\n",
    );
    let report = fix.run(&["structs"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("functional-update"),
        "{}",
        errs[0].message
    );
}

// -------------------------------------------------------------- config grammar

#[test]
fn undocumented_config_knob_is_an_error() {
    let fix = Fixture::new("undocumented_knob");
    fix.write(
        "rust/src/config/schema.rs",
        "fn parse(root: &Json) { let sec = section(root, \"workload\"); \
         let _ = get_u64(&sec, \"secret_knob\", 1); }\n",
    )
    .write("README.md", "The workload section is documented.\n");
    let report = fix.run(&["grammar"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("secret_knob"),
        "{}",
        errs[0].message
    );
}

#[test]
fn documented_key_outside_parser_vocabulary_is_an_error() {
    let fix = Fixture::new("ghost_knob");
    fix.write(
        "rust/src/config/schema.rs",
        "fn parse(root: &Json) { let _ = section(root, \"workload\"); }\n",
    )
    .write(
        "README.md",
        "The workload section, and bogus_knob in prose.\n\n\
         ```yaml\nworkload:\n  bogus_knob: 7\n```\n",
    );
    let report = fix.run(&["grammar"], false);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("bogus_knob"),
        "{}",
        errs[0].message
    );
}

// ------------------------------------------------------------------ reporting

#[test]
fn report_json_counts_errors_and_notes() {
    let fix = Fixture::new("report_shape");
    fix.write("rust/src/analysis/baseline.txt", EMPTY_BASELINE)
        .write(
            "rust/src/lib.rs",
            "pub fn f(v: Option<u32>) -> u32 { v.unwrap() }\n",
        );
    let report = fix.run(&["panics"], false);
    assert_eq!(report.error_count(), 1);
    let json = report.to_json().to_pretty();
    assert!(json.contains("\"sprobench.analysis/v1\""), "{json}");
    assert!(json.contains("\"errors\": 1"), "{json}");
    let rendered = report.render(false);
    assert!(rendered.contains("error: [panics]"), "{rendered}");
}

// ------------------------------------------------------------- lexer masking

/// Deterministic LCG so the property tests reproduce bit-for-bit.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn pick(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

/// Random-but-reproducible token soup: plain strings with escapes, raw
/// strings with 1–2 hashes (embedded quotes inside), raw byte strings,
/// nested block comments, line comments, char literals, lifetimes.
fn gen_source(seed: u64, tokens: usize) -> String {
    let mut rng = Lcg(seed);
    let mut out = String::from("fn main() {\n");
    for t in 0..tokens {
        match rng.pick(8) {
            0 => out.push_str(&format!("let v{t} = {};\n", rng.pick(100))),
            1 => out.push_str(&format!("call(\"lit{}\\\"esc\\\\n\");\n", rng.pick(10))),
            2 => {
                let h = "#".repeat(1 + rng.pick(2));
                out.push_str(&format!(
                    "raw(r{h}\"raw {} \"q\" body\"{h});\n",
                    rng.pick(10)
                ));
            }
            3 => out.push_str(&format!("/* c{} /* nested */ tail */ x();\n", rng.pick(10))),
            4 => out.push_str(&format!("// line comment {}\n", rng.pick(10))),
            5 => out.push_str("let c = '\\n'; let l: &'static str = \"s\";\n"),
            6 => out.push_str(&format!("br#\"bytes {}\"#.len();\n", rng.pick(10))),
            _ => out.push_str(&format!("b\"bs{}\";\n", rng.pick(10))),
        }
    }
    out.push_str("}\n");
    out
}

/// The invariant every pass relies on: the mask is the same length as
/// the source, every newline stays put, every masked byte is a space,
/// and every recorded string literal anchors its offset at the opening
/// quote with a correct line number.
#[test]
fn lexer_mask_preserves_offsets_and_lines_property() {
    use sprobench::analysis::lexer;
    for seed in 1..=25u64 {
        let src = gen_source(seed, 40);
        let scan = lexer::scan(&src);
        assert_eq!(scan.code.len(), src.len(), "seed {seed}: length changed");
        for (i, (a, b)) in src.bytes().zip(scan.code.bytes()).enumerate() {
            if a == b'\n' || a == b'\r' {
                assert_eq!(b, a, "seed {seed}: newline moved at byte {i}");
            }
            assert!(
                b == a || b == b' ',
                "seed {seed}: byte {i} was rewritten to something other than a space"
            );
        }
        for lit in &scan.strings {
            assert_eq!(
                src.as_bytes()[lit.offset],
                b'"',
                "seed {seed}: string offset {} is not an opening quote",
                lit.offset
            );
            let naive = src[..lit.offset].bytes().filter(|&b| b == b'\n').count() + 1;
            assert_eq!(lit.line, naive, "seed {seed}: string line drifted");
            assert_eq!(scan.line_of(lit.offset), naive, "seed {seed}: line_of drifted");
        }
    }
}

/// Sentinel contents of raw strings, nested comments, and escaped
/// strings must never leak into the masked view, while surrounding
/// code keeps its exact offsets.
#[test]
fn lexer_raw_strings_and_nested_comments_mask_cleanly() {
    use sprobench::analysis::lexer;
    let src = "let a = r#\"SENTINEL_RAW \"inner\" \"#; \
               /* outer /* SENTINEL_NESTED */ tail */\n\
               let b = \"esc\\\"SENTINEL_ESC\";\n\
               let c = a.len();\n";
    let scan = lexer::scan(src);
    assert_eq!(scan.code.len(), src.len());
    for needle in ["SENTINEL_RAW", "SENTINEL_NESTED", "SENTINEL_ESC", "inner"] {
        assert!(!scan.code.contains(needle), "{needle} leaked into the mask");
    }
    assert!(scan.code.contains("let c = a.len();"));
    assert_eq!(scan.strings.len(), 2);
    assert_eq!(scan.strings[0].value, "SENTINEL_RAW \"inner\" ");
    assert!(scan.strings[1].value.contains("SENTINEL_ESC"));
    assert_eq!(src.find(".len()"), scan.code.find(".len()"));
    assert_eq!(scan.line_of(src.find("let c").unwrap()), 3);
}
