//! Integration tests for the flow-sensitive analyzer passes
//! (`protocol`, `channels`, `conservation`, `locks2`) plus the SARIF
//! emitter and `--changed-since` plumbing: each fixture seeds one
//! violation into a throwaway mini-repository and asserts the pass
//! reports it with `file:line` provenance, and the round-trip test
//! checks the conservation pass's counter→key table against the schema
//! pass's emitter key table over the real tree.

use std::fs;
use std::path::{Path, PathBuf};

use sprobench::analysis::{
    self, conservation, schema, AnalyzeOptions, Finding, Report, Severity, Workspace,
};

/// A throwaway mini-repository under the system temp dir.
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new(name: &str) -> Fixture {
        let root = std::env::temp_dir().join(format!(
            "sprobench_flow_{}_{name}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&root).expect("create fixture root");
        Fixture { root }
    }

    fn write(&self, rel: &str, text: &str) -> &Fixture {
        let path = self.root.join(rel);
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent).expect("create fixture dir");
        }
        fs::write(&path, text).expect("write fixture file");
        self
    }

    fn run(&self, passes: &[&str]) -> Report {
        analysis::run(&AnalyzeOptions {
            root: self.root.clone(),
            passes: passes.iter().map(|s| s.to_string()).collect(),
            bless: false,
            changed_since: None,
        })
        .expect("analysis run")
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

fn errors(report: &Report) -> Vec<&Finding> {
    report
        .findings
        .iter()
        .filter(|f| f.severity == Severity::Error)
        .collect()
}

// ------------------------------------------------------------------ protocol

/// A complete control plane *except* the driver never checks READY:
/// the worker's barrier reply would be dropped on the floor and the
/// run would hang at the barrier.
#[test]
fn protocol_missing_ready_receive_is_flagged() {
    let fix = Fixture::new("missing_ready");
    fix.write(
        "rust/src/net/control.rs",
        "impl ControlPlane {\n\
         fn gather(&mut self) { if f.kind != kind::HELLO { return; } }\n\
         fn broadcast_assign(&mut self) { write_frame(s, kind::ASSIGN, 0, b\"\"); }\n\
         fn barrier(&mut self) { }\n\
         fn start_all(&mut self) { write_frame(s, kind::START, 0, b\"\"); }\n\
         fn collect_fragments(&mut self) { if f.kind == kind::FRAGMENT {} \
         if f.kind == kind::ERROR {} }\n\
         }\n\
         impl WorkerLink {\n\
         fn connect(&mut self) { write_frame(s, kind::HELLO, 0, b\"\"); \
         if f.kind != kind::ASSIGN { return; } }\n\
         fn ready(&mut self) { write_frame(s, kind::READY, 0, b\"\"); }\n\
         fn await_start(&mut self) { if f.kind != kind::START { return; } }\n\
         fn send_fragment(&mut self) { write_frame(s, kind::FRAGMENT, 0, b\"\"); }\n\
         fn fail(&mut self) { write_frame(s, kind::ERROR, 0, b\"\"); }\n\
         }\n\
         fn read_control(s: &mut S) -> R { match next(s) { Ok(None) => fail(), x => x } }\n",
    );
    let report = fix.run(&["protocol"]);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(errs[0].message.contains("READY"), "{}", errs[0].message);
    assert!(
        errs[0].message.contains("only one side"),
        "{}",
        errs[0].message
    );
    assert_eq!(errs[0].file, "rust/src/net/control.rs");
    assert!(errs[0].line > 0, "provenance should point at the send site");
}

/// `await_start` before `ready` inverts the worker flow: the driver's
/// barrier would wait on a READY that never comes.
#[test]
fn protocol_out_of_order_worker_flow_is_flagged() {
    let fix = Fixture::new("flow_order");
    fix.write(
        "rust/src/net/runner.rs",
        "fn worker_main(link: &mut WorkerLink) {\n\
         let spec = link.await_start(1);\n\
         link.ready();\n\
         link.send_fragment(frag);\n\
         }\n",
    );
    let report = fix.run(&["protocol"]);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(errs[0].message.contains("ready"), "{}", errs[0].message);
    assert!(
        errs[0].message.contains("inverting the protocol order"),
        "{}",
        errs[0].message
    );
    assert_eq!(errs[0].line, 3, "error anchors at the out-of-order call");
}

// ------------------------------------------------------------------ channels

#[test]
fn channels_orphaned_receiver_is_flagged() {
    let fix = Fixture::new("orphan_rx");
    fix.write(
        "rust/src/engine/exchange.rs",
        "fn leak() {\n\
         let (tx, rx) = bounded::<Event>(64);\n\
         tx.send(ev);\n\
         }\n",
    );
    let report = fix.run(&["channels"]);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(errs[0].message.contains("no drain"), "{}", errs[0].message);
    assert_eq!(errs[0].file, "rust/src/engine/exchange.rs");
    assert_eq!(errs[0].line, 2);
}

#[test]
fn channels_capacity_zero_and_unbounded_are_flagged() {
    let fix = Fixture::new("cap_zero");
    fix.write(
        "rust/src/broker/core.rs",
        "fn bad() {\n\
         let (tx, rx) = bounded(0);\n\
         let _ = rx.try_recv(); tx.close();\n\
         let (a, b) = mpsc::channel();\n\
         }\n",
    );
    let report = fix.run(&["channels"]);
    let errs = errors(&report);
    assert_eq!(errs.len(), 2, "{}", report.render(true));
    assert!(
        errs.iter().any(|e| e.message.contains("capacity-zero")),
        "{}",
        report.render(true)
    );
    assert!(
        errs.iter().any(|e| e.message.contains("mpsc::channel()")),
        "{}",
        report.render(true)
    );
}

// -------------------------------------------------------------- conservation

/// The PR-7 `parse_failures` bug class, reproduced: a counter bumped
/// on the hot path that no merge ever folds.
#[test]
fn conservation_unmerged_counter_is_flagged() {
    let fix = Fixture::new("unmerged_counter");
    fix.write(
        "rust/src/pipelines/mod.rs",
        "pub struct StepStats { pub parse_failures: u64 }\n\
         impl StepStats {\n\
         fn note_failure(&mut self) { self.parse_failures += 1; }\n\
         }\n",
    );
    let report = fix.run(&["conservation"]);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("parse_failures"),
        "{}",
        errs[0].message
    );
    assert!(
        errs[0].message.contains("silently lost"),
        "{}",
        errs[0].message
    );
    assert_eq!(errs[0].file, "rust/src/pipelines/mod.rs");
    assert_eq!(errs[0].line, 3, "provenance anchors at the bump site");
}

/// Merged but never emitted: the fold happens, then the value goes
/// nowhere — results.json never sees it.
#[test]
fn conservation_merged_but_unemitted_counter_is_flagged() {
    let fix = Fixture::new("unemitted_counter");
    fix.write(
        "rust/src/pipelines/mod.rs",
        "pub struct StepStats { pub dropped: u64 }\n\
         impl StepStats {\n\
         fn tick(&mut self) { self.dropped += 1; }\n\
         fn merge(&mut self, o: &StepStats) { self.dropped += o.dropped; }\n\
         }\n",
    );
    let report = fix.run(&["conservation"]);
    let errs = errors(&report);
    assert_eq!(errs.len(), 1, "{}", report.render(true));
    assert!(
        errs[0].message.contains("goes nowhere"),
        "{}",
        errs[0].message
    );
}

/// A counter that is bumped, merged, and emitted is clean end to end.
#[test]
fn conservation_full_provenance_chain_is_clean() {
    let fix = Fixture::new("conserved_counter");
    fix.write(
        "rust/src/pipelines/mod.rs",
        "pub struct StepStats { pub events_in: u64 }\n\
         impl StepStats {\n\
         fn tick(&mut self) { self.events_in += 1; }\n\
         fn merge(&mut self, o: &StepStats) { self.events_in += o.events_in; }\n\
         pub fn to_json(&self) -> Json { let mut j = Json::obj(); \
         j.set(\"events_in\", Json::Int(self.events_in as i64)); j }\n\
         }\n",
    )
    .write("README.md", "The `events_in` counter is documented here.\n");
    let report = fix.run(&["conservation"]);
    assert_eq!(errors(&report).len(), 0, "{}", report.render(true));
}

// ------------------------------------------------------------------- locks2

/// A guard held across a same-module helper call that blocks on a
/// channel: invisible to the lexical `locks` pass, caught by the
/// one-level interprocedural walk.
#[test]
fn locks2_guard_across_helper_call_is_flagged() {
    let src = "impl Exchange {\n\
               fn outer(&self) { let g = self.state.lock().expect(\"p\"); \
               self.flush(); }\n\
               fn flush(&self) { self.tx.send(1); }\n\
               }\n";
    let fix = Fixture::new("deep_lock");
    fix.write("rust/src/engine/exchange.rs", src);

    let shallow = fix.run(&["locks"]);
    assert_eq!(
        errors(&shallow).len(),
        0,
        "the lexical pass must be blind here: {}",
        shallow.render(true)
    );

    let deep = fix.run(&["locks2"]);
    let errs = errors(&deep);
    assert_eq!(errs.len(), 1, "{}", deep.render(true));
    assert!(
        errs[0].message.contains("call to `flush`"),
        "{}",
        errs[0].message
    );
    assert!(
        errs[0].message.contains("blocking channel op"),
        "{}",
        errs[0].message
    );
}

// -------------------------------------------------------------------- SARIF

#[test]
fn sarif_output_carries_rules_results_and_positive_lines() {
    let fix = Fixture::new("sarif_shape");
    fix.write(
        "rust/src/engine/exchange.rs",
        "fn leak() { let (tx, rx) = bounded(8); tx.send(1); }\n",
    );
    let report = fix.run(&["channels"]);
    assert!(report.error_count() > 0, "fixture must seed an error");
    let sarif = report.to_sarif().to_pretty();
    assert!(sarif.contains("\"2.1.0\""), "SARIF version missing:\n{sarif}");
    assert!(sarif.contains("sprobench-analyze"), "{sarif}");
    assert!(sarif.contains("\"ruleId\""), "{sarif}");
    assert!(sarif.contains("\"channels\""), "{sarif}");
    assert!(sarif.contains("\"error\""), "{sarif}");
    assert!(sarif.contains("\"startLine\""), "{sarif}");
    // Tree-level findings (line 0) must clamp to SARIF's 1-based lines.
    assert!(!sarif.contains("\"startLine\": 0"), "{sarif}");
}

// ------------------------------------------------------------- changed-since

/// `--changed-since` against a root that is not a git repository is a
/// hard error, never a silent "everything is pre-existing" demotion.
#[test]
fn changed_since_outside_git_is_a_hard_error() {
    let fix = Fixture::new("no_git");
    fix.write("rust/src/lib.rs", "pub fn f() {}\n");
    let result = analysis::run(&AnalyzeOptions {
        root: fix.root.clone(),
        passes: vec!["channels".to_string()],
        bless: false,
        changed_since: Some("HEAD".to_string()),
    });
    match result {
        Ok(_) => panic!("git diff must fail outside a repository"),
        Err(err) => assert!(err.contains("git"), "{err}"),
    }
}

/// Over the real tree (a git repository), diff-aware mode threads the
/// rev into the report and stays clean: demotion can only ever lower
/// severity.
#[test]
fn changed_since_over_real_tree_records_rev_and_stays_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analysis::run(&AnalyzeOptions {
        root: root.to_path_buf(),
        passes: Vec::new(), // all
        bless: false,
        changed_since: Some("HEAD".to_string()),
    })
    .expect("diff-aware analysis over the real tree");
    assert_eq!(report.changed_since.as_deref(), Some("HEAD"));
    assert_eq!(
        report.error_count(),
        0,
        "diff-aware run found errors:\n{}",
        report.render(false)
    );
    let json = report.to_json().to_pretty();
    assert!(json.contains("changed_since"), "{json}");
}

// --------------------------------------------------- key-table round-trip

/// Acceptance criterion: every results key the conservation pass maps
/// a counter to must exist in the schema pass's emitter key table —
/// the two passes must agree about what the emitters produce.
#[test]
fn conservation_key_table_round_trips_against_schema() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let ws = Workspace::load(root).expect("load real tree");
    let field_keys = conservation::field_key_table(&ws);
    let schema_keys = schema::emitter_key_table(&ws);
    assert!(
        !field_keys.is_empty(),
        "the real tree must map at least one counter to a results key"
    );
    for (field, keys) in &field_keys {
        for key in keys {
            assert!(
                schema_keys.contains_key(key),
                "counter `{field}` maps to key \"{key}\" which the schema pass \
                 does not know — emitter tables drifted apart"
            );
        }
    }
}
