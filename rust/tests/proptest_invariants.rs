//! Property-based tests on coordinator invariants (routing, batching,
//! state) using the in-tree mini property-test framework.

use sprobench::broker::{Broker, BrokerConfig, Record, Topic};
use sprobench::config::{BenchConfig, OpSpec, PipelineSpec};
use sprobench::engine::{
    AggKind, Checkpoint, CheckpointStore, EventBatch, LatePolicy, SlidingWindow, TaskPart,
    WatermarkTracker, WindowTime,
};
use sprobench::pipelines::{LockstepExchange, StepFactory};
use sprobench::util::clock;
use sprobench::util::histogram::Histogram;
use sprobench::util::json::Json;
use sprobench::util::proptest::{check, Config};
use sprobench::wgen::{EventFormat, SensorEvent};

#[test]
fn prop_routing_same_key_same_partition() {
    check(Config::default().cases(100), "routing-stability", |g| {
        let partitions = g.u64(1..32) as u32;
        let topic = Topic::new("t", partitions, 1024);
        let key = g.u64(0..1_000_000) as u32;
        let p1 = topic.partition_for_key(key);
        let p2 = topic.partition_for_key(key);
        if p1 != p2 {
            return Err(format!("key {key}: {p1} vs {p2}"));
        }
        if p1 >= partitions {
            return Err(format!("partition {p1} out of range {partitions}"));
        }
        Ok(())
    });
}

#[test]
fn prop_produce_batch_conserves_by_partition() {
    check(Config::default().cases(50), "batch-conservation", |g| {
        let broker = Broker::new(
            BrokerConfig {
                partitions: g.u64(1..8) as u32,
                queue_depth: 1 << 16,
                ..Default::default()
            },
            clock::wall(),
        );
        let topic = broker.create_topic("t");
        let n = g.usize(1..2000);
        let records: Vec<Record> = (0..n)
            .map(|i| Record::new(g.u64(0..5000) as u32, vec![0u8; 27], i as u64))
            .collect();
        broker.produce_batch(&topic, records).expect("produce");
        let appended = topic.total_appended();
        if appended != n as u64 {
            return Err(format!("appended {appended} != produced {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_event_roundtrip_any_size_and_value() {
    check(Config::default().cases(300), "event-roundtrip", |g| {
        let ev = SensorEvent {
            ts_micros: g.u64(0..(1 << 53)),
            sensor_id: g.u64(0..1 << 22) as u32,
            temp_c: g.f32(-500.0, 500.0),
        };
        let format = if g.bool() { EventFormat::Json } else { EventFormat::Csv };
        let target = g.usize(27..512);
        let mut buf = Vec::new();
        let n = ev.serialize_into(format, target, &mut buf);
        if n != buf.len() {
            return Err("length mismatch".into());
        }
        let parsed = SensorEvent::parse(&buf)
            .ok_or_else(|| format!("unparseable: {:?}", String::from_utf8_lossy(&buf)))?;
        if parsed.ts_micros != ev.ts_micros || parsed.sensor_id != ev.sensor_id {
            return Err(format!("ids/ts mismatch: {parsed:?} vs {ev:?}"));
        }
        if (parsed.temp_c - ev.temp_c).abs() > 0.006 {
            return Err(format!("temp drift: {} vs {}", parsed.temp_c, ev.temp_c));
        }
        Ok(())
    });
}

#[test]
fn prop_window_split_equals_whole() {
    // Accumulating a batch in two chunks must equal accumulating it whole
    // (the engine splits batches arbitrarily at poll boundaries).
    check(Config::default().cases(60), "window-split-merge", |g| {
        let k = 64;
        let n = g.usize(2..400);
        let ids: Vec<u32> = (0..n).map(|_| g.u64(0..k as u64) as u32).collect();
        let temps: Vec<f32> = (0..n).map(|_| g.f32(-50.0, 50.0)).collect();
        let cut = g.usize(1..n);

        let mut whole = SlidingWindow::new(k, 10_000_000, 2_000_000, 0);
        whole.accumulate_native(&ids, &temps);
        let mut split = SlidingWindow::new(k, 10_000_000, 2_000_000, 0);
        split.accumulate_native(&ids[..cut], &temps[..cut]);
        split.accumulate_native(&ids[cut..], &temps[cut..]);

        let (ew, es) = (whole.advance(2_000_000), split.advance(2_000_000));
        if ew.len() != 1 || es.len() != 1 {
            return Err("expected one emission each".into());
        }
        if ew[0].aggregates.len() != es[0].aggregates.len() {
            return Err("aggregate key sets differ".into());
        }
        for (a, b) in ew[0].aggregates.iter().zip(&es[0].aggregates) {
            if a.0 != b.0 || a.2 != b.2 || (a.1 - b.1).abs() > 1e-3 {
                return Err(format!("{a:?} vs {b:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_histogram_quantiles_bounded_by_min_max() {
    check(Config::default().cases(100), "histogram-bounds", |g| {
        let mut h = Histogram::new();
        let n = g.usize(1..500);
        let mut lo = u64::MAX;
        let mut hi = 0;
        for _ in 0..n {
            let v = g.u64(0..10_000_000);
            lo = lo.min(v);
            hi = hi.max(v);
            h.record(v);
        }
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let x = h.quantile(q);
            if x < lo || x > hi {
                return Err(format!("q{q}: {x} outside [{lo},{hi}]"));
            }
        }
        if h.count() != n as u64 {
            return Err("count mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_histogram_merge_commutes() {
    check(Config::default().cases(60), "histogram-merge-commute", |g| {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for _ in 0..g.usize(1..200) {
            a.record(g.u64(0..1_000_000));
        }
        for _ in 0..g.usize(1..200) {
            b.record(g.u64(0..1_000_000));
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        if ab.summary() != ba.summary() {
            return Err(format!("{:?} vs {:?}", ab.summary(), ba.summary()));
        }
        Ok(())
    });
}

/// Random event batch over `keys` sensors with exact-in-f32 values.
fn gen_batch(g: &mut sprobench::util::proptest::Gen, n: usize, keys: u64, t0: u64) -> EventBatch {
    let mut ids = Vec::with_capacity(n);
    let mut temps = Vec::with_capacity(n);
    let mut ts = Vec::with_capacity(n);
    for _ in 0..n {
        ids.push(g.u64(0..keys) as u32);
        temps.push((g.u64(0..120) as f32) * 0.25);
        ts.push(t0 + g.u64(0..400_000));
    }
    EventBatch {
        payload_bytes: n as u64 * 27,
        ids,
        temps,
        gen_ts: ts.clone(),
        append_ts: ts,
    }
}

#[test]
fn prop_window_chain_snapshot_restore_identity() {
    // snapshot → restore → snapshot is the identity on serialized state,
    // and the restored chain behaves identically on any suffix — for
    // processing-time and event-time windows under arbitrary sequences.
    check(Config::default().cases(30), "chain-snapshot-roundtrip", |g| {
        let event_time = g.bool();
        let agg = match g.u64(0..3) {
            0 => AggKind::Mean,
            1 => AggKind::Sum,
            _ => AggKind::Max,
        };
        let window = OpSpec::Window {
            agg,
            window_micros: 1_000_000,
            slide_micros: 500_000,
            time: if event_time { WindowTime::Event } else { WindowTime::Processing },
            allowed_lateness_micros: 1_000_000,
            late_policy: LatePolicy::MergeIfOpen,
            watermark_micros: 400_000,
        };
        let mut cfg = BenchConfig::default();
        cfg.engine.use_hlo = false;
        cfg.engine.parallelism = 1;
        cfg.workload.sensors = 32;
        cfg.engine.pipeline_spec = Some(PipelineSpec {
            ops: vec![window, OpSpec::EmitAggregates],
        });
        let factory = StepFactory::new(&cfg, None);

        let mut step = factory.create(0).map_err(|e| e.to_string())?;
        let mut sink = Vec::new();
        let rounds = g.usize(1..6);
        for r in 0..rounds as u64 {
            let b = gen_batch(g, g.usize(1..200), 32, 100_000 + r * 300_000);
            step.process(200_000 + r * 300_000, &[], &b, &mut sink)
                .map_err(|e| e.to_string())?;
        }
        let snap = step.snapshot().map_err(|e| e.to_string())?;

        let mut restored = factory.create(0).map_err(|e| e.to_string())?;
        restored.restore(&snap).map_err(|e| e.to_string())?;
        let again = restored.snapshot().map_err(|e| e.to_string())?;
        if again != snap {
            return Err(format!("state drifted through restore:\n{snap:?}\nvs\n{again:?}"));
        }

        // Identical suffix into the original and the restored twin.
        let t1 = 200_000 + rounds as u64 * 300_000;
        let suffix = gen_batch(g, g.usize(1..200), 32, t1);
        let end = t1 + 3_000_000;
        let (mut a, mut b) = (Vec::new(), Vec::new());
        step.process(t1, &[], &suffix, &mut a).map_err(|e| e.to_string())?;
        step.finish(end, &mut a).map_err(|e| e.to_string())?;
        restored.process(t1, &[], &suffix, &mut b).map_err(|e| e.to_string())?;
        restored.finish(end, &mut b).map_err(|e| e.to_string())?;
        let canon = |v: &[Record]| {
            let mut c: Vec<_> = v
                .iter()
                .map(|r| (r.gen_ts_micros, r.key, r.payload().to_vec()))
                .collect();
            c.sort();
            c
        };
        if canon(&a) != canon(&b) {
            return Err(format!(
                "restored chain diverged on the suffix: {} vs {} records",
                a.len(),
                b.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_keyed_topk_snapshot_restore_identity() {
    // The staged keyby→window→topk pipeline (top-k selection state, gate
    // frontiers, per-instance panes) round-trips through snapshot/restore
    // at a quiesce point under arbitrary event sequences.
    check(Config::default().cases(10), "topk-snapshot-roundtrip", |g| {
        let mut cfg = BenchConfig::default();
        cfg.engine.use_hlo = false;
        cfg.engine.parallelism = 1 + g.u64(0..2) as u32;
        cfg.workload.sensors = 32;
        cfg.engine.pipeline_spec = Some(PipelineSpec {
            ops: vec![
                OpSpec::KeyBy {
                    modulo: 8,
                    parallelism: 0,
                },
                OpSpec::window(AggKind::Sum, 1_000_000, 500_000),
                OpSpec::TopK {
                    k: 3,
                    parallelism: 0,
                },
                OpSpec::EmitAggregates,
            ],
        });
        let mut lx = LockstepExchange::compile(&cfg)
            .map_err(|e| e.to_string())?
            .ok_or("keyed spec must stage")?;
        let par = lx.parallelism() as usize;
        let mut sink = Vec::new();
        for r in 0..g.u64(1..4) {
            let b = gen_batch(g, g.usize(par..160), 32, 100_000 + r * 300_000);
            let now = 200_000 + r * 300_000;
            lx.process_round(now, &[b], &mut sink).map_err(|e| e.to_string())?;
            // Idle rounds quiesce the fabric (window emissions crossing
            // the topk boundary need an extra drain pass).
            for _ in 0..3 {
                lx.idle_round(now, &mut sink).map_err(|e| e.to_string())?;
            }
        }
        let snap = lx.snapshot().map_err(|e| e.to_string())?;
        let mut lx2 = LockstepExchange::compile(&cfg)
            .map_err(|e| e.to_string())?
            .ok_or("recompile must stage")?;
        lx2.restore(&snap).map_err(|e| e.to_string())?;
        let again = lx2.snapshot().map_err(|e| e.to_string())?;
        if again != snap {
            return Err("staged state drifted through restore".into());
        }
        Ok(())
    });
}

#[test]
fn prop_watermark_snapshot_restore_identity() {
    check(Config::default().cases(120), "watermark-snapshot-roundtrip", |g| {
        let bound = g.u64(0..2_000_000);
        let mut a = WatermarkTracker::new(bound);
        for _ in 0..g.usize(0..120) {
            a.observe(g.u64(0..1 << 40));
            if g.bool() {
                a.advance();
            }
        }
        let (max_ts, watermark, seen) = a.export_state();
        let mut b = WatermarkTracker::new(bound);
        b.import_state(max_ts, watermark, seen);
        if b.export_state() != a.export_state() {
            return Err("import is not the inverse of export".into());
        }
        // Identical suffix observations keep the twins in lockstep.
        for _ in 0..g.usize(1..40) {
            let t = g.u64(0..1 << 40);
            a.observe(t);
            b.observe(t);
            if a.advance() != b.advance() {
                return Err("watermarks diverged after restore".into());
            }
        }
        if a.watermark() != b.watermark() || a.max_ts() != b.max_ts() {
            return Err(format!(
                "final state diverged: {:?} vs {:?}",
                a.export_state(),
                b.export_state()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_corrupt_checkpoint_files_rejected_readably() {
    // Any truncation or single-bit flip of a checkpoint file must fail
    // the load with a readable error — never a panic, never a silently
    // wrong restore.
    let dir = std::env::temp_dir().join(format!("sprobench-prop-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = CheckpointStore::new(&dir, 0);
    check(Config::default().cases(150), "checkpoint-corruption", |g| {
        let tasks = (0..g.usize(1..4))
            .map(|t| {
                let mut state = Json::obj();
                state.set("pane", Json::Int(g.u64(0..1 << 50) as i64));
                TaskPart {
                    offsets: vec![(t as u32, g.u64(0..1 << 50))],
                    events_in: g.u64(0..1 << 50),
                    parse_failures: 0,
                    state,
                }
            })
            .collect();
        let ckpt = Checkpoint { epoch: 1, tasks };
        store.write(&ckpt).map_err(|e| e.to_string())?;
        let path = store.dir().join("ckpt-00000001.json");
        let mut bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
        if g.bool() {
            // Truncate to a proper prefix (possibly empty).
            bytes.truncate(g.usize(0..bytes.len()));
        } else {
            // Flip one bit anywhere in the document.
            let i = g.usize(0..bytes.len());
            bytes[i] ^= 1 << g.u64(0..8);
        }
        std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
        match store.load(1) {
            Ok(_) => Err("corrupt checkpoint loaded successfully".into()),
            Err(e) if e.is_empty() => Err("empty error message".into()),
            Err(_) => Ok(()),
        }
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_consumer_group_assignment_partitions_exactly() {
    check(Config::default().cases(100), "assignment-partition", |g| {
        let partitions = g.u64(1..64) as u32;
        let members = g.u64(1..16) as u32;
        let broker = Broker::new(
            BrokerConfig {
                partitions,
                ..Default::default()
            },
            clock::wall(),
        );
        broker.create_topic("t");
        let group = broker.subscribe("t", "g", members);
        let mut seen = vec![0u32; partitions as usize];
        for m in 0..members {
            for p in group.assignment(m) {
                seen[p as usize] += 1;
            }
        }
        if !seen.iter().all(|&c| c == 1) {
            return Err(format!("partitions not covered exactly once: {seen:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sim_fault_schedules_leave_aggregates_untouched() {
    // Restart and stall faults cost downtime, never records: in sim mode
    // any schedule without poison must leave the events / throughput /
    // latency blocks byte-identical to the fault-free run — the fault
    // model may only ADD the recovery / faults / resilience blocks.
    use sprobench::config::{FaultKind, FaultSpec};
    use sprobench::coordinator::simrun::{run_sim, SimModel};

    let model = SimModel::default();
    check(Config::default().cases(40), "sim-fault-aggregates", |g| {
        let mut cfg = BenchConfig::default();
        cfg.bench.name = "chaos-sim".into();
        cfg.bench.duration_micros = g.u64(2_000_000..30_000_000);
        cfg.workload.rate = g.u64(10_000..500_000);
        cfg.engine.parallelism = g.u64(1..8) as u32;
        cfg.checkpoint.interval_micros = g.u64(100_000..2_000_000);
        let baseline = run_sim(&cfg, &model).0.to_json();

        let n = g.usize(1..6);
        let mut chaotic = cfg.clone();
        for _ in 0..n {
            let at = g.u64(0..cfg.bench.duration_micros * 2); // may overshoot the run
            let kind = match g.u64(0..3) {
                0 => FaultKind::KillTask {
                    task: g.u64(0..cfg.engine.parallelism as u64) as u32,
                },
                1 => FaultKind::HangTask {
                    task: g.u64(0..cfg.engine.parallelism as u64) as u32,
                },
                _ => FaultKind::StallPartition {
                    partition: g.u64(0..cfg.broker.partitions as u64) as u32,
                },
            };
            chaotic.fault.schedule.push(FaultSpec {
                kind,
                at_micros: at,
                duration_micros: g.u64(0..1_000_000),
                seed: 0,
            });
        }
        chaotic.validate().map_err(|e| e.to_string())?;
        let faulted = run_sim(&chaotic, &model).0;
        if faulted.quarantined != 0 {
            return Err(format!(
                "no poison scheduled but quarantined={}",
                faulted.quarantined
            ));
        }
        let j = faulted.to_json();
        for block in ["events", "throughput", "latency_us"] {
            let a = baseline.get(block).map(|v| v.to_string());
            let b = j.get(block).map(|v| v.to_string());
            if a != b {
                return Err(format!("{block} diverged under faults: {a:?} vs {b:?}"));
            }
        }
        if j.get("resilience").is_none() {
            return Err("fault run missing resilience block".into());
        }
        Ok(())
    });
}

#[test]
fn prop_wall_chaos_schedules_never_deadlock() {
    // Random wall-mode schedules (kill / hang / stall / poison at random
    // offsets) must always terminate: either a healed summary with exact
    // conservation, or a loud budget-exhaustion error — never a hang.
    use sprobench::config::{FaultKind, FaultSpec};
    use sprobench::coordinator::run_recovery;
    use sprobench::postprocess::validate_results;

    check(Config::default().cases(4), "wall-chaos-liveness", |g| {
        let mut c = BenchConfig::default();
        c.bench.name = "chaos-wall".into();
        c.bench.warmup_micros = 0;
        c.bench.duration_micros = 900_000;
        c.workload.rate = 30_000;
        c.workload.sensors = 64;
        c.engine.parallelism = 2;
        c.engine.use_hlo = false;
        c.engine.batch_size = 256;
        c.checkpoint.interval_micros = 150_000;
        c.checkpoint.dir = std::env::temp_dir()
            .join(format!(
                "sprobench-prop-chaos-{}-{}",
                std::process::id(),
                g.u64(0..u64::MAX)
            ))
            .to_string_lossy()
            .into_owned();
        c.fault.heartbeat_timeout_micros = 150_000;
        let n = g.usize(1..4);
        for _ in 0..n {
            let kind = match g.u64(0..4) {
                0 => FaultKind::KillTask {
                    task: g.u64(0..2) as u32,
                },
                1 => FaultKind::HangTask {
                    task: g.u64(0..2) as u32,
                },
                2 => FaultKind::StallPartition {
                    partition: g.u64(0..c.broker.partitions as u64) as u32,
                },
                _ => FaultKind::PoisonRecords {
                    fraction: g.f64(0.01, 0.3),
                },
            };
            c.fault.schedule.push(FaultSpec {
                kind,
                at_micros: g.u64(50_000..800_000),
                duration_micros: g.u64(0..400_000),
                seed: g.u64(1..1 << 30),
            });
        }
        c.validate().map_err(|e| e.to_string())?;
        let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
        let t0 = std::time::Instant::now();
        let result = run_recovery(&c, None);
        let elapsed = t0.elapsed();
        let _ = std::fs::remove_dir_all(&c.checkpoint.dir);
        if elapsed.as_secs() >= 60 {
            return Err(format!("chaos run wedged for {elapsed:?}"));
        }
        match result {
            Ok((summary, _)) => {
                if summary.processed + summary.quarantined != summary.generated {
                    return Err(format!(
                        "conservation broken: {} + {} != {}",
                        summary.processed, summary.quarantined, summary.generated
                    ));
                }
                let violations = validate_results(&summary.to_json());
                if !violations.is_empty() {
                    return Err(format!("{violations:?}"));
                }
                Ok(())
            }
            // Budget exhaustion is a legal, loud outcome of a dense
            // schedule; anything else is a real failure.
            Err(e) if e.contains("max_restarts") => Ok(()),
            Err(e) => Err(e),
        }
    });
}

#[test]
fn prop_net_frame_roundtrip_and_corruption_rejected() {
    // Wire framing is total: any frame round-trips exactly, and any
    // truncation or single-bit flip either fails with a readable error
    // or decodes to a visibly different frame — never silently the
    // original.  (Flips in the kind/channel header bytes are outside
    // the CRC, so "visibly different" is the contract there.)
    use sprobench::net::frame::{decode_frame, encode_frame};

    check(Config::default().cases(300), "net-frame-codec", |g| {
        let kind = g.u64(1..13) as u8;
        let channel = g.u64(0..u32::MAX as u64) as u32;
        let n = g.usize(0..512);
        let payload: Vec<u8> = (0..n).map(|_| g.u64(0..256) as u8).collect();
        let mut buf = Vec::new();
        encode_frame(kind, channel, &payload, &mut buf);

        let (f, used) = decode_frame(&buf)?;
        if used != buf.len() || f.kind != kind || f.channel != channel || f.payload != payload {
            return Err(format!(
                "round-trip mismatch: kind {}→{}, consumed {used}/{}",
                kind,
                f.kind,
                buf.len()
            ));
        }

        let mut evil = buf.clone();
        if g.bool() {
            evil.truncate(g.usize(0..evil.len()));
            match decode_frame(&evil) {
                Err(e) if e.is_empty() => Err("empty truncation error".into()),
                Err(_) => Ok(()),
                Ok(_) => Err(format!("{}-byte truncation decoded", evil.len())),
            }
        } else {
            let i = g.usize(0..evil.len());
            evil[i] ^= 1 << g.u64(0..8);
            match decode_frame(&evil) {
                Err(e) if e.is_empty() => Err("empty corruption error".into()),
                Err(_) => Ok(()),
                Ok((f2, _))
                    if f2.kind == kind && f2.channel == channel && f2.payload == payload =>
                {
                    Err(format!("bit flip at byte {i} went unnoticed"))
                }
                Ok(_) => Ok(()),
            }
        }
    });
}

#[test]
fn prop_net_payload_codecs_roundtrip() {
    // The two data-plane payload shapes (record batches and exchange
    // row packets) survive encode→decode byte-exactly, and corrupting
    // the payload behind an intact frame header is caught by the CRC.
    use sprobench::broker::RecordBatchBuilder;
    use sprobench::net::frame::{
        decode_record_batch, decode_rows, encode_record_batch, encode_rows,
    };
    use sprobench::pipelines::RowBatch;

    check(Config::default().cases(200), "net-payload-codec", |g| {
        // Record batch.
        let n = g.usize(1..40);
        let mut b = RecordBatchBuilder::new();
        let mut expect = Vec::new();
        for _ in 0..n {
            let key = g.u64(0..1 << 20) as u32;
            let ts = g.u64(0..1 << 50);
            let len = g.usize(1..64);
            let payload: Vec<u8> = (0..len).map(|_| g.u64(0..256) as u8).collect();
            b.push(key, &payload, ts);
            expect.push((key, ts, payload));
        }
        let mut batch = b.build();
        batch.base_offset = g.u64(0..1 << 40);
        batch.append_ts_micros = g.u64(0..1 << 50);
        let partition = g.u64(0..64) as u32;
        let mut buf = Vec::new();
        encode_record_batch(partition, &batch, &mut buf);
        let (p2, back) = decode_record_batch(&buf)?;
        if p2 != partition
            || back.base_offset != batch.base_offset
            || back.append_ts_micros != batch.append_ts_micros
            || back.len() != n
        {
            return Err("batch header mismatch after round-trip".into());
        }
        for (i, (key, ts, payload)) in expect.iter().enumerate() {
            let e = back.entry(i);
            if e.key != *key || e.gen_ts_micros != *ts || back.payload(i) != &payload[..] {
                return Err(format!("record {i} mismatch after round-trip"));
            }
        }

        // Exchange rows.
        let m = g.usize(0..50);
        let mut rows = RowBatch::default();
        for _ in 0..m {
            rows.push(
                g.u64(0..1 << 16) as u32,
                g.f64(-1e4, 1e4) as f32,
                g.u64(0..1 << 50),
                g.u64(1..1 << 20),
            );
        }
        let sent = g.u64(0..1 << 50);
        let mut rbuf = Vec::new();
        encode_rows(&rows, sent, &mut rbuf);
        let (rows2, sent2) = decode_rows(&rbuf)?;
        if sent2 != sent
            || rows2.keys != rows.keys
            || rows2.ts != rows.ts
            || rows2.counts != rows.counts
            || rows2.vals.len() != rows.vals.len()
            || rows2
                .vals
                .iter()
                .zip(&rows.vals)
                .any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err("row packet mismatch after round-trip".into());
        }
        Ok(())
    });
}
