//! Property-based tests on coordinator invariants (routing, batching,
//! state) using the in-tree mini property-test framework.

use sprobench::broker::{Broker, BrokerConfig, Record, Topic};
use sprobench::engine::SlidingWindow;
use sprobench::util::clock;
use sprobench::util::histogram::Histogram;
use sprobench::util::proptest::{check, Config};
use sprobench::wgen::{EventFormat, SensorEvent};

#[test]
fn prop_routing_same_key_same_partition() {
    check(Config::default().cases(100), "routing-stability", |g| {
        let partitions = g.u64(1..32) as u32;
        let topic = Topic::new("t", partitions, 1024);
        let key = g.u64(0..1_000_000) as u32;
        let p1 = topic.partition_for_key(key);
        let p2 = topic.partition_for_key(key);
        if p1 != p2 {
            return Err(format!("key {key}: {p1} vs {p2}"));
        }
        if p1 >= partitions {
            return Err(format!("partition {p1} out of range {partitions}"));
        }
        Ok(())
    });
}

#[test]
fn prop_produce_batch_conserves_by_partition() {
    check(Config::default().cases(50), "batch-conservation", |g| {
        let broker = Broker::new(
            BrokerConfig {
                partitions: g.u64(1..8) as u32,
                queue_depth: 1 << 16,
                ..Default::default()
            },
            clock::wall(),
        );
        let topic = broker.create_topic("t");
        let n = g.usize(1..2000);
        let records: Vec<Record> = (0..n)
            .map(|i| Record::new(g.u64(0..5000) as u32, vec![0u8; 27], i as u64))
            .collect();
        broker.produce_batch(&topic, records).expect("produce");
        let appended = topic.total_appended();
        if appended != n as u64 {
            return Err(format!("appended {appended} != produced {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_event_roundtrip_any_size_and_value() {
    check(Config::default().cases(300), "event-roundtrip", |g| {
        let ev = SensorEvent {
            ts_micros: g.u64(0..(1 << 53)),
            sensor_id: g.u64(0..1 << 22) as u32,
            temp_c: g.f32(-500.0, 500.0),
        };
        let format = if g.bool() { EventFormat::Json } else { EventFormat::Csv };
        let target = g.usize(27..512);
        let mut buf = Vec::new();
        let n = ev.serialize_into(format, target, &mut buf);
        if n != buf.len() {
            return Err("length mismatch".into());
        }
        let parsed = SensorEvent::parse(&buf)
            .ok_or_else(|| format!("unparseable: {:?}", String::from_utf8_lossy(&buf)))?;
        if parsed.ts_micros != ev.ts_micros || parsed.sensor_id != ev.sensor_id {
            return Err(format!("ids/ts mismatch: {parsed:?} vs {ev:?}"));
        }
        if (parsed.temp_c - ev.temp_c).abs() > 0.006 {
            return Err(format!("temp drift: {} vs {}", parsed.temp_c, ev.temp_c));
        }
        Ok(())
    });
}

#[test]
fn prop_window_split_equals_whole() {
    // Accumulating a batch in two chunks must equal accumulating it whole
    // (the engine splits batches arbitrarily at poll boundaries).
    check(Config::default().cases(60), "window-split-merge", |g| {
        let k = 64;
        let n = g.usize(2..400);
        let ids: Vec<u32> = (0..n).map(|_| g.u64(0..k as u64) as u32).collect();
        let temps: Vec<f32> = (0..n).map(|_| g.f32(-50.0, 50.0)).collect();
        let cut = g.usize(1..n);

        let mut whole = SlidingWindow::new(k, 10_000_000, 2_000_000, 0);
        whole.accumulate_native(&ids, &temps);
        let mut split = SlidingWindow::new(k, 10_000_000, 2_000_000, 0);
        split.accumulate_native(&ids[..cut], &temps[..cut]);
        split.accumulate_native(&ids[cut..], &temps[cut..]);

        let (ew, es) = (whole.advance(2_000_000), split.advance(2_000_000));
        if ew.len() != 1 || es.len() != 1 {
            return Err("expected one emission each".into());
        }
        if ew[0].aggregates.len() != es[0].aggregates.len() {
            return Err("aggregate key sets differ".into());
        }
        for (a, b) in ew[0].aggregates.iter().zip(&es[0].aggregates) {
            if a.0 != b.0 || a.2 != b.2 || (a.1 - b.1).abs() > 1e-3 {
                return Err(format!("{a:?} vs {b:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_histogram_quantiles_bounded_by_min_max() {
    check(Config::default().cases(100), "histogram-bounds", |g| {
        let mut h = Histogram::new();
        let n = g.usize(1..500);
        let mut lo = u64::MAX;
        let mut hi = 0;
        for _ in 0..n {
            let v = g.u64(0..10_000_000);
            lo = lo.min(v);
            hi = hi.max(v);
            h.record(v);
        }
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let x = h.quantile(q);
            if x < lo || x > hi {
                return Err(format!("q{q}: {x} outside [{lo},{hi}]"));
            }
        }
        if h.count() != n as u64 {
            return Err("count mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_histogram_merge_commutes() {
    check(Config::default().cases(60), "histogram-merge-commute", |g| {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for _ in 0..g.usize(1..200) {
            a.record(g.u64(0..1_000_000));
        }
        for _ in 0..g.usize(1..200) {
            b.record(g.u64(0..1_000_000));
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        if ab.summary() != ba.summary() {
            return Err(format!("{:?} vs {:?}", ab.summary(), ba.summary()));
        }
        Ok(())
    });
}

#[test]
fn prop_consumer_group_assignment_partitions_exactly() {
    check(Config::default().cases(100), "assignment-partition", |g| {
        let partitions = g.u64(1..64) as u32;
        let members = g.u64(1..16) as u32;
        let broker = Broker::new(
            BrokerConfig {
                partitions,
                ..Default::default()
            },
            clock::wall(),
        );
        broker.create_topic("t");
        let group = broker.subscribe("t", "g", members);
        let mut seen = vec![0u32; partitions as usize];
        for m in 0..members {
            for p in group.assignment(m) {
                seen[p as usize] += 1;
            }
        }
        if !seen.iter().all(|&c| c == 1) {
            return Err(format!("partitions not covered exactly once: {seen:?}"));
        }
        Ok(())
    });
}
