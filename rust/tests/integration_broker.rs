//! Broker integration: multi-producer / multi-consumer stress under
//! backpressure, record conservation, fan-out to multiple groups.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use sprobench::broker::{Broker, BrokerConfig, Record};
use sprobench::util::clock;

fn records(n: usize, key_base: u32) -> Vec<Record> {
    (0..n)
        .map(|i| Record::new(key_base + i as u32, vec![0u8; 27], i as u64))
        .collect()
}

#[test]
fn multi_producer_multi_consumer_conserves_records() {
    let broker = Broker::new(
        BrokerConfig {
            partitions: 8,
            queue_depth: 2048,
            ..Default::default()
        },
        clock::wall(),
    );
    let topic = broker.create_topic("stress");
    let group = broker.subscribe("stress", "workers", 4);

    const PRODUCERS: usize = 4;
    const PER_PRODUCER: usize = 25_000;

    let consumed = Arc::new(AtomicU64::new(0));
    let consumers: Vec<_> = (0..4)
        .map(|m| {
            let g = group.clone();
            let consumed = consumed.clone();
            std::thread::spawn(move || loop {
                match g.poll(m, 512) {
                    Ok(Some(b)) => {
                        consumed.fetch_add(b.record_count() as u64, Ordering::SeqCst);
                        g.commit(b.partition, b.next_offset);
                    }
                    Ok(None) => std::thread::yield_now(),
                    Err(_) => return,
                }
            })
        })
        .collect();

    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let broker = broker.clone();
            let topic = topic.clone();
            std::thread::spawn(move || {
                for chunk in records(PER_PRODUCER, (p * PER_PRODUCER) as u32).chunks(500) {
                    broker.produce_batch(&topic, chunk.to_vec()).unwrap();
                }
            })
        })
        .collect();
    for p in producers {
        p.join().unwrap();
    }
    broker.shutdown();
    for c in consumers {
        c.join().unwrap();
    }
    assert_eq!(
        consumed.load(Ordering::SeqCst),
        (PRODUCERS * PER_PRODUCER) as u64
    );
    assert_eq!(broker.stats().backlog, 0);
}

#[test]
fn backpressure_throttles_but_never_drops() {
    // Tiny partitions; a slow consumer forces producers to block.
    let broker = Broker::new(
        BrokerConfig {
            partitions: 2,
            queue_depth: 64,
            ..Default::default()
        },
        clock::wall(),
    );
    let topic = broker.create_topic("bp");
    let group = broker.subscribe("bp", "slow", 1);
    let producer = {
        let broker = broker.clone();
        let topic = topic.clone();
        std::thread::spawn(move || {
            for chunk in records(20_000, 0).chunks(100) {
                broker.produce_batch(&topic, chunk.to_vec()).unwrap();
            }
        })
    };
    let mut seen = 0u64;
    while seen < 20_000 {
        if let Ok(Some(b)) = group.poll(0, 64) {
            seen += b.record_count() as u64;
            group.commit(b.partition, b.next_offset);
            // Simulate a slow consumer.
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
    }
    producer.join().unwrap();
    assert_eq!(seen, 20_000);
}

#[test]
fn fanout_to_two_groups_delivers_twice() {
    let broker = Broker::new(BrokerConfig::default(), clock::wall());
    let topic = broker.create_topic("fan");
    let g1 = broker.subscribe("fan", "a", 1);
    let g2 = broker.subscribe("fan", "b", 1);
    broker.produce_batch(&topic, records(5_000, 0)).unwrap();
    broker.shutdown();
    let drain = |g: Arc<sprobench::broker::ConsumerGroup>| {
        let mut n = 0;
        loop {
            match g.poll(0, 512) {
                Ok(Some(b)) => {
                    n += b.record_count();
                    g.commit(b.partition, b.next_offset);
                }
                Ok(None) => continue,
                Err(_) => return n,
            }
        }
    };
    assert_eq!(drain(g1), 5_000);
    assert_eq!(drain(g2), 5_000);
}

#[test]
fn per_partition_ordering_is_preserved() {
    let broker = Broker::new(BrokerConfig::default(), clock::wall());
    let topic = broker.create_topic("order");
    // Same key → same partition → strict order.
    for i in 0..1_000u64 {
        broker
            .produce(&topic, Record::new(7, i.to_le_bytes().to_vec(), i))
            .unwrap();
    }
    broker.shutdown();
    let g = broker.subscribe("order", "g", 1);
    let mut last = None;
    loop {
        match g.poll(0, 128) {
            Ok(Some(b)) => {
                for r in b.iter() {
                    let v = u64::from_le_bytes(r.payload[..8].try_into().unwrap());
                    if let Some(prev) = last {
                        assert!(v > prev, "order violated: {v} after {prev}");
                    }
                    last = Some(v);
                }
                g.commit(b.partition, b.next_offset);
            }
            Ok(None) => continue,
            Err(_) => break,
        }
    }
    assert_eq!(last, Some(999));
}
