//! Loopback distributed-equivalence suite: a multi-process TCP run
//! (`cluster.transport: tcp` — driver + broker + engine, generators
//! colocated or external) must produce **byte-identical** final
//! aggregates to the plain in-process run of the same spec.
//!
//! Determinism rests on count-bound generation (`workload.events > 0`):
//! synthetic generation timestamps from a fixed base, quarter-degree f32
//! temperatures (order-independent window sums), and event-time windows
//! whose `allowed_lateness` exceeds the whole synthetic span — so no
//! pane closes before the finish flush and pane membership cannot depend
//! on arrival timing.  Each run writes its canonical sorted egestion
//! dump (`metrics.egest_dump`); equality is over those files' bytes.
//!
//! The runs go through the real binary (`sprobench run --config …`), so
//! the TCP case exercises worker spawning, the control plane, framing,
//! the feeder/pump data path, and results.json merging end to end.

use std::path::{Path, PathBuf};
use std::process::Command;

use sprobench::util::json::{self, Json};

fn tmp(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("sprobench-dist-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

/// Master YAML shared by both topologies: count-bound workload,
/// keyby → event-time window → emit_aggregates at parallelism 2.
/// `cluster` selects the topology; `disorder` optionally injects the
/// out-of-order reorder/backdating model (same seed ⇒ same stream).
fn config_yaml(name: &str, dump: &Path, cluster: &str, disorder: &str) -> String {
    format!(
        "benchmark:
  name: {name}
  mode: wall
  duration: 20s
  warmup: 0s
workload:
  rate: 100K
  events: 40000
  sensors: 64
{disorder}engine:
  parallelism: 2
  use_hlo: false
  batch_size: 256
  pipeline:
    ops:
      - keyby:
          modulo: 16
      - window:
          agg: mean
          window: 1s
          slide: 500ms
          time: event
          allowed_lateness: 5s
          late_policy: merge_if_open
          watermark: 500ms
      - emit: aggregates
metrics:
  egest_dump: {dump}
{cluster}",
        dump = dump.display()
    )
}

const TCP_CLUSTER: &str = "cluster:
  transport: tcp
";

const TCP_CLUSTER_EXTERNAL_GEN: &str = "cluster:
  transport: tcp
  generators: 1
";

const DISORDER: &str = "  disorder:
    late_fraction: 0.25
    lateness: 100ms
    shuffle_window: 64
";

/// Run `sprobench run --config <cfg> --out <out>` through the real
/// binary; panics with the child's output on failure.
fn run_bin(cfg: &Path, out: &Path) {
    let output = Command::new(env!("CARGO_BIN_EXE_sprobench"))
        .args(["run", "--config"])
        .arg(cfg)
        .arg("--out")
        .arg(out)
        .output()
        .expect("launch sprobench binary");
    assert!(
        output.status.success(),
        "run failed ({}):\n--- stdout ---\n{}\n--- stderr ---\n{}",
        output.status,
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr),
    );
}

/// Parse `results.json` under the single run directory for `name`.
fn results_json(out: &Path, name: &str) -> Json {
    let dir = std::fs::read_dir(out)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with(name))
        })
        .unwrap_or_else(|| panic!("no run dir for {name} under {}", out.display()));
    let text = std::fs::read_to_string(dir.join("results.json")).unwrap();
    json::parse(&text).unwrap()
}

fn int(results: &Json, path: &[&str]) -> i64 {
    results
        .path(path)
        .and_then(|v| v.as_i64())
        .unwrap_or_else(|| panic!("{path:?} missing in {results:?}"))
}

/// Execute the local and TCP topologies of one spec and return
/// `(local dump bytes, tcp dump bytes, tcp results.json)`.
fn run_pair(tag: &str, cluster: &str, disorder: &str) -> (Vec<u8>, Vec<u8>, Json) {
    let base = tmp(tag);
    let mut dumps = Vec::new();
    for (name, cluster_block) in [("eqv-local", ""), ("eqv-tcp", cluster)] {
        let dump = base.join(format!("{name}.dump"));
        let cfg = base.join(format!("{name}.yaml"));
        std::fs::write(&cfg, config_yaml(name, &dump, cluster_block, disorder)).unwrap();
        run_bin(&cfg, &base.join(format!("{name}-out")));
        dumps.push(std::fs::read(&dump).unwrap_or_else(|e| {
            panic!("{name}: egest dump missing at {}: {e}", dump.display())
        }));
    }
    let results = results_json(&base.join("eqv-tcp-out"), "eqv-tcp");
    let tcp = dumps.pop().unwrap();
    let local = dumps.pop().unwrap();
    let _ = std::fs::remove_dir_all(&base);
    (local, tcp, results)
}

/// The merged distributed results.json must carry the wire counters and
/// conserve the count-bound budget exactly.
fn assert_distributed_results(results: &Json, events: i64) {
    assert_eq!(int(results, &["events", "generated"]), events, "count-bound budget");
    assert_eq!(
        int(results, &["events", "processed"]),
        int(results, &["events", "generated"]),
        "engine must drain everything the broker shipped"
    );
    assert!(int(results, &["events", "emitted"]) > 0, "aggregates must flow");
    assert!(int(results, &["transport", "records"]) >= events, "every record crossed the wire");
    assert!(int(results, &["transport", "frames"]) > 0);
    assert!(int(results, &["transport", "bytes"]) > 0);
    assert_eq!(int(results, &["parallelism"]), 2);
}

#[test]
fn tcp_loopback_matches_in_process_aggregates_byte_for_byte() {
    // The canonical 3-process layout: driver + broker (colocated fleet)
    // + engine over 127.0.0.1.
    let (local, tcp, results) = run_pair("plain", TCP_CLUSTER, "");
    assert!(!local.is_empty(), "in-process run must dump aggregates");
    assert_eq!(
        local, tcp,
        "multi-process TCP aggregates must be byte-identical to in-process"
    );
    assert_distributed_results(&results, 40_000);
}

#[test]
fn disordered_event_time_run_stays_byte_identical_over_tcp() {
    // Same equivalence under the out-of-order workload model: the
    // disorder stream is seeded, so both topologies see the same
    // reordered/backdated events, and the event-time window flushes
    // every pane at finish regardless of arrival interleaving.
    let (local, tcp, results) = run_pair("disorder", TCP_CLUSTER, DISORDER);
    assert!(!local.is_empty());
    assert_eq!(
        local, tcp,
        "disordered event-time aggregates must survive the wire byte for byte"
    );
    assert_distributed_results(&results, 40_000);
}

#[test]
fn external_generator_worker_reproduces_the_colocated_stream() {
    // 4-process layout: a dedicated generator worker stages and ships
    // the stream to the broker instead of a colocated fleet.  A single
    // external generator keeps the configured seed and full rate/count
    // share, so it emits the exact stream the in-process fleet does.
    let (local, tcp, results) = run_pair("extgen", TCP_CLUSTER_EXTERNAL_GEN, "");
    assert!(!local.is_empty());
    assert_eq!(
        local, tcp,
        "an external generator worker must reproduce the colocated stream"
    );
    assert_distributed_results(&results, 40_000);
}
