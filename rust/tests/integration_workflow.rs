//! Workflow + SLURM + CLI integration: the paper's Sec. 3.1 automation
//! path from one master config to archived, validated runs.

use std::path::PathBuf;

use sprobench::config::{expand_experiments, load_file, yaml};
use sprobench::coordinator::simrun::{run_sim, SimModel};
use sprobench::postprocess::validate_results;
use sprobench::slurm::{ClusterSpec, JobState, Scheduler};
use sprobench::workflow::WorkflowManager;

fn tmp(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("sprobench-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

const CAMPAIGN: &str = "
benchmark:
  name: campaign
  mode: sim
  duration: 30s
workload:
  rate: 2M
generators:
  max_instances: 64
engine:
  pipeline: cpu
experiments:
  - name: p2
    engine.parallelism: 2
  - name: p8
    engine.parallelism: 8
  - name: p16
    engine.parallelism: 16
";

#[test]
fn config_file_to_validated_run_dirs() {
    let base = tmp("e2e");
    let cfg_path = base.join("campaign.yaml");
    std::fs::write(&cfg_path, CAMPAIGN).unwrap();

    // File → experiments (the CLI `run` path).
    let exps = load_file(&cfg_path).unwrap();
    assert_eq!(exps.len(), 3);

    let wm = WorkflowManager::new(base.join("runs"));
    let model = SimModel::default();
    let outcomes = wm
        .run_all(&exps, |exp, dir| {
            let (summary, store) = run_sim(&exp.config, &model);
            std::fs::write(
                dir.metrics_dir().join("series.json"),
                store.to_json().to_pretty(),
            )
            .map_err(|e| e.to_string())?;
            let results = summary.to_json();
            let v = validate_results(&results);
            if !v.is_empty() {
                return Err(format!("{v:?}"));
            }
            Ok(results)
        })
        .unwrap();

    assert_eq!(outcomes.len(), 3);
    for o in &outcomes {
        for f in ["config.resolved.json", "job.sbatch", "results.json", "trace.log"] {
            assert!(o.dir.join(f).exists(), "{} missing {f}", o.name);
        }
        assert!(o.dir.join("metrics/series.json").exists());
        // sbatch script references this experiment.
        let sbatch = std::fs::read_to_string(o.dir.join("job.sbatch")).unwrap();
        assert!(sbatch.contains(&format!("--job-name=sprobench-{}", o.name)));
    }
    // Parallelism override took effect and shows in results.
    let p16 = &outcomes[2];
    assert_eq!(
        p16.results.path(&["parallelism"]).unwrap().as_i64(),
        Some(16)
    );
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn chained_batch_campaign_on_barnard_model() {
    let exps = expand_experiments(&yaml::parse(CAMPAIGN).unwrap()).unwrap();
    let mut sched = Scheduler::new(ClusterSpec::default());
    let wm = WorkflowManager::new(tmp("batch"));
    let ids = wm.submit_batch(&exps, &mut sched, true, |e| {
        e.config.bench.duration_micros
    });
    sched.run_to_completion();
    let mut last_end = 0;
    for id in ids {
        let j = sched.job(id).unwrap();
        assert_eq!(j.state, JobState::Completed);
        assert!(j.start_micros.unwrap() >= last_end, "chain violated");
        last_end = j.end_micros.unwrap();
    }
}

#[test]
fn sim_sweep_reproduces_fig7_shape_through_workflow() {
    // The whole loop: experiments → runs → results → shape claim.
    let exps = expand_experiments(&yaml::parse(CAMPAIGN).unwrap()).unwrap();
    let model = SimModel::default();
    let rates: Vec<f64> = exps
        .iter()
        .map(|e| {
            let mut cfg = e.config.clone();
            cfg.workload.rate = 50_000_000; // saturating
            cfg.generators.max_instances = 1024;
            run_sim(&cfg, &model).0.processed_rate
        })
        .collect();
    assert!(
        rates.windows(2).all(|w| w[1] > w[0]),
        "throughput must grow with parallelism: {rates:?}"
    );
    let early = rates[1] / rates[0]; // 8/2
    let late = rates[2] / rates[1]; // 16/8
    assert!(late < early, "no plateau: {rates:?}");
}
