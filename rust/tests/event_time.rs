//! End-to-end event-time semantics: disorder-injected workloads through
//! event-time window chains.
//!
//! The headline guarantee (Karimov et al.'s event-time correctness
//! argument): with a watermark bound covering the stream's real disorder
//! and a `merge_if_open` late policy, a disordered stream produces
//! **byte-identical** window aggregates to the same stream fed in order —
//! and the full wall-mode pipeline surfaces late/dropped counts and
//! watermark lag in `results.json operators[]` and the CLI summary table.

use sprobench::bench::scenarios;
use sprobench::config::{BenchConfig, OpSpec, PipelineSpec};
use sprobench::coordinator::run_wall;
use sprobench::engine::{AggKind, EventBatch, LatePolicy, WindowTime};
use sprobench::pipelines::{Chain, PipelineStep};
use sprobench::postprocess::{operator_stats_table, validate_results};

/// Build the event-time chain under test: window(event) → emit_aggregates.
fn event_chain(watermark: u64, lateness: u64, policy: LatePolicy) -> Chain {
    let mut cfg = BenchConfig::default();
    cfg.engine.use_hlo = false;
    cfg.workload.sensors = 64;
    let spec = PipelineSpec {
        ops: vec![
            OpSpec::Window {
                agg: AggKind::Mean,
                window_micros: 1_000_000,
                slide_micros: 500_000,
                time: WindowTime::Event,
                allowed_lateness_micros: lateness,
                late_policy: policy,
                watermark_micros: watermark,
            },
            OpSpec::EmitAggregates,
        ],
    };
    Chain::compile(&cfg, &spec, "event-chain", None, None, 0).expect("compile event-time chain")
}

/// Feed `(key, val, gen_ts)` events through a chain in batches; returns
/// the emitted `(key, payload)` records plus the chain's final stats.
fn run_stream(
    chain: &mut Chain,
    events: &[(u32, f32, u64)],
) -> (Vec<(u32, Vec<u8>)>, sprobench::pipelines::StepStats) {
    let mut out = Vec::new();
    for (i, chunk) in events.chunks(100).enumerate() {
        let batch = EventBatch {
            ids: chunk.iter().map(|e| e.0).collect(),
            temps: chunk.iter().map(|e| e.1).collect(),
            gen_ts: chunk.iter().map(|e| e.2).collect(),
            append_ts: chunk.iter().map(|e| e.2).collect(),
            payload_bytes: chunk.len() as u64 * 27,
        };
        chain
            .process(i as u64 * 1_000, &[], &batch, &mut out)
            .unwrap();
    }
    chain.finish(events.len() as u64 * 1_000, &mut out).unwrap();
    let records = out
        .into_iter()
        .map(|r| (r.key, r.payload().to_vec()))
        .collect();
    (records, chain.stats())
}

#[test]
fn disordered_stream_reproduces_in_order_aggregates_byte_identically() {
    // 2000 events, 5ms apart, 7 hot keys.
    let ordered: Vec<(u32, f32, u64)> = (0..2_000u64)
        .map(|i| ((i % 7) as u32, (i % 23) as f32 * 1.5 - 10.0, i * 5_000))
        .collect();
    // Bounded disorder: reverse 32-event blocks → max displacement
    // 31 × 5ms = 155ms.  The watermark bound (100ms) is deliberately
    // *below* that, so a slice of the stream genuinely arrives behind the
    // watermark; allowed_lateness (200ms) keeps their windows open, and
    // merge_if_open folds them in.
    let mut disordered = ordered.clone();
    for block in disordered.chunks_mut(32) {
        block.reverse();
    }

    let mut a = event_chain(100_000, 200_000, LatePolicy::MergeIfOpen);
    let (out_ordered, stats_ordered) = run_stream(&mut a, &ordered);
    let mut b = event_chain(100_000, 200_000, LatePolicy::MergeIfOpen);
    let (out_disordered, stats_disordered) = run_stream(&mut b, &disordered);

    assert_eq!(stats_ordered.dropped_events, 0);
    assert_eq!(stats_ordered.late_events, 0, "in-order stream has no lates");
    assert_eq!(stats_disordered.dropped_events, 0, "bounded disorder must not drop");
    assert!(
        stats_disordered.late_events > 0,
        "the disorder exceeds the watermark bound, so merges must happen"
    );
    assert!(!out_ordered.is_empty(), "windows must have emitted");
    assert_eq!(
        out_ordered, out_disordered,
        "event-time aggregates must be independent of arrival order"
    );
}

#[test]
fn drop_policy_diverges_and_accounts_for_losses() {
    let ordered: Vec<(u32, f32, u64)> = (0..2_000u64)
        .map(|i| ((i % 7) as u32, (i % 23) as f32, i * 5_000))
        .collect();
    let mut disordered = ordered.clone();
    for block in disordered.chunks_mut(32) {
        block.reverse();
    }
    // Zero allowed lateness + a tight watermark: the same disorder now
    // loses events, and the accounting must say so.
    let mut a = event_chain(100_000, 0, LatePolicy::Drop);
    let (out_ordered, _) = run_stream(&mut a, &ordered);
    let mut b = event_chain(100_000, 0, LatePolicy::Drop);
    let (out_disordered, stats) = run_stream(&mut b, &disordered);
    assert!(stats.dropped_events > 0, "tight watermark + drop must lose events");
    assert_ne!(
        out_ordered, out_disordered,
        "dropping late records must change the aggregates"
    );
}

#[test]
fn wall_run_surfaces_event_time_metrics_in_results_and_cli_table() {
    // The event_time_disorder preset scaled down to a sub-second smoke;
    // stragglers bumped so late accounting is guaranteed visible.
    let mut cfg = scenarios::event_time_disorder();
    cfg.bench.name = "event-time-e2e".into();
    cfg.bench.duration_micros = 800_000;
    cfg.bench.warmup_micros = 0;
    cfg.workload.rate = 40_000;
    cfg.workload.sensors = 128;
    cfg.workload.disorder.straggler_fraction = 0.05;
    cfg.workload.disorder.straggler_micros = 1_000_000;
    cfg.engine.parallelism = 2;
    cfg.engine.use_hlo = false;
    cfg.engine.batch_size = 256;
    cfg.metrics.sample_interval_micros = 100_000;
    cfg.validate().unwrap();

    let (summary, _store) = run_wall(&cfg, None).unwrap();
    assert_eq!(summary.processed, summary.generated, "engine must drain");
    assert!(summary.emitted > 0, "finish-flush emits pending event-time panes");

    // (b1) results.json operators[]: the window op carries the event-time
    // counters.
    let results = summary.to_json();
    assert!(validate_results(&results).is_empty());
    let ops = results.get("operators").and_then(|v| v.as_arr()).unwrap();
    let window = ops
        .iter()
        .find(|o| o.get("op").and_then(|v| v.as_str()) == Some("window"))
        .expect("window op in results.json operators[]");
    let field = |k: &str| window.get(k).and_then(|v| v.as_i64()).expect(k);
    assert!(
        field("late_events") + field("dropped_events") > 0,
        "5% stragglers beyond the watermark bound must register as late/dropped"
    );
    assert!(field("watermark_lag_us") > 0, "watermark trails processing time");

    // (b2) CLI summary table: same counters, rendered columns.
    let table = operator_stats_table(&summary.operators);
    for needle in ["late", "dropped", "wm_lag_us", "window"] {
        assert!(table.contains(needle), "missing '{needle}' in:\n{table}");
    }
    let (_, wstats) = summary
        .operators
        .iter()
        .find(|(n, _)| n == "window")
        .expect("window op in summary.operators");
    assert!(wstats.watermark_lag_micros > 0);
}
