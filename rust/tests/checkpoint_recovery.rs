//! Crash/restore equivalence suite: an aligned checkpoint taken
//! mid-stream, a kill that throws away everything after it, and a
//! restore into a freshly built pipeline must together produce **byte
//! identical final aggregates** to an unkilled run — across several
//! checkpoint positions, during an open event-time pane, and across a
//! keyed exchange at parallelism 2 and 4 (the `shuffle_equivalence`
//! methodology: canonical multiset equality over sorted
//! `(window end, key, payload)` triples).
//!
//! The state round-trips through real [`CheckpointStore`] files — magic,
//! version, CRC32, temp-then-rename — not through in-memory Json, so the
//! suite also proves the on-disk format carries everything a restore
//! needs.  One wall-mode test drives the threaded engine's full
//! kill-and-restore path and checks `recovery` lands in results.json.
//!
//! Values are multiples of 0.25 in a small range, so pane sums are exact
//! in f32 and aggregation is order-independent: equality tests the
//! snapshot/restore and routing logic, not float-summation luck.

use sprobench::broker::Record;
use sprobench::config::{BenchConfig, OpSpec, PipelineSpec};
use sprobench::coordinator::run_recovery;
use sprobench::engine::{
    AggKind, Checkpoint, CheckpointStore, EventBatch, LatePolicy, TaskPart, WindowTime,
};
use sprobench::pipelines::{LockstepExchange, StepFactory};
use sprobench::postprocess::validate_results;
use sprobench::util::json::Json;

/// One synthetic event: (sensor id, value, generation timestamp).
type Ev = (u32, f32, u64);

/// Canonicalized egestion output: sorted `(window end, key, payload)`.
type Canon = Vec<(u64, u32, Vec<u8>)>;

fn canonical(out: &[Record]) -> Canon {
    let mut v: Vec<_> = out
        .iter()
        .map(|r| (r.gen_ts_micros, r.key, r.payload().to_vec()))
        .collect();
    v.sort();
    v
}

/// Multiset containment: every entry of `sub` appears in `sup` at least
/// as many times (both canonical, i.e. sorted).
fn multiset_contains(sup: &Canon, sub: &Canon) -> bool {
    let mut i = 0;
    for s in sub {
        while i < sup.len() && &sup[i] < s {
            i += 1;
        }
        if i >= sup.len() || &sup[i] != s {
            return false;
        }
        i += 1;
    }
    true
}

fn batch_of(events: &[Ev]) -> EventBatch {
    EventBatch {
        ids: events.iter().map(|e| e.0).collect(),
        temps: events.iter().map(|e| e.1).collect(),
        gen_ts: events.iter().map(|e| e.2).collect(),
        append_ts: events.iter().map(|e| e.2).collect(),
        payload_bytes: events.len() as u64 * 27,
    }
}

fn shard(events: &[Ev], par: usize) -> Vec<Vec<Ev>> {
    let mut shards = vec![Vec::new(); par];
    for (i, ev) in events.iter().enumerate() {
        shards[i % par].push(*ev);
    }
    shards
}

fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("sprobench-ckptrec-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Round-trip one snapshot through an on-disk checkpoint file and hand
/// back the restored state plus the epoch it claims.
fn through_store(tag: &str, epoch: u64, events_in: u64, state: Json) -> (u64, Json) {
    let dir = ckpt_dir(tag);
    let store = CheckpointStore::new(&dir, 3);
    store
        .write(&Checkpoint {
            epoch,
            tasks: vec![TaskPart {
                offsets: vec![(0, events_in)],
                events_in,
                parse_failures: 0,
                state,
            }],
        })
        .expect("checkpoint write");
    let scan = store.latest();
    assert!(scan.skipped.is_empty(), "clean dir must scan clean: {:?}", scan.skipped);
    let ckpt = scan.checkpoint.expect("just-written checkpoint is latest");
    let _ = std::fs::remove_dir_all(&dir);
    (ckpt.epoch, ckpt.tasks[0].state.clone())
}

// --- flat chain --------------------------------------------------------------

/// Deterministic batches for the flat-chain tests: 12 feeds of 250
/// events, one per 100ms, with event timestamps spread over the first
/// 75ms of each feed interval (so event-time panes straddle feeds).
fn flat_batches() -> Vec<Vec<Ev>> {
    (0..12u64)
        .map(|b| {
            (0..250u64)
                .map(|i| {
                    let n = b * 250 + i;
                    (
                        ((n * 7) % 64) as u32,
                        ((n % 40) as f32) * 0.25,
                        100_000 + b * 100_000 + i * 300,
                    )
                })
                .collect()
        })
        .collect()
}

fn flat_now(b: usize) -> u64 {
    200_000 + b as u64 * 100_000
}

/// Run the flat windowed chain over `batches`, optionally killing after
/// a snapshot at batch index `kill_at` and restoring from an on-disk
/// checkpoint file.  Returns the canonical output an observer that
/// deduplicates the kill-window sees: pre-snapshot emissions + the
/// restored run's.
fn run_flat(
    spec: &PipelineSpec,
    batches: &[Vec<Ev>],
    kill_at: Option<usize>,
    tag: &str,
) -> Canon {
    let mut cfg = BenchConfig::default();
    cfg.engine.use_hlo = false;
    cfg.engine.parallelism = 1;
    cfg.workload.sensors = 64;
    cfg.engine.pipeline_spec = Some(spec.clone());
    let factory = StepFactory::new(&cfg, None);
    let end = flat_now(batches.len()) + 2_500_000;

    let mut step = factory.create(0).expect("compile flat chain");
    let mut out = Vec::new();
    let Some(k) = kill_at else {
        for (b, evs) in batches.iter().enumerate() {
            step.process(flat_now(b), &[], &batch_of(evs), &mut out).unwrap();
        }
        step.finish(end, &mut out).unwrap();
        return canonical(&out);
    };

    // Doomed incarnation: feed to the snapshot point, checkpoint, then
    // keep working a little — everything after the snapshot dies with it.
    for (b, evs) in batches.iter().enumerate().take(k) {
        step.process(flat_now(b), &[], &batch_of(evs), &mut out).unwrap();
    }
    let snap = step.snapshot().expect("flat chain snapshots");
    let n_snap = out.len();
    let fed: u64 = batches.iter().take(k).map(|b| b.len() as u64).sum();
    for (b, evs) in batches.iter().enumerate().skip(k).take(2) {
        step.process(flat_now(b), &[], &batch_of(evs), &mut out).unwrap();
    }
    drop(step); // the kill: no finish, no flush

    let (epoch, state) = through_store(tag, k as u64, fed, snap);
    assert_eq!(epoch, k as u64);
    let mut restored = factory.create(0).expect("recompile flat chain");
    restored.restore(&state).expect("restore flat chain");
    let mut out2 = Vec::new();
    for (b, evs) in batches.iter().enumerate().skip(k) {
        restored.process(flat_now(b), &[], &batch_of(evs), &mut out2).unwrap();
    }
    restored.finish(end, &mut out2).unwrap();

    // At-least-once: whatever the doomed incarnation emitted after the
    // snapshot is re-emitted (as duplicates) by the restored run.
    assert!(
        multiset_contains(&canonical(&out2), &canonical(&out[n_snap..])),
        "{tag}: post-snapshot emissions lost by the restore"
    );
    let mut merged: Vec<Record> = out[..n_snap].to_vec();
    merged.extend(out2);
    canonical(&merged)
}

#[test]
fn flat_chain_restore_equivalence_at_several_checkpoint_positions() {
    let spec = PipelineSpec {
        ops: vec![
            OpSpec::window(AggKind::Sum, 1_000_000, 500_000),
            OpSpec::EmitAggregates,
        ],
    };
    let batches = flat_batches();
    let baseline = run_flat(&spec, &batches, None, "flat-base");
    assert!(!baseline.is_empty(), "windows must emit");
    // Early, mid-run, and late checkpoints; every kill+restore converges
    // to the same final aggregates.
    for k in [2usize, 5, 9] {
        let got = run_flat(&spec, &batches, Some(k), &format!("flat-k{k}"));
        assert_eq!(
            got, baseline,
            "kill after batch {k} must be byte-identical to the unkilled run"
        );
    }
}

#[test]
fn event_time_flat_chain_restores_during_an_open_pane_under_disorder() {
    // Event-time panes stay open across the snapshot point (1s windows,
    // 100ms feeds), and the stream is block-reversed (`disorder`-style
    // bounded displacement): the snapshot must carry the open pane
    // contents AND the watermark tracker, or replayed rows double-count
    // and pane boundaries shift.
    let spec = PipelineSpec {
        ops: vec![
            OpSpec::Window {
                agg: AggKind::Mean,
                window_micros: 1_000_000,
                slide_micros: 500_000,
                time: WindowTime::Event,
                allowed_lateness_micros: 2_000_000,
                late_policy: LatePolicy::MergeIfOpen,
                watermark_micros: 500_000,
            },
            OpSpec::EmitAggregates,
        ],
    };
    // Block-reverse each feed (≤ 31 × 300µs = 9.3ms displacement, far
    // inside the allowed lateness): the same events, out of order.
    let ordered = flat_batches();
    let mut disordered = ordered.clone();
    for b in &mut disordered {
        for block in b.chunks_mut(32) {
            block.reverse();
        }
    }
    let baseline = run_flat(&spec, &ordered, None, "evt-base");
    assert!(!baseline.is_empty());
    for k in [3usize, 7] {
        let got = run_flat(&spec, &disordered, Some(k), &format!("evt-k{k}"));
        assert_eq!(
            got, baseline,
            "disordered event-time kill after batch {k} must match the \
             ordered unkilled run"
        );
    }
}

// --- keyed exchange ----------------------------------------------------------

fn keyed_spec() -> PipelineSpec {
    PipelineSpec {
        ops: vec![
            OpSpec::KeyBy {
                modulo: 16,
                parallelism: 0,
            },
            OpSpec::Window {
                agg: AggKind::Mean,
                window_micros: 1_000_000,
                slide_micros: 500_000,
                time: WindowTime::Event,
                allowed_lateness_micros: 2_000_000,
                late_policy: LatePolicy::MergeIfOpen,
                watermark_micros: 500_000,
            },
            OpSpec::EmitAggregates,
        ],
    }
}

/// A disordered keyed event-time stream: 4 000 events over 8 s,
/// block-reversed in chunks of 32 (≤ 62ms displacement).
fn keyed_stream() -> Vec<Ev> {
    let mut evs: Vec<Ev> = (0..4_000u64)
        .map(|i| (((i * 7) % 64) as u32, ((i % 40) as f32) * 0.25, 100_000 + i * 2_000))
        .collect();
    for block in evs.chunks_mut(32) {
        block.reverse();
    }
    evs
}

/// Drive the staged keyed chain on the lockstep harness in 20 feed
/// rounds, optionally snapshotting after round `kill_at` (through a real
/// checkpoint file), killing, and restoring into a recompiled pipeline.
fn run_keyed(par: u32, kill_at: Option<usize>, tag: &str) -> Canon {
    let mut cfg = BenchConfig::default();
    cfg.engine.use_hlo = false;
    cfg.engine.parallelism = par;
    cfg.workload.sensors = 64;
    cfg.engine.pipeline_spec = Some(keyed_spec());
    let stream = keyed_stream();
    let chunks: Vec<&[Ev]> = stream.chunks(200).collect();
    let now_of = |chunk: &[Ev]| chunk.iter().map(|e| e.2).max().unwrap() + 10_000;
    let end = stream.iter().map(|e| e.2).max().unwrap() + 4_000_000;

    let mut lx = LockstepExchange::compile(&cfg).unwrap().expect("keyed spec stages");
    let p = lx.parallelism() as usize;
    let mut out = Vec::new();
    let feed = |lx: &mut LockstepExchange, chunk: &[Ev], out: &mut Vec<Record>| {
        let batches: Vec<EventBatch> = shard(chunk, p).iter().map(|s| batch_of(s)).collect();
        lx.process_round(now_of(chunk), &batches, out).unwrap();
    };

    let Some(k) = kill_at else {
        for (i, chunk) in chunks.iter().enumerate() {
            feed(&mut lx, chunk, &mut out);
            if i + 1 == 8 {
                // Mirror the killed runs' quiesce rounds so the round
                // schedule is identical in both schedules.
                for _ in 0..4 {
                    lx.idle_round(now_of(chunk), &mut out).unwrap();
                }
            }
        }
        for _ in 0..4 {
            lx.idle_round(end, &mut out).unwrap();
        }
        lx.finish(end, &mut out).unwrap();
        return canonical(&out);
    };

    for chunk in chunks.iter().take(k) {
        feed(&mut lx, chunk, &mut out);
    }
    // Aligned snapshot needs a quiesced fabric: idle rounds drain it.
    let quiesce_now = now_of(chunks[k - 1]);
    for _ in 0..4 {
        lx.idle_round(quiesce_now, &mut out).unwrap();
    }
    let snap = lx.snapshot().expect("quiesced staged pipeline snapshots");
    let n_snap = out.len();
    let fed = (k * 200) as u64;
    for chunk in chunks.iter().skip(k).take(2) {
        feed(&mut lx, chunk, &mut out);
    }
    drop(lx); // the kill, mid-open-pane and mid-exchange

    let (_, state) = through_store(tag, k as u64, fed, snap);
    let mut lx2 = LockstepExchange::compile(&cfg).unwrap().expect("recompile");
    lx2.restore(&state).expect("restore staged pipeline");
    let mut out2 = Vec::new();
    for chunk in chunks.iter().skip(k) {
        feed(&mut lx2, chunk, &mut out2);
    }
    for _ in 0..4 {
        lx2.idle_round(end, &mut out2).unwrap();
    }
    lx2.finish(end, &mut out2).unwrap();
    assert!(
        multiset_contains(&canonical(&out2), &canonical(&out[n_snap..])),
        "{tag}: post-snapshot emissions lost by the restore"
    );
    let mut merged: Vec<Record> = out[..n_snap].to_vec();
    merged.extend(out2);
    canonical(&merged)
}

#[test]
fn keyed_exchange_restore_equivalence_at_parallelism_2_and_4() {
    // The unkilled parallelism-1 run is the ground truth; kills at
    // parallelism 2 and 4 cross the keyed exchange (routing state, gate
    // frontiers, per-instance panes) and must still converge to it.
    let baseline = run_keyed(1, None, "keyed-base");
    assert!(!baseline.is_empty(), "keyed windows must emit");
    for par in [2u32, 4] {
        let unkilled = run_keyed(par, None, &format!("keyed-p{par}-clean"));
        assert_eq!(
            unkilled, baseline,
            "par {par}: unkilled run must already be parallelism-invariant"
        );
        let killed = run_keyed(par, Some(8), &format!("keyed-p{par}-kill"));
        assert_eq!(
            killed, baseline,
            "par {par}: kill+restore across the exchange must be byte-identical"
        );
    }
}

// --- wall-mode end to end ----------------------------------------------------

#[test]
fn wall_mode_kill_and_restore_reports_recovery_in_results_json() {
    // The real threaded engine: checkpoints every 150ms, a watchdog kills
    // the fleet 500ms in, the driver restores from the latest checkpoint
    // file and replays.  Exactly-once accounting must hold end to end and
    // results.json must carry a consistent, validated recovery block.
    let mut cfg = BenchConfig::default();
    cfg.bench.name = "ckpt-e2e".into();
    cfg.bench.duration_micros = 1_500_000;
    cfg.bench.warmup_micros = 0;
    cfg.workload.rate = 60_000;
    cfg.workload.sensors = 128;
    cfg.engine.parallelism = 2;
    cfg.engine.use_hlo = false;
    cfg.engine.batch_size = 256;
    cfg.metrics.sample_interval_micros = 100_000;
    cfg.checkpoint.interval_micros = 150_000;
    cfg.checkpoint.dir = ckpt_dir("wall-e2e").to_string_lossy().into_owned();
    cfg.fault.kill_task = 1;
    cfg.fault.kill_after_micros = 500_000;
    cfg.validate().expect("kill-and-restore config must validate");

    let (summary, _store) = run_recovery(&cfg, None).unwrap();
    let _ = std::fs::remove_dir_all(&cfg.checkpoint.dir);

    let rec = summary.recovery.expect("fault run must report recovery");
    assert!(rec.recovery_time_micros > 0, "kill→ready must take time");
    assert!(rec.replayed_records > 0, "kill mid-epoch must force replay");
    assert!(!rec.cold_start, "a committed checkpoint must be restored");
    assert!(rec.checkpoints > 0 && rec.checkpoint_bytes > 0);
    assert_eq!(summary.processed, summary.generated, "exactly-once accounting");
    assert!(summary.emitted >= summary.processed, "at-least-once egestion");

    let j = summary.to_json();
    let f = |k: &str| j.path(&["recovery", k]).and_then(|v| v.as_i64()).expect(k);
    assert!(f("recovery_time_us") > 0);
    assert!(f("replayed_records") > 0);
    assert!(f("checkpoints") > 0);
    assert_eq!(
        j.path(&["recovery", "cold_start"]).and_then(|v| v.as_bool()),
        Some(false)
    );
    let violations = validate_results(&j);
    assert!(violations.is_empty(), "{violations:?}");
}
