//! Full-stack integration: generator fleet → broker → engine → broker,
//! across pipelines × frameworks, with the HLO compute path whenever the
//! artifacts are built (the default for `make test`).

use sprobench::bench::scenarios;
use sprobench::config::{Framework, PipelineKind};
use sprobench::coordinator::run_wall;
use sprobench::metrics::MeasurementPoint;
use sprobench::postprocess::validate_results;
use sprobench::runtime::RuntimeFactory;

fn rtf() -> Option<RuntimeFactory> {
    let f = RuntimeFactory::default_dir();
    f.available().then_some(f)
}

fn quick(pipeline: PipelineKind, framework: Framework, use_hlo: bool) -> sprobench::config::BenchConfig {
    let mut cfg = scenarios::wall_base("itest");
    cfg.bench.duration_micros = 800_000;
    cfg.bench.warmup_micros = 0;
    cfg.workload.rate = 60_000;
    cfg.engine.pipeline = pipeline;
    cfg.engine.framework = framework;
    cfg.engine.parallelism = 2;
    cfg.engine.use_hlo = use_hlo;
    cfg.engine.window_micros = 400_000;
    cfg.engine.slide_micros = 200_000;
    cfg
}

#[test]
fn every_pipeline_validates_with_hlo_compute() {
    let Some(f) = rtf() else {
        panic!("artifacts not built — run `make artifacts` before `cargo test`");
    };
    for pipeline in [
        PipelineKind::PassThrough,
        PipelineKind::CpuIntensive,
        PipelineKind::MemIntensive,
        PipelineKind::Fused,
    ] {
        let cfg = quick(pipeline, Framework::Flink, true);
        let (summary, _) = run_wall(&cfg, Some(f.clone())).unwrap_or_else(|e| {
            panic!("{} failed: {e}", pipeline.name());
        });
        assert_eq!(
            summary.processed, summary.generated,
            "{}: engine did not drain",
            pipeline.name()
        );
        let violations = validate_results(&summary.to_json());
        assert!(violations.is_empty(), "{}: {violations:?}", pipeline.name());
    }
}

#[test]
fn hlo_and_native_agree_on_alert_counts() {
    let Some(f) = rtf() else { return };
    // Same seed → same events → alert counts must match across compute
    // backends (the cross-layer correctness check).
    let run = |use_hlo: bool| {
        let cfg = quick(PipelineKind::CpuIntensive, Framework::Flink, use_hlo);
        let (s, _) = run_wall(&cfg, use_hlo.then(|| f.clone())).expect("run");
        s
    };
    let native = run(false);
    let hlo = run(true);
    // Event counts depend on timing; compare alert *fractions*.
    let nf = native.generated as f64;
    let hf = hlo.generated as f64;
    assert!(nf > 0.0 && hf > 0.0);
    // (alerts are not in RunSummary directly; emitted==processed suffices
    // for conservation, and pipeline-level agreement is covered by unit
    // tests — here we assert both backends complete and validate.)
    assert!(validate_results(&native.to_json()).is_empty());
    assert!(validate_results(&hlo.to_json()).is_empty());
}

#[test]
fn frameworks_differ_in_latency_not_delivery() {
    let mut p50s = Vec::new();
    for fw in [Framework::Flink, Framework::Spark, Framework::KStreams] {
        let mut cfg = quick(PipelineKind::CpuIntensive, fw, false);
        cfg.engine.microbatch_micros = 100_000;
        let (s, _) = run_wall(&cfg, None).expect("run");
        assert_eq!(s.processed, s.generated, "{fw:?} lost events");
        assert_eq!(s.emitted, s.processed, "{fw:?} lost outputs");
        p50s.push((
            fw,
            s.latency_at(MeasurementPoint::EndToEnd).expect("e2e").p50,
        ));
    }
    // Spark (micro-batch) must have the highest p50 of the three.
    let spark = p50s.iter().find(|(f, _)| *f == Framework::Spark).expect("spark ran").1;
    let flink = p50s.iter().find(|(f, _)| *f == Framework::Flink).expect("flink ran").1;
    assert!(
        spark > flink,
        "micro-batching should cost latency: {p50s:?}"
    );
}

#[test]
fn burst_pattern_flows_through_the_stack() {
    let mut cfg = quick(PipelineKind::PassThrough, Framework::Flink, false);
    cfg.workload.pattern = sprobench::config::Pattern::Burst;
    cfg.workload.burst.interval_micros = 200_000;
    cfg.workload.burst.burst_rate = 400_000;
    let (s, _) = run_wall(&cfg, None).expect("run");
    assert!(s.generated > 0);
    assert_eq!(s.emitted, s.processed);
}

#[test]
fn random_pattern_flows_through_the_stack() {
    let mut cfg = quick(PipelineKind::PassThrough, Framework::Flink, false);
    cfg.workload.pattern = sprobench::config::Pattern::Random;
    cfg.workload.random.min_rate = 20_000;
    cfg.workload.random.max_rate = 100_000;
    let (s, _) = run_wall(&cfg, None).expect("run");
    assert!(s.generated > 0);
    assert_eq!(s.emitted, s.processed);
}

#[test]
fn key_skew_does_not_break_conservation() {
    let mut cfg = quick(PipelineKind::MemIntensive, Framework::Flink, false);
    cfg.workload.key_skew = 1.5;
    let (s, _) = run_wall(&cfg, None).expect("run");
    assert_eq!(s.processed, s.generated);
    assert!(s.emitted > 0, "window aggregates must be emitted");
}

#[test]
fn larger_events_respect_configured_size() {
    let mut cfg = quick(PipelineKind::PassThrough, Framework::Flink, false);
    cfg.workload.event_bytes = 256;
    let (s, _) = run_wall(&cfg, None).expect("run");
    let implied = s.offered_bytes_rate / s.offered_rate.max(1.0);
    assert!(
        (implied - 256.0).abs() < 1.0,
        "event size on the wire {implied} != 256"
    );
}
