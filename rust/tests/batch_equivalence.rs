//! Equivalence of the batched and per-record data planes.
//!
//! The batch-first refactor must be invisible to consumers: the same
//! logical event stream pushed through `Broker::produce` (per-record
//! compatibility path) and through `Broker::produce_batches`
//! (`PartitionedBatchBuilder`, the hot path) has to deliver identical
//! per-partition sequences; and under concurrent batched producers and
//! consumers every event must arrive exactly once with per-key order
//! preserved — the broker-level extension of the channel's
//! `mpmc_all_items_delivered_once` invariant.

use std::sync::{Arc, Mutex};

use sprobench::broker::{Broker, BrokerConfig, PartitionedBatchBuilder, Record};
use sprobench::util::clock;

fn payload(producer: u32, seq: u64) -> Vec<u8> {
    let mut p = Vec::with_capacity(12);
    p.extend_from_slice(&producer.to_le_bytes());
    p.extend_from_slice(&seq.to_le_bytes());
    p
}

fn decode(p: &[u8]) -> (u32, u64) {
    (
        u32::from_le_bytes(p[..4].try_into().unwrap()),
        u64::from_le_bytes(p[4..12].try_into().unwrap()),
    )
}

#[test]
fn batched_and_per_record_planes_deliver_identical_streams() {
    const EVENTS: u32 = 5_000;
    let broker = Broker::new(
        BrokerConfig {
            partitions: 4,
            queue_depth: 1 << 16,
            ..Default::default()
        },
        clock::wall(),
    );
    let per_record = broker.create_topic("per-record");
    let batched = broker.create_topic("batched");

    // Same logical stream into both topics.
    for i in 0..EVENTS {
        let key = i % 257;
        broker
            .produce(&per_record, Record::new(key, payload(key, i as u64), i as u64))
            .unwrap();
    }
    let mut pb = PartitionedBatchBuilder::new(batched.partition_count());
    for i in 0..EVENTS {
        let key = i % 257;
        pb.push(
            batched.partition_for_key(key),
            key,
            &payload(key, i as u64),
            i as u64,
        );
        // Several mid-stream flushes so fetches cross batch boundaries.
        if i % 700 == 699 {
            let parts = std::mem::replace(
                &mut pb,
                PartitionedBatchBuilder::new(batched.partition_count()),
            );
            broker.produce_batches(&batched, parts.finish()).unwrap();
        }
    }
    broker.produce_batches(&batched, pb.finish()).unwrap();
    broker.shutdown();

    // Drain each topic per partition and compare the full sequences.
    let drain = |name: &str| -> Vec<Vec<(u32, u32, u64, u64)>> {
        let g = broker.subscribe(name, &format!("drain-{name}"), 1);
        let topic = broker.topic(name).unwrap();
        let mut by_partition: Vec<Vec<(u32, u32, u64, u64)>> =
            (0..topic.partition_count()).map(|_| Vec::new()).collect();
        loop {
            match g.poll(0, 333) {
                Ok(Some(b)) => {
                    for r in b.iter() {
                        let (prod, seq) = decode(r.payload);
                        by_partition[b.partition as usize]
                            .push((r.key, prod, seq, r.gen_ts_micros));
                    }
                    g.commit(b.partition, b.next_offset);
                }
                Ok(None) => continue,
                Err(_) => return by_partition,
            }
        }
    };
    let a = drain("per-record");
    let b = drain("batched");
    assert_eq!(
        a.iter().map(|p| p.len()).sum::<usize>(),
        EVENTS as usize,
        "per-record plane lost or duplicated events"
    );
    assert_eq!(a, b, "planes disagree on partition content or order");
}

#[test]
fn concurrent_batched_producers_deliver_exactly_once_in_key_order() {
    const PRODUCERS: u32 = 4;
    const PER_PRODUCER: u64 = 20_000;
    const CHUNK: u64 = 512;
    const MEMBERS: u32 = 3;

    let broker = Broker::new(
        BrokerConfig {
            partitions: 8,
            queue_depth: 4096,
            ..Default::default()
        },
        clock::wall(),
    );
    let topic = broker.create_topic("equiv");
    let group = broker.subscribe("equiv", "workers", MEMBERS);

    // Each member's observations, in the order it saw them.  A key lives
    // on one partition, and a partition is owned by one member, so
    // per-key order is checkable per member.
    let seen: Arc<Vec<Mutex<Vec<(u32, u32, u64)>>>> =
        Arc::new((0..MEMBERS).map(|_| Mutex::new(Vec::new())).collect());
    let consumers: Vec<_> = (0..MEMBERS)
        .map(|m| {
            let g = group.clone();
            let seen = seen.clone();
            std::thread::spawn(move || loop {
                match g.poll(m, 256) {
                    Ok(Some(b)) => {
                        let mut mine = seen[m as usize].lock().unwrap();
                        for r in b.iter() {
                            let (prod, seq) = decode(r.payload);
                            mine.push((r.key, prod, seq));
                        }
                        drop(mine);
                        g.commit(b.partition, b.next_offset);
                    }
                    Ok(None) => std::thread::yield_now(),
                    Err(_) => return,
                }
            })
        })
        .collect();

    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let broker = broker.clone();
            let topic = topic.clone();
            std::thread::spawn(move || {
                let mut seq = 0u64;
                while seq < PER_PRODUCER {
                    let mut pb = PartitionedBatchBuilder::new(topic.partition_count());
                    for _ in 0..CHUNK.min(PER_PRODUCER - seq) {
                        // Keys are single-writer (derived from the
                        // producer id), so per-key order must hold.
                        let key = p * 8 + (seq % 8) as u32;
                        pb.push(
                            topic.partition_for_key(key),
                            key,
                            &payload(p, seq),
                            seq,
                        );
                        seq += 1;
                    }
                    broker.produce_batches(&topic, pb.finish()).unwrap();
                }
            })
        })
        .collect();
    for p in producers {
        p.join().unwrap();
    }
    broker.shutdown();
    for c in consumers {
        c.join().unwrap();
    }

    let mut all: Vec<(u32, u32, u64)> = Vec::new();
    let mut per_key_last: std::collections::BTreeMap<u32, u64> = Default::default();
    for m in seen.iter() {
        for &(key, prod, seq) in m.lock().unwrap().iter() {
            if let Some(&last) = per_key_last.get(&key) {
                assert!(
                    seq > last,
                    "key {key}: seq {seq} observed after {last} — order violated"
                );
            }
            per_key_last.insert(key, seq);
            all.push((key, prod, seq));
        }
    }
    assert_eq!(
        all.len(),
        (PRODUCERS as u64 * PER_PRODUCER) as usize,
        "event count mismatch"
    );
    all.sort_unstable();
    let before = all.len();
    all.dedup();
    assert_eq!(all.len(), before, "duplicate delivery");
    assert_eq!(broker.stats().backlog, 0, "commits should reclaim the log");
}
