//! Keyed-exchange equivalence suite: with the exchange on, keyed results
//! are **invariant under `engine.parallelism`** — byte-identical at 1, 2
//! and 4 task instances, including under out-of-order (`disorder`-style)
//! input — and the pre-exchange task-local behaviour (per-key aggregates
//! silently changing with parallelism) is pinned as a regression behind
//! the explicit `exchange: none` opt-out.
//!
//! The staged pipelines run on the deterministic lockstep harness
//! ([`LockstepExchange`]); one wall-mode test drives the real threaded
//! engine end to end and checks the exchange surfaces in results.json.
//!
//! Values are multiples of 0.25 in a small range, so every pane sum is
//! exactly representable in f32 and aggregation is order-independent —
//! the byte-equality below tests routing/watermark/gating logic, not
//! float-summation luck.

use sprobench::broker::Record;
use sprobench::config::{BenchConfig, ExchangeMode, OpSpec, PipelineSpec};
use sprobench::coordinator::run_wall;
use sprobench::engine::{AggKind, EventBatch, LatePolicy, WindowTime};
use sprobench::pipelines::{LockstepExchange, PipelineStep, StepFactory, StepStats};
use sprobench::postprocess::validate_results;

/// One synthetic event: (sensor id, value, generation timestamp).
type Ev = (u32, f32, u64);

/// Canonicalized egestion output: sorted `(window end, key, payload)`.
type Canon = Vec<(u64, u32, Vec<u8>)>;

fn base_cfg(parallelism: u32) -> BenchConfig {
    let mut cfg = BenchConfig::default();
    cfg.engine.use_hlo = false;
    cfg.engine.parallelism = parallelism;
    cfg.workload.sensors = 64;
    cfg
}

fn keyed_window_spec() -> PipelineSpec {
    PipelineSpec {
        ops: vec![
            OpSpec::KeyBy {
                modulo: 16,
                parallelism: 0,
            },
            OpSpec::window(AggKind::Mean, 1_000_000, 500_000),
            OpSpec::EmitAggregates,
        ],
    }
}

fn keyed_topk_spec() -> PipelineSpec {
    PipelineSpec {
        ops: vec![
            OpSpec::KeyBy {
                modulo: 16,
                parallelism: 0,
            },
            OpSpec::window(AggKind::Sum, 1_000_000, 500_000),
            OpSpec::TopK {
                k: 3,
                parallelism: 0,
            },
            OpSpec::EmitAggregates,
        ],
    }
}

/// Split a global stream across `par` source tasks (what distinct broker
/// partition assignments do to the real engine).
fn shard(events: &[Ev], par: usize) -> Vec<Vec<Ev>> {
    let mut shards = vec![Vec::new(); par];
    for (i, ev) in events.iter().enumerate() {
        shards[i % par].push(*ev);
    }
    shards
}

fn batch_of(events: &[Ev]) -> EventBatch {
    EventBatch {
        ids: events.iter().map(|e| e.0).collect(),
        temps: events.iter().map(|e| e.1).collect(),
        gen_ts: events.iter().map(|e| e.2).collect(),
        append_ts: events.iter().map(|e| e.2).collect(),
        payload_bytes: events.len() as u64 * 27,
    }
}

/// Canonicalize egestion output: parallel instances emit in an
/// instance-interleaved order, so equality is over the sorted
/// `(window end, key, payload bytes)` multiset.
fn canonical(out: Vec<Record>) -> Canon {
    let mut v: Vec<_> = out
        .into_iter()
        .map(|r| (r.gen_ts_micros, r.key, r.payload().to_vec()))
        .collect();
    v.sort();
    v
}

/// Drive a staged chain over feed phases `(now, events)` in lockstep
/// rounds (a few idle rounds after each phase drain the fabric), then
/// finish at `end_now`.  Returns canonical outputs, rows routed, and the
/// merged per-operator stats.
fn run_staged(
    cfg: &BenchConfig,
    phases: &[(u64, &[Ev])],
    end_now: u64,
) -> (Canon, u64, Vec<(String, StepStats)>) {
    let mut lx = LockstepExchange::compile(cfg)
        .expect("compile staged chain")
        .expect("spec must stage");
    let par = lx.parallelism() as usize;
    let mut out = Vec::new();
    for &(now, events) in phases {
        let batches: Vec<EventBatch> = shard(events, par).iter().map(|s| batch_of(s)).collect();
        lx.process_round(now, &batches, &mut out).unwrap();
        for _ in 0..4 {
            lx.idle_round(now, &mut out).unwrap();
        }
    }
    lx.finish(end_now, &mut out).unwrap();
    let routed = lx.routed_records();
    let stats = lx.operator_stats();
    (canonical(out), routed, stats)
}

/// Deterministic event set: keys sweep the sensor space, values are
/// multiples of 0.25 (exact f32 sums).
fn events(n: u64, ts: u64) -> Vec<Ev> {
    (0..n)
        .map(|i| (((i * 7) % 64) as u32, ((i % 40) as f32) * 0.25, ts))
        .collect()
}

#[test]
fn keyed_window_results_byte_identical_across_parallelism() {
    let evs = events(3_000, 100_000);
    let phases: &[(u64, &[Ev])] = &[(100_000, &evs)];
    let mut results = Vec::new();
    for par in [1u32, 2, 4] {
        let mut cfg = base_cfg(par);
        cfg.engine.pipeline_spec = Some(keyed_window_spec());
        let (out, routed, _) = run_staged(&cfg, phases, 650_000);
        assert!(!out.is_empty(), "par {par}: windows must emit");
        assert_eq!(routed, 3_000, "par {par}: every row crosses the keyby boundary");
        results.push((par, out));
    }
    let (_, baseline) = &results[0];
    for (par, out) in &results[1..] {
        assert_eq!(
            out, baseline,
            "parallelism {par} must be byte-identical to parallelism 1"
        );
    }
    // Sanity: 16 derived key groups, each exactly once per window.
    let first_window = baseline.iter().filter(|(w, ..)| *w == 500_000).count();
    assert_eq!(first_window, 16, "one aggregate per derived key");
}

#[test]
fn keyed_topk_results_byte_identical_across_parallelism() {
    // Two window-fulls so top-k selects per window end, with a global
    // (parallelism-1) top-k stage fed by the gated exchange.
    let first = events(2_000, 100_000);
    let second: Vec<Ev> = (0..2_000u64)
        .map(|i| (((i * 11) % 64) as u32, ((i % 23) as f32) * 0.5, 700_000))
        .collect();
    // The empty 600ms phase is a barrier: every window instance advances
    // past the 500ms boundary (emitting it) before any second-window row
    // arrives, so pane membership is identical at every parallelism.
    let phases: &[(u64, &[Ev])] = &[(100_000, &first), (600_000, &[]), (700_000, &second)];
    let mut results = Vec::new();
    for par in [1u32, 2, 4] {
        let mut cfg = base_cfg(par);
        cfg.engine.pipeline_spec = Some(keyed_topk_spec());
        let (out, routed, stats) = run_staged(&cfg, phases, 1_300_000);
        assert!(!out.is_empty(), "par {par}: top-k must emit");
        assert!(routed >= 4_000, "par {par}: events + aggregates cross boundaries");
        // Every window end emits at most k = 3 aggregates.
        for w in [500_000u64, 1_000_000, 1_500_000] {
            let per = out.iter().filter(|(e, ..)| *e == w).count();
            assert!(per <= 3, "par {par}: window {w} emitted {per} > k");
        }
        // The staged op list carries one exchange entry per boundary.
        let names: Vec<&str> = stats.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec!["keyby", "exchange", "window", "exchange", "topk", "emit_aggregates"]
        );
        results.push((par, out));
    }
    let (_, baseline) = &results[0];
    for (par, out) in &results[1..] {
        assert_eq!(
            out, baseline,
            "parallelism {par} top-k must be byte-identical to parallelism 1"
        );
    }
}

#[test]
fn event_time_keyed_window_equivalent_under_disorder_and_parallelism() {
    // An out-of-order stream (workload.disorder's reorder-buffer class:
    // block-reversed emission) through an event-time keyed window.  The
    // exchange must propagate watermarks as the min over upstreams, so
    // results stay byte-identical to the ordered stream at parallelism 1.
    let spec = PipelineSpec {
        ops: vec![
            OpSpec::KeyBy {
                modulo: 16,
                parallelism: 0,
            },
            OpSpec::Window {
                agg: AggKind::Mean,
                window_micros: 1_000_000,
                slide_micros: 500_000,
                time: WindowTime::Event,
                allowed_lateness_micros: 2_000_000,
                late_policy: LatePolicy::MergeIfOpen,
                watermark_micros: 500_000,
            },
            OpSpec::EmitAggregates,
        ],
    };
    let ordered: Vec<Ev> = (0..4_000u64)
        .map(|i| (((i * 7) % 64) as u32, ((i % 40) as f32) * 0.25, 100_000 + i * 2_000))
        .collect();
    let mut disordered = ordered.clone();
    for block in disordered.chunks_mut(32) {
        block.reverse(); // ≤ 31 × 2ms = 62ms displacement, well in bound
    }
    let run = |par: u32, stream: &[Ev]| {
        let mut cfg = base_cfg(par);
        cfg.engine.pipeline_spec = Some(spec.clone());
        // Feed in bounded rounds; `now` tracks the stream frontier.
        let mut lx = LockstepExchange::compile(&cfg).unwrap().unwrap();
        let p = lx.parallelism() as usize;
        let mut out = Vec::new();
        for chunk in stream.chunks(128) {
            let now = chunk.iter().map(|e| e.2).max().unwrap() + 10_000;
            let batches: Vec<EventBatch> =
                shard(chunk, p).iter().map(|s| batch_of(s)).collect();
            lx.process_round(now, &batches, &mut out).unwrap();
        }
        let end = stream.iter().map(|e| e.2).max().unwrap() + 4_000_000;
        for _ in 0..4 {
            lx.idle_round(end, &mut out).unwrap();
        }
        lx.finish(end, &mut out).unwrap();
        let stats = lx.operator_stats();
        let window = stats
            .iter()
            .find(|(n, _)| n == "window")
            .expect("window op")
            .1;
        assert_eq!(window.dropped_events, 0, "bounded disorder must not drop");
        (canonical(out), window)
    };
    let (baseline, _) = run(1, &ordered);
    assert!(!baseline.is_empty());
    for par in [1u32, 2, 4] {
        let (got, window) = run(par, &disordered);
        assert_eq!(
            got, baseline,
            "par {par}: disordered event-time aggregates must match the \
             ordered parallelism-1 run byte for byte"
        );
        assert!(
            window.watermark_lag_micros < 6_000_000,
            "par {par}: watermark lag unbounded: {}",
            window.watermark_lag_micros
        );
    }
}

/// The pre-exchange behaviour, pinned: with `exchange: none` every task
/// keeps its own keyed state, so a derived key group split across tasks
/// emits one partial aggregate per task and per-key results change with
/// parallelism — exactly the task-sensitivity the exchange removes.
#[test]
fn exchange_none_regression_keeps_task_local_split_state() {
    let evs = events(2_000, 100_000);
    let run_local = |par: usize| {
        let mut cfg = base_cfg(par as u32);
        cfg.engine.exchange = ExchangeMode::None;
        cfg.engine.pipeline_spec = Some(keyed_window_spec());
        assert!(
            LockstepExchange::compile(&cfg).unwrap().is_none(),
            "exchange: none must not stage"
        );
        let factory = StepFactory::new(&cfg, None);
        let mut out = Vec::new();
        for sh in shard(&evs, par) {
            let mut step = factory.create(0).unwrap();
            step.process(100_000, &[], &batch_of(&sh), &mut out).unwrap();
            step.finish(650_000, &mut out).unwrap();
        }
        canonical(out)
    };
    let p1 = run_local(1);
    let p4 = run_local(4);
    assert_ne!(
        p1, p4,
        "task-local keyed state must split key groups (the documented \
         pre-exchange behaviour the opt-out preserves)"
    );
    // The split shows up as duplicate (window, key) emissions: one
    // partial aggregate per task that saw the key.
    let dup = |v: &[(u64, u32, Vec<u8>)]| {
        let mut seen = std::collections::HashSet::new();
        v.iter().filter(|(w, k, _)| !seen.insert((*w, *k))).count()
    };
    assert_eq!(dup(&p1), 0);
    assert!(dup(&p4) > 0, "split key groups emit per-task partials");
}

#[test]
fn wall_engine_surfaces_exchange_in_results_json() {
    // The real threaded engine over a disordered keyed event-time chain:
    // conservation holds, the exchange reports non-zero routed
    // records/bytes in results.json operators[], and watermark lag stays
    // bounded.
    let mut cfg = base_cfg(2);
    cfg.bench.name = "shuffle-e2e".into();
    cfg.bench.duration_micros = 700_000;
    cfg.bench.warmup_micros = 0;
    cfg.workload.rate = 40_000;
    cfg.workload.sensors = 128;
    cfg.workload.disorder.lateness_micros = 100_000;
    cfg.workload.disorder.late_fraction = 0.25;
    cfg.workload.disorder.shuffle_window = 64;
    cfg.engine.batch_size = 256;
    cfg.metrics.sample_interval_micros = 100_000;
    cfg.engine.pipeline_spec = Some(PipelineSpec {
        ops: vec![
            OpSpec::KeyBy {
                modulo: 32,
                parallelism: 0,
            },
            OpSpec::Window {
                agg: AggKind::Mean,
                window_micros: 500_000,
                slide_micros: 250_000,
                time: WindowTime::Event,
                allowed_lateness_micros: 250_000,
                late_policy: LatePolicy::MergeIfOpen,
                watermark_micros: 100_000,
            },
            OpSpec::EmitAggregates,
        ],
    });
    cfg.validate().unwrap();

    let (summary, _store) = run_wall(&cfg, None).unwrap();
    assert_eq!(summary.processed, summary.generated, "engine must drain");
    assert!(summary.emitted > 0, "keyed aggregates must flow");

    let results = summary.to_json();
    assert!(validate_results(&results).is_empty());
    let ops = results.get("operators").and_then(|v| v.as_arr()).unwrap();
    let names: Vec<&str> = ops
        .iter()
        .filter_map(|o| o.get("op").and_then(|v| v.as_str()))
        .collect();
    assert_eq!(names, vec!["keyby", "exchange", "window", "emit_aggregates"]);
    let exchange = &ops[1];
    let field = |o: &sprobench::util::json::Json, k: &str| {
        o.get(k).and_then(|v| v.as_i64()).expect(k)
    };
    assert_eq!(
        field(exchange, "exchange_records") as u64,
        summary.processed,
        "every row crosses the keyby boundary"
    );
    assert!(field(exchange, "exchange_bytes") > 0);
    assert_eq!(
        field(exchange, "events_in"),
        field(exchange, "events_out"),
        "sent == drained once the run flushed"
    );
    let window = &ops[2];
    let lag = field(window, "watermark_lag_us");
    assert!(lag > 0, "event-time window must observe watermark lag");
    assert!(lag < 10_000_000, "watermark lag unbounded: {lag}");
}
