//! Operator-chain ↔ monolith equivalence suite.
//!
//! The operator-chain redesign re-expresses the four paper pipelines
//! (passthrough / cpu / mem / fused) as canonical chains compiled by
//! `StepFactory`.  This suite proves the redesign is behavior-preserving
//! on the native compute path: for identical input sequences, each chain
//! produces **byte-identical** egestion output (payload bytes, keys,
//! generation timestamps, in order) and **matching `StepStats`** against
//! the pre-redesign monolithic implementations, which remain in-tree as
//! reference implementations.

use sprobench::broker::Record;
use sprobench::config::{BenchConfig, PipelineKind};
use sprobench::engine::EventBatch;
use sprobench::pipelines::{
    Compute, CpuIntensive, Fused, MemIntensive, PassThrough, PipelineStep, StepFactory,
};

const SENSORS: u32 = 64;
const WINDOW_MICROS: u64 = 2_000_000;
const SLIDE_MICROS: u64 = 1_000_000;

fn cfg(kind: PipelineKind) -> BenchConfig {
    let mut cfg = BenchConfig::default();
    cfg.engine.pipeline = kind;
    cfg.engine.use_hlo = false; // native path: byte-exact comparisons
    cfg.engine.threshold_f = 80.0;
    cfg.engine.window_micros = WINDOW_MICROS;
    cfg.engine.slide_micros = SLIDE_MICROS;
    cfg.workload.event_bytes = 27;
    cfg.workload.sensors = SENSORS;
    cfg
}

fn legacy(kind: PipelineKind) -> Box<dyn PipelineStep> {
    match kind {
        PipelineKind::PassThrough => Box::new(PassThrough::new()),
        PipelineKind::CpuIntensive => Box::new(CpuIntensive::new(Compute::Native, 80.0, 27)),
        PipelineKind::MemIntensive => Box::new(MemIntensive::new(
            Compute::Native,
            SENSORS as usize,
            WINDOW_MICROS,
            SLIDE_MICROS,
            0,
        )),
        PipelineKind::Fused => Box::new(Fused::new(
            Compute::Native,
            80.0,
            27,
            SENSORS as usize,
            WINDOW_MICROS,
            SLIDE_MICROS,
            0,
        )),
    }
}

fn chain(kind: PipelineKind) -> Box<dyn PipelineStep> {
    StepFactory::new(&cfg(kind), None)
        .create(0)
        .expect("canonical chain compiles")
}

/// A deterministic, varied batch: skewed keys (including one id outside
/// the keyed-state width), negative and alert-crossing temperatures.
fn batch(seq: u64, len: usize) -> EventBatch {
    let mut b = EventBatch::default();
    for i in 0..len {
        let x = seq.wrapping_mul(31).wrapping_add(i as u64);
        let id = if i % 17 == 0 {
            SENSORS + 5 // out of range: dropped by keyed state, kept by cpu
        } else {
            (x % SENSORS as u64) as u32
        };
        b.ids.push(id);
        b.temps.push(((x % 160) as f32) - 40.0 + (i as f32) * 0.125);
        b.gen_ts.push(seq * 1000 + i as u64);
        b.append_ts.push(seq * 1000 + i as u64 + 7);
    }
    b.payload_bytes = (len * 27) as u64;
    b
}

/// Drive a step through the shared scenario: several parsed batches with
/// advancing processing time (crossing multiple slide boundaries, with an
/// idle gap), then the end-of-stream flush.
fn drive_parsed(step: &mut dyn PipelineStep) -> Vec<Record> {
    let mut out = Vec::new();
    let script: &[(u64, usize)] = &[
        (0, 200),
        (400_000, 64),
        (1_100_000, 300),   // after first slide boundary
        (1_700_000, 1),
        (3_200_000, 128),   // skips a boundary entirely
        (3_300_000, 0),     // empty poll
    ];
    for &(now, len) in script {
        let b = if len == 0 {
            EventBatch::default()
        } else {
            batch(now / 100 + len as u64, len)
        };
        step.process(now, &[], &b, &mut out).expect("process");
    }
    step.finish(3_900_000, &mut out).expect("finish");
    out
}

fn assert_identical(kind: &str, legacy_out: &[Record], chain_out: &[Record]) {
    assert_eq!(
        legacy_out.len(),
        chain_out.len(),
        "{kind}: egestion record count differs"
    );
    for (i, (l, c)) in legacy_out.iter().zip(chain_out).enumerate() {
        assert_eq!(l.key, c.key, "{kind}: key differs at record {i}");
        assert_eq!(
            l.gen_ts_micros, c.gen_ts_micros,
            "{kind}: gen_ts differs at record {i}"
        );
        assert_eq!(
            l.payload(),
            c.payload(),
            "{kind}: payload bytes differ at record {i}: {:?} vs {:?}",
            String::from_utf8_lossy(l.payload()),
            String::from_utf8_lossy(c.payload()),
        );
    }
}

#[test]
fn cpu_chain_is_byte_identical_to_monolith() {
    let mut l = legacy(PipelineKind::CpuIntensive);
    let mut c = chain(PipelineKind::CpuIntensive);
    assert_eq!(c.name(), "cpu");
    let (lo, co) = (drive_parsed(l.as_mut()), drive_parsed(c.as_mut()));
    assert!(!lo.is_empty());
    assert_identical("cpu", &lo, &co);
    assert_eq!(l.stats(), c.stats(), "cpu: StepStats must match");
    assert!(c.stats().alerts > 0, "scenario must cross the alert threshold");
}

#[test]
fn mem_chain_is_byte_identical_to_monolith() {
    let mut l = legacy(PipelineKind::MemIntensive);
    let mut c = chain(PipelineKind::MemIntensive);
    assert_eq!(c.name(), "mem");
    let (lo, co) = (drive_parsed(l.as_mut()), drive_parsed(c.as_mut()));
    assert!(!lo.is_empty(), "windows must emit");
    assert_identical("mem", &lo, &co);
    assert_eq!(l.stats(), c.stats(), "mem: StepStats must match");
    assert!(c.stats().window_emits >= 3, "several boundaries crossed");
}

#[test]
fn fused_chain_is_byte_identical_to_monolith() {
    let mut l = legacy(PipelineKind::Fused);
    let mut c = chain(PipelineKind::Fused);
    assert_eq!(c.name(), "fused");
    let (lo, co) = (drive_parsed(l.as_mut()), drive_parsed(c.as_mut()));
    assert_identical("fused", &lo, &co);
    assert_eq!(l.stats(), c.stats(), "fused: StepStats must match");
    // Both output classes present: transformed events and aggregates.
    assert!(co.iter().any(|r| r.payload().starts_with(b"{\"win\":")));
    assert!(co.iter().any(|r| !r.payload().starts_with(b"{\"win\":")));
}

#[test]
fn passthrough_chain_is_identical_and_shares_storage() {
    let mut l = legacy(PipelineKind::PassThrough);
    let mut c = chain(PipelineKind::PassThrough);
    assert_eq!(c.name(), "passthrough");
    assert!(!c.needs_parse(), "raw chain must skip parsing");
    let records: Vec<Record> = (0..257)
        .map(|i| {
            let payload = format!("1000,{},{:.2}", i % 64, 20.0 + i as f32);
            Record::new(i % 64, payload.into_bytes(), 1000 + i as u64)
        })
        .collect();
    let mut lo = Vec::new();
    let mut co = Vec::new();
    l.process(5, &records, &EventBatch::default(), &mut lo).unwrap();
    c.process(5, &records, &EventBatch::default(), &mut co).unwrap();
    l.finish(10, &mut lo).unwrap();
    c.finish(10, &mut co).unwrap();
    assert_identical("passthrough", &lo, &co);
    for (r, o) in records.iter().zip(&co) {
        assert!(o.shares_storage_with(r), "payloads must be forwarded, not copied");
    }
    assert_eq!(l.stats(), c.stats());
}

#[test]
fn chain_exposes_per_operator_stats_the_monolith_cannot() {
    let mut c = chain(PipelineKind::Fused);
    drive_parsed(c.as_mut());
    let per_op = c.operator_stats();
    let names: Vec<&str> = per_op.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(
        names,
        vec!["cpu_transform", "emit_events", "window", "emit_aggregates"]
    );
    // The per-op breakdown is self-consistent with the chain totals.
    let total = c.stats();
    assert_eq!(per_op[0].1.events_in, total.events_in);
    assert_eq!(total.alerts, per_op[0].1.alerts);
    assert_eq!(total.window_emits, per_op[2].1.window_emits);
    assert_eq!(
        total.events_out,
        per_op[1].1.events_out + per_op[3].1.events_out,
        "chain egestion = transformed events + aggregates"
    );
}
