//! SProBench CLI entrypoint.
fn main() {
    let code = sprobench::cli::main();
    std::process::exit(code);
}
