//! SProBench CLI entrypoint.
#![forbid(unsafe_code)]
#![deny(unused_must_use)]

fn main() {
    let code = sprobench::cli::main();
    std::process::exit(code);
}
