//! Distributed runtime: the transport subsystem that takes broker,
//! generators, and engine multi-process over TCP.
//!
//! Three pieces (ARCHITECTURE.md §Distributed execution):
//!
//! * [`frame`] — the wire format: length-prefixed, CRC-checked,
//!   versioned-handshake frames over `std::net` blocking sockets, plus
//!   payload codecs for broker [`RecordBatch`](crate::broker::RecordBatch)
//!   arenas (serialized once per batch) and exchange row batches.
//! * [`transport`] — the [`Transport`](transport::Transport) trait
//!   abstracting the two data paths that used to be shared memory (the
//!   broker→engine poll feed and the exchange
//!   [`Boundary`](crate::engine::exchange::Boundary)), with
//!   [`LocalTransport`](transport::LocalTransport) (in-process channels)
//!   and [`TcpTransport`](transport::TcpTransport) (per-peer
//!   reader/writer threads) implementations.
//! * [`control`] — the driver-side control plane: role assignment,
//!   resolved-config distribution, the start barrier, and per-worker
//!   `RunSummary` fragment collection merged into results.json with a
//!   `transport` block.
//!
//! [`runner`] hosts the role mains behind `sprobench worker --role ...`
//! and the driver entry used by `sprobench run` when
//! `cluster.transport: tcp` is configured.

pub mod control;
pub mod frame;
pub mod runner;
pub mod transport;

pub use control::{ControlPlane, WorkerLink};
pub use transport::{
    accept_with_timeout, connect_with_retry, FeedBatch, LocalTransport, TcpOptions, TcpTransport,
    Transport, TransportStats, Wire,
};
