//! Role mains for distributed runs: the driver entry used by
//! `sprobench run` when `cluster.transport: tcp` is configured, and the
//! worker harnesses behind `sprobench worker --role <broker|generator|engine>`.
//!
//! Topology (one process per box):
//!
//! ```text
//!             control (HELLO/ASSIGN/READY/START/FRAGMENT)
//!   driver ◄────────────────────────────────────────────► workers
//!
//!   generator ──feed──► broker ──feed──► engine
//!   (N ≥ 0; 0 =         (owns the        (mirror broker +
//!    fleet colocated     ingest topic)    unchanged Engine)
//!    on the broker)
//! ```
//!
//! The broker worker owns the authoritative `ingest` topic.  Generator
//! workers (or a colocated fleet when `cluster.generators: 0`) fill it;
//! a feeder ships every committed batch to the engine worker over a
//! [`TcpTransport<FeedBatch>`](super::transport::TcpTransport).  The
//! engine worker re-produces the received batches into a local mirror
//! broker so the unchanged [`Engine`] — tasks, exchange, windows,
//! egestion drainer — runs exactly as in-process; its slice of the
//! results document ships back to the driver as a FRAGMENT and
//! [`merge_results`](super::control::merge_results) assembles
//! results.json.
//!
//! Liveness: every wait is deadline-bounded.  A peer that dies mid-run
//! surfaces on the engine side as a [`FaultKind::PeerDisconnect`] fault
//! (link error, or heartbeat staleness via [`TaskMonitor`]) and on the
//! driver as a control-plane timeout — never a hang.

use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use super::control::{self, merge_results, ControlPlane, WorkerLink};
use super::frame::role;
use super::transport::{
    accept_with_timeout, connect_with_retry, FeedBatch, TcpOptions, TcpTransport, Transport,
};
use crate::broker::{Broker, BrokerConfig};
use crate::config::{BenchConfig, FaultKind, FaultSpec};
use crate::coordinator::{EgestDump, RunSummary};
use crate::engine::{Engine, FaultOutcome, TaskMonitor};
use crate::metrics::{LatencyRecorder, MeasurementPoint, ThroughputRecorder};
use crate::util::clock::{self, ClockRef};
use crate::util::json::Json;
use crate::wgen::{Fleet, GeneratorConfig, Pattern};

/// Control-plane dial deadline used before the worker has seen its
/// config (the configured `cluster.connect_timeout` arrives in ASSIGN,
/// over the very link being dialed).  Matches the 30 s cap that
/// validation enforces on the configured timeout.
const CONTROL_TIMEOUT_MICROS: u64 = 30_000_000;

/// Post-run slack the driver grants workers beyond the nominal span
/// before a missing FRAGMENT fails the run: engine drain + teardown.
const FRAGMENT_SLACK_MICROS: u64 = 120_000_000;

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// Drive one distributed run to a merged results.json document.
///
/// Binds the control listener, (optionally) spawns the worker processes
/// locally via `current_exe()`, gathers HELLOs, broadcasts the resolved
/// config, releases the START barrier, collects result fragments, and
/// merges them.  Child processes are killed and reaped on any failure.
pub fn run_driver(cfg: &BenchConfig, resolved: &Json) -> Result<Json, String> {
    let (listener, addr) = ControlPlane::listen(&cfg.cluster.driver_bind)?;
    let mut expected = vec![role::BROKER, role::ENGINE];
    for _ in 0..cfg.cluster.generators {
        expected.push(role::GENERATOR);
    }
    let children = if cfg.cluster.spawn_workers {
        spawn_local_workers(cfg, &addr)?
    } else {
        eprintln!("[driver] control listener at {addr}; waiting for externally launched workers");
        Vec::new()
    };
    let result = drive(cfg, resolved, &listener, &expected);
    reap(children, result.is_err());
    result
}

fn drive(
    cfg: &BenchConfig,
    resolved: &Json,
    listener: &TcpListener,
    expected: &[u8],
) -> Result<Json, String> {
    let mut cp = ControlPlane::gather(listener, expected, cfg.cluster.connect_timeout_micros)?;
    let broker_data = cp
        .workers
        .iter()
        .find(|w| w.role == role::BROKER)
        .map(|w| w.data_addr.clone())
        .unwrap_or_default();
    if broker_data.is_empty() {
        return Err("broker worker advertised no data-plane address".into());
    }
    let generators = cfg.cluster.generators;
    cp.broadcast_assign(|_, index| {
        let mut j = Json::obj();
        j.set("config", resolved.clone());
        j.set("broker_data", Json::Str(broker_data.clone()));
        j.set("generators", Json::Int(generators as i64));
        j.set("index", Json::Int(index as i64));
        j
    })?;
    cp.barrier(cfg.cluster.ready_timeout_micros)?;
    let collect_timeout =
        cfg.bench.duration_micros + cfg.bench.warmup_micros + FRAGMENT_SLACK_MICROS;
    let fragments = cp.collect_fragments(collect_timeout)?;
    merge_results(&fragments)
}

/// Launch the worker fleet as child processes of this binary (loopback
/// single-node mode; SLURM launches them via srun instead).
fn spawn_local_workers(cfg: &BenchConfig, driver_addr: &str) -> Result<Vec<Child>, String> {
    let exe = std::env::current_exe().map_err(|e| format!("locate own binary: {e}"))?;
    let mut children: Vec<Child> = Vec::new();
    let mut launch = |role_name: &str, bind: Option<&str>| -> Result<(), String> {
        let mut c = Command::new(&exe);
        c.arg("worker")
            .arg("--role")
            .arg(role_name)
            .arg("--driver")
            .arg(driver_addr)
            .stdin(Stdio::null());
        if let Some(b) = bind {
            c.arg("--bind").arg(b);
        }
        match c.spawn() {
            Ok(child) => {
                children.push(child);
                Ok(())
            }
            Err(e) => Err(format!("spawn {role_name} worker: {e}")),
        }
    };
    let r = launch("broker", Some(&cfg.cluster.data_bind))
        .and_then(|_| launch("engine", None))
        .and_then(|_| (0..cfg.cluster.generators).try_for_each(|_| launch("generator", None)));
    if let Err(e) = r {
        reap(children, true);
        return Err(e);
    }
    Ok(children)
}

fn reap(children: Vec<Child>, kill: bool) {
    for mut c in children {
        if kill {
            let _ = c.kill();
        }
        let _ = c.wait();
    }
}

// ---------------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------------

/// Entry point for `sprobench worker --role <r> --driver <addr>`.
pub fn run_worker(role_name: &str, driver: &str, bind: Option<&str>) -> Result<(), String> {
    match control::role_from_name(role_name) {
        Some(role::BROKER) => run_broker_worker(driver, bind.unwrap_or("127.0.0.1:0")),
        Some(role::GENERATOR) => run_generator_worker(driver),
        Some(role::ENGINE) => run_engine_worker(driver),
        _ => Err(format!(
            "unknown worker role '{role_name}' (expected broker, generator, or engine)"
        )),
    }
}

/// The fields every worker reads out of its ASSIGN payload.
struct Assignment {
    cfg: BenchConfig,
    broker_data: String,
    generators: u32,
    index: u32,
}

fn parse_assign(assign: &Json) -> Result<Assignment, String> {
    let doc = assign.get("config").ok_or("ASSIGN carries no config")?;
    let cfg = BenchConfig::from_json(doc).map_err(|e| format!("assigned config: {e}"))?;
    let get_u32 = |k: &str| assign.get(k).and_then(|v| v.as_i64()).unwrap_or(0) as u32;
    Ok(Assignment {
        cfg,
        broker_data: assign
            .get("broker_data")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string(),
        generators: get_u32("generators"),
        index: get_u32("index"),
    })
}

// --------------------------- broker worker ---------------------------------

fn run_broker_worker(driver: &str, bind: &str) -> Result<(), String> {
    let listener =
        TcpListener::bind(bind).map_err(|e| format!("bind data listener {bind}: {e}"))?;
    let data_addr = listener
        .local_addr()
        .map_err(|e| format!("data listener addr: {e}"))?
        .to_string();
    let (mut link, assign) =
        WorkerLink::connect(driver, role::BROKER, Some(&data_addr), CONTROL_TIMEOUT_MICROS)?;
    match broker_body(&mut link, &assign, &listener) {
        Ok(fragment) => link.send_fragment(&fragment),
        Err(e) => {
            link.send_error(&e);
            Err(format!("broker worker: {e}"))
        }
    }
}

fn broker_body(
    link: &mut WorkerLink,
    assign: &Json,
    listener: &TcpListener,
) -> Result<Json, String> {
    let a = parse_assign(assign)?;
    let cfg = a.cfg;
    let clk: ClockRef = clock::wall();
    let broker = Broker::new(BrokerConfig::from_section(&cfg.broker), clk.clone());
    let in_topic = broker.create_topic("ingest");

    // Data peers dial in: the engine, plus any external generators.
    let mut engine_feed: Option<Arc<TcpTransport<FeedBatch>>> = None;
    let mut gen_feeds: Vec<Arc<TcpTransport<FeedBatch>>> = Vec::new();
    for _ in 0..(1 + a.generators) {
        let (stream, peer) =
            accept_with_timeout(listener, role::BROKER, cfg.cluster.connect_timeout_micros)?;
        let t = TcpTransport::<FeedBatch>::spawn(stream, 1, 1, TcpOptions::default())?;
        match peer {
            role::ENGINE if engine_feed.is_none() => engine_feed = Some(t),
            role::GENERATOR => gen_feeds.push(t),
            other => {
                return Err(format!(
                    "unexpected data peer: {}",
                    control::role_name(other)
                ))
            }
        }
    }
    let engine_feed = engine_feed.ok_or("engine never dialed the data plane")?;

    // Feeder: committed ingest batches → engine link.  Spawned before
    // the load starts so topic backpressure propagates to the producers
    // instead of filling the partitions.
    let feeder = {
        let group = broker.subscribe("ingest", "netfeed", 1);
        let feed = engine_feed.clone();
        std::thread::Builder::new()
            .name("net-feeder".into())
            .spawn(move || -> Result<u64, String> {
                let mut shipped = 0u64;
                loop {
                    match group.poll(0, 4096) {
                        Ok(Some(pb)) => {
                            let partition = pb.partition;
                            let next = pb.next_offset;
                            for batch in pb.batches {
                                shipped += batch.len() as u64;
                                feed.send(0, FeedBatch { partition, batch })?;
                            }
                            group.commit(partition, next);
                        }
                        Ok(None) => std::thread::sleep(Duration::from_micros(500)),
                        // Every partition closed and drained: end of run.
                        Err(_) => break,
                    }
                }
                feed.finish_upstream(0);
                feed.finish_sending();
                Ok(shipped)
            })
            .map_err(|e| format!("spawn net feeder: {e}"))?
    };

    link.ready()?;
    link.await_start(cfg.cluster.ready_timeout_micros)?;

    // Fill the ingest topic: colocated fleet, or pumps from the
    // generator workers.  Either way the topic closes when the offered
    // load ends, which terminates the feeder.
    let t0 = clk.now_micros();
    let (generated, offered, offered_bytes) = if a.generators == 0 {
        let stop = Arc::new(AtomicBool::new(false));
        let fleet = Fleet::new(
            GeneratorConfig::from_config(&cfg),
            clk.clone(),
            Arc::new(ThroughputRecorder::new()),
            Arc::new(LatencyRecorder::new()),
        );
        let duration = cfg.bench.duration_micros + cfg.bench.warmup_micros;
        let workload = cfg.workload.clone();
        let report = fleet.run(&broker, &in_topic, duration, &stop, |share| {
            Pattern::from_config(&workload, share)
        });
        in_topic.close();
        (report.events, report.rate_events, report.rate_bytes)
    } else {
        let mut pumped = 0u64;
        let mut pumped_bytes = 0u64;
        let mut buf: Vec<FeedBatch> = Vec::new();
        let mut live = gen_feeds.clone();
        while !live.is_empty() {
            let mut moved = false;
            let mut failure: Option<String> = None;
            live.retain(|g| {
                while g.drain(0, &mut buf, 256) > 0 {
                    moved = true;
                    for fb in buf.drain(..) {
                        let records = fb.batch.len() as u64;
                        let bytes = fb.batch.payload_bytes();
                        if broker
                            .produce_batches(&in_topic, vec![(fb.partition, fb.batch)])
                            .is_err()
                        {
                            failure =
                                Some("ingest closed while generators still feeding".into());
                            return false;
                        }
                        pumped += records;
                        pumped_bytes += bytes;
                    }
                }
                if g.upstream_done(0) && g.is_drained(0) {
                    return false;
                }
                if let Some(e) = g.error() {
                    failure = Some(format!("generator link: {e}"));
                    return false;
                }
                true
            });
            if let Some(e) = failure {
                return Err(e);
            }
            if !moved {
                std::thread::sleep(Duration::from_micros(500));
            }
        }
        in_topic.close();
        let elapsed = clk.now_micros().saturating_sub(t0).max(1);
        (
            pumped,
            pumped as f64 * 1e6 / elapsed as f64,
            pumped_bytes as f64 * 1e6 / elapsed as f64,
        )
    };

    let shipped = feeder
        .join()
        .map_err(|_| "feeder thread panicked".to_string())??;
    engine_feed.join();
    broker.shutdown();

    // Wire counters: this endpoint *sent* the engine feed, so the
    // engine-link records/bytes are counted here (once); the generator
    // links contribute only receive-side wait time.
    let mut transport = engine_feed.stats();
    for g in &gen_feeds {
        transport.merge(&g.stats());
    }

    let mut fragment = Json::obj();
    fragment.set("role", Json::Str("broker".into()));
    fragment.set("generated", Json::Int(generated as i64));
    fragment.set("shipped", Json::Int(shipped as i64));
    fragment.set("offered", Json::Num(offered));
    fragment.set("offered_bytes", Json::Num(offered_bytes));
    fragment.set("transport", transport.to_json());
    Ok(fragment)
}

// --------------------------- generator worker ------------------------------

/// This worker's slice of a total split `n` ways (worker 0 absorbs the
/// division remainder, mirroring the fleet's instance split).
fn share_of(total: u64, n: u64, index: u64) -> u64 {
    let base = total / n;
    if index == 0 {
        base + (total - base * n)
    } else {
        base
    }
}

fn run_generator_worker(driver: &str) -> Result<(), String> {
    let (mut link, assign) =
        WorkerLink::connect(driver, role::GENERATOR, None, CONTROL_TIMEOUT_MICROS)?;
    match generator_body(&mut link, &assign) {
        Ok(fragment) => link.send_fragment(&fragment),
        Err(e) => {
            link.send_error(&e);
            Err(format!("generator worker: {e}"))
        }
    }
}

fn generator_body(link: &mut WorkerLink, assign: &Json) -> Result<Json, String> {
    let a = parse_assign(assign)?;
    let mut cfg = a.cfg;
    // This worker's share of the offered load (and of the count budget
    // in count-bound mode).
    let n = a.generators.max(1) as u64;
    cfg.workload.rate = share_of(cfg.workload.rate, n, a.index as u64).max(1);
    cfg.workload.events = share_of(cfg.workload.events, n, a.index as u64);

    let clk: ClockRef = clock::wall();
    // Staging broker: the unchanged fleet produces locally; the pump
    // below ships committed batches to the broker worker.  Same
    // partition count ⇒ the staged partition index is the authoritative
    // ingest partition index.
    let staging = Broker::new(BrokerConfig::from_section(&cfg.broker), clk.clone());
    let topic = staging.create_topic("stage");
    let group = staging.subscribe("stage", "ship", 1);

    let (stream, peer) =
        connect_with_retry(&a.broker_data, role::GENERATOR, cfg.cluster.connect_timeout_micros)?;
    if peer != role::BROKER {
        return Err(format!(
            "data peer at {} is a {}, not the broker",
            a.broker_data,
            control::role_name(peer)
        ));
    }
    let feed = TcpTransport::<FeedBatch>::spawn(stream, 1, 1, TcpOptions::default())?;

    link.ready()?;
    link.await_start(cfg.cluster.ready_timeout_micros)?;

    let mut gen_cfg = GeneratorConfig::from_config(&cfg);
    // Workers past the first re-key their seed so parallel workers never
    // emit duplicate streams; a single external generator keeps the
    // configured seed and so emits the same stream a colocated fleet
    // would.
    if a.index > 0 {
        gen_cfg.seed ^= 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(a.index as u64);
    }
    let duration = cfg.bench.duration_micros + cfg.bench.warmup_micros;
    let stop = Arc::new(AtomicBool::new(false));
    let fleet_thread = {
        let staging = staging.clone();
        let topic = topic.clone();
        let clk = clk.clone();
        let stop = stop.clone();
        let workload = cfg.workload.clone();
        std::thread::Builder::new()
            .name("gen-fleet".into())
            .spawn(move || {
                let fleet = Fleet::new(
                    gen_cfg,
                    clk,
                    Arc::new(ThroughputRecorder::new()),
                    Arc::new(LatencyRecorder::new()),
                );
                let report = fleet.run(&staging, &topic, duration, &stop, |share| {
                    Pattern::from_config(&workload, share)
                });
                topic.close();
                report
            })
            .map_err(|e| format!("spawn generator fleet: {e}"))?
    };

    // Ship every committed staged batch; a dead broker link fails loudly.
    let mut shipped = 0u64;
    let ship_result: Result<(), String> = loop {
        match group.poll(0, 4096) {
            Ok(Some(pb)) => {
                let partition = pb.partition;
                let next = pb.next_offset;
                let mut err = None;
                for batch in pb.batches {
                    shipped += batch.len() as u64;
                    if let Err(e) = feed.send(0, FeedBatch { partition, batch }) {
                        err = Some(e);
                        break;
                    }
                }
                if let Some(e) = err {
                    break Err(format!("broker link: {e}"));
                }
                group.commit(partition, next);
            }
            Ok(None) => std::thread::sleep(Duration::from_micros(500)),
            Err(_) => break Ok(()),
        }
    };
    stop.store(true, Ordering::SeqCst);
    feed.finish_upstream(0);
    feed.finish_sending();
    let report = fleet_thread
        .join()
        .map_err(|_| "generator fleet panicked".to_string())?;
    feed.join();
    staging.shutdown();
    ship_result?;

    let mut fragment = Json::obj();
    fragment.set("role", Json::Str("generator".into()));
    fragment.set("index", Json::Int(a.index as i64));
    fragment.set("generated", Json::Int(report.events as i64));
    fragment.set("shipped", Json::Int(shipped as i64));
    fragment.set("transport", feed.stats().to_json());
    Ok(fragment)
}

// --------------------------- engine worker ---------------------------------

fn run_engine_worker(driver: &str) -> Result<(), String> {
    let (mut link, assign) =
        WorkerLink::connect(driver, role::ENGINE, None, CONTROL_TIMEOUT_MICROS)?;
    match engine_body(&mut link, &assign) {
        Ok(fragment) => link.send_fragment(&fragment),
        Err(e) => {
            link.send_error(&e);
            Err(format!("engine worker: {e}"))
        }
    }
}

fn engine_body(link: &mut WorkerLink, assign: &Json) -> Result<Json, String> {
    let a = parse_assign(assign)?;
    let cfg = a.cfg;
    let clk: ClockRef = clock::wall();
    let throughput = Arc::new(ThroughputRecorder::new());
    let latency = Arc::new(LatencyRecorder::new());

    // Mirror broker: received feed batches are re-produced here so the
    // unchanged engine + egestion drainer run exactly as in-process.
    let broker = Broker::new(BrokerConfig::from_section(&cfg.broker), clk.clone());
    let in_topic = broker.create_topic("ingest");
    let out_topic = broker.create_topic("egest");

    let drain_group = broker.subscribe("egest", "downstream", 1);
    let dump_path = cfg.metrics.egest_dump.clone();
    let drainer = std::thread::Builder::new()
        .name("egest-drain".into())
        .spawn(move || {
            let mut n = 0u64;
            let mut dump = (!dump_path.is_empty()).then(EgestDump::new);
            loop {
                match drain_group.poll(0, 4096) {
                    Ok(Some(b)) => {
                        n += b.record_count() as u64;
                        if let Some(d) = dump.as_mut() {
                            for rb in &b.batches {
                                d.absorb(rb);
                            }
                        }
                        drain_group.commit(b.partition, b.next_offset);
                    }
                    Ok(None) => std::thread::sleep(Duration::from_micros(500)),
                    Err(_) => {
                        if let Some(d) = dump.take() {
                            if let Err(e) = d.write(&dump_path) {
                                eprintln!("[engine-worker] {e}");
                            }
                        }
                        return n;
                    }
                }
            }
        })
        .map_err(|e| format!("spawn egest drainer: {e}"))?;

    // Data plane: dial the broker worker.  Every received frame (PINGs
    // included) beats monitor slot 0, so a vanished or frozen broker
    // goes stale within the watchdog deadline below.
    let monitor = Arc::new(TaskMonitor::new(1));
    let (stream, peer) =
        connect_with_retry(&a.broker_data, role::ENGINE, cfg.cluster.connect_timeout_micros)?;
    if peer != role::BROKER {
        return Err(format!(
            "data peer at {} is a {}, not the broker",
            a.broker_data,
            control::role_name(peer)
        ));
    }
    let feed = TcpTransport::<FeedBatch>::spawn(
        stream,
        1,
        1,
        TcpOptions {
            monitor: Some((monitor.clone(), 0, clk.clone())),
            ..TcpOptions::default()
        },
    )?;

    // Staleness deadline: must exceed the peer's idle-ping interval
    // (1 s) or a quiet-but-healthy link would trip it.
    let stale_after = cfg.fault.heartbeat_timeout_micros.max(5_000_000);
    let stop = Arc::new(AtomicBool::new(false));
    let faults: Arc<Mutex<Vec<FaultOutcome>>> = Arc::new(Mutex::new(Vec::new()));

    // Pump: received batches → mirror ingest topic.  Doubles as the
    // peer supervisor: a dead link or stale heartbeat is recorded as a
    // detected PeerDisconnect fault and ends the run instead of hanging.
    let pump = {
        let feed = feed.clone();
        let broker = broker.clone();
        let in_topic = in_topic.clone();
        let clk = clk.clone();
        let stop = stop.clone();
        let faults = faults.clone();
        let monitor = monitor.clone();
        let t0 = clk.now_micros();
        std::thread::Builder::new()
            .name("net-pump".into())
            .spawn(move || {
                let mut buf: Vec<FeedBatch> = Vec::new();
                loop {
                    if feed.drain(0, &mut buf, 256) > 0 {
                        for fb in buf.drain(..) {
                            if broker
                                .produce_batches(&in_topic, vec![(fb.partition, fb.batch)])
                                .is_err()
                            {
                                in_topic.close();
                                return;
                            }
                        }
                        continue;
                    }
                    if feed.upstream_done(0) && feed.is_drained(0) {
                        break;
                    }
                    let now = clk.now_micros();
                    let dead = feed.error();
                    let stale = monitor.stale_task(now, stale_after).is_some();
                    if dead.is_some() || stale {
                        let mut outcome = FaultOutcome::new(FaultSpec {
                            kind: FaultKind::PeerDisconnect {
                                worker: role::BROKER as u32,
                            },
                            at_micros: now.saturating_sub(t0),
                            duration_micros: 0,
                            seed: 0,
                        });
                        outcome.injected_at = Some(now);
                        outcome.detected_at = Some(now);
                        faults
                            .lock()
                            .unwrap_or_else(PoisonError::into_inner)
                            .push(outcome);
                        match dead {
                            Some(e) => eprintln!("[engine-worker] broker link failed: {e}"),
                            None => eprintln!(
                                "[engine-worker] broker link stale beyond {stale_after}µs"
                            ),
                        }
                        stop.store(true, Ordering::SeqCst);
                        break;
                    }
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    std::thread::sleep(Duration::from_micros(500));
                }
                in_topic.close();
            })
            .map_err(|e| format!("spawn net pump: {e}"))?
    };

    // Run the engine on this thread while a scoped control thread holds
    // the READY barrier until every task compiled, then awaits START.
    let engine = Engine::new(&cfg, clk.clone(), throughput.clone(), latency.clone());
    let deadline = cfg.bench.duration_micros + cfg.bench.warmup_micros + 30_000_000;
    let runtime_factory = cfg
        .engine
        .use_hlo
        .then(crate::runtime::RuntimeFactory::default_dir);
    let parallelism = cfg.engine.parallelism;
    let ready_timeout = cfg.cluster.ready_timeout_micros;
    let ready = Arc::new(AtomicU32::new(0));
    let run_done = AtomicBool::new(false);

    let report = std::thread::scope(|s| {
        let ctrl = {
            let ready = ready.clone();
            let stop = stop.clone();
            let run_done = &run_done;
            let link: &mut WorkerLink = link;
            s.spawn(move || -> Result<(), String> {
                let barrier = (|| {
                    loop {
                        if ready.load(Ordering::SeqCst) >= parallelism {
                            break;
                        }
                        if run_done.load(Ordering::SeqCst) {
                            return Err("engine exited before its tasks became ready".into());
                        }
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    link.ready()?;
                    link.await_start(ready_timeout)
                })();
                if barrier.is_err() {
                    // Unblock the engine (and the pump) so the scope exits
                    // promptly instead of draining out the full deadline.
                    stop.store(true, Ordering::SeqCst);
                }
                barrier
            })
        };
        let run = engine.run(
            &broker,
            "ingest",
            &out_topic,
            &stop,
            deadline,
            runtime_factory,
            Some(ready.clone()),
        );
        run_done.store(true, Ordering::SeqCst);
        match ctrl.join() {
            Ok(Ok(())) => run,
            Ok(Err(e)) => Err(format!("control barrier: {e}")),
            Err(_) => Err("control thread panicked".to_string()),
        }
    })?;

    stop.store(true, Ordering::SeqCst);
    pump.join().map_err(|_| "net pump panicked".to_string())?;
    feed.finish_sending();
    feed.join();
    broker.shutdown();
    let emitted = drainer
        .join()
        .map_err(|_| "egest drainer panicked".to_string())?;

    let latency_summary: Vec<_> = MeasurementPoint::ALL
        .iter()
        .map(|&p| (p, latency.summary(p)))
        .collect();
    let transport = feed.stats();
    let summary = RunSummary {
        name: cfg.bench.name.clone(),
        pipeline: cfg.engine.pipeline_label(),
        framework: cfg.engine.framework.name(),
        parallelism: cfg.engine.parallelism,
        // Overlaid from the broker fragment by merge_results.
        generated: 0,
        processed: report.events_in,
        emitted,
        elapsed_micros: report.elapsed_micros,
        offered_rate: 0.0,
        processed_rate: report.rate_events,
        offered_bytes_rate: 0.0,
        latency: latency_summary,
        // No JMX/energy sampler in the distributed worker (yet): the
        // blocks are emitted as zeros, not fabricated.
        gc_young_count: 0,
        gc_young_time_micros: 0,
        energy_joules: 0.0,
        parse_failures: report.parse_failures,
        batches: report.batches,
        operators: report.operators.clone(),
        recovery: None,
        quarantined: 0,
        faults: faults.lock().unwrap_or_else(PoisonError::into_inner).clone(),
        resilience: None,
        transport: Some(transport.clone()),
    };

    let mut fragment = Json::obj();
    fragment.set("role", Json::Str("engine".into()));
    fragment.set("summary", summary.to_json());
    fragment.set("transport", transport.to_json());
    Ok(fragment)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_cover_the_total_exactly() {
        for total in [0u64, 1, 7, 100, 1_000_003] {
            for n in 1u64..6 {
                let sum: u64 = (0..n).map(|i| share_of(total, n, i)).sum();
                assert_eq!(sum, total, "total {total} over {n}");
                // Worker 0 absorbs the remainder; everyone else is equal.
                for i in 2..n {
                    assert_eq!(share_of(total, n, i), share_of(total, n, 1));
                }
            }
        }
    }

    #[test]
    fn assign_parsing_rejects_missing_config() {
        let j = Json::obj();
        assert!(parse_assign(&j).is_err());
    }

    #[test]
    fn unknown_role_is_rejected() {
        let e = run_worker("conductor", "127.0.0.1:1", None).unwrap_err();
        assert!(e.contains("unknown worker role"), "{e}");
    }
}
