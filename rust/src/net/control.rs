//! Driver↔worker control plane for distributed runs.
//!
//! One driver process owns the run: workers (broker / generator /
//! engine) dial its control listener, introduce themselves (HELLO,
//! carrying the broker's data-plane address), receive their assignment
//! (ASSIGN: the resolved config plus peer addresses), barrier at READY,
//! and are released together by START.  After the run each worker ships
//! a FRAGMENT (its slice of the results document) and the driver merges
//! the fragments into the standard results.json shape plus the
//! `transport` block.  Every wait is deadline-bounded: a missing or
//! crashed worker fails the run loudly instead of hanging it.
//!
//! Control payloads are JSON over the same CRC-checked framing as the
//! data plane ([`super::frame`]); the handshake pins protocol version
//! and role on both planes.

use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use super::frame::{kind, read_frame, role, write_frame, Frame};
use super::transport::{accept_with_timeout, connect_with_retry, TransportStats};
use crate::util::json::{self, Json};

pub fn role_name(r: u8) -> &'static str {
    match r {
        role::DRIVER => "driver",
        role::BROKER => "broker",
        role::GENERATOR => "generator",
        role::ENGINE => "engine",
        _ => "unknown",
    }
}

pub fn role_from_name(name: &str) -> Option<u8> {
    match name {
        "driver" => Some(role::DRIVER),
        "broker" => Some(role::BROKER),
        "generator" => Some(role::GENERATOR),
        "engine" => Some(role::ENGINE),
        _ => None,
    }
}

/// Read one control frame within `timeout`, skipping PINGs.  `what`
/// names the expectation in errors.
fn read_control(stream: &mut TcpStream, timeout: Duration, what: &str) -> Result<Frame, String> {
    stream
        .set_read_timeout(Some(timeout.max(Duration::from_millis(1))))
        .map_err(|e| format!("set control timeout: {e}"))?;
    loop {
        match read_frame(stream) {
            Ok(Some(f)) if f.kind == kind::PING => continue,
            Ok(Some(f)) => return Ok(f),
            Ok(None) => return Err(format!("peer closed the control link awaiting {what}")),
            Err(e) => return Err(format!("awaiting {what} (timeout {timeout:?}): {e}")),
        }
    }
}

fn json_payload(f: &Frame) -> Result<Json, String> {
    let text = std::str::from_utf8(&f.payload)
        .map_err(|_| "control payload is not UTF-8".to_string())?;
    json::parse(text).map_err(|e| format!("control payload: {e}"))
}

/// Raise `err` if the frame is an ERROR report from the peer.
fn check_error(f: &Frame, from: &str) -> Result<(), String> {
    if f.kind == kind::ERROR {
        let msg = json_payload(f)
            .ok()
            .and_then(|j| j.get("message").and_then(|m| m.as_str()).map(String::from))
            .unwrap_or_else(|| "<unreadable error payload>".into());
        return Err(format!("{from} failed: {msg}"));
    }
    Ok(())
}

/// Driver-side handle to one connected worker.
pub struct WorkerHandle {
    pub role: u8,
    /// The worker's advertised data-plane listener ("" when it has none).
    pub data_addr: String,
    stream: TcpStream,
}

/// The driver's view of the cluster once every expected worker reported.
pub struct ControlPlane {
    pub workers: Vec<WorkerHandle>,
}

impl ControlPlane {
    /// Bind the control listener; returns it with its resolved address.
    pub fn listen(bind: &str) -> Result<(TcpListener, String), String> {
        let listener =
            TcpListener::bind(bind).map_err(|e| format!("bind control listener {bind}: {e}"))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("control listener addr: {e}"))?
            .to_string();
        Ok((listener, addr))
    }

    /// Accept + HELLO every expected worker (one role byte per expected
    /// worker) within the deadline.
    pub fn gather(
        listener: &TcpListener,
        expected: &[u8],
        timeout_micros: u64,
    ) -> Result<ControlPlane, String> {
        let deadline = std::time::Instant::now() + Duration::from_micros(timeout_micros);
        let mut workers = Vec::new();
        for _ in 0..expected.len() {
            let left = deadline
                .saturating_duration_since(std::time::Instant::now())
                .as_micros() as u64;
            let (mut stream, peer_role) = accept_with_timeout(listener, role::DRIVER, left.max(1))?;
            let hello = read_control(
                &mut stream,
                deadline.saturating_duration_since(std::time::Instant::now()),
                "HELLO",
            )?;
            if hello.kind != kind::HELLO {
                return Err(format!(
                    "expected HELLO from {}, got frame kind {}",
                    role_name(peer_role),
                    hello.kind
                ));
            }
            let j = json_payload(&hello)?;
            let data_addr = j
                .get("data_addr")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string();
            workers.push(WorkerHandle {
                role: peer_role,
                data_addr,
                stream,
            });
        }
        // Role census: the gathered multiset must match the expectation.
        for r in [role::BROKER, role::GENERATOR, role::ENGINE] {
            let want = expected.iter().filter(|&&e| e == r).count();
            let got = workers.iter().filter(|w| w.role == r).count();
            if want != got {
                return Err(format!(
                    "role mismatch: expected {want} {}(s), got {got}",
                    role_name(r)
                ));
            }
        }
        Ok(ControlPlane { workers })
    }

    /// Send each worker its ASSIGN payload (role, index-within-role).
    pub fn broadcast_assign(
        &mut self,
        payload: impl Fn(u8, usize) -> Json,
    ) -> Result<(), String> {
        let mut per_role_index = std::collections::BTreeMap::new();
        for w in &mut self.workers {
            let idx = per_role_index.entry(w.role).or_insert(0usize);
            let body = payload(w.role, *idx).to_string();
            *idx += 1;
            write_frame(&mut w.stream, kind::ASSIGN, 0, body.as_bytes())
                .map_err(|e| format!("send ASSIGN to {}: {e}", role_name(w.role)))?;
        }
        Ok(())
    }

    /// Barrier: wait for READY from every worker, then broadcast START.
    pub fn barrier(&mut self, timeout_micros: u64) -> Result<(), String> {
        let timeout = Duration::from_micros(timeout_micros);
        for w in &mut self.workers {
            let name = role_name(w.role);
            let f = read_control(&mut w.stream, timeout, "READY")?;
            check_error(&f, name)?;
            if f.kind != kind::READY {
                return Err(format!("expected READY from {name}, got frame kind {}", f.kind));
            }
        }
        for w in &mut self.workers {
            write_frame(&mut w.stream, kind::START, 0, b"{}")
                .map_err(|e| format!("send START to {}: {e}", role_name(w.role)))?;
        }
        Ok(())
    }

    /// Collect one result FRAGMENT per worker (bounded by the run span
    /// plus slack — a worker that dies mid-run errors here, not never).
    pub fn collect_fragments(&mut self, timeout_micros: u64) -> Result<Vec<(u8, Json)>, String> {
        let timeout = Duration::from_micros(timeout_micros);
        let mut out = Vec::new();
        for w in &mut self.workers {
            let name = role_name(w.role);
            let f = read_control(&mut w.stream, timeout, "FRAGMENT")?;
            check_error(&f, name)?;
            if f.kind != kind::FRAGMENT {
                return Err(format!(
                    "expected FRAGMENT from {name}, got frame kind {}",
                    f.kind
                ));
            }
            out.push((w.role, json_payload(&f)?));
        }
        Ok(out)
    }
}

/// Worker-side control client.
pub struct WorkerLink {
    stream: TcpStream,
}

impl WorkerLink {
    /// Dial the driver, introduce this worker, and wait for ASSIGN.
    pub fn connect(
        driver: &str,
        my_role: u8,
        data_addr: Option<&str>,
        timeout_micros: u64,
    ) -> Result<(WorkerLink, Json), String> {
        let (mut stream, peer) = connect_with_retry(driver, my_role, timeout_micros)?;
        if peer != role::DRIVER {
            return Err(format!(
                "control peer at {driver} is a {}, not the driver",
                role_name(peer)
            ));
        }
        let mut hello = Json::obj();
        hello.set("role", Json::Str(role_name(my_role).into()));
        if let Some(addr) = data_addr {
            hello.set("data_addr", Json::Str(addr.into()));
        }
        write_frame(&mut stream, kind::HELLO, 0, hello.to_string().as_bytes())
            .map_err(|e| format!("send HELLO: {e}"))?;
        let f = read_control(&mut stream, Duration::from_micros(timeout_micros), "ASSIGN")?;
        if f.kind != kind::ASSIGN {
            return Err(format!("expected ASSIGN, got frame kind {}", f.kind));
        }
        let assign = json_payload(&f)?;
        Ok((WorkerLink { stream }, assign))
    }

    /// Report setup complete; the driver releases the barrier with START.
    pub fn ready(&mut self) -> Result<(), String> {
        write_frame(&mut self.stream, kind::READY, 0, b"{}")
            .map_err(|e| format!("send READY: {e}"))
    }

    pub fn await_start(&mut self, timeout_micros: u64) -> Result<(), String> {
        let f = read_control(&mut self.stream, Duration::from_micros(timeout_micros), "START")?;
        if f.kind != kind::START {
            return Err(format!("expected START, got frame kind {}", f.kind));
        }
        Ok(())
    }

    pub fn send_fragment(&mut self, fragment: &Json) -> Result<(), String> {
        write_frame(
            &mut self.stream,
            kind::FRAGMENT,
            0,
            fragment.to_string().as_bytes(),
        )
        .map_err(|e| format!("send FRAGMENT: {e}"))
    }

    /// Best-effort failure report so the driver errors with a cause
    /// instead of a bare timeout.
    pub fn send_error(&mut self, msg: &str) {
        let mut j = Json::obj();
        j.set("message", Json::Str(msg.into()));
        let _ = write_frame(&mut self.stream, kind::ERROR, 0, j.to_string().as_bytes());
    }
}

/// Merge per-worker result fragments into one results.json document.
///
/// The engine fragment's `summary` (the standard [`RunSummary`]
/// [`to_json`](crate::coordinator::RunSummary::to_json) shape) is the
/// base; the broker fragment supplies what only the generator side
/// knows (generated count, offered rates); the `transport` block sums
/// every worker's wire counters (send-side byte/record/frame counts are
/// counted once, at the sending endpoint).
pub fn merge_results(fragments: &[(u8, Json)]) -> Result<Json, String> {
    let engine = fragments
        .iter()
        .find(|(r, _)| *r == role::ENGINE)
        .map(|(_, j)| j)
        .ok_or("no engine fragment collected")?;
    let mut base = engine
        .get("summary")
        .cloned()
        .ok_or("engine fragment has no summary")?;

    let broker = fragments
        .iter()
        .find(|(r, _)| *r == role::BROKER)
        .map(|(_, j)| j)
        .ok_or("no broker fragment collected")?;
    let generated = broker
        .get("generated")
        .and_then(|v| v.as_i64())
        .unwrap_or(0);
    let mut events = base.get("events").cloned().unwrap_or_else(Json::obj);
    events.set("generated", Json::Int(generated));
    base.set("events", events);
    let mut tp = base.get("throughput").cloned().unwrap_or_else(Json::obj);
    tp.set(
        "offered",
        Json::Num(broker.get("offered").and_then(|v| v.as_f64()).unwrap_or(0.0)),
    );
    tp.set(
        "offered_bytes",
        Json::Num(
            broker
                .get("offered_bytes")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
        ),
    );
    base.set("throughput", tp);

    let mut total = TransportStats::default();
    for (_, frag) in fragments {
        if let Some(t) = frag.get("transport") {
            total.merge(&transport_from_json(t));
        }
    }
    base.set("transport", total.to_json());
    Ok(base)
}

/// Read a `transport` block back into counters (driver-side merge and
/// test assertions).
pub fn transport_from_json(j: &Json) -> TransportStats {
    let g = |k: &str| j.get(k).and_then(|v| v.as_i64()).unwrap_or(0) as u64;
    TransportStats {
        records: g("records"),
        bytes: g("bytes"),
        frames: g("frames"),
        send_wait_micros: g("send_wait_us"),
        recv_wait_micros: g("recv_wait_us"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roles_roundtrip_by_name() {
        for r in [role::DRIVER, role::BROKER, role::GENERATOR, role::ENGINE] {
            assert_eq!(role_from_name(role_name(r)), Some(r));
        }
        assert_eq!(role_from_name("coordinator"), None);
    }

    #[test]
    fn hello_assign_barrier_fragment_over_loopback() {
        let (listener, addr) = ControlPlane::listen("127.0.0.1:0").unwrap();
        let worker = std::thread::spawn(move || {
            let (mut link, assign) =
                WorkerLink::connect(&addr, role::ENGINE, None, 5_000_000).unwrap();
            assert_eq!(assign.get("x").and_then(|v| v.as_i64()), Some(7));
            link.ready().unwrap();
            link.await_start(5_000_000).unwrap();
            let mut frag = Json::obj();
            frag.set("role", Json::Str("engine".into()));
            let t = TransportStats {
                records: 11,
                bytes: 264,
                frames: 2,
                ..Default::default()
            };
            frag.set("transport", t.to_json());
            link.send_fragment(&frag).unwrap();
        });
        let mut cp = ControlPlane::gather(&listener, &[role::ENGINE], 5_000_000).unwrap();
        assert_eq!(cp.workers.len(), 1);
        assert_eq!(cp.workers[0].role, role::ENGINE);
        cp.broadcast_assign(|_, _| {
            let mut j = Json::obj();
            j.set("x", Json::Int(7));
            j
        })
        .unwrap();
        cp.barrier(5_000_000).unwrap();
        let frags = cp.collect_fragments(5_000_000).unwrap();
        worker.join().unwrap();
        assert_eq!(frags.len(), 1);
        let t = transport_from_json(frags[0].1.get("transport").unwrap());
        assert_eq!(t.records, 11);
        assert_eq!(t.frames, 2);
    }

    #[test]
    fn gather_times_out_when_a_worker_never_arrives() {
        let (listener, _addr) = ControlPlane::listen("127.0.0.1:0").unwrap();
        let t0 = std::time::Instant::now();
        let err = ControlPlane::gather(&listener, &[role::BROKER], 200_000).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(30), "bounded wait");
        assert!(err.contains("timed out"), "{err}");
    }

    #[test]
    fn worker_error_report_fails_the_barrier_with_the_cause() {
        let (listener, addr) = ControlPlane::listen("127.0.0.1:0").unwrap();
        let worker = std::thread::spawn(move || {
            let (mut link, _assign) =
                WorkerLink::connect(&addr, role::BROKER, Some("127.0.0.1:1"), 5_000_000).unwrap();
            link.send_error("no artifacts dir");
        });
        let mut cp = ControlPlane::gather(&listener, &[role::BROKER], 5_000_000).unwrap();
        assert_eq!(cp.workers[0].data_addr, "127.0.0.1:1");
        cp.broadcast_assign(|_, _| Json::obj()).unwrap();
        let err = cp.barrier(5_000_000).unwrap_err();
        worker.join().unwrap();
        assert!(err.contains("no artifacts dir"), "{err}");
    }

    #[test]
    fn merge_overlays_broker_counts_and_sums_transport() {
        let mut engine_frag = Json::obj();
        let mut summary = Json::obj();
        let mut events = Json::obj();
        events.set("generated", Json::Int(0));
        events.set("processed", Json::Int(500));
        summary.set("events", events);
        engine_frag.set("summary", summary);
        let et = TransportStats {
            recv_wait_micros: 42,
            ..Default::default()
        };
        engine_frag.set("transport", et.to_json());

        let mut broker_frag = Json::obj();
        broker_frag.set("generated", Json::Int(500));
        broker_frag.set("offered", Json::Num(1000.0));
        broker_frag.set("offered_bytes", Json::Num(27_000.0));
        let bt = TransportStats {
            records: 500,
            bytes: 13_500,
            frames: 9,
            ..Default::default()
        };
        broker_frag.set("transport", bt.to_json());

        let merged = merge_results(&[
            (role::ENGINE, engine_frag),
            (role::BROKER, broker_frag),
        ])
        .unwrap();
        assert_eq!(
            merged.path(&["events", "generated"]).and_then(|v| v.as_i64()),
            Some(500)
        );
        assert_eq!(
            merged.path(&["events", "processed"]).and_then(|v| v.as_i64()),
            Some(500)
        );
        assert_eq!(
            merged.path(&["throughput", "offered"]).and_then(|v| v.as_f64()),
            Some(1000.0)
        );
        let t = transport_from_json(merged.get("transport").unwrap());
        assert_eq!(t.records, 500);
        assert_eq!(t.recv_wait_micros, 42);
        assert_eq!(t.frames, 9);
    }
}
