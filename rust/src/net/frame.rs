//! Length-prefixed wire framing for the distributed transport.
//!
//! Every TCP connection in the distributed runtime — data plane and
//! control plane alike — speaks the same framing: a fixed handshake
//! (magic + protocol version + role byte) followed by a stream of
//! self-delimiting frames.  A frame is
//!
//! ```text
//! [len: u32 LE] [kind: u8] [channel: u32 LE] [crc: u32 LE] [payload: len bytes]
//! ```
//!
//! where `crc` is the IEEE CRC-32 of the payload (the same
//! [`crc32`](crate::engine::checkpoint::crc32) the checkpoint files use).
//! Decoding is total: truncation, oversized lengths, and bit flips all
//! come back as readable `Err(String)`s — never a panic, never silently
//! wrong data (`rust/tests/proptest_invariants.rs` holds the line).
//!
//! Payload codecs for the two data-plane message shapes live here too:
//! [`RecordBatch`] (broker→engine feed; the arena is serialized once per
//! batch) and [`RowBatch`] exchange packets (keyed shuffle rows).

use std::io::{Read, Write};

use crate::broker::{RecordBatch, RecordBatchBuilder};
use crate::engine::checkpoint::crc32;
use crate::pipelines::RowBatch;

/// Connection magic: every sprobench socket opens with these four bytes.
pub const MAGIC: [u8; 4] = *b"SPRB";
/// Wire protocol version; bumped on any incompatible frame change.
pub const PROTOCOL_VERSION: u16 = 1;
/// Upper bound on a single frame payload (corrupt lengths fail loudly
/// instead of attempting a multi-gigabyte allocation).
pub const MAX_FRAME_BYTES: u32 = 64 << 20;

/// Frame kinds.  Data-plane kinds carry binary payloads; control-plane
/// kinds carry UTF-8 JSON.
pub mod kind {
    /// A serialized [`super::RecordBatch`] (broker→engine feed).
    pub const BATCH: u8 = 1;
    /// A serialized exchange packet ([`super::RowBatch`] + send stamp).
    pub const ROWS: u8 = 2;
    /// A monotone frontier publication for upstream `channel`.
    pub const FRONTIER: u8 = 3;
    /// Upstream `channel` finished (frontier stops constraining).
    pub const FINISH: u8 = 4;
    /// The sender will emit no further data frames on any channel.
    pub const EOF: u8 = 5;
    /// Liveness ping (idle links heartbeat so peer death is detectable).
    pub const PING: u8 = 6;
    /// Control plane: worker → driver registration (JSON).
    pub const HELLO: u8 = 7;
    /// Control plane: driver → worker role assignment + config (JSON).
    pub const ASSIGN: u8 = 8;
    /// Control plane: worker → driver "set up, holding at barrier".
    pub const READY: u8 = 9;
    /// Control plane: driver → worker start barrier release.
    pub const START: u8 = 10;
    /// Control plane: worker → driver RunSummary fragment (JSON).
    pub const FRAGMENT: u8 = 11;
    /// Control plane: either side reports a fatal error (UTF-8 text).
    pub const ERROR: u8 = 12;
}

/// Worker roles, as carried in the handshake role byte.
pub mod role {
    pub const DRIVER: u8 = 0;
    pub const BROKER: u8 = 1;
    pub const GENERATOR: u8 = 2;
    pub const ENGINE: u8 = 3;
}

/// One decoded frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: u8,
    pub channel: u32,
    pub payload: Vec<u8>,
}

const HEADER_BYTES: usize = 4 + 1 + 4 + 4;

/// Serialize one frame into `out` (appends).
pub fn encode_frame(kind: u8, channel: u32, payload: &[u8], out: &mut Vec<u8>) {
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&channel.to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Decode one frame from the front of `buf`; returns the frame and how
/// many bytes it consumed.  Any malformation is a readable error.
pub fn decode_frame(buf: &[u8]) -> Result<(Frame, usize), String> {
    if buf.len() < HEADER_BYTES {
        return Err(format!(
            "truncated frame header: {} of {HEADER_BYTES} bytes",
            buf.len()
        ));
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if len > MAX_FRAME_BYTES {
        return Err(format!(
            "frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap (corrupt stream?)"
        ));
    }
    let kind = buf[4];
    let channel = u32::from_le_bytes([buf[5], buf[6], buf[7], buf[8]]);
    let stored_crc = u32::from_le_bytes([buf[9], buf[10], buf[11], buf[12]]);
    let total = HEADER_BYTES + len as usize;
    if buf.len() < total {
        return Err(format!(
            "truncated frame payload: {} of {} bytes",
            buf.len() - HEADER_BYTES,
            len
        ));
    }
    let payload = &buf[HEADER_BYTES..total];
    let actual = crc32(payload);
    if actual != stored_crc {
        return Err(format!(
            "frame CRC mismatch: stored {stored_crc:#010x}, computed {actual:#010x}"
        ));
    }
    Ok((
        Frame {
            kind,
            channel,
            payload: payload.to_vec(),
        },
        total,
    ))
}

/// Write one frame to a stream.
pub fn write_frame(
    w: &mut impl Write,
    kind: u8,
    channel: u32,
    payload: &[u8],
) -> Result<(), String> {
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len());
    encode_frame(kind, channel, payload, &mut buf);
    w.write_all(&buf).map_err(|e| format!("frame write: {e}"))
}

/// Read one frame from a stream.  `Ok(None)` is a clean end of stream
/// (EOF exactly at a frame boundary); EOF mid-frame is an error.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>, String> {
    let mut header = [0u8; HEADER_BYTES];
    let mut got = 0;
    while got < HEADER_BYTES {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(format!(
                    "connection closed mid-frame ({got} of {HEADER_BYTES} header bytes)"
                ))
            }
            Ok(n) => got += n,
            Err(e) => return Err(format!("frame header read: {e}")),
        }
    }
    let len = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
    if len > MAX_FRAME_BYTES {
        return Err(format!(
            "frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap (corrupt stream?)"
        ));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| format!("frame payload read ({len} bytes): {e}"))?;
    let stored_crc = u32::from_le_bytes([header[9], header[10], header[11], header[12]]);
    let actual = crc32(&payload);
    if actual != stored_crc {
        return Err(format!(
            "frame CRC mismatch: stored {stored_crc:#010x}, computed {actual:#010x}"
        ));
    }
    Ok(Some(Frame {
        kind: header[4],
        channel: u32::from_le_bytes([header[5], header[6], header[7], header[8]]),
        payload,
    }))
}

/// Write the connection handshake: magic, protocol version, role byte.
pub fn write_handshake(w: &mut impl Write, role_byte: u8) -> Result<(), String> {
    let mut buf = Vec::with_capacity(7);
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    buf.push(role_byte);
    w.write_all(&buf).map_err(|e| format!("handshake write: {e}"))
}

/// Read and verify the peer's handshake; returns its role byte.
pub fn read_handshake(r: &mut impl Read) -> Result<u8, String> {
    let mut buf = [0u8; 7];
    r.read_exact(&mut buf)
        .map_err(|e| format!("handshake read: {e}"))?;
    if buf[0..4] != MAGIC {
        return Err(format!(
            "bad handshake magic {:02x?} (expected {:02x?} — not a sprobench peer?)",
            &buf[0..4],
            MAGIC
        ));
    }
    let version = u16::from_le_bytes([buf[4], buf[5]]);
    if version != PROTOCOL_VERSION {
        return Err(format!(
            "protocol version mismatch: peer speaks v{version}, this build speaks v{PROTOCOL_VERSION}"
        ));
    }
    Ok(buf[6])
}

// ---------------------------------------------------------------------------
// Payload codecs
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian cursor (decode side).
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.buf.len() - self.pos < n {
            return Err(format!(
                "truncated {what}: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32, String> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64, String> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    fn f32(&mut self, what: &str) -> Result<f32, String> {
        let s = self.take(4, what)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn done(&self, what: &str) -> Result<(), String> {
        if self.pos != self.buf.len() {
            return Err(format!(
                "{what}: {} trailing bytes after payload",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

/// Serialize a [`RecordBatch`] (plus its source partition) into a BATCH
/// frame payload.  The arena is walked once; per-record layout is
/// `[key u32][gen_ts u64][len u32][payload bytes]`.
pub fn encode_record_batch(partition: u32, batch: &RecordBatch, out: &mut Vec<u8>) {
    out.extend_from_slice(&partition.to_le_bytes());
    out.extend_from_slice(&batch.base_offset.to_le_bytes());
    out.extend_from_slice(&batch.append_ts_micros.to_le_bytes());
    out.extend_from_slice(&(batch.len() as u32).to_le_bytes());
    for i in 0..batch.len() {
        let e = batch.entry(i);
        let payload = batch.payload(i);
        out.extend_from_slice(&e.key.to_le_bytes());
        out.extend_from_slice(&e.gen_ts_micros.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(payload);
    }
}

/// Decode a BATCH frame payload back into `(partition, RecordBatch)`.
/// The rebuilt batch owns one fresh arena (a single allocation, like the
/// producer path) and carries the original base offset and append stamp.
pub fn decode_record_batch(buf: &[u8]) -> Result<(u32, RecordBatch), String> {
    let mut c = Cursor::new(buf);
    let partition = c.u32("batch partition")?;
    let base_offset = c.u64("batch base offset")?;
    let append_ts = c.u64("batch append ts")?;
    let count = c.u32("batch record count")?;
    if count as usize > buf.len() {
        // Each record needs at least its 16-byte header; a count larger
        // than the whole payload is corruption, caught before reserving.
        return Err(format!(
            "batch record count {count} impossible for a {}-byte payload",
            buf.len()
        ));
    }
    let mut b = RecordBatchBuilder::with_capacity(count as usize, buf.len());
    for _ in 0..count {
        let key = c.u32("record key")?;
        let gen_ts = c.u64("record gen ts")?;
        let len = c.u32("record payload length")? as usize;
        let payload = c.take(len, "record payload")?;
        b.push(key, payload, gen_ts);
    }
    c.done("record batch")?;
    let mut batch = b.build();
    batch.base_offset = base_offset;
    batch.append_ts_micros = append_ts;
    Ok((partition, batch))
}

/// Serialize an exchange packet (rows + send stamp) into a ROWS frame
/// payload: `[sent u64][n u32]` then `n × [key u32][val f32][ts u64][count u64]`
/// — exactly [`ROW_WIRE_BYTES`](crate::engine::exchange::ROW_WIRE_BYTES)
/// per row.
pub fn encode_rows(rows: &RowBatch, sent_micros: u64, out: &mut Vec<u8>) {
    out.extend_from_slice(&sent_micros.to_le_bytes());
    out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    for i in 0..rows.len() {
        out.extend_from_slice(&rows.keys[i].to_le_bytes());
        out.extend_from_slice(&rows.vals[i].to_le_bytes());
        out.extend_from_slice(&rows.ts[i].to_le_bytes());
        out.extend_from_slice(&rows.counts[i].to_le_bytes());
    }
}

/// Decode a ROWS frame payload back into `(rows, sent_micros)`.
pub fn decode_rows(buf: &[u8]) -> Result<(RowBatch, u64), String> {
    let mut c = Cursor::new(buf);
    let sent = c.u64("rows send stamp")?;
    let n = c.u32("row count")?;
    let need = n as u64 * 24;
    if need > (buf.len() as u64) {
        return Err(format!(
            "row count {n} impossible for a {}-byte payload",
            buf.len()
        ));
    }
    let mut rows = RowBatch::default();
    for _ in 0..n {
        let key = c.u32("row key")?;
        let val = c.f32("row value")?;
        let ts = c.u64("row timestamp")?;
        let count = c.u64("row count field")?;
        rows.push(key, val, ts, count);
    }
    c.done("row batch")?;
    Ok((rows, sent))
}

/// Serialize a frontier publication (8 bytes).
pub fn encode_frontier(micros: u64) -> Vec<u8> {
    micros.to_le_bytes().to_vec()
}

/// Decode a frontier publication.
pub fn decode_frontier(buf: &[u8]) -> Result<u64, String> {
    let mut c = Cursor::new(buf);
    let v = c.u64("frontier")?;
    c.done("frontier")?;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_batch() -> RecordBatch {
        let mut b = RecordBatchBuilder::new();
        b.push(7, b"hello", 100);
        b.push(9, b"", 200);
        b.push(7, &[0xff, 0x00, 0x7f], 300);
        let mut batch = b.build();
        batch.base_offset = 4242;
        batch.append_ts_micros = 999_999;
        batch
    }

    #[test]
    fn frame_roundtrip_preserves_everything() {
        let mut wire = Vec::new();
        encode_frame(kind::BATCH, 3, b"payload bytes", &mut wire);
        encode_frame(kind::FRONTIER, 0, &encode_frontier(12345), &mut wire);
        let (f1, used) = decode_frame(&wire).unwrap();
        assert_eq!(f1.kind, kind::BATCH);
        assert_eq!(f1.channel, 3);
        assert_eq!(f1.payload, b"payload bytes");
        let (f2, used2) = decode_frame(&wire[used..]).unwrap();
        assert_eq!(f2.kind, kind::FRONTIER);
        assert_eq!(decode_frontier(&f2.payload).unwrap(), 12345);
        assert_eq!(used + used2, wire.len());
    }

    #[test]
    fn stream_roundtrip_and_clean_eof() {
        let mut wire = Vec::new();
        encode_frame(kind::PING, 0, &[], &mut wire);
        encode_frame(kind::ERROR, 1, b"boom", &mut wire);
        let mut r = &wire[..];
        let f = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(f.kind, kind::PING);
        assert!(f.payload.is_empty());
        let f = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(f.payload, b"boom");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn mid_frame_eof_is_loud() {
        let mut wire = Vec::new();
        encode_frame(kind::BATCH, 0, b"0123456789", &mut wire);
        for cut in 1..wire.len() {
            let mut r = &wire[..cut];
            let err = match read_frame(&mut r) {
                Err(e) => e,
                Ok(f) => panic!("truncation at {cut} accepted: {f:?}"),
            };
            assert!(!err.is_empty());
        }
    }

    #[test]
    fn bit_flips_fail_the_crc() {
        let mut wire = Vec::new();
        encode_frame(kind::ROWS, 2, b"some payload worth protecting", &mut wire);
        // Flip one payload bit: CRC must catch it.
        let mut bad = wire.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        let err = decode_frame(&bad).unwrap_err();
        assert!(err.contains("CRC"), "{err}");
        // Flip a stored-CRC bit: same rejection.
        let mut bad = wire.clone();
        bad[9] ^= 0x01;
        assert!(decode_frame(&bad).unwrap_err().contains("CRC"));
    }

    #[test]
    fn oversized_length_is_rejected_without_allocating() {
        let mut wire = Vec::new();
        encode_frame(kind::BATCH, 0, b"x", &mut wire);
        wire[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_frame(&wire).unwrap_err();
        assert!(err.contains("cap"), "{err}");
        let mut r = &wire[..];
        assert!(read_frame(&mut r).unwrap_err().contains("cap"));
    }

    #[test]
    fn handshake_roundtrip_and_rejections() {
        let mut wire = Vec::new();
        write_handshake(&mut wire, role::ENGINE).unwrap();
        let mut r = &wire[..];
        assert_eq!(read_handshake(&mut r).unwrap(), role::ENGINE);

        let mut bad = wire.clone();
        bad[0] = b'X';
        assert!(read_handshake(&mut &bad[..]).unwrap_err().contains("magic"));

        let mut bad = wire.clone();
        bad[4] = 99;
        let err = read_handshake(&mut &bad[..]).unwrap_err();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn record_batch_roundtrip_is_identity() {
        let batch = sample_batch();
        let mut payload = Vec::new();
        encode_record_batch(5, &batch, &mut payload);
        let (partition, back) = decode_record_batch(&payload).unwrap();
        assert_eq!(partition, 5);
        assert_eq!(back.len(), batch.len());
        assert_eq!(back.base_offset, 4242);
        assert_eq!(back.append_ts_micros, 999_999);
        for i in 0..batch.len() {
            assert_eq!(back.entry(i).key, batch.entry(i).key);
            assert_eq!(back.entry(i).gen_ts_micros, batch.entry(i).gen_ts_micros);
            assert_eq!(back.payload(i), batch.payload(i));
        }
    }

    #[test]
    fn rows_roundtrip_is_identity() {
        let mut rows = RowBatch::default();
        rows.push(1, 0.25, 100, 1);
        rows.push(2, -3.5, 200, 4);
        rows.push(u32::MAX, f32::MIN_POSITIVE, u64::MAX, u64::MAX);
        let mut payload = Vec::new();
        encode_rows(&rows, 777, &mut payload);
        let (back, sent) = decode_rows(&payload).unwrap();
        assert_eq!(sent, 777);
        assert_eq!(back.keys, rows.keys);
        assert_eq!(back.vals, rows.vals);
        assert_eq!(back.ts, rows.ts);
        assert_eq!(back.counts, rows.counts);
    }

    #[test]
    fn payload_truncations_are_readable_errors() {
        let batch = sample_batch();
        let mut payload = Vec::new();
        encode_record_batch(1, &batch, &mut payload);
        for cut in 0..payload.len() {
            match decode_record_batch(&payload[..cut]) {
                Err(e) => assert!(!e.is_empty()),
                Ok(_) => panic!("truncated batch at {cut} decoded"),
            }
        }
        let mut rows = RowBatch::default();
        rows.push(1, 1.0, 2, 3);
        let mut payload = Vec::new();
        encode_rows(&rows, 9, &mut payload);
        for cut in 0..payload.len() {
            assert!(decode_rows(&payload[..cut]).is_err());
        }
    }
}
