//! The [`Transport`] abstraction: one trait over the two data paths that
//! used to exist only in shared memory.
//!
//! A transport is a set of numbered channels toward a consumer (`dest`
//! in `try_send`/`drain`) plus per-upstream frontier/done lanes — exactly
//! the semantics of the exchange [`Boundary`](crate::engine::exchange::Boundary)
//! (which now delegates here) and of the broker→engine poll feed.
//!
//! * [`LocalTransport`] wraps [`util::chan`](crate::util::chan) bounded
//!   channels and atomics: today's in-process fast path, byte-for-byte
//!   the old `Boundary` behaviour.
//! * [`TcpTransport`] carries the same semantics over one TCP socket
//!   with blocking I/O and a per-peer reader/writer thread pair, using
//!   the length-prefixed CRC-checked framing in [`super::frame`].
//!   Frontier publications and finish marks travel as control frames and
//!   land in local atomic mirrors on both ends, so `safe_frontier()`
//!   reads never block on the network.
//!
//! Message payloads are pluggable through [`Wire`]: the exchange moves
//! [`ExchangePacket`]s (row batches), the feed moves [`FeedBatch`]es
//! (serialized [`RecordBatch`] arenas — one serialization per batch).
//!
//! Liveness: an idle TCP link pings every `ping_interval`; every received
//! frame beats an optional [`TaskMonitor`] slot, so a vanished peer
//! surfaces through the supervisor's heartbeat deadline (bounded
//! detection, no hang) as well as through [`TcpTransport::error`].

use std::io::Write as _;
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use super::frame::{
    self, kind, read_frame, read_handshake, write_frame, write_handshake, Frame,
};
use crate::broker::RecordBatch;
use crate::engine::exchange::{ExchangePacket, ROW_WIRE_BYTES};
use crate::engine::supervisor::TaskMonitor;
use crate::util::chan::{self, Receiver, RecvTimeout, Sender, TrySendError};
use crate::util::clock::ClockRef;

/// Wire-wise transport counters, surfaced as the results.json `transport`
/// block.  `bytes` is what actually moved: framed bytes (header +
/// payload) on TCP, logical record bytes on the local path.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TransportStats {
    pub records: u64,
    pub bytes: u64,
    pub frames: u64,
    /// Cumulative time senders spent blocked on a full outbound queue.
    pub send_wait_micros: u64,
    /// Cumulative time the receive side spent waiting for the next frame.
    pub recv_wait_micros: u64,
}

impl TransportStats {
    pub fn merge(&mut self, other: &TransportStats) {
        self.records += other.records;
        self.bytes += other.bytes;
        self.frames += other.frames;
        self.send_wait_micros += other.send_wait_micros;
        self.recv_wait_micros += other.recv_wait_micros;
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("records", crate::util::json::Json::Int(self.records as i64));
        j.set("bytes", crate::util::json::Json::Int(self.bytes as i64));
        j.set("frames", crate::util::json::Json::Int(self.frames as i64));
        j.set(
            "send_wait_us",
            crate::util::json::Json::Int(self.send_wait_micros as i64),
        );
        j.set(
            "recv_wait_us",
            crate::util::json::Json::Int(self.recv_wait_micros as i64),
        );
        j
    }
}

/// A message a transport can carry: self-serializing, self-metering.
pub trait Wire: Sized + Send + 'static {
    /// The data frame kind this message travels as.
    fn frame_kind() -> u8;
    /// Serialize into `out` (appends).
    fn encode(&self, out: &mut Vec<u8>);
    /// Total decode; every malformation is a readable error.
    fn decode(buf: &[u8]) -> Result<Self, String>;
    /// `(records, logical wire bytes)` this message accounts for.
    fn meter(&self) -> (u64, u64);
}

impl Wire for ExchangePacket {
    fn frame_kind() -> u8 {
        kind::ROWS
    }

    fn encode(&self, out: &mut Vec<u8>) {
        frame::encode_rows(&self.rows, self.sent_micros, out);
    }

    fn decode(buf: &[u8]) -> Result<Self, String> {
        let (rows, sent_micros) = frame::decode_rows(buf)?;
        Ok(ExchangePacket { rows, sent_micros })
    }

    fn meter(&self) -> (u64, u64) {
        let n = self.rows.len() as u64;
        (n, n * ROW_WIRE_BYTES)
    }
}

/// One broker batch in flight on the feed path: the source partition plus
/// the batch itself (arena serialized once per batch, never per record).
pub struct FeedBatch {
    pub partition: u32,
    pub batch: RecordBatch,
}

impl Wire for FeedBatch {
    fn frame_kind() -> u8 {
        kind::BATCH
    }

    fn encode(&self, out: &mut Vec<u8>) {
        frame::encode_record_batch(self.partition, &self.batch, out);
    }

    fn decode(buf: &[u8]) -> Result<Self, String> {
        let (partition, batch) = frame::decode_record_batch(buf)?;
        Ok(FeedBatch { partition, batch })
    }

    fn meter(&self) -> (u64, u64) {
        let n = self.batch.len() as u64;
        // Exact encoded size: 24-byte batch header + 16 bytes/record + payloads.
        (n, 24 + 16 * n + self.batch.payload_bytes())
    }
}

/// The transport contract shared by the exchange boundary and the feed.
///
/// Channel/`dest` indexes address downstream consumer instances; `upstream`
/// indexes address producer instances for frontier bookkeeping.  The
/// semantics mirror the pre-distributed `Boundary` exactly:
/// `try_send` is non-blocking and hands the message back on a full (or
/// closed) channel; `publish_frontier` is a monotone max; a finished
/// upstream stops constraining the safe frontier.
pub trait Transport<M: Wire>: Send + Sync {
    /// Non-blocking send toward consumer `dest`; the message comes back
    /// on backpressure so the caller can relieve its own queues first.
    fn try_send(&self, dest: u32, msg: M) -> Result<(), M>;
    /// Blocking send (feed-pump path, where the sender never consumes).
    fn send(&self, dest: u32, msg: M) -> Result<(), String>;
    /// Drain up to `max` pending messages for consumer `dest`.
    fn drain(&self, dest: u32, buf: &mut Vec<M>, max: usize) -> usize;
    /// True when consumer `dest` has nothing queued.
    fn is_drained(&self, dest: u32) -> bool;
    /// Publish upstream `upstream`'s monotone frontier.
    fn publish_frontier(&self, upstream: u32, micros: u64);
    /// Mark upstream `upstream` finished.
    fn finish_upstream(&self, upstream: u32);
    /// Last published frontier of upstream `upstream`.
    fn frontier(&self, upstream: u32) -> u64;
    /// Whether upstream `upstream` marked itself finished.
    fn upstream_done(&self, upstream: u32) -> bool;
    fn upstreams(&self) -> u32;
    fn downstreams(&self) -> u32;
    fn stats(&self) -> TransportStats;
}

// ---------------------------------------------------------------------------
// Local (in-process) transport
// ---------------------------------------------------------------------------

/// Shared-memory transport: bounded channels + atomics.  This is the old
/// exchange `Boundary` data structure behind the trait.
pub struct LocalTransport<M> {
    txs: Vec<Sender<M>>,
    rxs: Vec<Receiver<M>>,
    frontiers: Vec<AtomicU64>,
    done: Vec<AtomicBool>,
    records: AtomicU64,
    bytes: AtomicU64,
    frames: AtomicU64,
    send_wait: AtomicU64,
}

impl<M: Wire> LocalTransport<M> {
    pub fn new(upstreams: u32, downstreams: u32, capacity: usize) -> Self {
        let (txs, rxs) = (0..downstreams.max(1))
            .map(|_| chan::bounded(capacity))
            .unzip();
        Self {
            txs,
            rxs,
            frontiers: (0..upstreams.max(1)).map(|_| AtomicU64::new(0)).collect(),
            done: (0..upstreams.max(1)).map(|_| AtomicBool::new(false)).collect(),
            records: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            frames: AtomicU64::new(0),
            send_wait: AtomicU64::new(0),
        }
    }

    fn count(&self, records: u64, bytes: u64) {
        self.records.fetch_add(records, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.frames.fetch_add(1, Ordering::Relaxed);
    }
}

impl<M: Wire> Transport<M> for LocalTransport<M> {
    fn try_send(&self, dest: u32, msg: M) -> Result<(), M> {
        let (r, b) = msg.meter();
        match self.txs[dest as usize].try_send(msg) {
            Ok(()) => {
                self.count(r, b);
                Ok(())
            }
            Err(TrySendError::Full(m)) | Err(TrySendError::Closed(m)) => Err(m),
        }
    }

    fn send(&self, dest: u32, msg: M) -> Result<(), String> {
        let (r, b) = msg.meter();
        let t0 = Instant::now();
        self.txs[dest as usize]
            .send(msg)
            .map_err(|_| format!("local transport channel {dest} closed"))?;
        self.send_wait
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        self.count(r, b);
        Ok(())
    }

    fn drain(&self, dest: u32, buf: &mut Vec<M>, max: usize) -> usize {
        self.rxs[dest as usize].drain_into(buf, max)
    }

    fn is_drained(&self, dest: u32) -> bool {
        self.rxs[dest as usize].is_empty()
    }

    fn publish_frontier(&self, upstream: u32, micros: u64) {
        self.frontiers[upstream as usize].fetch_max(micros, Ordering::SeqCst);
    }

    fn finish_upstream(&self, upstream: u32) {
        self.done[upstream as usize].store(true, Ordering::SeqCst);
    }

    fn frontier(&self, upstream: u32) -> u64 {
        self.frontiers[upstream as usize].load(Ordering::SeqCst)
    }

    fn upstream_done(&self, upstream: u32) -> bool {
        self.done[upstream as usize].load(Ordering::SeqCst)
    }

    fn upstreams(&self) -> u32 {
        self.done.len() as u32
    }

    fn downstreams(&self) -> u32 {
        self.txs.len() as u32
    }

    fn stats(&self) -> TransportStats {
        TransportStats {
            records: self.records.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            frames: self.frames.load(Ordering::Relaxed),
            send_wait_micros: self.send_wait.load(Ordering::Relaxed),
            recv_wait_micros: 0,
        }
    }
}

// ---------------------------------------------------------------------------
// TCP transport
// ---------------------------------------------------------------------------

/// Options for a TCP endpoint.
#[derive(Clone)]
pub struct TcpOptions {
    /// Per-channel inbound queue depth and outbound queue depth.
    pub capacity: usize,
    /// Idle-link ping interval (keeps heartbeat monitors fed), µs.
    pub ping_interval_micros: u64,
    /// Heartbeat surface: every received frame beats `monitor` slot
    /// `task` at the clock's now, so a supervising watchdog detects a
    /// dead peer by staleness within its deadline.
    pub monitor: Option<(Arc<TaskMonitor>, u32, ClockRef)>,
}

impl Default for TcpOptions {
    fn default() -> Self {
        Self {
            capacity: 1024,
            ping_interval_micros: 1_000_000,
            monitor: None,
        }
    }
}

enum Out<M> {
    Data(u32, M),
    Frontier(u32, u64),
    Finish(u32),
    Eof,
}

struct TcpShared<M> {
    inbound_tx: Vec<Sender<M>>,
    frontiers: Vec<AtomicU64>,
    done: Vec<AtomicBool>,
    records: AtomicU64,
    bytes: AtomicU64,
    frames: AtomicU64,
    send_wait: AtomicU64,
    recv_wait: AtomicU64,
    error: Mutex<Option<String>>,
    monitor: Option<(Arc<TaskMonitor>, u32, ClockRef)>,
}

impl<M> TcpShared<M> {
    fn fail(&self, e: String) {
        // A panicking I/O thread must not cascade: recover the slot
        // from poisoning instead of propagating the panic.
        let mut slot = self.error.lock().unwrap_or_else(PoisonError::into_inner);
        if slot.is_none() {
            *slot = Some(e);
        }
    }

    fn beat(&self) {
        if let Some((mon, task, clock)) = &self.monitor {
            mon.beat(*task, clock.now_micros());
        }
    }
}

/// One TCP endpoint of a transport link (full duplex: this end both
/// sends toward `downstreams` consumer channels on the peer and receives
/// its own `downstreams` channels — shapes are symmetric per direction
/// of use; unused directions are simply never exercised).
pub struct TcpTransport<M: Wire> {
    shared: Arc<TcpShared<M>>,
    inbound_rx: Vec<Receiver<M>>,
    outbound_tx: Sender<Out<M>>,
    upstream_count: u32,
    downstream_count: u32,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl<M: Wire> TcpTransport<M> {
    /// Wrap a handshaken stream: spawns the reader and writer threads
    /// and returns the endpoint.
    pub fn spawn(
        stream: TcpStream,
        upstreams: u32,
        downstreams: u32,
        opts: TcpOptions,
    ) -> Result<Arc<Self>, String> {
        stream.set_nodelay(true).ok();
        let read_half = stream
            .try_clone()
            .map_err(|e| format!("clone stream for reader: {e}"))?;
        let (inbound_tx, inbound_rx): (Vec<_>, Vec<_>) = (0..downstreams.max(1))
            .map(|_| chan::bounded(opts.capacity))
            .unzip();
        let (outbound_tx, outbound_rx) = chan::bounded::<Out<M>>(opts.capacity);
        let shared = Arc::new(TcpShared {
            inbound_tx,
            frontiers: (0..upstreams.max(1)).map(|_| AtomicU64::new(0)).collect(),
            done: (0..upstreams.max(1)).map(|_| AtomicBool::new(false)).collect(),
            records: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            frames: AtomicU64::new(0),
            send_wait: AtomicU64::new(0),
            recv_wait: AtomicU64::new(0),
            error: Mutex::new(None),
            monitor: opts.monitor.clone(),
        });

        let reader = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("net-reader".into())
                .spawn(move || reader_loop::<M>(read_half, &shared))
                .map_err(|e| format!("spawn net reader: {e}"))?
        };
        let writer = {
            let shared = shared.clone();
            let ping = opts.ping_interval_micros.max(1_000);
            std::thread::Builder::new()
                .name("net-writer".into())
                .spawn(move || writer_loop::<M>(stream, outbound_rx, &shared, ping))
                .map_err(|e| format!("spawn net writer: {e}"))?
        };

        Ok(Arc::new(Self {
            shared,
            inbound_rx,
            outbound_tx,
            upstream_count: upstreams.max(1),
            downstream_count: downstreams.max(1),
            threads: Mutex::new(vec![reader, writer]),
        }))
    }

    /// The link's first fatal error (I/O failure, CRC mismatch, peer
    /// disconnect without EOF), if any.
    pub fn error(&self) -> Option<String> {
        self.shared
            .error
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    /// Declare this end done sending: an EOF frame is flushed and the
    /// write half shuts down.  Receiving continues until the peer EOFs.
    pub fn finish_sending(&self) {
        let _ = self.outbound_tx.send(Out::Eof);
        self.outbound_tx.close();
    }

    /// Join the I/O threads (call after `finish_sending`, once consumers
    /// drained).  Idempotent.
    pub fn join(&self) {
        let handles: Vec<_> = {
            let mut t = self
                .threads
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            t.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

fn reader_loop<M: Wire>(mut stream: TcpStream, shared: &TcpShared<M>) {
    let mut clean_eof = false;
    loop {
        let t0 = Instant::now();
        let f: Frame = match read_frame(&mut stream) {
            Ok(Some(f)) => f,
            Ok(None) => break, // peer closed without an EOF frame
            Err(e) => {
                shared.fail(format!("transport receive: {e}"));
                break;
            }
        };
        shared
            .recv_wait
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        shared.beat();
        match f.kind {
            k if k == M::frame_kind() => {
                let ch = f.channel as usize;
                if ch >= shared.inbound_tx.len() {
                    shared.fail(format!(
                        "data frame for channel {ch} of {} (corrupt header?)",
                        shared.inbound_tx.len()
                    ));
                    break;
                }
                match M::decode(&f.payload) {
                    Ok(msg) => {
                        // Blocking: a full inbound queue backpressures the
                        // socket, which backpressures the sender — the TCP
                        // analogue of a full local channel.
                        if shared.inbound_tx[ch].send(msg).is_err() {
                            break; // consumer went away; stop reading
                        }
                    }
                    Err(e) => {
                        shared.fail(format!("transport decode: {e}"));
                        break;
                    }
                }
            }
            kind::FRONTIER => {
                let up = f.channel as usize;
                match frame::decode_frontier(&f.payload) {
                    Ok(v) if up < shared.frontiers.len() => {
                        shared.frontiers[up].fetch_max(v, Ordering::SeqCst);
                    }
                    Ok(_) => {
                        shared.fail(format!("frontier for unknown upstream {up}"));
                        break;
                    }
                    Err(e) => {
                        shared.fail(format!("transport decode: {e}"));
                        break;
                    }
                }
            }
            kind::FINISH => {
                let up = f.channel as usize;
                if up < shared.done.len() {
                    shared.done[up].store(true, Ordering::SeqCst);
                } else {
                    shared.fail(format!("finish for unknown upstream {up}"));
                    break;
                }
            }
            kind::EOF => {
                clean_eof = true;
                break;
            }
            kind::PING => {}
            other => {
                shared.fail(format!("unexpected frame kind {other} on data link"));
                break;
            }
        }
    }
    let failed_already = shared
        .error
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .is_some();
    if !clean_eof && !failed_already {
        shared.fail("peer disconnected before EOF".into());
    }
    // Unblock consumers: close every inbound channel (they drain what
    // already arrived, then see Closed).
    for tx in &shared.inbound_tx {
        tx.close();
    }
    let _ = stream.shutdown(Shutdown::Read);
}

fn writer_loop<M: Wire>(
    mut stream: TcpStream,
    outbound_rx: Receiver<Out<M>>,
    shared: &TcpShared<M>,
    ping_interval_micros: u64,
) {
    let mut payload = Vec::new();
    loop {
        let out = match outbound_rx.recv_timeout(Duration::from_micros(ping_interval_micros)) {
            RecvTimeout::Item(out) => out,
            RecvTimeout::TimedOut => {
                // Idle link: ping so the peer's heartbeat stays fresh.
                if let Err(e) = write_frame(&mut stream, kind::PING, 0, &[]) {
                    shared.fail(format!("transport send: {e}"));
                    break;
                }
                shared.frames.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            RecvTimeout::Closed => {
                let _ = write_frame(&mut stream, kind::EOF, 0, &[]);
                break;
            }
        };
        let result = match out {
            Out::Data(ch, msg) => {
                payload.clear();
                msg.encode(&mut payload);
                let (r, _) = msg.meter();
                let res = write_frame(&mut stream, M::frame_kind(), ch, &payload);
                if res.is_ok() {
                    shared.records.fetch_add(r, Ordering::Relaxed);
                    shared
                        .bytes
                        .fetch_add(13 + payload.len() as u64, Ordering::Relaxed);
                    shared.frames.fetch_add(1, Ordering::Relaxed);
                }
                res
            }
            Out::Frontier(up, v) => {
                let res = write_frame(&mut stream, kind::FRONTIER, up, &frame::encode_frontier(v));
                if res.is_ok() {
                    shared.frames.fetch_add(1, Ordering::Relaxed);
                }
                res
            }
            Out::Finish(up) => {
                let res = write_frame(&mut stream, kind::FINISH, up, &[]);
                if res.is_ok() {
                    shared.frames.fetch_add(1, Ordering::Relaxed);
                }
                res
            }
            Out::Eof => {
                let _ = write_frame(&mut stream, kind::EOF, 0, &[]);
                break;
            }
        };
        if let Err(e) = result {
            shared.fail(format!("transport send: {e}"));
            break;
        }
    }
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Write);
}

impl<M: Wire> Transport<M> for TcpTransport<M> {
    fn try_send(&self, dest: u32, msg: M) -> Result<(), M> {
        match self.outbound_tx.try_send(Out::Data(dest, msg)) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(Out::Data(_, m)))
            | Err(TrySendError::Closed(Out::Data(_, m))) => Err(m),
            Err(_) => unreachable!("try_send returns the message it was given"),
        }
    }

    fn send(&self, dest: u32, msg: M) -> Result<(), String> {
        let t0 = Instant::now();
        self.outbound_tx.send(Out::Data(dest, msg)).map_err(|_| {
            self.error()
                .unwrap_or_else(|| "transport outbound queue closed".into())
        })?;
        self.shared
            .send_wait
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn drain(&self, dest: u32, buf: &mut Vec<M>, max: usize) -> usize {
        self.inbound_rx[dest as usize].drain_into(buf, max)
    }

    fn is_drained(&self, dest: u32) -> bool {
        self.inbound_rx[dest as usize].is_empty()
    }

    fn publish_frontier(&self, upstream: u32, micros: u64) {
        // Local mirror first (same-process readers see it immediately),
        // then the wire copy for the peer.
        self.shared.frontiers[upstream as usize].fetch_max(micros, Ordering::SeqCst);
        let _ = self.outbound_tx.send(Out::Frontier(upstream, micros));
    }

    fn finish_upstream(&self, upstream: u32) {
        self.shared.done[upstream as usize].store(true, Ordering::SeqCst);
        let _ = self.outbound_tx.send(Out::Finish(upstream));
    }

    fn frontier(&self, upstream: u32) -> u64 {
        self.shared.frontiers[upstream as usize].load(Ordering::SeqCst)
    }

    fn upstream_done(&self, upstream: u32) -> bool {
        self.shared.done[upstream as usize].load(Ordering::SeqCst)
    }

    fn upstreams(&self) -> u32 {
        self.upstream_count
    }

    fn downstreams(&self) -> u32 {
        self.downstream_count
    }

    fn stats(&self) -> TransportStats {
        TransportStats {
            records: self.shared.records.load(Ordering::Relaxed),
            bytes: self.shared.bytes.load(Ordering::Relaxed),
            frames: self.shared.frames.load(Ordering::Relaxed),
            send_wait_micros: self.shared.send_wait.load(Ordering::Relaxed),
            recv_wait_micros: self.shared.recv_wait.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------------
// Connection helpers (timeouts are load-bearing: a missing peer must fail
// loudly, never hang)
// ---------------------------------------------------------------------------

/// Connect to `addr`, retrying until `timeout_micros` (the peer may not
/// be listening yet during cluster startup), then handshake.  Returns
/// the stream and the peer's role byte.
pub fn connect_with_retry(
    addr: &str,
    my_role: u8,
    timeout_micros: u64,
) -> Result<(TcpStream, u8), String> {
    let target = addr
        .to_socket_addrs()
        .map_err(|e| format!("resolve {addr}: {e}"))?
        .next()
        .ok_or_else(|| format!("resolve {addr}: no addresses"))?;
    let deadline = Instant::now() + Duration::from_micros(timeout_micros);
    let mut last_err = String::new();
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return Err(format!(
                "connect to {addr} timed out after {:.1}s (last error: {last_err})",
                timeout_micros as f64 / 1e6
            ));
        }
        match TcpStream::connect_timeout(&target, left.min(Duration::from_secs(2))) {
            Ok(mut stream) => {
                write_handshake(&mut stream, my_role)?;
                let peer = read_handshake(&mut stream)?;
                return Ok((stream, peer));
            }
            Err(e) => {
                last_err = e.to_string();
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Accept one handshaken connection within `timeout_micros`, failing
/// loudly if no peer arrives.
pub fn accept_with_timeout(
    listener: &TcpListener,
    my_role: u8,
    timeout_micros: u64,
) -> Result<(TcpStream, u8), String> {
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("listener nonblocking: {e}"))?;
    let deadline = Instant::now() + Duration::from_micros(timeout_micros);
    loop {
        match listener.accept() {
            Ok((mut stream, _)) => {
                stream
                    .set_nonblocking(false)
                    .map_err(|e| format!("stream blocking: {e}"))?;
                write_handshake(&mut stream, my_role)?;
                let peer = read_handshake(&mut stream)?;
                return Ok((stream, peer));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(format!(
                        "accept on {:?} timed out after {:.1}s: no peer connected",
                        listener.local_addr().ok(),
                        timeout_micros as f64 / 1e6
                    ));
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(format!("accept: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipelines::RowBatch;

    fn packet(n: usize, ts0: u64, sent: u64) -> ExchangePacket {
        let mut rows = RowBatch::default();
        for i in 0..n {
            rows.push(i as u32, 0.5, ts0 + i as u64, 1);
        }
        ExchangePacket {
            rows,
            sent_micros: sent,
        }
    }

    /// A connected TCP endpoint pair over loopback, handshaken.
    fn tcp_pair(
        upstreams: u32,
        downstreams: u32,
        opts: TcpOptions,
    ) -> (Arc<TcpTransport<ExchangePacket>>, Arc<TcpTransport<ExchangePacket>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let client = std::thread::spawn(move || {
            connect_with_retry(&addr, frame::role::ENGINE, 5_000_000).unwrap()
        });
        let (server_stream, peer) =
            accept_with_timeout(&listener, frame::role::BROKER, 5_000_000).unwrap();
        assert_eq!(peer, frame::role::ENGINE);
        let (client_stream, peer) = client.join().unwrap();
        assert_eq!(peer, frame::role::BROKER);
        let a = TcpTransport::spawn(server_stream, upstreams, downstreams, opts.clone()).unwrap();
        let b = TcpTransport::spawn(client_stream, upstreams, downstreams, opts).unwrap();
        (a, b)
    }

    fn drain_all(
        t: &TcpTransport<ExchangePacket>,
        dest: u32,
        want: usize,
        timeout: Duration,
    ) -> Vec<ExchangePacket> {
        let deadline = Instant::now() + timeout;
        let mut got = Vec::new();
        while got.len() < want && Instant::now() < deadline {
            if t.drain(dest, &mut got, 64) == 0 {
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        got
    }

    #[test]
    fn local_transport_meters_like_the_old_boundary() {
        let t = LocalTransport::<ExchangePacket>::new(2, 4, 16);
        assert!(t.try_send(1, packet(5, 0, 9)).is_ok());
        assert_eq!(t.stats().records, 5);
        assert_eq!(t.stats().bytes, 5 * ROW_WIRE_BYTES);
        assert_eq!(t.stats().frames, 1);
        let mut buf = Vec::new();
        assert_eq!(t.drain(1, &mut buf, 8), 1);
        assert_eq!(buf[0].rows.len(), 5);
        assert!(t.is_drained(1));
    }

    #[test]
    fn tcp_roundtrip_rows_frontiers_and_finish() {
        let (a, b) = tcp_pair(2, 2, TcpOptions::default());
        a.send(0, packet(3, 100, 7)).unwrap();
        a.send(1, packet(2, 200, 8)).unwrap();
        a.publish_frontier(0, 5_000);
        a.publish_frontier(1, 9_000);
        a.finish_upstream(1);

        let got0 = drain_all(&b, 0, 1, Duration::from_secs(5));
        assert_eq!(got0.len(), 1);
        assert_eq!(got0[0].rows.len(), 3);
        assert_eq!(got0[0].sent_micros, 7);
        assert_eq!(got0[0].rows.ts, vec![100, 101, 102]);
        let got1 = drain_all(&b, 1, 1, Duration::from_secs(5));
        assert_eq!(got1[0].rows.len(), 2);

        // Frontier/finish propagate to the peer's atomic mirrors.
        let deadline = Instant::now() + Duration::from_secs(5);
        while (b.frontier(0) != 5_000 || !b.upstream_done(1)) && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(b.frontier(0), 5_000);
        assert_eq!(b.frontier(1), 9_000);
        assert!(b.upstream_done(1));
        assert!(!b.upstream_done(0));
        // Sender-side mirrors agree without any wire round trip.
        assert_eq!(a.frontier(0), 5_000);
        assert!(a.upstream_done(1));

        let stats = a.stats();
        assert_eq!(stats.records, 5);
        assert!(stats.bytes > 5 * ROW_WIRE_BYTES, "framed bytes include headers");
        assert!(stats.frames >= 5, "2 data + 2 frontier + 1 finish");

        a.finish_sending();
        b.finish_sending();
        a.join();
        b.join();
        assert!(a.error().is_none(), "{:?}", a.error());
        assert!(b.error().is_none(), "{:?}", b.error());
    }

    #[test]
    fn peer_death_surfaces_as_error_not_hang() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let client = std::thread::spawn(move || {
            connect_with_retry(&addr, frame::role::ENGINE, 5_000_000).unwrap()
        });
        let (server_stream, _) =
            accept_with_timeout(&listener, frame::role::BROKER, 5_000_000).unwrap();
        let (client_stream, _) = client.join().unwrap();
        let survivor =
            TcpTransport::<ExchangePacket>::spawn(server_stream, 1, 1, TcpOptions::default())
                .unwrap();
        // The peer dies abruptly: no EOF frame, just a closed socket.
        drop(client_stream);
        let deadline = Instant::now() + Duration::from_secs(10);
        while survivor.error().is_none() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let err = survivor.error().expect("death must be detected");
        assert!(
            err.contains("disconnected") || err.contains("receive"),
            "unreadable death: {err}"
        );
        survivor.finish_sending();
        survivor.join();
    }

    #[test]
    fn missing_peer_fails_connect_and_accept_loudly() {
        // Nobody listens on this port (bind then drop to reserve-and-free).
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = Instant::now();
        let err = connect_with_retry(&dead, frame::role::ENGINE, 300_000).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(30), "must bound the wait");
        assert!(err.contains("timed out"), "{err}");

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let t0 = Instant::now();
        let err = accept_with_timeout(&listener, frame::role::DRIVER, 200_000).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(30));
        assert!(err.contains("timed out"), "{err}");
    }
}
