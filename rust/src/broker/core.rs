//! Broker facade: topic registry, producer API, thread pools, stats.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use super::batch::{PartitionedBatchBuilder, RecordBatch};
use super::consumer::{ConsumerGroup, PruneCoordinator};
use super::partition::PartitionClosed;
use super::record::Record;
use super::topic::Topic;
use crate::util::clock::ClockRef;
use crate::util::pool::ThreadPool;

/// Broker tuning (paper Sec. 4: "5 GB for Kafka, with 20 threads for I/O
/// and 10 threads for network operations", 4 topic partitions).
#[derive(Clone, Debug)]
pub struct BrokerConfig {
    pub partitions: u32,
    pub queue_depth: usize,
    pub io_threads: u32,
    pub network_threads: u32,
    /// Simulated per-record handling cost in nanoseconds (0 = free).
    /// Models broker CPU work so sim-mode capacity is finite.
    pub record_overhead_nanos: u64,
}

impl Default for BrokerConfig {
    fn default() -> Self {
        Self {
            partitions: 4,
            queue_depth: 65_536,
            io_threads: 4,
            network_threads: 2,
            record_overhead_nanos: 0,
        }
    }
}

impl BrokerConfig {
    pub fn from_section(s: &crate::config::schema::BrokerSection) -> Self {
        Self {
            partitions: s.partitions,
            queue_depth: s.queue_depth,
            io_threads: s.io_threads,
            network_threads: s.network_threads,
            record_overhead_nanos: s.record_overhead_nanos,
        }
    }
}

/// Aggregate broker statistics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BrokerStats {
    pub topics: usize,
    pub records_appended: u64,
    pub bytes_appended: u64,
    pub backlog: u64,
}

/// The in-process broker.
pub struct Broker {
    config: BrokerConfig,
    clock: ClockRef,
    topics: Mutex<BTreeMap<String, (Arc<Topic>, Arc<PruneCoordinator>)>>,
    /// "Network" pool: carries async produce traffic.
    network_pool: ThreadPool,
    /// "I/O" pool: carries background housekeeping (pruning sweeps).
    io_pool: ThreadPool,
}

impl Broker {
    pub fn new(config: BrokerConfig, clock: ClockRef) -> Arc<Self> {
        let network_pool = ThreadPool::new(
            "broker-net",
            config.network_threads.max(1) as usize,
            4096,
        );
        let io_pool = ThreadPool::new("broker-io", config.io_threads.max(1) as usize, 4096);
        Arc::new(Self {
            config,
            clock,
            topics: Mutex::new(BTreeMap::new()),
            network_pool,
            io_pool,
        })
    }

    pub fn config(&self) -> &BrokerConfig {
        &self.config
    }

    /// Create (or get) a topic with the broker-default partition count.
    pub fn create_topic(&self, name: &str) -> Arc<Topic> {
        self.create_topic_with(name, self.config.partitions)
    }

    /// Create (or get) a topic with an explicit partition count.
    pub fn create_topic_with(&self, name: &str, partitions: u32) -> Arc<Topic> {
        let mut topics = self.topics.lock().expect("broker topics");
        topics
            .entry(name.to_string())
            .or_insert_with(|| {
                let t = Arc::new(Topic::new(name, partitions, self.config.queue_depth));
                let c = Arc::new(PruneCoordinator::new(t.clone()));
                (t, c)
            })
            .0
            .clone()
    }

    pub fn topic(&self, name: &str) -> Option<Arc<Topic>> {
        self.topics
            .lock()
            .expect("broker topics")
            .get(name)
            .map(|(t, _)| t.clone())
    }

    /// Subscribe a consumer group to a topic.
    pub fn subscribe(&self, topic: &str, group: &str, members: u32) -> Arc<ConsumerGroup> {
        let (t, c) = self
            .topics
            .lock()
            .expect("broker topics")
            .get(topic)
            .cloned()
            .unwrap_or_else(|| panic!("subscribe to unknown topic '{topic}'"));
        ConsumerGroup::new(group, t, c, members)
    }

    /// Synchronous produce (generator thread = network client thread).
    pub fn produce(&self, topic: &Topic, record: Record) -> Result<u64, PartitionClosed> {
        self.burn_overhead(1);
        topic.produce(record, self.clock.now_micros())
    }

    /// Append ready-built per-partition batches, one lock acquisition
    /// each — the primary (batch-first) produce path.  Returns records
    /// appended.
    pub fn produce_batches(
        &self,
        topic: &Topic,
        parts: Vec<(u32, RecordBatch)>,
    ) -> Result<usize, PartitionClosed> {
        let n: usize = parts.iter().map(|(_, b)| b.len()).sum();
        if n == 0 {
            return Ok(0);
        }
        self.burn_overhead(n as u64);
        let now = self.clock.now_micros();
        for (p, batch) in parts {
            topic.partition(p).append_record_batch(batch, now)?;
        }
        Ok(n)
    }

    /// Synchronous batched produce from a `Vec<Record>` (compatibility
    /// path): routes the records into per-partition arenas, then appends
    /// each under one lock acquisition.  Returns records appended.
    pub fn produce_batch(
        &self,
        topic: &Topic,
        mut records: Vec<Record>,
    ) -> Result<usize, PartitionClosed> {
        self.produce_records(topic, &mut records)
    }

    /// Like [`Broker::produce_batch`] but drains the caller's buffer in
    /// place so its allocation is reused across produce calls (the
    /// engine's emit path).
    ///
    /// Trade-off: payloads are *copied* into fresh per-partition arenas
    /// (the old path moved `Record`s into the log with zero payload
    /// copies).  The memcpy of small payloads buys one lock/condvar
    /// handshake per partition instead of per record, per-batch refcount
    /// traffic, and arena compaction — forwarded records no longer pin
    /// their whole source arena in the egestion log.
    pub fn produce_records(
        &self,
        topic: &Topic,
        records: &mut Vec<Record>,
    ) -> Result<usize, PartitionClosed> {
        let n = records.len();
        if n == 0 {
            return Ok(0);
        }
        let mut pb = PartitionedBatchBuilder::new(topic.partition_count());
        for r in records.iter() {
            pb.push(
                topic.partition_for_key(r.key),
                r.key,
                r.payload(),
                r.gen_ts_micros,
            );
        }
        records.clear();
        self.produce_batches(topic, pb.finish())
    }

    /// Fire-and-forget produce through the network pool (ack-less client).
    pub fn produce_async(self: &Arc<Self>, topic: Arc<Topic>, record: Record) {
        let this = self.clone();
        self.network_pool.submit(move || {
            let _ = this.produce(&topic, record);
        });
    }

    /// Acked produce: the batch is handled by a broker **network thread**
    /// (serialization point) and the caller blocks until the append is
    /// acknowledged — the Kafka `acks=1` client model.  Under load the
    /// network pool becomes the queueing server, which is what makes
    /// broker latency grow with offered load (the paper's Fig. 6 latency
    /// curve).
    pub fn produce_batch_acked(
        self: &Arc<Self>,
        topic: &Arc<Topic>,
        records: Vec<Record>,
    ) -> Result<usize, PartitionClosed> {
        let (ack_tx, ack_rx) = crate::util::chan::bounded::<Result<usize, PartitionClosed>>(1);
        let this = self.clone();
        let topic = topic.clone();
        self.network_pool.submit(move || {
            let result = this.produce_batch(&topic, records);
            let _ = ack_tx.send(result);
        });
        ack_rx.recv().unwrap_or(Err(PartitionClosed))
    }

    /// Acked batch-first produce: ready-built per-partition batches are
    /// appended by a broker network thread while the caller blocks for the
    /// ack — same `acks=1` queueing model as
    /// [`Broker::produce_batch_acked`], minus the `Vec<Record>` detour.
    pub fn produce_batches_acked(
        self: &Arc<Self>,
        topic: &Arc<Topic>,
        parts: Vec<(u32, RecordBatch)>,
    ) -> Result<usize, PartitionClosed> {
        let (ack_tx, ack_rx) = crate::util::chan::bounded::<Result<usize, PartitionClosed>>(1);
        let this = self.clone();
        let topic = topic.clone();
        self.network_pool.submit(move || {
            let result = this.produce_batches(&topic, parts);
            let _ = ack_tx.send(result);
        });
        ack_rx.recv().unwrap_or(Err(PartitionClosed))
    }

    /// Run a background housekeeping sweep on the I/O pool (prune all
    /// topics to their groups' committed offsets).
    pub fn housekeep(self: &Arc<Self>) {
        let topics: Vec<(Arc<Topic>, Arc<PruneCoordinator>)> = self
            .topics
            .lock()
            .expect("broker topics")
            .values()
            .cloned()
            .collect();
        for (t, c) in topics {
            self.io_pool.submit(move || {
                for p in 0..t.partition_count() {
                    c.prune(p);
                }
            });
        }
    }

    /// Wait for queued async work to finish (tests + shutdown).
    pub fn quiesce(&self) {
        self.network_pool.wait_idle();
        self.io_pool.wait_idle();
    }

    /// Model per-record broker CPU cost. In wall mode this busy-burns (it
    /// is a *cost*, not a pause); in sim mode it advances virtual time.
    #[inline]
    fn burn_overhead(&self, records: u64) {
        let nanos = self.config.record_overhead_nanos * records;
        if nanos == 0 {
            return;
        }
        if self.clock.is_virtual() {
            self.clock.sleep_micros(nanos / 1_000);
        } else {
            let start = std::time::Instant::now();
            while (std::time::Instant::now() - start).as_nanos() < nanos as u128 {
                std::hint::spin_loop();
            }
        }
    }

    pub fn stats(&self) -> BrokerStats {
        let topics = self.topics.lock().expect("broker topics");
        let mut s = BrokerStats {
            topics: topics.len(),
            ..Default::default()
        };
        for (t, _) in topics.values() {
            s.records_appended += t.total_appended();
            s.bytes_appended += t.total_bytes();
            s.backlog += t.total_lag();
        }
        s
    }

    /// Close every topic (producers error, consumers drain).
    pub fn shutdown(&self) {
        for (t, _) in self.topics.lock().expect("broker topics").values() {
            t.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock;

    fn broker() -> Arc<Broker> {
        Broker::new(BrokerConfig::default(), clock::wall())
    }

    fn rec(key: u32) -> Record {
        Record::new(key, vec![0u8; 27], 0)
    }

    #[test]
    fn create_topic_is_idempotent() {
        let b = broker();
        let t1 = b.create_topic("in");
        let t2 = b.create_topic("in");
        assert!(Arc::ptr_eq(&t1, &t2));
        assert_eq!(t1.partition_count(), 4);
    }

    #[test]
    fn produce_and_consume_roundtrip() {
        let b = broker();
        let t = b.create_topic("in");
        let g = b.subscribe("in", "engine", 1);
        for k in 0..50 {
            b.produce(&t, rec(k)).unwrap();
        }
        let mut n = 0;
        while let Ok(Some(batch)) = g.poll(0, 16) {
            n += batch.record_count();
            g.commit(batch.partition, batch.next_offset);
        }
        assert_eq!(n, 50);
        let s = b.stats();
        assert_eq!(s.records_appended, 50);
        assert_eq!(s.bytes_appended, 50 * 27);
        assert_eq!(s.backlog, 0);
    }

    #[test]
    fn produce_batch_appends_everything() {
        let b = broker();
        let t = b.create_topic("in");
        let records: Vec<Record> = (0..500).map(rec).collect();
        assert_eq!(b.produce_batch(&t, records).unwrap(), 500);
        assert_eq!(t.total_appended(), 500);
    }

    #[test]
    fn produce_batches_appends_prebuilt_partition_batches() {
        let b = broker();
        let t = b.create_topic("in");
        let mut pb = PartitionedBatchBuilder::new(t.partition_count());
        for k in 0..100u32 {
            pb.push(t.partition_for_key(k), k, &[0u8; 27], 5);
        }
        assert_eq!(b.produce_batches(&t, pb.finish()).unwrap(), 100);
        assert_eq!(t.total_appended(), 100);
        assert_eq!(t.total_bytes(), 2700);
        // Acked variant goes through the network pool and still lands.
        let mut pb = PartitionedBatchBuilder::new(t.partition_count());
        pb.push(0, 1, &[0u8; 27], 6);
        assert_eq!(b.produce_batches_acked(&t, pb.finish()).unwrap(), 1);
        assert_eq!(t.total_appended(), 101);
    }

    #[test]
    fn async_produce_lands_after_quiesce() {
        let b = broker();
        let t = b.create_topic("in");
        for k in 0..20 {
            b.produce_async(t.clone(), rec(k));
        }
        b.quiesce();
        assert_eq!(t.total_appended(), 20);
    }

    #[test]
    fn append_ts_is_stamped_by_broker_clock() {
        let b = broker();
        let t = b.create_topic("in");
        b.produce(&t, rec(1)).unwrap();
        let g = b.subscribe("in", "g", 1);
        let batch = g.poll(0, 1).unwrap().unwrap();
        assert!(batch.iter().next().unwrap().append_ts_micros > 0);
    }

    #[test]
    fn record_overhead_advances_sim_clock() {
        let c = clock::sim();
        let b = Broker::new(
            BrokerConfig {
                record_overhead_nanos: 2_000, // 2us per record
                ..Default::default()
            },
            c.clone(),
        );
        let t = b.create_topic("in");
        let records: Vec<Record> = (0..1000).map(rec).collect();
        b.produce_batch(&t, records).unwrap();
        assert_eq!(c.now_micros(), 2_000);
    }

    #[test]
    fn shutdown_propagates_to_producers() {
        let b = broker();
        let t = b.create_topic("in");
        b.shutdown();
        assert!(b.produce(&t, rec(0)).is_err());
    }
}
