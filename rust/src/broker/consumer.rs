//! Consumer groups: per-partition offsets, static member assignment,
//! commit-driven log pruning.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::batch::{RecordBatch, RecordView};
use super::partition::PartitionClosed;
use super::record::Record;
use super::topic::Topic;

/// Committed offsets of one group over one topic.
pub struct GroupOffsets {
    committed: Vec<AtomicU64>,
}

impl GroupOffsets {
    fn new(partitions: u32) -> Self {
        Self {
            committed: (0..partitions).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn committed(&self, partition: u32) -> u64 {
        self.committed[partition as usize].load(Ordering::SeqCst)
    }
}

/// Coordinates pruning across all groups consuming a topic: a partition's
/// records are reclaimable once *every* registered group committed past
/// them (Kafka analog: retention by consumer progress — the variant that
/// produces backpressure instead of data loss).
pub struct PruneCoordinator {
    topic: Arc<Topic>,
    groups: Mutex<Vec<Arc<GroupOffsets>>>,
}

impl PruneCoordinator {
    pub fn new(topic: Arc<Topic>) -> Self {
        Self {
            topic,
            groups: Mutex::new(Vec::new()),
        }
    }

    fn register(&self, offsets: Arc<GroupOffsets>) {
        self.groups.lock().expect("prune groups").push(offsets);
    }

    fn unregister(&self, offsets: &Arc<GroupOffsets>) {
        self.groups
            .lock()
            .expect("prune groups")
            .retain(|g| !Arc::ptr_eq(g, offsets));
    }

    /// Prune `partition` up to the min committed offset across groups.
    pub fn prune(&self, partition: u32) {
        let groups = self.groups.lock().expect("prune groups");
        if groups.is_empty() {
            return;
        }
        let min = groups
            .iter()
            .map(|g| g.committed(partition))
            .min()
            .unwrap_or(0);
        drop(groups);
        self.topic.partition(partition).prune(min);
    }
}

/// One poll result: whole [`RecordBatch`] views from a single partition
/// (boundary batches arrive pre-sliced by the log — no payload copies).
pub struct PolledBatch {
    pub partition: u32,
    pub batches: Vec<RecordBatch>,
    /// Offset to commit after processing this batch.
    pub next_offset: u64,
}

impl PolledBatch {
    /// Total records across the polled batches.
    pub fn record_count(&self) -> usize {
        self.batches.iter().map(|b| b.len()).sum()
    }

    /// Total payload bytes across the polled batches.
    pub fn payload_bytes(&self) -> u64 {
        self.batches.iter().map(|b| b.payload_bytes()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.batches.iter().all(|b| b.is_empty())
    }

    /// Iterate every record as a borrowed view, in offset order.
    pub fn iter(&self) -> impl Iterator<Item = RecordView<'_>> {
        self.batches.iter().flat_map(|b| b.iter())
    }

    /// Materialize owning [`Record`]s (compatibility path; payload arenas
    /// are shared, not copied).
    pub fn to_records(&self) -> Vec<Record> {
        let mut out = Vec::with_capacity(self.record_count());
        for b in &self.batches {
            for i in 0..b.len() {
                out.push(b.record(i));
            }
        }
        out
    }
}

/// One consumer group over one topic.
///
/// Members are assigned partitions statically round-robin (member `m`
/// owns partitions `p` with `p % members == m`) — the rebalancing model
/// Kafka uses for a stable group.
pub struct ConsumerGroup {
    pub name: String,
    topic: Arc<Topic>,
    coordinator: Arc<PruneCoordinator>,
    offsets: Arc<GroupOffsets>,
    /// Next fetch position per partition (may run ahead of committed).
    positions: Vec<AtomicU64>,
    members: u32,
}

impl ConsumerGroup {
    pub fn new(
        name: &str,
        topic: Arc<Topic>,
        coordinator: Arc<PruneCoordinator>,
        members: u32,
    ) -> Arc<Self> {
        assert!(members > 0);
        let offsets = Arc::new(GroupOffsets::new(topic.partition_count()));
        coordinator.register(offsets.clone());
        let positions = (0..topic.partition_count())
            .map(|_| AtomicU64::new(0))
            .collect();
        Arc::new(Self {
            name: name.to_string(),
            topic,
            coordinator,
            offsets,
            positions,
            members,
        })
    }

    /// Partitions owned by `member`.
    pub fn assignment(&self, member: u32) -> Vec<u32> {
        (0..self.topic.partition_count())
            .filter(|p| p % self.members == member % self.members)
            .collect()
    }

    /// Poll up to `max` records for `member` as batch views, round-robin
    /// over its partitions. Non-blocking: returns `None` when nothing is available
    /// everywhere. Returns `Err` only when every owned partition is closed
    /// and drained.
    pub fn poll(&self, member: u32, max: usize) -> Result<Option<PolledBatch>, PartitionClosed> {
        let owned = self.assignment(member);
        if owned.is_empty() {
            return Ok(None);
        }
        let mut all_closed = true;
        // Start from a rotating index so one hot partition cannot starve
        // the others.
        let start = (self.positions[owned[0] as usize].load(Ordering::Relaxed) as usize)
            % owned.len();
        let mut buf: Vec<RecordBatch> = Vec::new();
        for i in 0..owned.len() {
            let p = owned[(start + i) % owned.len()];
            let pos = self.positions[p as usize].load(Ordering::SeqCst);
            match self.topic.partition(p).fetch_batches(pos, max, &mut buf, false) {
                Ok(next) => {
                    all_closed = false;
                    if !buf.is_empty() {
                        self.positions[p as usize].store(next, Ordering::SeqCst);
                        return Ok(Some(PolledBatch {
                            partition: p,
                            batches: buf,
                            next_offset: next,
                        }));
                    }
                }
                Err(PartitionClosed) => {}
            }
        }
        if all_closed {
            Err(PartitionClosed)
        } else {
            Ok(None)
        }
    }

    /// Commit `offset` for `partition` and let the coordinator reclaim.
    pub fn commit(&self, partition: u32, offset: u64) {
        self.offsets.committed[partition as usize].fetch_max(offset, Ordering::SeqCst);
        self.coordinator.prune(partition);
    }

    /// Current fetch position for `partition` (the next offset a poll
    /// would read; may run ahead of the committed offset).
    pub fn position(&self, partition: u32) -> u64 {
        self.positions[partition as usize].load(Ordering::SeqCst)
    }

    /// Rewind (or advance) the fetch position for `partition` — the
    /// recovery path: after a restore, positions are seeked back to the
    /// checkpoint's recorded offsets so every record processed after the
    /// snapshot is replayed.  The committed offset is untouched; commits
    /// are monotone (`fetch_max`), so replayed batches re-commit
    /// harmlessly.  The prune coordinator only reclaims below *committed*
    /// offsets, which deferred (checkpoint-gated) commits keep at the
    /// last durable snapshot — so seeked-back records are still in the
    /// log.
    pub fn seek(&self, partition: u32, offset: u64) {
        self.positions[partition as usize].store(offset, Ordering::SeqCst);
    }

    /// Deregister this group from prune coordination.  A crashed engine
    /// incarnation's group must not pin the log forever: its committed
    /// offsets are frozen, so leaving lets the surviving groups' progress
    /// bound retention again.  The group object stays usable for reads;
    /// only its pruning veto is dropped (pruning is monotone, so nothing
    /// already retained is at risk until a remaining group commits past
    /// it).
    pub fn leave(&self) {
        self.coordinator.unregister(&self.offsets);
    }

    /// Total committed records across partitions.
    pub fn total_committed(&self) -> u64 {
        (0..self.topic.partition_count())
            .map(|p| self.offsets.committed(p))
            .sum()
    }

    /// Lag: records appended but not yet committed by this group.
    pub fn total_lag(&self) -> u64 {
        (0..self.topic.partition_count())
            .map(|p| {
                self.topic
                    .partition(p)
                    .high_watermark()
                    .saturating_sub(self.offsets.committed(p))
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(partitions: u32, members: u32) -> (Arc<Topic>, Arc<ConsumerGroup>) {
        let topic = Arc::new(Topic::new("t", partitions, 4096));
        let coord = Arc::new(PruneCoordinator::new(topic.clone()));
        let group = ConsumerGroup::new("g", topic.clone(), coord, members);
        (topic, group)
    }

    fn rec(key: u32) -> Record {
        Record::new(key, vec![0u8; 27], 0)
    }

    #[test]
    fn assignment_covers_all_partitions_exactly_once() {
        let (_, g) = setup(8, 3);
        let mut seen = vec![0u32; 8];
        for m in 0..3 {
            for p in g.assignment(m) {
                seen[p as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn poll_returns_appended_records() {
        let (t, g) = setup(2, 1);
        for k in 0..100 {
            t.produce(rec(k), 0).unwrap();
        }
        let mut total = 0;
        while let Ok(Some(batch)) = g.poll(0, 32) {
            total += batch.record_count();
            g.commit(batch.partition, batch.next_offset);
            if total >= 100 {
                break;
            }
        }
        assert_eq!(total, 100);
        assert_eq!(g.total_committed(), 100);
        assert_eq!(g.total_lag(), 0);
    }

    #[test]
    fn commit_prunes_when_sole_group() {
        let (t, g) = setup(1, 1);
        for k in 0..10 {
            t.produce(rec(k), 0).unwrap();
        }
        let batch = g.poll(0, 10).unwrap().unwrap();
        g.commit(batch.partition, batch.next_offset);
        assert_eq!(t.partition(0).low_watermark(), 10);
        assert_eq!(t.total_lag(), 0);
    }

    #[test]
    fn second_group_blocks_pruning_until_it_commits() {
        let topic = Arc::new(Topic::new("t", 1, 4096));
        let coord = Arc::new(PruneCoordinator::new(topic.clone()));
        let g1 = ConsumerGroup::new("g1", topic.clone(), coord.clone(), 1);
        let g2 = ConsumerGroup::new("g2", topic.clone(), coord, 1);
        for k in 0..5 {
            topic.produce(rec(k), 0).unwrap();
        }
        let b = g1.poll(0, 10).unwrap().unwrap();
        g1.commit(b.partition, b.next_offset);
        assert_eq!(topic.partition(0).low_watermark(), 0, "g2 has not committed");
        let b = g2.poll(0, 10).unwrap().unwrap();
        g2.commit(b.partition, b.next_offset);
        assert_eq!(topic.partition(0).low_watermark(), 5);
    }

    #[test]
    fn poll_after_close_and_drain_errors() {
        let (t, g) = setup(1, 1);
        t.produce(rec(1), 0).unwrap();
        t.close();
        // First poll drains the remaining record…
        let b = g.poll(0, 10).unwrap();
        assert!(b.is_none() || b.unwrap().record_count() == 1);
        // …after which the group reports closure.
        assert_eq!(g.poll(0, 10).err(), Some(PartitionClosed));
    }

    #[test]
    fn left_group_no_longer_blocks_pruning() {
        let topic = Arc::new(Topic::new("t", 1, 4096));
        let coord = Arc::new(PruneCoordinator::new(topic.clone()));
        let g1 = ConsumerGroup::new("dead", topic.clone(), coord.clone(), 1);
        let g2 = ConsumerGroup::new("live", topic.clone(), coord, 1);
        for k in 0..5 {
            topic.produce(rec(k), 0).unwrap();
        }
        let b = g2.poll(0, 10).unwrap().unwrap();
        g2.commit(b.partition, b.next_offset);
        assert_eq!(topic.partition(0).low_watermark(), 0, "dead group pins the log");
        g1.leave();
        // Any later commit re-evaluates the prune point without g1's veto.
        g2.commit(0, 5);
        assert_eq!(topic.partition(0).low_watermark(), 5);
    }

    #[test]
    fn seek_rewinds_and_replays_uncommitted_records() {
        let (t, g) = setup(1, 1);
        for k in 0..20 {
            t.produce(rec(k), 0).unwrap();
        }
        let b = g.poll(0, 20).unwrap().unwrap();
        assert_eq!(b.record_count(), 20);
        assert_eq!(g.position(0), 20);
        // No commit happened (checkpoint-gated), so the log retains
        // everything and a seek-back replays the same records.
        g.seek(0, 5);
        assert_eq!(g.position(0), 5);
        let b = g.poll(0, 20).unwrap().unwrap();
        assert_eq!(b.record_count(), 15, "offsets 5..20 replayed");
        assert_eq!(b.next_offset, 20);
    }

    #[test]
    fn members_see_disjoint_records() {
        let (t, g) = setup(4, 2);
        for k in 0..1000 {
            t.produce(rec(k), 0).unwrap();
        }
        let mut got = [0usize; 2];
        for m in 0..2 {
            while let Ok(Some(batch)) = g.poll(m, 64) {
                got[m as usize] += batch.record_count();
                g.commit(batch.partition, batch.next_offset);
            }
        }
        assert_eq!(got[0] + got[1], 1000);
        assert!(got[0] > 0 && got[1] > 0);
    }
}
