//! Broker record: key + payload view + timestamps.
//!
//! Payload storage is a shared `Arc<[u8]>` plus an `(offset, len)` view:
//! producers serialize a whole chunk into one arena allocation and carve
//! per-record views out of it (one allocation per *chunk*, not per
//! event — EXPERIMENTS.md §Perf), while fan-out to multiple consumer
//! groups still only clones pointers.

use std::sync::Arc;

/// One record in a partition log.
#[derive(Clone, Debug)]
pub struct Record {
    /// Partitioning key (sensor id for the default workload).
    pub key: u32,
    data: Arc<[u8]>,
    off: u32,
    len: u32,
    /// Time the event was *generated* (drives end-to-end latency).
    pub gen_ts_micros: u64,
    /// Time the broker appended it (drives broker latency); set on append.
    pub append_ts_micros: u64,
}

impl Record {
    /// Standalone record owning its own allocation.
    pub fn new(key: u32, payload: impl Into<Arc<[u8]>>, gen_ts_micros: u64) -> Self {
        let data: Arc<[u8]> = payload.into();
        let len = data.len() as u32;
        Self {
            key,
            data,
            off: 0,
            len,
            gen_ts_micros,
            append_ts_micros: 0,
        }
    }

    /// A view into a shared arena (chunked producer path).
    pub fn from_arena(
        key: u32,
        arena: Arc<[u8]>,
        off: usize,
        len: usize,
        gen_ts_micros: u64,
    ) -> Self {
        debug_assert!(off + len <= arena.len());
        Self {
            key,
            data: arena,
            off: off as u32,
            len: len as u32,
            gen_ts_micros,
            append_ts_micros: 0,
        }
    }

    #[inline]
    pub fn payload(&self) -> &[u8] {
        &self.data[self.off as usize..(self.off + self.len) as usize]
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when two records share the same backing allocation.
    pub fn shares_storage_with(&self, other: &Record) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Backing arena and view range — lets `RecordBatch` wrap a record
    /// without copying its payload (crate-internal bridge).
    pub(crate) fn storage(&self) -> (Arc<[u8]>, u32, u32) {
        (self.data.clone(), self.off, self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fanout_shares_payload() {
        let r = Record::new(7, vec![1u8, 2, 3], 100);
        let r2 = r.clone();
        assert!(r.shares_storage_with(&r2));
        assert_eq!(r2.len(), 3);
        assert_eq!(r2.key, 7);
        assert_eq!(r2.payload(), &[1, 2, 3]);
    }

    #[test]
    fn arena_views_are_disjoint_but_shared() {
        let arena: Arc<[u8]> = vec![9u8, 8, 7, 6, 5, 4].into();
        let a = Record::from_arena(1, arena.clone(), 0, 3, 10);
        let b = Record::from_arena(2, arena, 3, 3, 11);
        assert_eq!(a.payload(), &[9, 8, 7]);
        assert_eq!(b.payload(), &[6, 5, 4]);
        assert!(a.shares_storage_with(&b));
        assert_eq!(a.len(), 3);
    }
}
