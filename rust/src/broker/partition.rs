//! Bounded segmented partition log.
//!
//! A partition is an append-only record log addressed by offset.  Capacity
//! is bounded: when `hwm - low_watermark >= capacity` the producer blocks
//! until consumers advance and [`Partition::prune`] reclaims — this is the
//! broker-side backpressure that keeps Fig. 6's broker latency linear in
//! offered load instead of unbounded.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use super::record::Record;

struct Log {
    /// Records from `base_offset` upward.
    records: VecDeque<Record>,
    base_offset: u64,
    /// Next offset to assign (high watermark).
    hwm: u64,
    /// Everything below this is consumed by all groups and reclaimable.
    low_watermark: u64,
    closed: bool,
    /// Cumulative appended bytes (stats).
    appended_bytes: u64,
}

/// One partition of a topic.
pub struct Partition {
    log: Mutex<Log>,
    space: Condvar,
    data: Condvar,
    capacity: usize,
}

#[derive(Debug, PartialEq, Eq)]
pub struct PartitionClosed;

impl Partition {
    pub fn new(capacity: usize) -> Self {
        Self {
            log: Mutex::new(Log {
                records: VecDeque::new(),
                base_offset: 0,
                hwm: 0,
                low_watermark: 0,
                closed: false,
                appended_bytes: 0,
            }),
            space: Condvar::new(),
            data: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Append one record, blocking while the partition is at capacity.
    /// Stamps `append_ts_micros`. Returns the assigned offset.
    pub fn append(&self, mut record: Record, now_micros: u64) -> Result<u64, PartitionClosed> {
        let mut log = self.log.lock().expect("partition log");
        while (log.hwm - log.low_watermark) as usize >= self.capacity && !log.closed {
            log = self.space.wait(log).expect("partition log");
        }
        if log.closed {
            return Err(PartitionClosed);
        }
        let offset = log.hwm;
        record.append_ts_micros = now_micros;
        log.appended_bytes += record.len() as u64;
        log.records.push_back(record);
        log.hwm += 1;
        drop(log);
        self.data.notify_all();
        Ok(offset)
    }

    /// Append a batch (one lock acquisition; producer batching path).
    pub fn append_batch(
        &self,
        records: &mut Vec<Record>,
        now_micros: u64,
    ) -> Result<u64, PartitionClosed> {
        if records.is_empty() {
            let log = self.log.lock().expect("partition log");
            return Ok(log.hwm);
        }
        let mut log = self.log.lock().expect("partition log");
        // Admit the batch as a unit once there is room for at least one
        // record; allowing slight overshoot keeps producers coarse-grained
        // (Kafka batches behave the same way).
        while (log.hwm - log.low_watermark) as usize >= self.capacity && !log.closed {
            log = self.space.wait(log).expect("partition log");
        }
        if log.closed {
            return Err(PartitionClosed);
        }
        for mut r in records.drain(..) {
            r.append_ts_micros = now_micros;
            log.appended_bytes += r.len() as u64;
            log.records.push_back(r);
            log.hwm += 1;
        }
        let last = log.hwm - 1;
        drop(log);
        self.data.notify_all();
        Ok(last)
    }

    /// Read up to `max` records starting at `offset` into `buf`.
    /// Returns the next offset to read. Blocks until data or close when
    /// `blocking`; a closed, fully-drained partition returns `Err`.
    pub fn fetch(
        &self,
        offset: u64,
        max: usize,
        buf: &mut Vec<Record>,
        blocking: bool,
    ) -> Result<u64, PartitionClosed> {
        let mut log = self.log.lock().expect("partition log");
        loop {
            if offset < log.hwm {
                let start = offset.max(log.base_offset);
                let idx = (start - log.base_offset) as usize;
                let n = max.min(log.records.len().saturating_sub(idx));
                for i in 0..n {
                    buf.push(log.records[idx + i].clone());
                }
                return Ok(start + n as u64);
            }
            if log.closed {
                return Err(PartitionClosed);
            }
            if !blocking {
                return Ok(offset);
            }
            log = self.data.wait(log).expect("partition log");
        }
    }

    /// Advance the low watermark (min committed offset across groups) and
    /// drop reclaimable records, releasing blocked producers.
    pub fn prune(&self, min_committed: u64) {
        let mut log = self.log.lock().expect("partition log");
        if min_committed <= log.low_watermark {
            return;
        }
        let lw = min_committed.min(log.hwm);
        log.low_watermark = lw;
        while log.base_offset < lw && !log.records.is_empty() {
            log.records.pop_front();
            log.base_offset += 1;
        }
        drop(log);
        self.space.notify_all();
    }

    /// Close the partition: producers error immediately, consumers drain.
    pub fn close(&self) {
        let mut log = self.log.lock().expect("partition log");
        log.closed = true;
        drop(log);
        self.space.notify_all();
        self.data.notify_all();
    }

    pub fn high_watermark(&self) -> u64 {
        self.log.lock().expect("partition log").hwm
    }

    pub fn low_watermark(&self) -> u64 {
        self.log.lock().expect("partition log").low_watermark
    }

    /// Records currently retained (hwm - low watermark): the queue depth.
    pub fn lag(&self) -> u64 {
        let log = self.log.lock().expect("partition log");
        log.hwm - log.low_watermark
    }

    pub fn appended_bytes(&self) -> u64 {
        self.log.lock().expect("partition log").appended_bytes
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn rec(key: u32, ts: u64) -> Record {
        Record::new(key, vec![0u8; 27], ts)
    }

    #[test]
    fn offsets_are_sequential() {
        let p = Partition::new(1024);
        for i in 0..10 {
            assert_eq!(p.append(rec(0, i), i).unwrap(), i);
        }
        assert_eq!(p.high_watermark(), 10);
    }

    #[test]
    fn fetch_reads_in_order_and_sets_next_offset() {
        let p = Partition::new(1024);
        for i in 0..5 {
            p.append(rec(i as u32, i), 100 + i).unwrap();
        }
        let mut buf = Vec::new();
        let next = p.fetch(0, 3, &mut buf, false).unwrap();
        assert_eq!(next, 3);
        assert_eq!(buf.len(), 3);
        assert_eq!(buf[0].key, 0);
        assert_eq!(buf[2].key, 2);
        assert_eq!(buf[0].append_ts_micros, 100);
        let next = p.fetch(next, 10, &mut buf, false).unwrap();
        assert_eq!(next, 5);
        assert_eq!(buf.len(), 5);
    }

    #[test]
    fn nonblocking_fetch_at_hwm_returns_same_offset() {
        let p = Partition::new(16);
        let mut buf = Vec::new();
        assert_eq!(p.fetch(0, 8, &mut buf, false).unwrap(), 0);
        assert!(buf.is_empty());
    }

    #[test]
    fn capacity_blocks_producer_until_prune() {
        let p = Arc::new(Partition::new(4));
        for i in 0..4 {
            p.append(rec(0, i), i).unwrap();
        }
        let p2 = p.clone();
        let producer = std::thread::spawn(move || p2.append(rec(9, 99), 99).map(|_| ()));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!producer.is_finished(), "producer should be backpressured");
        p.prune(2);
        producer.join().unwrap().unwrap();
        assert_eq!(p.high_watermark(), 5);
        assert_eq!(p.lag(), 3);
    }

    #[test]
    fn prune_drops_consumed_records_but_keeps_unconsumed() {
        let p = Partition::new(64);
        for i in 0..10 {
            p.append(rec(i as u32, i), i).unwrap();
        }
        p.prune(6);
        let mut buf = Vec::new();
        let next = p.fetch(6, 10, &mut buf, false).unwrap();
        assert_eq!(next, 10);
        assert_eq!(buf.len(), 4);
        assert_eq!(buf[0].key, 6);
        // Fetching below the low watermark silently clamps forward.
        buf.clear();
        let next = p.fetch(0, 10, &mut buf, false).unwrap();
        assert_eq!(next, 10);
        assert_eq!(buf[0].key, 6);
    }

    #[test]
    fn prune_never_rewinds() {
        let p = Partition::new(64);
        for i in 0..4 {
            p.append(rec(0, i), i).unwrap();
        }
        p.prune(3);
        p.prune(1); // no-op
        assert_eq!(p.low_watermark(), 3);
    }

    #[test]
    fn close_unblocks_everyone() {
        let p = Arc::new(Partition::new(2));
        p.append(rec(0, 0), 0).unwrap();
        p.append(rec(0, 1), 1).unwrap();
        let pc = p.clone();
        let blocked_producer = std::thread::spawn(move || pc.append(rec(0, 2), 2));
        let pf = p.clone();
        let blocked_consumer = std::thread::spawn(move || {
            let mut buf = Vec::new();
            // Drain the two records, then block at hwm.
            let next = pf.fetch(0, 10, &mut buf, true).unwrap();
            pf.fetch(next, 10, &mut buf, true)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        p.close();
        assert_eq!(blocked_producer.join().unwrap(), Err(PartitionClosed));
        assert_eq!(blocked_consumer.join().unwrap(), Err(PartitionClosed));
    }

    #[test]
    fn append_batch_assigns_contiguous_offsets() {
        let p = Partition::new(64);
        let mut batch: Vec<Record> = (0..5).map(|i| rec(i as u32, i)).collect();
        let last = p.append_batch(&mut batch, 500).unwrap();
        assert_eq!(last, 4);
        assert!(batch.is_empty());
        let mut buf = Vec::new();
        p.fetch(0, 10, &mut buf, false).unwrap();
        assert!(buf.iter().all(|r| r.append_ts_micros == 500));
    }

    #[test]
    fn appended_bytes_accumulates() {
        let p = Partition::new(8);
        p.append(rec(0, 0), 0).unwrap();
        p.append(rec(0, 1), 1).unwrap();
        assert_eq!(p.appended_bytes(), 54);
    }
}
