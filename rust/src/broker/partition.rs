//! Bounded segmented partition log, batch-first.
//!
//! A partition is an append-only log addressed by *record* offset but
//! stored as [`RecordBatch`]es: one `Mutex` acquisition and one condvar
//! handshake admits or serves a whole batch, so harness overhead is
//! amortized over hundreds of records (the data-plane batching refactor —
//! see docs/ARCHITECTURE.md §Data plane batching).  Watermarks still count
//! records: when `hwm - low_watermark >= capacity` the producer blocks
//! until consumers advance and [`Partition::prune`] reclaims — the
//! broker-side backpressure that keeps Fig. 6's broker latency linear in
//! offered load instead of unbounded.
//!
//! Fetching at an offset that lands mid-batch returns a cheap sliced view
//! (`RecordBatch::slice`), never a payload copy; pruning that lands
//! mid-batch likewise retains a sliced tail.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};

use super::batch::RecordBatch;
use super::record::Record;

struct Log {
    /// Batches from `base_offset` upward; record offsets are contiguous
    /// across batches (`batches[i].base_offset + batches[i].len()` is
    /// `batches[i+1].base_offset`).
    batches: VecDeque<RecordBatch>,
    /// Offset of the first retained record.
    base_offset: u64,
    /// Next offset to assign (high watermark).
    hwm: u64,
    /// Everything below this is consumed by all groups and reclaimable.
    low_watermark: u64,
    closed: bool,
    /// Cumulative appended bytes (stats).
    appended_bytes: u64,
}

impl Log {
    /// Index into `batches` of the batch containing record `offset`.
    fn batch_index(&self, offset: u64) -> usize {
        // Batches are sorted by base_offset; partition_point finds the
        // first batch starting *after* offset, so the one before holds it.
        self.batches
            .partition_point(|b| b.base_offset <= offset)
            .saturating_sub(1)
    }
}

/// One partition of a topic.
pub struct Partition {
    log: Mutex<Log>,
    space: Condvar,
    data: Condvar,
    capacity: usize,
    /// Fault-injection switch (`fault.schedule: stall_partition`): while
    /// set, fetches serve no data — consumers see an empty poll and retry,
    /// producers keep appending until capacity backpressures them.
    stalled: AtomicBool,
}

#[derive(Debug, PartialEq, Eq)]
pub struct PartitionClosed;

impl Partition {
    pub fn new(capacity: usize) -> Self {
        Self {
            log: Mutex::new(Log {
                batches: VecDeque::new(),
                base_offset: 0,
                hwm: 0,
                low_watermark: 0,
                closed: false,
                appended_bytes: 0,
            }),
            space: Condvar::new(),
            data: Condvar::new(),
            capacity: capacity.max(1),
            stalled: AtomicBool::new(false),
        }
    }

    /// Freeze or release fetches (fault injection).  A stalled partition
    /// behaves like a broker node that stopped answering fetch requests:
    /// appended data is retained but not served until the stall clears.
    pub fn set_stalled(&self, stalled: bool) {
        self.stalled.store(stalled, Ordering::Release);
        if !stalled {
            self.data.notify_all();
        }
    }

    pub fn is_stalled(&self) -> bool {
        self.stalled.load(Ordering::Acquire)
    }

    /// Append a whole batch under one lock acquisition: stamps the batch's
    /// shared `append_ts_micros` and assigns its `base_offset`.  Blocks
    /// while the partition is at capacity; the batch is admitted as a unit
    /// once there is room for at least one record (slight overshoot keeps
    /// producers coarse-grained — Kafka batches behave the same way).
    /// Returns the offset of the batch's first record.
    pub fn append_record_batch(
        &self,
        mut batch: RecordBatch,
        now_micros: u64,
    ) -> Result<u64, PartitionClosed> {
        let mut log = self.log.lock().expect("partition log");
        if batch.is_empty() {
            return Ok(log.hwm);
        }
        while (log.hwm - log.low_watermark) as usize >= self.capacity && !log.closed {
            log = self.space.wait(log).expect("partition log");
        }
        if log.closed {
            return Err(PartitionClosed);
        }
        batch.append_ts_micros = now_micros;
        let base = log.hwm;
        batch.base_offset = base;
        log.hwm += batch.len() as u64;
        log.appended_bytes += batch.payload_bytes();
        log.batches.push_back(batch);
        drop(log);
        self.data.notify_all();
        Ok(base)
    }

    /// Append one record (legacy per-record path): wraps it in a
    /// single-record batch sharing its arena.  Returns the assigned offset.
    pub fn append(&self, record: Record, now_micros: u64) -> Result<u64, PartitionClosed> {
        self.append_record_batch(RecordBatch::from_record(&record), now_micros)
    }

    /// Append a `Vec<Record>` as one batch (compatibility path: copies the
    /// payloads into a single fresh arena).  Returns the last offset.
    pub fn append_batch(
        &self,
        records: &mut Vec<Record>,
        now_micros: u64,
    ) -> Result<u64, PartitionClosed> {
        if records.is_empty() {
            let log = self.log.lock().expect("partition log");
            return Ok(log.hwm);
        }
        let n = records.len() as u64;
        let batch = RecordBatch::from_records(records);
        records.clear();
        self.append_record_batch(batch, now_micros)
            .map(|base| base + n - 1)
    }

    /// Read up to `max` records starting at `offset` as batch views pushed
    /// into `out` (boundary batches are sliced — no payload copies).
    /// Returns the next offset to read.  Blocks until data or close when
    /// `blocking`; a closed, fully-drained partition returns `Err`.
    pub fn fetch_batches(
        &self,
        offset: u64,
        max: usize,
        out: &mut Vec<RecordBatch>,
        blocking: bool,
    ) -> Result<u64, PartitionClosed> {
        if max == 0 {
            return Ok(offset);
        }
        let mut log = self.log.lock().expect("partition log");
        loop {
            // A stalled (fault-injected) partition serves nothing until
            // released; close still wins so teardown drains are never stuck.
            if self.stalled.load(Ordering::Acquire) && !log.closed {
                if !blocking {
                    return Ok(offset);
                }
                log = self.data.wait(log).expect("partition log");
                continue;
            }
            if offset < log.hwm {
                // Fetching below the low watermark silently clamps forward.
                let start = offset.max(log.base_offset);
                let mut pos = start;
                let mut remaining = max;
                let mut idx = log.batch_index(start);
                while remaining > 0 && pos < log.hwm {
                    let b = &log.batches[idx];
                    let skip = (pos - b.base_offset) as usize;
                    let take = (b.len() - skip).min(remaining);
                    out.push(if skip == 0 && take == b.len() {
                        b.clone()
                    } else {
                        b.slice(skip, take)
                    });
                    pos += take as u64;
                    remaining -= take;
                    idx += 1;
                }
                return Ok(pos);
            }
            if log.closed {
                return Err(PartitionClosed);
            }
            if !blocking {
                return Ok(offset);
            }
            log = self.data.wait(log).expect("partition log");
        }
    }

    /// Read up to `max` records starting at `offset` into `buf` as
    /// materialized [`Record`]s (compatibility view; payload `Arc`s are
    /// shared, not copied).  Returns the next offset to read.
    pub fn fetch(
        &self,
        offset: u64,
        max: usize,
        buf: &mut Vec<Record>,
        blocking: bool,
    ) -> Result<u64, PartitionClosed> {
        let mut batches = Vec::new();
        let next = self.fetch_batches(offset, max, &mut batches, blocking)?;
        for b in &batches {
            for i in 0..b.len() {
                buf.push(b.record(i));
            }
        }
        Ok(next)
    }

    /// Advance the low watermark (min committed offset across groups) and
    /// drop reclaimable batches, releasing blocked producers.  A watermark
    /// landing mid-batch retains a sliced tail view.
    pub fn prune(&self, min_committed: u64) {
        let mut log = self.log.lock().expect("partition log");
        if min_committed <= log.low_watermark {
            return;
        }
        let lw = min_committed.min(log.hwm);
        log.low_watermark = lw;
        while let Some(front) = log.batches.front() {
            if front.next_offset() <= lw {
                log.batches.pop_front();
            } else if front.base_offset < lw {
                let skip = (lw - front.base_offset) as usize;
                let tail = front.slice(skip, front.len() - skip);
                log.batches[0] = tail;
                break;
            } else {
                break;
            }
        }
        log.base_offset = match log.batches.front() {
            Some(b) => b.base_offset,
            None => lw,
        };
        drop(log);
        self.space.notify_all();
    }

    /// Close the partition: producers error immediately, consumers drain.
    pub fn close(&self) {
        let mut log = self.log.lock().expect("partition log");
        log.closed = true;
        drop(log);
        self.space.notify_all();
        self.data.notify_all();
    }

    pub fn high_watermark(&self) -> u64 {
        self.log.lock().expect("partition log").hwm
    }

    pub fn low_watermark(&self) -> u64 {
        self.log.lock().expect("partition log").low_watermark
    }

    /// Records currently retained (hwm - low watermark): the queue depth.
    pub fn lag(&self) -> u64 {
        let log = self.log.lock().expect("partition log");
        log.hwm - log.low_watermark
    }

    pub fn appended_bytes(&self) -> u64 {
        self.log.lock().expect("partition log").appended_bytes
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::batch::RecordBatchBuilder;
    use std::sync::Arc;

    fn rec(key: u32, ts: u64) -> Record {
        Record::new(key, vec![0u8; 27], ts)
    }

    fn batch(keys: std::ops::Range<u32>, ts: u64) -> RecordBatch {
        let mut b = RecordBatchBuilder::new();
        for k in keys {
            b.push(k, &[0u8; 27], ts);
        }
        b.build()
    }

    #[test]
    fn offsets_are_sequential() {
        let p = Partition::new(1024);
        for i in 0..10 {
            assert_eq!(p.append(rec(0, i), i).unwrap(), i);
        }
        assert_eq!(p.high_watermark(), 10);
    }

    #[test]
    fn fetch_reads_in_order_and_sets_next_offset() {
        let p = Partition::new(1024);
        for i in 0..5 {
            p.append(rec(i as u32, i), 100 + i).unwrap();
        }
        let mut buf = Vec::new();
        let next = p.fetch(0, 3, &mut buf, false).unwrap();
        assert_eq!(next, 3);
        assert_eq!(buf.len(), 3);
        assert_eq!(buf[0].key, 0);
        assert_eq!(buf[2].key, 2);
        assert_eq!(buf[0].append_ts_micros, 100);
        let next = p.fetch(next, 10, &mut buf, false).unwrap();
        assert_eq!(next, 5);
        assert_eq!(buf.len(), 5);
    }

    #[test]
    fn fetch_batches_slices_at_boundaries() {
        let p = Partition::new(1024);
        p.append_record_batch(batch(0..8, 100), 100).unwrap();
        p.append_record_batch(batch(8..16, 200), 200).unwrap();
        // Start mid-batch, cap mid-second-batch: 5..13 → [5..8), [8..13).
        let mut out = Vec::new();
        let next = p.fetch_batches(5, 8, &mut out, false).unwrap();
        assert_eq!(next, 13);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].base_offset, 5);
        assert_eq!(out[0].len(), 3);
        assert_eq!(out[0].get(0).key, 5);
        assert_eq!(out[0].append_ts_micros, 100);
        assert_eq!(out[1].base_offset, 8);
        assert_eq!(out[1].len(), 5);
        assert_eq!(out[1].get(4).key, 12);
        assert_eq!(out[1].append_ts_micros, 200);
    }

    #[test]
    fn nonblocking_fetch_at_hwm_returns_same_offset() {
        let p = Partition::new(16);
        let mut buf = Vec::new();
        assert_eq!(p.fetch(0, 8, &mut buf, false).unwrap(), 0);
        assert!(buf.is_empty());
    }

    #[test]
    fn capacity_blocks_producer_until_prune() {
        let p = Arc::new(Partition::new(4));
        for i in 0..4 {
            p.append(rec(0, i), i).unwrap();
        }
        let p2 = p.clone();
        let producer = std::thread::spawn(move || p2.append(rec(9, 99), 99).map(|_| ()));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!producer.is_finished(), "producer should be backpressured");
        p.prune(2);
        producer.join().unwrap().unwrap();
        assert_eq!(p.high_watermark(), 5);
        assert_eq!(p.lag(), 3);
    }

    #[test]
    fn prune_drops_consumed_records_but_keeps_unconsumed() {
        let p = Partition::new(64);
        for i in 0..10 {
            p.append(rec(i as u32, i), i).unwrap();
        }
        p.prune(6);
        let mut buf = Vec::new();
        let next = p.fetch(6, 10, &mut buf, false).unwrap();
        assert_eq!(next, 10);
        assert_eq!(buf.len(), 4);
        assert_eq!(buf[0].key, 6);
        // Fetching below the low watermark silently clamps forward.
        buf.clear();
        let next = p.fetch(0, 10, &mut buf, false).unwrap();
        assert_eq!(next, 10);
        assert_eq!(buf[0].key, 6);
    }

    #[test]
    fn prune_mid_batch_retains_sliced_tail() {
        let p = Partition::new(64);
        p.append_record_batch(batch(0..10, 7), 7).unwrap();
        p.prune(4);
        assert_eq!(p.low_watermark(), 4);
        assert_eq!(p.lag(), 6);
        let mut out = Vec::new();
        let next = p.fetch_batches(0, 100, &mut out, false).unwrap();
        assert_eq!(next, 10);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].base_offset, 4);
        assert_eq!(out[0].get(0).key, 4);
    }

    #[test]
    fn prune_never_rewinds() {
        let p = Partition::new(64);
        for i in 0..4 {
            p.append(rec(0, i), i).unwrap();
        }
        p.prune(3);
        p.prune(1); // no-op
        assert_eq!(p.low_watermark(), 3);
    }

    #[test]
    fn close_unblocks_everyone() {
        let p = Arc::new(Partition::new(2));
        p.append(rec(0, 0), 0).unwrap();
        p.append(rec(0, 1), 1).unwrap();
        let pc = p.clone();
        let blocked_producer = std::thread::spawn(move || pc.append(rec(0, 2), 2));
        let pf = p.clone();
        let blocked_consumer = std::thread::spawn(move || {
            let mut buf = Vec::new();
            // Drain the two records, then block at hwm.
            let next = pf.fetch(0, 10, &mut buf, true).unwrap();
            pf.fetch(next, 10, &mut buf, true)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        p.close();
        assert_eq!(blocked_producer.join().unwrap(), Err(PartitionClosed));
        assert_eq!(blocked_consumer.join().unwrap(), Err(PartitionClosed));
    }

    #[test]
    fn append_batch_assigns_contiguous_offsets() {
        let p = Partition::new(64);
        let mut batch: Vec<Record> = (0..5).map(|i| rec(i as u32, i)).collect();
        let last = p.append_batch(&mut batch, 500).unwrap();
        assert_eq!(last, 4);
        assert!(batch.is_empty());
        let mut buf = Vec::new();
        p.fetch(0, 10, &mut buf, false).unwrap();
        assert!(buf.iter().all(|r| r.append_ts_micros == 500));
    }

    #[test]
    fn stalled_partition_serves_nothing_until_released() {
        let p = Partition::new(64);
        for i in 0..4 {
            p.append(rec(i as u32, i), i).unwrap();
        }
        p.set_stalled(true);
        assert!(p.is_stalled());
        let mut buf = Vec::new();
        // Non-blocking fetch looks like an empty poll, not an error.
        assert_eq!(p.fetch(0, 10, &mut buf, false).unwrap(), 0);
        assert!(buf.is_empty());
        // Producers keep appending while stalled.
        p.append(rec(9, 9), 9).unwrap();
        // A blocking fetch parks until the stall is released.
        let p2 = Arc::new(Partition::new(64));
        p2.append(rec(0, 0), 0).unwrap();
        p2.set_stalled(true);
        let pf = p2.clone();
        let fetcher = std::thread::spawn(move || {
            let mut b = Vec::new();
            pf.fetch(0, 10, &mut b, true).map(|next| (next, b.len()))
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!fetcher.is_finished(), "fetcher should wait out the stall");
        p2.set_stalled(false);
        assert_eq!(fetcher.join().unwrap().unwrap(), (1, 1));
        // Release on the first partition serves the retained backlog.
        p.set_stalled(false);
        assert_eq!(p.fetch(0, 10, &mut buf, false).unwrap(), 5);
        assert_eq!(buf.len(), 5);
    }

    #[test]
    fn close_wins_over_stall_for_drains() {
        let p = Partition::new(64);
        p.append(rec(0, 0), 0).unwrap();
        p.set_stalled(true);
        p.close();
        let mut buf = Vec::new();
        // Teardown drains still see the data even if a stall was pending.
        assert_eq!(p.fetch(0, 10, &mut buf, true).unwrap(), 1);
        assert_eq!(buf.len(), 1);
        assert_eq!(p.fetch(1, 10, &mut buf, true), Err(PartitionClosed));
    }

    #[test]
    fn appended_bytes_accumulates() {
        let p = Partition::new(8);
        p.append(rec(0, 0), 0).unwrap();
        p.append(rec(0, 1), 1).unwrap();
        assert_eq!(p.appended_bytes(), 54);
    }
}
