//! `RecordBatch`: the unit of the data plane's hot path.
//!
//! A batch is a shared `Arc<[u8]>` payload arena plus a packed entry table
//! of `(key, off, len, gen_ts)` per record, one `append_ts` stamp for the
//! whole batch, and the partition offset of its first record.  Everything
//! that moves through the broker — producer appends, the partition log,
//! consumer polls — moves whole batches, so the lock/condvar handshake and
//! the refcount traffic are amortized over hundreds of records instead of
//! paid per event (ShuffleBench's "harness must never be the bottleneck"
//! rule; SProBench's >10× throughput headline depends on it).
//!
//! Slicing a batch (`slice`) is two `Arc` clones plus range arithmetic, so
//! a fetch that starts mid-batch or a prune that lands mid-batch never
//! copies payload bytes.  The per-record [`Record`] type remains as a thin
//! compatibility view materialized on demand ([`RecordBatch::record`]).

use std::sync::Arc;

use super::record::Record;

/// Packed per-record entry in a batch: 24 bytes, no payload indirection.
#[derive(Clone, Copy, Debug)]
pub struct BatchEntry {
    /// Partitioning key (sensor id for the default workload).
    pub key: u32,
    off: u32,
    len: u32,
    /// Time the event was generated (end-to-end latency anchor).
    pub gen_ts_micros: u64,
}

impl BatchEntry {
    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// A borrowed view of one record inside a batch — the zero-copy analog of
/// [`Record`] for consumers that only need to look, not own.
#[derive(Clone, Copy, Debug)]
pub struct RecordView<'a> {
    pub key: u32,
    pub payload: &'a [u8],
    pub gen_ts_micros: u64,
    /// Broker append stamp — shared by every record in the batch.
    pub append_ts_micros: u64,
}

impl RecordView<'_> {
    #[inline]
    pub fn len(&self) -> usize {
        self.payload.len()
    }

    pub fn is_empty(&self) -> bool {
        self.payload.is_empty()
    }

    /// Materialize an owning [`Record`] (copies the payload).
    pub fn to_record(&self) -> Record {
        let mut r = Record::new(self.key, self.payload.to_vec(), self.gen_ts_micros);
        r.append_ts_micros = self.append_ts_micros;
        r
    }
}

/// An immutable batch of records sharing one payload arena.
///
/// Cloning is cheap (two `Arc` bumps); the entry range makes sliced views
/// equally cheap.  `base_offset` and `append_ts_micros` are stamped once by
/// the partition on append.
#[derive(Clone, Debug)]
pub struct RecordBatch {
    arena: Arc<[u8]>,
    entries: Arc<[BatchEntry]>,
    /// View range into `entries`.
    start: u32,
    count: u32,
    /// Partition offset of the first record in this view.
    pub base_offset: u64,
    /// Broker append time — one stamp for the whole batch.
    pub append_ts_micros: u64,
}

impl RecordBatch {
    /// A single-record batch sharing the record's existing arena — the
    /// zero-copy bridge for the legacy per-record produce path.
    pub fn from_record(r: &Record) -> Self {
        let (arena, off, len) = r.storage();
        let entries: Arc<[BatchEntry]> = Arc::from(vec![BatchEntry {
            key: r.key,
            off,
            len,
            gen_ts_micros: r.gen_ts_micros,
        }]);
        Self {
            arena,
            entries,
            start: 0,
            count: 1,
            base_offset: 0,
            append_ts_micros: 0,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Offset one past the last record in this view.
    #[inline]
    pub fn next_offset(&self) -> u64 {
        self.base_offset + self.count as u64
    }

    #[inline]
    pub fn entry(&self, i: usize) -> &BatchEntry {
        &self.entries[self.start as usize + i]
    }

    #[inline]
    pub fn payload(&self, i: usize) -> &[u8] {
        let e = self.entry(i);
        &self.arena[e.off as usize..(e.off + e.len) as usize]
    }

    #[inline]
    pub fn get(&self, i: usize) -> RecordView<'_> {
        let e = self.entry(i);
        RecordView {
            key: e.key,
            payload: &self.arena[e.off as usize..(e.off + e.len) as usize],
            gen_ts_micros: e.gen_ts_micros,
            append_ts_micros: self.append_ts_micros,
        }
    }

    /// Iterate the records as borrowed views (no clones, no locks).
    pub fn iter(&self) -> impl Iterator<Item = RecordView<'_>> {
        (0..self.len()).map(move |i| self.get(i))
    }

    /// Total payload bytes in this view.
    pub fn payload_bytes(&self) -> u64 {
        (0..self.len()).map(|i| self.entry(i).len() as u64).sum()
    }

    /// Cheap sub-view of records `[from, from + count)`; `base_offset`
    /// advances by `from`.  Panics when the range exceeds the view.
    pub fn slice(&self, from: usize, count: usize) -> RecordBatch {
        assert!(from + count <= self.len(), "slice out of range");
        RecordBatch {
            arena: self.arena.clone(),
            entries: self.entries.clone(),
            start: self.start + from as u32,
            count: count as u32,
            base_offset: self.base_offset + from as u64,
            append_ts_micros: self.append_ts_micros,
        }
    }

    /// Materialize record `i` as an owning [`Record`] sharing the arena —
    /// the compatibility view for per-record consumers.
    pub fn record(&self, i: usize) -> Record {
        let e = self.entry(i);
        let mut r = Record::from_arena(
            e.key,
            self.arena.clone(),
            e.off as usize,
            e.len as usize,
            e.gen_ts_micros,
        );
        r.append_ts_micros = self.append_ts_micros;
        r
    }

    /// True when two batches share the same backing arena.
    pub fn shares_storage_with(&self, other: &RecordBatch) -> bool {
        Arc::ptr_eq(&self.arena, &other.arena)
    }
}

/// Builds one [`RecordBatch`]: payloads are serialized straight into the
/// arena, entries packed alongside — no intermediate `Vec<Record>`.
#[derive(Default)]
pub struct RecordBatchBuilder {
    arena: Vec<u8>,
    entries: Vec<BatchEntry>,
}

impl RecordBatchBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(records: usize, bytes: usize) -> Self {
        Self {
            arena: Vec::with_capacity(bytes),
            entries: Vec::with_capacity(records),
        }
    }

    /// Append one record's payload to the arena.
    #[inline]
    pub fn push(&mut self, key: u32, payload: &[u8], gen_ts_micros: u64) {
        let off = self.arena.len() as u32;
        self.arena.extend_from_slice(payload);
        self.entries.push(BatchEntry {
            key,
            off,
            len: payload.len() as u32,
            gen_ts_micros,
        });
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn payload_bytes(&self) -> u64 {
        self.arena.len() as u64
    }

    /// Freeze into an immutable batch (offset/append stamp set on append).
    pub fn build(self) -> RecordBatch {
        let count = self.entries.len() as u32;
        RecordBatch {
            arena: self.arena.into(),
            entries: self.entries.into(),
            start: 0,
            count,
            base_offset: 0,
            append_ts_micros: 0,
        }
    }
}

impl RecordBatch {
    /// Copy a slice of `Record`s into a fresh single-arena batch — the
    /// compatibility bridge for producers still assembling `Vec<Record>`.
    pub fn from_records(records: &[Record]) -> RecordBatch {
        let bytes = records.iter().map(|r| r.len()).sum();
        let mut b = RecordBatchBuilder::with_capacity(records.len(), bytes);
        for r in records {
            b.push(r.key, r.payload(), r.gen_ts_micros);
        }
        b.build()
    }
}

/// Routes records into one [`RecordBatchBuilder`] per partition, so a
/// producer serializes a whole chunk and hands the broker ready-to-append
/// per-partition batches (one lock acquisition each).
pub struct PartitionedBatchBuilder {
    builders: Vec<RecordBatchBuilder>,
}

impl PartitionedBatchBuilder {
    pub fn new(partitions: u32) -> Self {
        Self {
            builders: (0..partitions).map(|_| RecordBatchBuilder::new()).collect(),
        }
    }

    #[inline]
    pub fn push(&mut self, partition: u32, key: u32, payload: &[u8], gen_ts_micros: u64) {
        self.builders[partition as usize].push(key, payload, gen_ts_micros);
    }

    pub fn total_records(&self) -> usize {
        self.builders.iter().map(|b| b.len()).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.builders.iter().map(|b| b.payload_bytes()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.builders.iter().all(|b| b.is_empty())
    }

    /// Non-empty `(partition, batch)` pairs, ready for appending.
    pub fn finish(self) -> Vec<(u32, RecordBatch)> {
        self.builders
            .into_iter()
            .enumerate()
            .filter(|(_, b)| !b.is_empty())
            .map(|(p, b)| (p as u32, b.build()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch_of(n: usize) -> RecordBatch {
        let mut b = RecordBatchBuilder::with_capacity(n, n * 4);
        for i in 0..n {
            b.push(i as u32, &[i as u8; 4], 100 + i as u64);
        }
        b.build()
    }

    #[test]
    fn builder_packs_entries_and_arena() {
        let rb = batch_of(3);
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.payload_bytes(), 12);
        assert_eq!(rb.get(1).key, 1);
        assert_eq!(rb.payload(1), &[1, 1, 1, 1]);
        assert_eq!(rb.get(2).gen_ts_micros, 102);
        assert_eq!(rb.iter().count(), 3);
    }

    #[test]
    fn slice_is_a_cheap_view() {
        let mut rb = batch_of(10);
        rb.base_offset = 50;
        rb.append_ts_micros = 999;
        let s = rb.slice(4, 3);
        assert_eq!(s.len(), 3);
        assert_eq!(s.base_offset, 54);
        assert_eq!(s.next_offset(), 57);
        assert_eq!(s.get(0).key, 4);
        assert_eq!(s.get(0).append_ts_micros, 999);
        assert!(s.shares_storage_with(&rb));
    }

    #[test]
    #[should_panic(expected = "slice out of range")]
    fn slice_past_end_panics() {
        batch_of(2).slice(1, 2);
    }

    #[test]
    fn record_compat_view_shares_arena() {
        let mut rb = batch_of(2);
        rb.append_ts_micros = 777;
        let r0 = rb.record(0);
        let r1 = rb.record(1);
        assert_eq!(r0.key, 0);
        assert_eq!(r0.append_ts_micros, 777);
        assert_eq!(r1.payload(), &[1, 1, 1, 1]);
        assert!(r0.shares_storage_with(&r1));
    }

    #[test]
    fn from_records_roundtrip() {
        let records = vec![
            Record::new(5, vec![1u8, 2, 3], 10),
            Record::new(6, vec![4u8, 5], 20),
        ];
        let rb = RecordBatch::from_records(&records);
        assert_eq!(rb.len(), 2);
        assert_eq!(rb.payload(0), &[1, 2, 3]);
        assert_eq!(rb.get(1).key, 6);
        assert_eq!(rb.get(1).gen_ts_micros, 20);
    }

    #[test]
    fn from_record_is_zero_copy() {
        let r = Record::new(9, vec![7u8; 8], 33);
        let rb = RecordBatch::from_record(&r);
        assert_eq!(rb.len(), 1);
        assert_eq!(rb.payload(0), &[7u8; 8]);
        // Shares the record's arena: materializing back shares storage.
        assert!(rb.record(0).shares_storage_with(&r));
    }

    #[test]
    fn partitioned_builder_routes() {
        let mut pb = PartitionedBatchBuilder::new(3);
        pb.push(0, 1, b"aa", 1);
        pb.push(2, 2, b"bb", 2);
        pb.push(0, 3, b"cc", 3);
        assert_eq!(pb.total_records(), 3);
        assert_eq!(pb.total_bytes(), 6);
        let parts = pb.finish();
        assert_eq!(parts.len(), 2, "empty partition elided");
        assert_eq!(parts[0].0, 0);
        assert_eq!(parts[0].1.len(), 2);
        assert_eq!(parts[0].1.get(1).key, 3);
        assert_eq!(parts[1].0, 2);
        assert_eq!(parts[1].1.payload(0), b"bb");
    }
}
