//! In-process message broker substrate (the paper's Apache Kafka role).
//!
//! The benchmark uses Kafka purely as a decoupling queue: the workload
//! generator produces to an *ingestion* topic, the engine consumes it and
//! produces results to an *egestion* topic (paper Fig. 4).  This substrate
//! reproduces the mechanisms that matter for the measurements:
//!
//! * topics split into **partitions** (the parallelism unit, Sec. 4 uses 4),
//! * partitions are bounded segmented logs — a full partition **blocks the
//!   producer**, which is the backpressure signal that shapes Fig. 6's
//!   latency curve,
//! * **consumer groups** with per-partition offsets and rebalancing,
//! * configurable **I/O and network thread pools** mirroring the paper's
//!   Kafka tuning ("20 threads for I/O and 10 threads for network"),
//! * per-record timestamps so broker latency (append → poll) is measurable.
//!
//! The data plane is **batch-first**: [`batch::RecordBatch`] (shared
//! payload arena + packed entries + one append stamp) is the unit moved
//! through produce, the partition log, and consumer polls; the per-record
//! [`Record`] remains as a thin compatibility view (see
//! docs/ARCHITECTURE.md §Data plane batching).
//!
//! Modules: [`batch`], [`record`], [`partition`], [`topic`], [`core`]
//! (the broker facade), [`consumer`].

pub mod batch;
pub mod consumer;
pub mod core;
pub mod partition;
pub mod record;
pub mod topic;

pub use batch::{BatchEntry, PartitionedBatchBuilder, RecordBatch, RecordBatchBuilder, RecordView};
pub use consumer::{ConsumerGroup, PolledBatch};
pub use core::{Broker, BrokerConfig, BrokerStats};
pub use record::Record;
pub use topic::{fib_slot, Topic};
