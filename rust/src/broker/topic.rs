//! Topic: a named set of partitions plus the partitioning function.

use std::sync::Arc;

use super::partition::{Partition, PartitionClosed};
use super::record::Record;

/// Fibonacci multiplicative hash of `key` into a slot in `[0, n)`.
///
/// This is the single routing function shared by broker partitioning
/// ([`Topic::partition_for_key`]) and the engine's keyed exchange
/// ([`crate::engine::exchange`]): both planes must agree on how a dense
/// sensor-id keyspace spreads, so a key's exchange route stays consistent
/// with the broker partition that carried it.
#[inline]
pub fn fib_slot(key: u32, n: u32) -> u32 {
    debug_assert!(n > 0);
    let h = (key as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    (h >> 33) as u32 % n
}

/// A named topic with `n` partitions.
pub struct Topic {
    pub name: String,
    partitions: Vec<Arc<Partition>>,
}

impl Topic {
    pub fn new(name: &str, partitions: u32, capacity_per_partition: usize) -> Self {
        Self {
            name: name.to_string(),
            partitions: (0..partitions)
                .map(|_| Arc::new(Partition::new(capacity_per_partition)))
                .collect(),
        }
    }

    pub fn partition_count(&self) -> u32 {
        self.partitions.len() as u32
    }

    pub fn partition(&self, idx: u32) -> &Arc<Partition> {
        &self.partitions[idx as usize]
    }

    /// Key → partition routing (Kafka's default: hash of key mod n).
    /// Fibonacci hashing spreads dense sensor-id keyspaces evenly.
    #[inline]
    pub fn partition_for_key(&self, key: u32) -> u32 {
        fib_slot(key, self.partition_count())
    }

    /// Append via key routing.
    pub fn produce(&self, record: Record, now_micros: u64) -> Result<u64, PartitionClosed> {
        let p = self.partition_for_key(record.key);
        self.partitions[p as usize].append(record, now_micros)
    }

    /// Total records appended across partitions (high watermark sum).
    pub fn total_appended(&self) -> u64 {
        self.partitions.iter().map(|p| p.high_watermark()).sum()
    }

    /// Total bytes appended across partitions.
    pub fn total_bytes(&self) -> u64 {
        self.partitions.iter().map(|p| p.appended_bytes()).sum()
    }

    /// Total retained records (backlog) across partitions.
    pub fn total_lag(&self) -> u64 {
        self.partitions.iter().map(|p| p.lag()).sum()
    }

    pub fn close(&self) {
        for p in &self.partitions {
            p.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_in_range() {
        let t = Topic::new("in", 4, 1024);
        for key in 0..1000u32 {
            let p1 = t.partition_for_key(key);
            let p2 = t.partition_for_key(key);
            assert_eq!(p1, p2);
            assert!(p1 < 4);
        }
    }

    #[test]
    fn routing_spreads_dense_keys() {
        let t = Topic::new("in", 4, 1024);
        let mut counts = [0usize; 4];
        for key in 0..4096u32 {
            counts[t.partition_for_key(key) as usize] += 1;
        }
        for &c in &counts {
            // Each partition should get 25% ± 10% of a dense keyspace.
            assert!((c as f64 - 1024.0).abs() < 410.0, "skewed: {counts:?}");
        }
    }

    #[test]
    fn produce_routes_same_key_to_same_partition() {
        let t = Topic::new("in", 4, 1024);
        for i in 0..10 {
            t.produce(Record::new(77, vec![0u8; 27], i), i).unwrap();
        }
        let p = t.partition_for_key(77);
        assert_eq!(t.partition(p).high_watermark(), 10);
        assert_eq!(t.total_appended(), 10);
    }

    #[test]
    fn fib_slot_agrees_with_partition_routing() {
        // The exchange plane routes with the same function the broker
        // partitions with; the two must never drift apart.
        let t = Topic::new("in", 6, 1024);
        for key in 0..2048u32 {
            assert_eq!(fib_slot(key, 6), t.partition_for_key(key));
        }
        // Every slot count covers its full range.
        for n in 1..9u32 {
            let mut seen = vec![false; n as usize];
            for key in 0..4096u32 {
                let s = fib_slot(key, n);
                assert!(s < n);
                seen[s as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "unreached slot at n={n}");
        }
    }

    #[test]
    fn totals_aggregate_partitions() {
        let t = Topic::new("in", 2, 1024);
        for key in 0..100u32 {
            t.produce(Record::new(key, vec![0u8; 27], 0), 0).unwrap();
        }
        assert_eq!(t.total_appended(), 100);
        assert_eq!(t.total_bytes(), 2700);
        assert_eq!(t.total_lag(), 100);
    }
}
