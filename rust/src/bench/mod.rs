//! Custom benchmark harness (criterion is not vendored offline).
//!
//! Drives the `rust/benches/*.rs` targets (`harness = false`): warmup +
//! measured iterations, mean/p50/p99 wall time, derived throughput when
//! the benched closure reports work units, aligned-table output and CSV
//! export into `bench_results/`.
//!
//! [`scenarios`] holds the shared configuration builders that keep the
//! bench targets, the examples and the max-capacity presets
//! ([`scenarios::max_capacity`]) on identical setups.

pub mod scenarios;

use std::time::Instant;

use crate::postprocess::{ascii_table, csv_from_rows};
use crate::util::stats::percentile;

/// One measured configuration.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration wall times, seconds.
    pub times: Vec<f64>,
    /// Work units (events) per iteration, for throughput derivation.
    pub units_per_iter: f64,
    /// Free-form labelled values to carry alongside (latency p50, …).
    pub extras: Vec<(String, f64)>,
}

impl Measurement {
    pub fn mean_time(&self) -> f64 {
        if self.times.is_empty() {
            return 0.0;
        }
        self.times.iter().sum::<f64>() / self.times.len() as f64
    }

    pub fn p50_time(&self) -> f64 {
        percentile(&self.times, 0.5)
    }

    pub fn p99_time(&self) -> f64 {
        percentile(&self.times, 0.99)
    }

    /// Work units per second at the mean time.
    pub fn throughput(&self) -> f64 {
        let m = self.mean_time();
        if m <= 0.0 {
            0.0
        } else {
            self.units_per_iter / m
        }
    }
}

/// Bench collection for one target.
pub struct Bencher {
    target: String,
    measurements: Vec<Measurement>,
}

impl Bencher {
    pub fn new(target: &str) -> Self {
        println!("== bench target: {target} ==");
        Self {
            target: target.to_string(),
            measurements: Vec::new(),
        }
    }

    /// Measure `f` (returns work units done) for `iters` iterations after
    /// `warmup` unmeasured ones.
    pub fn measure<F: FnMut() -> f64>(&mut self, name: &str, warmup: usize, iters: usize, mut f: F) {
        for _ in 0..warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(iters);
        let mut units = 0.0;
        for _ in 0..iters.max(1) {
            let t0 = Instant::now();
            units = std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: name.to_string(),
            times,
            units_per_iter: units,
            extras: Vec::new(),
        };
        println!(
            "  {name}: mean {:.3}s p50 {:.3}s  {:.0} units/s",
            m.mean_time(),
            m.p50_time(),
            m.throughput()
        );
        self.measurements.push(m);
    }

    /// Record an externally-produced measurement (scenario benches that
    /// compute their own rates/latencies).
    pub fn record(&mut self, m: Measurement) {
        println!(
            "  {}: mean {:.3}s  {:.0} units/s  {}",
            m.name,
            m.mean_time(),
            m.throughput(),
            m.extras
                .iter()
                .map(|(k, v)| format!("{k}={v:.1}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
        self.measurements.push(m);
    }

    pub fn measurements(&self) -> &[Measurement] {
        &self.measurements
    }

    /// Render the results table; also writes
    /// `bench_results/<target>.csv` for offline analysis.
    pub fn finish(self) -> String {
        let mut extra_keys: Vec<String> = Vec::new();
        for m in &self.measurements {
            for (k, _) in &m.extras {
                if !extra_keys.contains(k) {
                    extra_keys.push(k.clone());
                }
            }
        }
        let mut headers: Vec<&str> = vec!["case", "mean_s", "p50_s", "p99_s", "units/s"];
        let extra_refs: Vec<&str> = extra_keys.iter().map(|s| s.as_str()).collect();
        headers.extend(extra_refs.iter());
        let rows: Vec<Vec<String>> = self
            .measurements
            .iter()
            .map(|m| {
                let mut row = vec![
                    m.name.clone(),
                    format!("{:.4}", m.mean_time()),
                    format!("{:.4}", m.p50_time()),
                    format!("{:.4}", m.p99_time()),
                    format!("{:.0}", m.throughput()),
                ];
                for k in &extra_keys {
                    let v = m
                        .extras
                        .iter()
                        .find(|(ek, _)| ek == k)
                        .map(|(_, v)| format!("{v:.2}"))
                        .unwrap_or_default();
                    row.push(v);
                }
                row
            })
            .collect();
        let table = ascii_table(&headers, &rows);
        println!("{table}");
        let csv = csv_from_rows(&headers, &rows);
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{}.csv", self.target)), csv);
        }
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations_and_units() {
        let mut b = Bencher::new("test-target");
        let mut calls = 0;
        b.measure("noop", 2, 5, || {
            calls += 1;
            1000.0
        });
        assert_eq!(calls, 7); // 2 warmup + 5 measured
        let m = &b.measurements()[0];
        assert_eq!(m.times.len(), 5);
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn finish_renders_all_cases_and_extras() {
        let mut b = Bencher::new("test-target2");
        b.record(Measurement {
            name: "case-a".into(),
            times: vec![0.5],
            units_per_iter: 500.0,
            extras: vec![("p50_ms".into(), 12.0)],
        });
        let table = b.finish();
        assert!(table.contains("case-a"));
        assert!(table.contains("p50_ms"));
        assert!(table.contains("1000")); // 500 units / 0.5s
        let _ = std::fs::remove_file("bench_results/test-target2.csv");
    }
}
