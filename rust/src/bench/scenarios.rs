//! Shared scenario builders for the bench targets and examples.
//!
//! The paper's experiments ran on Barnard (630 × 104 cores); the wall-mode
//! benches here run the same *scenarios* scaled to one box, and the
//! sim-mode variants run them at paper scale.  These builders keep every
//! bench target on identical configurations so the figures stay
//! comparable.

use crate::config::{
    BenchConfig, CmpOp, DisorderSection, ExchangeMode, ExecMode, Framework, OpSpec, PipelineKind,
    PipelineSpec,
};
use crate::engine::{AggKind, LatePolicy, WindowTime};

/// Baseline wall-mode scenario: short, laptop-friendly.
pub fn wall_base(name: &str) -> BenchConfig {
    let mut cfg = BenchConfig::default();
    cfg.bench.name = name.into();
    cfg.bench.mode = ExecMode::Wall;
    cfg.bench.duration_micros = 2_000_000;
    cfg.bench.warmup_micros = 300_000;
    cfg.workload.rate = 100_000;
    cfg.workload.event_bytes = 27;
    cfg.workload.sensors = 1024;
    cfg.engine.framework = Framework::Flink;
    cfg.engine.pipeline = PipelineKind::CpuIntensive;
    cfg.engine.parallelism = 4;
    cfg.engine.batch_size = 1024;
    cfg.engine.window_micros = 1_000_000;
    cfg.engine.slide_micros = 500_000;
    cfg.metrics.sample_interval_micros = 250_000;
    cfg
}

/// Fig. 6 scenario: generator → broker only is approximated by the
/// pass-through pipeline at parallelism 1 (the engine adds no compute),
/// 4 partitions as in the paper.
pub fn fig6(rate: u64) -> BenchConfig {
    let mut cfg = wall_base(&format!("fig6-{rate}"));
    cfg.engine.pipeline = PipelineKind::PassThrough;
    cfg.engine.parallelism = 2;
    cfg.broker.partitions = 4;
    // Finite broker capacity (≈1.1 M ev/s: one network thread at ~0.9 µs
    // per record) so the measured load range [50K, 800K] sweeps broker
    // utilisation 5%→72% — the regime where the paper's Fig. 6 latency
    // curve lives.  Throughput stays generator-limited (1:1 line).
    cfg.broker.network_threads = 1;
    cfg.broker.record_overhead_nanos = 900;
    cfg.workload.rate = rate;
    cfg
}

/// Fig. 7/8 scenario: CPU-intensive pipeline at a given parallelism and
/// offered load (paper: parallelism {1,2,4,8,16}, 0.5–8 M ev/s; wall mode
/// scales the loads down by ~10× to fit one box).
pub fn fig7(parallelism: u32, rate: u64, use_hlo: bool) -> BenchConfig {
    let mut cfg = wall_base(&format!("fig7-p{parallelism}-r{rate}"));
    cfg.engine.pipeline = PipelineKind::CpuIntensive;
    cfg.engine.parallelism = parallelism;
    cfg.engine.use_hlo = use_hlo;
    cfg.workload.rate = rate;
    cfg.broker.partitions = parallelism.max(4);
    cfg
}

/// Paper-scale sim variant of the Fig. 7 grid.
pub fn fig7_sim(parallelism: u32, rate: u64) -> BenchConfig {
    let mut cfg = fig7(parallelism, rate, false);
    cfg.bench.mode = ExecMode::Sim;
    cfg.bench.duration_micros = 60_000_000;
    cfg.generators.max_instances = 1024;
    cfg
}

/// Max-capacity escalation preset for one pipeline kind (wall mode).
///
/// Short probe iterations keep a full sweep (≈6 escalations + 3
/// refinements) in the tens of seconds on one box; the `experiment:`
/// knobs start each pipeline near a rate it comfortably sustains so the
/// escalation phase shows several sustainable doublings before the knee.
pub fn max_capacity(kind: PipelineKind) -> BenchConfig {
    let mut cfg = wall_base(&format!("maxcap-{}", kind.name()));
    cfg.engine.pipeline = kind;
    cfg.bench.duration_micros = 1_000_000;
    cfg.bench.warmup_micros = 200_000;
    cfg.workload.rate = match kind {
        PipelineKind::PassThrough => 200_000,
        PipelineKind::CpuIntensive => 100_000,
        PipelineKind::MemIntensive => 100_000,
        PipelineKind::Fused => 80_000,
    };
    cfg.generators.max_instances = 1024;
    cfg.experiment.start_rate = cfg.workload.rate;
    cfg.experiment.step_factor = 2.0;
    cfg.experiment.max_iterations = 6;
    cfg.experiment.refine_steps = 3;
    cfg.experiment.sustain_ratio = 0.90;
    cfg
}

/// Paper-scale sim variant of the max-capacity sweep: same escalation
/// logic over the analytic cluster model, so the MST lands near the
/// model's engine-capacity plateau (the Fig. 7 ceiling).
pub fn max_capacity_sim(kind: PipelineKind, parallelism: u32) -> BenchConfig {
    let mut cfg = max_capacity(kind);
    cfg.bench.name = format!("maxcap-sim-{}-p{parallelism}", kind.name());
    cfg.bench.mode = ExecMode::Sim;
    cfg.bench.duration_micros = 30_000_000;
    cfg.engine.parallelism = parallelism;
    cfg.broker.partitions = parallelism.max(4);
    cfg.workload.rate = 1_000_000;
    cfg.experiment.start_rate = 1_000_000;
    cfg.experiment.max_iterations = 10;
    cfg.experiment.refine_steps = 5;
    cfg.experiment.sustain_ratio = 0.95;
    cfg
}

/// Chained-topology preset: `filter → keyby → window(mean) → topk →
/// emit_aggregates` — the shuffle-heavy keyed regrouping shape of
/// Karimov et al. / ShuffleBench, expressed as an operator-chain spec.
pub fn chained_filter_topk() -> BenchConfig {
    let mut cfg = wall_base("chained-filter-topk");
    cfg.workload.sensors = 1024;
    cfg.engine.pipeline_spec = Some(PipelineSpec {
        ops: vec![
            OpSpec::Filter {
                cmp: CmpOp::Gt,
                value: 20.0,
            },
            OpSpec::KeyBy {
                modulo: 64,
                parallelism: 0,
            },
            OpSpec::window(AggKind::Mean, 1_000_000, 500_000),
            OpSpec::TopK {
                k: 10,
                parallelism: 0,
            },
            OpSpec::EmitAggregates,
        ],
    });
    cfg
}

/// The shared keyed-exchange chain behind the shuffle presets:
/// `keyby → window(mean) → topk → emit_aggregates`, split into stages and
/// hash-routed between tasks (`engine.exchange: hash`).
fn shuffle_chain(cfg: &mut BenchConfig) {
    cfg.engine.exchange = ExchangeMode::Hash;
    cfg.engine.pipeline_spec = Some(PipelineSpec {
        ops: vec![
            OpSpec::KeyBy {
                modulo: 64,
                parallelism: 0,
            },
            OpSpec::window(AggKind::Mean, 1_000_000, 500_000),
            OpSpec::TopK {
                k: 10,
                parallelism: 0,
            },
            OpSpec::EmitAggregates,
        ],
    });
}

/// Skewed-key shuffle scenario (the ShuffleBench regime the exchange is
/// accountable to): a Zipf tail plus a concentrated hot set — half the
/// stream hammers 4 sensors — through the keyed exchange chain.  Hot
/// derived keys all land on single stage instances, so this preset is the
/// one that makes exchange imbalance visible in per-operator stats.
pub fn shuffle_skew() -> BenchConfig {
    let mut cfg = wall_base("shuffle-skew");
    cfg.workload.sensors = 1024;
    cfg.workload.key_skew = 1.1;
    cfg.workload.hot_keys = 4;
    cfg.workload.hot_fraction = 0.5;
    shuffle_chain(&mut cfg);
    cfg
}

/// Uniform-key control for [`shuffle_skew`]: identical chain and load,
/// keys drawn uniformly — the baseline an exchange-imbalance comparison
/// reads against.
pub fn shuffle_uniform() -> BenchConfig {
    let mut cfg = wall_base("shuffle-uniform");
    cfg.workload.sensors = 1024;
    shuffle_chain(&mut cfg);
    cfg
}

/// Chained-topology preset: `filter → map(°C→°F) → emit_events` — a
/// projection/enrichment shape (selective forwarding, no keyed state).
pub fn chained_hot_projection() -> BenchConfig {
    let mut cfg = wall_base("chained-hot-projection");
    cfg.engine.pipeline_spec = Some(PipelineSpec {
        ops: vec![
            OpSpec::Filter {
                cmp: CmpOp::Gt,
                value: 25.0,
            },
            OpSpec::Map {
                scale: 1.8,
                offset: 32.0,
            },
            OpSpec::EmitEvents,
        ],
    });
    cfg
}

/// Event-time scenario: disordered workload (bounded lateness + shuffle
/// window + a sliver of droppable stragglers) through an event-time
/// window whose watermark bound matches the disorder's lateness and whose
/// late policy merges still-open windows — the configuration under which
/// event-time aggregates reproduce the in-order stream's results, modulo
/// stragglers.
pub fn event_time_disorder() -> BenchConfig {
    let mut cfg = wall_base("event-time-disorder");
    cfg.workload.sensors = 256;
    cfg.workload.disorder = DisorderSection {
        lateness_micros: 250_000,
        late_fraction: 0.25,
        straggler_fraction: 0.01,
        straggler_micros: 2_000_000,
        shuffle_window: 128,
    };
    cfg.engine.pipeline_spec = Some(PipelineSpec {
        ops: vec![
            OpSpec::Window {
                agg: AggKind::Mean,
                window_micros: 1_000_000,
                slide_micros: 500_000,
                time: WindowTime::Event,
                allowed_lateness_micros: 250_000,
                late_policy: LatePolicy::MergeIfOpen,
                // Explicitly pinned to the disorder's lateness bound (the
                // omitted-field inherit would resolve to max(lateness,
                // slide) = 500ms and overshoot the documented scenario).
                watermark_micros: 250_000,
            },
            OpSpec::EmitAggregates,
        ],
    });
    cfg
}

/// Event-time scenario, strict flavour: a tight watermark, zero allowed
/// lateness and a `drop` policy over the same disordered workload — the
/// configuration that makes lateness *visible* (dropped counts, watermark
/// lag) and exercises the `max_late_fraction` sustainability check.
pub fn event_time_strict() -> BenchConfig {
    let mut cfg = event_time_disorder();
    cfg.bench.name = "event-time-strict".into();
    cfg.engine.pipeline_spec = Some(PipelineSpec {
        ops: vec![
            OpSpec::Window {
                agg: AggKind::Mean,
                window_micros: 1_000_000,
                slide_micros: 500_000,
                time: WindowTime::Event,
                allowed_lateness_micros: 0,
                late_policy: LatePolicy::Drop,
                watermark_micros: 50_000, // far below the 250ms disorder
            },
            OpSpec::EmitAggregates,
        ],
    });
    // A quarter of the stream is late by construction; fail the run only
    // when more than half goes missing.
    cfg.experiment.max_late_fraction = 0.5;
    cfg
}

/// The paper's parallelism grid.
pub const PARALLELISM_GRID: [u32; 5] = [1, 2, 4, 8, 16];

/// Paper Fig. 7 workload grid (events/second).
pub const PAPER_RATE_GRID: [u64; 5] = [500_000, 1_000_000, 2_000_000, 4_000_000, 8_000_000];

/// Wall-mode (single box) scaled-down workload grid.
pub const WALL_RATE_GRID: [u64; 3] = [50_000, 100_000, 200_000];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenarios_validate() {
        wall_base("x").validate().unwrap();
        fig6(500_000).validate().unwrap();
        fig7(16, 200_000, false).validate().unwrap();
        fig7_sim(16, 8_000_000).validate().unwrap();
        for kind in [
            PipelineKind::PassThrough,
            PipelineKind::CpuIntensive,
            PipelineKind::MemIntensive,
            PipelineKind::Fused,
        ] {
            max_capacity(kind).validate().unwrap();
            max_capacity_sim(kind, 8).validate().unwrap();
        }
    }

    #[test]
    fn chained_presets_validate_and_carry_specs() {
        for cfg in [chained_filter_topk(), chained_hot_projection()] {
            cfg.validate().unwrap();
            let spec = cfg.engine.pipeline_spec.as_ref().expect("preset has a spec");
            assert!(spec.ops.len() >= 3, "chained topology, not a single op");
            assert!(cfg.engine.pipeline_label().starts_with("chain["));
        }
        assert!(chained_filter_topk()
            .engine
            .pipeline_spec
            .unwrap()
            .has_window());
    }

    #[test]
    fn shuffle_presets_validate_and_stage() {
        for cfg in [shuffle_skew(), shuffle_uniform()] {
            cfg.validate().unwrap();
            assert_eq!(cfg.engine.exchange, ExchangeMode::Hash);
            let stages = cfg
                .engine
                .effective_spec()
                .split_stages(cfg.engine.parallelism);
            assert_eq!(stages.len(), 3, "keyby and topk boundaries");
            assert_eq!(stages[2].parallelism, 1, "global top-k stage");
        }
        let skew = shuffle_skew();
        assert!(skew.workload.key_skew > 0.0);
        assert_eq!(skew.workload.hot_keys, 4);
        assert_eq!(skew.workload.hot_fraction, 0.5);
        let uniform = shuffle_uniform();
        assert_eq!(uniform.workload.key_skew, 0.0);
        assert_eq!(uniform.workload.hot_fraction, 0.0);
    }

    #[test]
    fn event_time_presets_validate_and_differ_in_policy() {
        for cfg in [event_time_disorder(), event_time_strict()] {
            cfg.validate().unwrap();
            assert!(cfg.workload.disorder.enabled());
            let spec = cfg.engine.pipeline_spec.as_ref().unwrap();
            match &spec.ops[0] {
                OpSpec::Window { time, .. } => assert_eq!(*time, WindowTime::Event),
                other => panic!("expected an event-time window, got {other:?}"),
            }
        }
        let relaxed = event_time_disorder();
        match &relaxed.engine.pipeline_spec.unwrap().ops[0] {
            OpSpec::Window {
                late_policy,
                allowed_lateness_micros,
                ..
            } => {
                assert_eq!(*late_policy, LatePolicy::MergeIfOpen);
                assert!(*allowed_lateness_micros > 0);
            }
            _ => unreachable!(),
        }
        let strict = event_time_strict();
        assert_eq!(strict.experiment.max_late_fraction, 0.5);
        match &strict.engine.pipeline_spec.unwrap().ops[0] {
            OpSpec::Window { late_policy, .. } => assert_eq!(*late_policy, LatePolicy::Drop),
            _ => unreachable!(),
        }
    }

    #[test]
    fn max_capacity_presets_start_conservative() {
        for kind in [
            PipelineKind::PassThrough,
            PipelineKind::CpuIntensive,
            PipelineKind::MemIntensive,
            PipelineKind::Fused,
        ] {
            let cfg = max_capacity(kind);
            assert_eq!(cfg.engine.pipeline, kind);
            assert_eq!(cfg.experiment.start_rate, cfg.workload.rate);
            assert!(cfg.experiment.step_factor > 1.0);
            assert!(
                cfg.workload.rate <= 200_000,
                "wall presets must start below one box's capacity"
            );
        }
        let sim = max_capacity_sim(PipelineKind::PassThrough, 16);
        assert_eq!(sim.bench.mode, ExecMode::Sim);
        assert_eq!(sim.engine.parallelism, 16);
    }

    #[test]
    fn fig7_sim_uses_paper_scale() {
        let cfg = fig7_sim(16, 8_000_000);
        assert_eq!(cfg.bench.mode, ExecMode::Sim);
        assert_eq!(cfg.workload.rate, 8_000_000);
    }
}
