//! Automatic SLURM resource calculation + `#SBATCH` script generation.
//!
//! The paper: "By referencing the memory and CPU requirements specified in
//! the configuration file, the interface automatically determines the
//! appropriate SLURM job parameters.  Once the resources are allocated,
//! the interface defines all the environment variables necessary for the
//! benchmark processes."  This module is that calculation, plus the script
//! writer the batch path uses.

use crate::config::{BenchConfig, TransportMode};

/// Resources derived from a benchmark configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ResourceRequest {
    pub nodes: u32,
    pub tasks: u32,
    pub cpus_per_task: u32,
    pub mem_per_node_bytes: u64,
    pub time_limit_micros: u64,
}

/// Compute the SLURM request for one experiment.
///
/// CPU demand = generator instances + broker (I/O + network threads) +
/// engine parallelism + 2 service threads (samplers, drainer); memory =
/// generator heaps + broker heap + a working-set margin.
pub fn resource_request(cfg: &BenchConfig) -> ResourceRequest {
    let gen_cpus = cfg.generator_instances();
    let broker_cpus = cfg.broker.io_threads + cfg.broker.network_threads;
    let engine_cpus = cfg.engine.parallelism;
    let service_cpus = 2;
    let total_cpus = gen_cpus + broker_cpus + engine_cpus + service_cpus;

    let mem = cfg.generators.heap_bytes * cfg.generator_instances() as u64
        + cfg.broker.heap_bytes
        + (cfg.engine.parallelism as u64) * (1 << 30);

    let cpus_per_node = cfg.slurm.cpus_per_task.max(1);
    let nodes = cfg
        .slurm
        .nodes
        .max(((total_cpus + cpus_per_node - 1) / cpus_per_node).max(1));

    ResourceRequest {
        nodes,
        tasks: nodes,
        cpus_per_task: cpus_per_node,
        mem_per_node_bytes: (mem / nodes as u64).min(cfg.slurm.mem_bytes),
        // Duration + warmup + 20% margin + fixed setup allowance.
        time_limit_micros: cfg
            .slurm
            .time_limit_micros
            .max((cfg.bench.duration_micros + cfg.bench.warmup_micros) * 12 / 10 + 60_000_000),
    }
}

/// Render the `#SBATCH` batch script for one experiment.
pub fn sbatch_script(cfg: &BenchConfig, config_path: &str) -> String {
    let req = resource_request(cfg);
    let mem_mb = req.mem_per_node_bytes / (1 << 20);
    let time_min = (req.time_limit_micros / 60_000_000).max(1);
    let mut s = String::new();
    s.push_str("#!/bin/bash\n");
    s.push_str(&format!("#SBATCH --job-name=sprobench-{}\n", cfg.bench.name));
    s.push_str(&format!("#SBATCH --partition={}\n", cfg.slurm.partition));
    s.push_str(&format!("#SBATCH --nodes={}\n", req.nodes));
    s.push_str(&format!("#SBATCH --ntasks={}\n", req.tasks));
    s.push_str(&format!("#SBATCH --cpus-per-task={}\n", req.cpus_per_task));
    s.push_str(&format!("#SBATCH --mem={}M\n", mem_mb));
    s.push_str(&format!("#SBATCH --time={}\n", fmt_slurm_time(time_min)));
    s.push_str("#SBATCH --output=runs/%x-%j.out\n");
    s.push('\n');
    s.push_str("# Environment for the benchmark processes (auto-generated).\n");
    s.push_str(&format!(
        "export SPROBENCH_EXPERIMENT={}\n",
        cfg.bench.name
    ));
    s.push_str(&format!("export SPROBENCH_SEED={}\n", cfg.bench.seed));
    s.push_str(&format!(
        "export SPROBENCH_PARALLELISM={}\n",
        cfg.engine.parallelism
    ));
    s.push_str(&format!(
        "export SPROBENCH_GENERATORS={}\n",
        cfg.generator_instances()
    ));
    s.push('\n');
    if cfg.cluster.transport == TransportMode::Tcp && !cfg.cluster.spawn_workers {
        // Multi-node distributed launch: one srun step per role, the
        // driver on the first allocated node.  Workers retry the control
        // dial until the driver binds (bounded by connect_timeout), so
        // launch order does not matter.
        let driver_port = port_of(&cfg.cluster.driver_bind, 7700);
        let data_port = port_of(&cfg.cluster.data_bind, 7701);
        s.push_str("# Distributed launch: driver + one worker process per role over TCP.\n");
        s.push_str(
            "DRIVER_HOST=$(scontrol show hostnames \"$SLURM_JOB_NODELIST\" | head -n 1)\n",
        );
        s.push_str(&format!("DRIVER_ADDR=${{DRIVER_HOST}}:{driver_port}\n"));
        s.push_str(&format!(
            "srun --ntasks=1 --nodes=1 sprobench worker --role broker --driver ${{DRIVER_ADDR}} --bind 0.0.0.0:{data_port} &\n"
        ));
        s.push_str(
            "srun --ntasks=1 --nodes=1 sprobench worker --role engine --driver ${DRIVER_ADDR} &\n",
        );
        for _ in 0..cfg.cluster.generators {
            s.push_str(
                "srun --ntasks=1 --nodes=1 sprobench worker --role generator --driver ${DRIVER_ADDR} &\n",
            );
        }
        s.push_str(&format!(
            "srun --ntasks=1 --nodes=1 -w \"$DRIVER_HOST\" sprobench run --config {} --experiment {}\n",
            config_path, cfg.bench.name
        ));
        s.push_str("wait\n");
    } else {
        // Single-step launch; with `cluster.transport: tcp` and
        // `spawn_workers: true` the driver forks its worker processes on
        // the allocated node itself.
        s.push_str(&format!(
            "srun sprobench run --config {} --experiment {}\n",
            config_path, cfg.bench.name
        ));
    }
    s
}

/// The port a `host:port` bind pins, or `fallback` when unset/0.
fn port_of(addr: &str, fallback: u16) -> u16 {
    addr.rsplit(':')
        .next()
        .and_then(|p| p.parse::<u16>().ok())
        .filter(|&p| p != 0)
        .unwrap_or(fallback)
}

fn fmt_slurm_time(total_min: u64) -> String {
    format!("{:02}:{:02}:00", total_min / 60, total_min % 60)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resource_calc_counts_all_components() {
        let mut cfg = BenchConfig::default();
        cfg.workload.rate = 2_000_000; // 4 generator instances
        cfg.engine.parallelism = 8;
        cfg.broker.io_threads = 20;
        cfg.broker.network_threads = 10;
        cfg.slurm.cpus_per_task = 16;
        let r = resource_request(&cfg);
        // 4 + 30 + 8 + 2 = 44 cpus → 3 nodes of 16.
        assert_eq!(r.nodes, 3);
        assert_eq!(r.cpus_per_task, 16);
        assert!(r.mem_per_node_bytes > 0);
    }

    #[test]
    fn explicit_nodes_override_when_larger() {
        let mut cfg = BenchConfig::default();
        cfg.slurm.nodes = 10;
        let r = resource_request(&cfg);
        assert_eq!(r.nodes, 10);
    }

    #[test]
    fn script_contains_the_paper_knobs() {
        let mut cfg = BenchConfig::default();
        cfg.bench.name = "exp7".into();
        let s = sbatch_script(&cfg, "configs/exp.yaml");
        assert!(s.starts_with("#!/bin/bash\n"));
        assert!(s.contains("#SBATCH --job-name=sprobench-exp7"));
        assert!(s.contains("#SBATCH --partition=barnard"));
        assert!(s.contains("--cpus-per-task=16"));
        assert!(s.contains("export SPROBENCH_PARALLELISM=4"));
        assert!(s.contains("srun sprobench run --config configs/exp.yaml"));
    }

    #[test]
    fn tcp_cluster_script_emits_one_srun_step_per_role() {
        let mut cfg = BenchConfig::default();
        cfg.bench.name = "dist".into();
        cfg.cluster.transport = TransportMode::Tcp;
        cfg.cluster.spawn_workers = false;
        cfg.cluster.driver_bind = "0.0.0.0:7700".into();
        cfg.cluster.data_bind = "0.0.0.0:7701".into();
        cfg.cluster.generators = 2;
        let s = sbatch_script(&cfg, "configs/dist.yaml");
        assert!(s.contains("--role broker"), "{s}");
        assert!(s.contains("--bind 0.0.0.0:7701"), "{s}");
        assert!(s.contains("--role engine"), "{s}");
        assert_eq!(s.matches("--role generator").count(), 2, "{s}");
        assert!(s.contains("DRIVER_ADDR=${DRIVER_HOST}:7700"), "{s}");
        assert!(s.contains("sprobench run --config configs/dist.yaml --experiment dist"), "{s}");
        assert!(s.ends_with("wait\n"), "{s}");
        // Workers spawned by the driver itself: back to the single step.
        cfg.cluster.spawn_workers = true;
        let s = sbatch_script(&cfg, "configs/dist.yaml");
        assert!(!s.contains("--role broker"), "{s}");
        assert!(s.contains("srun sprobench run"), "{s}");
    }

    #[test]
    fn port_extraction_falls_back_on_unpinned_binds() {
        assert_eq!(port_of("0.0.0.0:7700", 1), 7700);
        assert_eq!(port_of("127.0.0.1:0", 7700), 7700);
        assert_eq!(port_of("", 7701), 7701);
    }

    #[test]
    fn time_limit_covers_duration_plus_margin() {
        let mut cfg = BenchConfig::default();
        cfg.bench.duration_micros = 600_000_000; // 10 min
        cfg.slurm.time_limit_micros = 0;
        let r = resource_request(&cfg);
        assert!(r.time_limit_micros >= 600_000_000);
    }

    #[test]
    fn slurm_time_formatting() {
        assert_eq!(fmt_slurm_time(30), "00:30:00");
        assert_eq!(fmt_slurm_time(90), "01:30:00");
    }
}
