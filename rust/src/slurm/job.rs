//! SLURM job model: requests, lifecycle states, records.

/// Job identifier (monotonic, like SLURM job ids).
pub type JobId = u64;

/// A submitted job's resource and scheduling request.
#[derive(Clone, Debug, PartialEq)]
pub struct JobRequest {
    pub name: String,
    pub nodes: u32,
    pub cores_per_node: u32,
    pub mem_per_node_bytes: u64,
    /// Declared wall-time limit.
    pub time_limit_micros: u64,
    /// Actual runtime in the simulation (≤ limit, or the job times out).
    pub runtime_micros: u64,
    /// `--dependency=afterok:<id>` equivalent.
    pub after_ok: Option<JobId>,
}

impl JobRequest {
    /// Small convenience for tests/examples.
    pub fn simple(name: &str, nodes: u32, cores: u32, runtime_micros: u64) -> Self {
        Self {
            name: name.to_string(),
            nodes,
            cores_per_node: cores,
            mem_per_node_bytes: 1 << 30,
            time_limit_micros: runtime_micros * 2,
            runtime_micros,
            after_ok: None,
        }
    }
}

/// Lifecycle state (matches `squeue` vocabulary).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Completed,
    Timeout,
    Cancelled,
}

/// Scheduler-side job record.
#[derive(Clone, Debug)]
pub struct Job {
    pub id: JobId,
    pub request: JobRequest,
    pub state: JobState,
    pub submit_micros: u64,
    pub start_micros: Option<u64>,
    pub end_micros: Option<u64>,
    /// Node indices allocated while running.
    pub allocated_nodes: Vec<u32>,
}

impl Job {
    pub fn wait_micros(&self) -> Option<u64> {
        self.start_micros.map(|s| s - self.submit_micros)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wait_time_is_start_minus_submit() {
        let j = Job {
            id: 1,
            request: JobRequest::simple("x", 1, 4, 1_000),
            state: JobState::Running,
            submit_micros: 100,
            start_micros: Some(350),
            end_micros: None,
            allocated_nodes: vec![0],
        };
        assert_eq!(j.wait_micros(), Some(250));
    }
}
