//! Cluster model: homogeneous nodes with core + memory capacity.

/// Cluster description. Default models TU Dresden's Barnard (paper Sec. 4):
/// 630 nodes × dual Xeon 8470 (104 cores) × 512 GB DDR5.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    pub nodes: u32,
    pub cores_per_node: u32,
    pub mem_per_node_bytes: u64,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self {
            nodes: 630,
            cores_per_node: 104,
            mem_per_node_bytes: 512 << 30,
        }
    }
}

impl ClusterSpec {
    pub fn total_cores(&self) -> u64 {
        self.nodes as u64 * self.cores_per_node as u64
    }

    /// A laptop-scale cluster for tests and wall-mode runs.
    pub fn tiny(nodes: u32, cores: u32) -> Self {
        Self {
            nodes,
            cores_per_node: cores,
            mem_per_node_bytes: 16 << 30,
        }
    }
}

/// Mutable per-node allocation state used by the scheduler.
#[derive(Clone, Debug)]
pub struct NodeState {
    pub free_cores: u32,
    pub free_mem_bytes: u64,
}

impl NodeState {
    pub fn new(spec: &ClusterSpec) -> Self {
        Self {
            free_cores: spec.cores_per_node,
            free_mem_bytes: spec.mem_per_node_bytes,
        }
    }

    pub fn fits(&self, cores: u32, mem: u64) -> bool {
        self.free_cores >= cores && self.free_mem_bytes >= mem
    }

    pub fn take(&mut self, cores: u32, mem: u64) {
        debug_assert!(self.fits(cores, mem));
        self.free_cores -= cores;
        self.free_mem_bytes -= mem;
    }

    pub fn release(&mut self, cores: u32, mem: u64, spec: &ClusterSpec) {
        self.free_cores = (self.free_cores + cores).min(spec.cores_per_node);
        self.free_mem_bytes = (self.free_mem_bytes + mem).min(spec.mem_per_node_bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barnard_defaults() {
        let c = ClusterSpec::default();
        assert_eq!(c.total_cores(), 65_520); // the paper's number
    }

    #[test]
    fn node_take_release_roundtrip() {
        let spec = ClusterSpec::tiny(1, 8);
        let mut n = NodeState::new(&spec);
        assert!(n.fits(4, 1 << 30));
        n.take(4, 1 << 30);
        assert!(!n.fits(5, 0));
        n.release(4, 1 << 30, &spec);
        assert_eq!(n.free_cores, 8);
        assert_eq!(n.free_mem_bytes, 16 << 30);
    }
}
