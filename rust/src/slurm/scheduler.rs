//! Virtual-time FIFO + backfill scheduler.
//!
//! Discrete-event simulation: jobs are submitted, queued FIFO, and started
//! when their node request fits.  EASY backfill lets a later job jump the
//! queue iff it can finish before the queue head's earliest possible start
//! (computed from running jobs' declared limits), so it never delays the
//! head.  Dependencies (`after_ok`) hold jobs back until the parent
//! completes successfully.

use std::collections::BTreeMap;

use super::cluster::{ClusterSpec, NodeState};
use super::job::{Job, JobId, JobRequest, JobState};

/// Aggregate scheduler statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SchedulerStats {
    pub submitted: u64,
    pub completed: u64,
    pub timed_out: u64,
    pub backfilled: u64,
    /// Core-seconds actually used / core-seconds available over makespan.
    pub utilization: f64,
}

pub struct Scheduler {
    spec: ClusterSpec,
    nodes: Vec<NodeState>,
    jobs: BTreeMap<JobId, Job>,
    queue: Vec<JobId>,
    running: Vec<JobId>,
    next_id: JobId,
    now_micros: u64,
    backfilled: u64,
    used_core_micros: u128,
}

impl Scheduler {
    pub fn new(spec: ClusterSpec) -> Self {
        let nodes = (0..spec.nodes).map(|_| NodeState::new(&spec)).collect();
        Self {
            spec,
            nodes,
            jobs: BTreeMap::new(),
            queue: Vec::new(),
            running: Vec::new(),
            next_id: 1,
            now_micros: 0,
            backfilled: 0,
            used_core_micros: 0,
        }
    }

    pub fn now_micros(&self) -> u64 {
        self.now_micros
    }

    /// Submit a job; returns its id (sbatch semantics: queue, don't run).
    pub fn submit(&mut self, request: JobRequest) -> JobId {
        let id = self.next_id;
        self.next_id += 1;
        self.jobs.insert(
            id,
            Job {
                id,
                request,
                state: JobState::Pending,
                submit_micros: self.now_micros,
                start_micros: None,
                end_micros: None,
                allocated_nodes: Vec::new(),
            },
        );
        self.queue.push(id);
        id
    }

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(&id)
    }

    /// Run the event loop until every job reached a terminal state.
    /// Returns the makespan in microseconds.
    pub fn run_to_completion(&mut self) -> u64 {
        loop {
            self.schedule_pass();
            if self.running.is_empty() {
                if self.queue_is_stuck() {
                    // Remaining queue can never run (deps failed or
                    // requests exceed the cluster): cancel them.
                    let stuck: Vec<JobId> = self.queue.drain(..).collect();
                    for id in stuck {
                        self.jobs.get_mut(&id).expect("job exists").state = JobState::Cancelled;
                    }
                }
                if self.running.is_empty() && self.queue.is_empty() {
                    return self.now_micros;
                }
            }
            // Advance to the next completion event.
            let next_end = self
                .running
                .iter()
                .map(|id| self.end_time(&self.jobs[id]))
                .min()
                .expect("running nonempty");
            self.now_micros = next_end;
            self.complete_finished();
        }
    }

    fn end_time(&self, job: &Job) -> u64 {
        let start = job.start_micros.expect("running job has start");
        start + job.request.runtime_micros.min(job.request.time_limit_micros)
    }

    fn complete_finished(&mut self) {
        let now = self.now_micros;
        let done: Vec<JobId> = self
            .running
            .iter()
            .copied()
            .filter(|id| self.end_time(&self.jobs[id]) <= now)
            .collect();
        for id in done {
            self.running.retain(|&r| r != id);
            let (cores, mem, nodes, timed_out, runtime) = {
                let job = &self.jobs[&id];
                (
                    job.request.cores_per_node,
                    job.request.mem_per_node_bytes,
                    job.allocated_nodes.clone(),
                    job.request.runtime_micros > job.request.time_limit_micros,
                    job.request.runtime_micros.min(job.request.time_limit_micros),
                )
            };
            for n in &nodes {
                self.nodes[*n as usize].release(cores, mem, &self.spec);
            }
            self.used_core_micros += cores as u128 * nodes.len() as u128 * runtime as u128;
            let job = self.jobs.get_mut(&id).expect("job exists");
            job.end_micros = Some(now);
            job.state = if timed_out {
                JobState::Timeout
            } else {
                JobState::Completed
            };
        }
    }

    /// Can `job` start right now? If so, which nodes?
    fn find_nodes(&self, request: &JobRequest) -> Option<Vec<u32>> {
        let mut picked = Vec::new();
        for (i, n) in self.nodes.iter().enumerate() {
            if n.fits(request.cores_per_node, request.mem_per_node_bytes) {
                picked.push(i as u32);
                if picked.len() == request.nodes as usize {
                    return Some(picked);
                }
            }
        }
        None
    }

    fn dependency_ready(&self, request: &JobRequest) -> Result<bool, ()> {
        match request.after_ok {
            None => Ok(true),
            Some(dep) => match self.jobs.get(&dep).map(|j| j.state) {
                Some(JobState::Completed) => Ok(true),
                Some(JobState::Pending | JobState::Running) => Ok(false),
                // Failed/timeout/cancelled parent: dependency unsatisfiable.
                _ => Err(()),
            },
        }
    }

    fn start(&mut self, id: JobId, nodes: Vec<u32>) {
        let (cores, mem) = {
            let job = &self.jobs[&id];
            (job.request.cores_per_node, job.request.mem_per_node_bytes)
        };
        for n in &nodes {
            self.nodes[*n as usize].take(cores, mem);
        }
        let now = self.now_micros;
        let job = self.jobs.get_mut(&id).expect("job exists");
        job.state = JobState::Running;
        job.start_micros = Some(now);
        job.allocated_nodes = nodes;
        self.running.push(id);
        self.queue.retain(|&q| q != id);
    }

    /// One FIFO + EASY-backfill scheduling pass.
    fn schedule_pass(&mut self) {
        // Drop jobs whose dependency can never be satisfied.
        let mut cancelled = Vec::new();
        self.queue.retain(|&id| {
            match self.jobs[&id].request.after_ok.map(|d| self.jobs.get(&d).map(|j| j.state)) {
                Some(Some(JobState::Timeout | JobState::Cancelled)) => {
                    cancelled.push(id);
                    false
                }
                _ => true,
            }
        });
        for id in cancelled {
            self.jobs.get_mut(&id).expect("job exists").state = JobState::Cancelled;
        }

        // FIFO: start queue-head jobs while they fit.
        loop {
            let Some(&head) = self.queue.first() else { return };
            let ready = match self.dependency_ready(&self.jobs[&head].request) {
                Ok(r) => r,
                Err(()) => unreachable!("unsatisfiable deps pruned above"),
            };
            if ready {
                if let Some(nodes) = self.find_nodes(&self.jobs[&head].request) {
                    self.start(head, nodes);
                    continue;
                }
            }
            break;
        }

        // EASY backfill: the head is blocked; estimate its earliest start
        // as the soonest running-job end (conservative), and start any
        // later job that fits now and finishes before then.
        let Some(&head) = self.queue.first() else { return };
        let head_eta = self
            .running
            .iter()
            .map(|id| self.end_time(&self.jobs[id]))
            .min()
            .unwrap_or(self.now_micros);
        let candidates: Vec<JobId> = self.queue.iter().copied().skip(1).collect();
        for id in candidates {
            let req = self.jobs[&id].request.clone();
            if self.dependency_ready(&req) != Ok(true) {
                continue;
            }
            let finishes_by = self.now_micros + req.runtime_micros.min(req.time_limit_micros);
            if finishes_by <= head_eta {
                if let Some(nodes) = self.find_nodes(&req) {
                    self.start(id, nodes);
                    self.backfilled += 1;
                }
            }
        }
        let _ = head;
    }

    fn queue_is_stuck(&self) -> bool {
        self.queue.iter().all(|&id| {
            let req = &self.jobs[&id].request;
            // Unsatisfiable: bad dependency or impossible resource ask.
            self.dependency_ready(req) == Err(())
                || req.nodes > self.spec.nodes
                || req.cores_per_node > self.spec.cores_per_node
                || req.mem_per_node_bytes > self.spec.mem_per_node_bytes
        }) && self.running.is_empty()
    }

    pub fn stats(&self) -> SchedulerStats {
        let completed = self
            .jobs
            .values()
            .filter(|j| j.state == JobState::Completed)
            .count() as u64;
        let timed_out = self
            .jobs
            .values()
            .filter(|j| j.state == JobState::Timeout)
            .count() as u64;
        let makespan = self.now_micros.max(1);
        let available = self.spec.total_cores() as u128 * makespan as u128;
        SchedulerStats {
            submitted: self.jobs.len() as u64,
            completed,
            timed_out,
            backfilled: self.backfilled,
            utilization: self.used_core_micros as f64 / available as f64,
        }
    }

    pub fn jobs(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scheduler {
        Scheduler::new(ClusterSpec::tiny(2, 8))
    }

    #[test]
    fn single_job_runs_immediately() {
        let mut s = tiny();
        let id = s.submit(JobRequest::simple("a", 1, 4, 1_000_000));
        let makespan = s.run_to_completion();
        assert_eq!(makespan, 1_000_000);
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Completed);
        assert_eq!(j.wait_micros(), Some(0));
    }

    #[test]
    fn fifo_queues_when_full() {
        let mut s = tiny();
        // Each job takes a full node; 3 jobs on 2 nodes → one waits.
        let a = s.submit(JobRequest::simple("a", 1, 8, 1_000_000));
        let b = s.submit(JobRequest::simple("b", 1, 8, 1_000_000));
        let c = s.submit(JobRequest::simple("c", 1, 8, 1_000_000));
        let makespan = s.run_to_completion();
        assert_eq!(makespan, 2_000_000);
        assert_eq!(s.job(a).unwrap().wait_micros(), Some(0));
        assert_eq!(s.job(b).unwrap().wait_micros(), Some(0));
        assert_eq!(s.job(c).unwrap().wait_micros(), Some(1_000_000));
    }

    #[test]
    fn backfill_fills_holes_without_delaying_head() {
        let mut s = tiny();
        // a: both nodes, 10s. b (head after a): both nodes → must wait.
        // c: 1 node, 1s → cannot run while a holds both nodes either; make
        // a hold ONE node so there is a hole.
        let _a = s.submit(JobRequest::simple("a", 1, 8, 10_000_000));
        let b = s.submit(JobRequest::simple("b", 2, 8, 5_000_000));
        let c = s.submit(JobRequest::simple("c", 1, 8, 2_000_000));
        let _ = s.run_to_completion();
        // c fits in the idle node and finishes (2s) before b could start
        // (10s) → backfilled.
        assert!(s.stats().backfilled >= 1);
        assert_eq!(s.job(c).unwrap().wait_micros(), Some(0));
        assert_eq!(s.job(b).unwrap().wait_micros(), Some(10_000_000));
    }

    #[test]
    fn dependencies_hold_jobs_back() {
        let mut s = tiny();
        let a = s.submit(JobRequest::simple("a", 1, 4, 3_000_000));
        let mut req = JobRequest::simple("b", 1, 4, 1_000_000);
        req.after_ok = Some(a);
        let b = s.submit(req);
        s.run_to_completion();
        let (ja, jb) = (s.job(a).unwrap(), s.job(b).unwrap());
        assert!(jb.start_micros.unwrap() >= ja.end_micros.unwrap());
    }

    #[test]
    fn dependency_on_failed_job_cancels() {
        let mut s = tiny();
        let mut bad = JobRequest::simple("bad", 1, 4, 10_000_000);
        bad.time_limit_micros = 1_000_000; // will time out
        let a = s.submit(bad);
        let mut req = JobRequest::simple("child", 1, 4, 1_000_000);
        req.after_ok = Some(a);
        let b = s.submit(req);
        s.run_to_completion();
        assert_eq!(s.job(a).unwrap().state, JobState::Timeout);
        assert_eq!(s.job(b).unwrap().state, JobState::Cancelled);
    }

    #[test]
    fn impossible_request_is_cancelled_not_hung() {
        let mut s = tiny();
        let id = s.submit(JobRequest::simple("huge", 99, 8, 1_000));
        s.run_to_completion();
        assert_eq!(s.job(id).unwrap().state, JobState::Cancelled);
    }

    #[test]
    fn utilization_accounts_core_time() {
        let mut s = tiny(); // 16 cores total
        s.submit(JobRequest::simple("a", 2, 8, 1_000_000)); // full cluster 1s
        s.run_to_completion();
        let st = s.stats();
        assert!((st.utilization - 1.0).abs() < 1e-9, "{st:?}");
    }

    #[test]
    fn concurrent_experiments_share_the_cluster() {
        // The paper's multi-experiment workflow: 4 half-node jobs on 2
        // nodes run 2-at-a-time... actually 4 × 4 cores fit 2 per node →
        // all 4 run immediately.
        let mut s = tiny();
        let ids: Vec<JobId> = (0..4)
            .map(|i| s.submit(JobRequest::simple(&format!("e{i}"), 1, 4, 2_000_000)))
            .collect();
        let makespan = s.run_to_completion();
        assert_eq!(makespan, 2_000_000, "all four must run concurrently");
        for id in ids {
            assert_eq!(s.job(id).unwrap().wait_micros(), Some(0));
        }
    }
}
