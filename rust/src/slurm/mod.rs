//! SLURM integration substrate.
//!
//! The paper's headline differentiator is native SLURM support: the CLI
//! "facilitates the allocation of resources in a SLURM-based environment
//! … by referencing the memory and CPU requirements specified in the
//! configuration file, the interface automatically determines the
//! appropriate SLURM job parameters" (Sec. 3), supports interactive and
//! batch execution, concurrent experiments and job dependencies
//! (Sec. 3.1).  No SLURM cluster exists here, so this module provides:
//!
//! * [`cluster`] — a cluster model (nodes × cores × memory; defaults match
//!   Barnard: 630 nodes, 104 cores, 512 GB),
//! * [`job`] — job requests/records with SLURM-like lifecycle,
//! * [`scheduler`] — a virtual-time FIFO + backfill scheduler,
//! * [`script`] — `#SBATCH` script generation + automatic resource
//!   calculation from the master config (the paper's feature).
//!
//! The workflow manager drives experiments through this simulator in
//! `mode: sim`, and emits the same sbatch scripts a real deployment would
//! use in `mode: wall`.

pub mod cluster;
pub mod job;
pub mod scheduler;
pub mod script;

pub use cluster::ClusterSpec;
pub use job::{JobId, JobRequest, JobState};
pub use scheduler::{Scheduler, SchedulerStats};
pub use script::{resource_request, sbatch_script, ResourceRequest};
