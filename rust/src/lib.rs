//! # SProBench — Stream Processing Benchmark for HPC Infrastructure
//!
//! A from-scratch reproduction of *SProBench* (Kulkarni & Ghiasvand, 2025)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the benchmark suite itself: workload generator
//!   ([`wgen`]), message broker ([`broker`]), stream-processing engine
//!   ([`engine`]) with three framework personalities, composable
//!   operator-chain pipelines ([`pipelines`]) covering the three paper
//!   pipelines as canonical chains, metric collection ([`metrics`], [`jvm`],
//!   [`sysmon`]), SLURM integration ([`slurm`]), workflow automation
//!   ([`workflow`]), post-processing ([`postprocess`]), the baseline
//!   benchmark models ([`baselines`]), the spot-run driver
//!   ([`coordinator`]) and the max-capacity experiment driver
//!   ([`experiment`]).
//! * **L2/L1 (build time)** — the pipelines' per-event compute as JAX +
//!   Pallas programs, AOT-lowered to HLO text by `python/compile/aot.py`
//!   and executed on the hot path through [`runtime`] (PJRT CPU client).
//!
//! Python never runs at request time: `make artifacts` compiles once, the
//! Rust binary is self-contained afterwards.
//!
//! See the repository `README.md` for a quickstart and the module map,
//! and `docs/ARCHITECTURE.md` for the run lifecycle and layering.

// The tree is unsafe-free by construction (pure std, no FFI on the
// default path) — lock that in, and make dropped `Result`s a hard
// error: a swallowed send/IO error in a benchmark harness silently
// corrupts measurements.
#![forbid(unsafe_code)]
#![deny(unused_must_use)]

pub mod analysis;
pub mod baselines;
pub mod bench;
pub mod broker;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiment;
pub mod jvm;
pub mod metrics;
pub mod net;
pub mod pipelines;
pub mod postprocess;
pub mod runtime;
pub mod slurm;
pub mod sysmon;
pub mod util;
pub mod wgen;
pub mod workflow;
