//! Stream-processing engine substrate (the paper's Flink / Spark Streaming /
//! Kafka Streams role).
//!
//! A deliberately compact dataflow engine: source (broker consumer) →
//! pipeline step (the paper's three pipelines, compute via AOT HLO) → sink
//! (broker producer), replicated across `parallelism` task slots.  Three
//! *personalities* reproduce the execution disciplines of the frameworks
//! the paper integrates:
//!
//! * **Flink** — record-pipelined: process every poll immediately,
//!   moderate poll batches;
//! * **Spark** — micro-batched: accumulate for a batch interval, then
//!   process the accumulated slice at once (higher latency, high
//!   throughput);
//! * **Kafka Streams** — per-partition, small polls, commit per poll
//!   (lowest latency, more per-batch overhead).
//!
//! * [`batch`] — parsed event batches (records → tensors-ready arrays).
//! * [`window`] — sliding-window pane state for the keyed pipeline, in
//!   processing-time and event-time (watermark-driven) flavours.
//! * [`watermark`] — bounded-disorder watermark tracking.
//! * [`exchange`] — keyed inter-task exchange (shuffle) fabric: stage
//!   boundaries with hash-routed row channels and min-merged frontiers.
//! * [`checkpoint`] — aligned checkpoints: CRC-validated snapshot files
//!   and the epoch coordinator behind kill-and-restore recovery.
//! * [`supervisor`] — heartbeats, watchdog state, and recovery SLO
//!   accounting for the in-run self-healing driver.
//! * [`personality`] — the framework execution disciplines.
//! * [`task`] — one task slot's poll→process→produce→commit loop.
//! * [`core`] — engine lifecycle: spawn tasks, join, aggregate stats.

pub mod batch;
pub mod checkpoint;
pub mod core;
pub mod exchange;
pub mod personality;
pub mod supervisor;
pub mod task;
pub mod watermark;
pub mod window;

pub use batch::EventBatch;
pub use checkpoint::{Checkpoint, CheckpointCoordinator, CheckpointStats, CheckpointStore, TaskPart};
pub use core::{Engine, EngineReport, RunHooks};
pub use supervisor::{FaultOutcome, ResilienceStats, TaskMonitor};
pub use exchange::{Boundary, ExchangeFabric, ExchangePacket};
pub use personality::Personality;
pub use watermark::WatermarkTracker;
pub use window::{AggKind, EventTimeWindow, LatePolicy, SlidingWindow, WindowEmit, WindowTime};
