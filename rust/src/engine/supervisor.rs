//! In-run supervision: heartbeats, a watchdog, and recovery accounting.
//!
//! Every task slot publishes a heartbeat each poll-loop iteration into a
//! [`TaskMonitor`]; the supervising driver (`coordinator::run_recovery`)
//! runs a watchdog that injects scheduled faults ([`crate::config::FaultSpec`])
//! and detects dead or hung tasks by heartbeat deadline, then heals them
//! by restarting the engine incarnation from the latest committed
//! checkpoint — bounded retries, exponential backoff, and a counted cold
//! start when no checkpoint is usable.
//!
//! This module holds the shared state and the pure accounting:
//!
//! * [`TaskMonitor`] — per-task heartbeat/hang/done state shared between
//!   task threads and the watchdog;
//! * [`FaultOutcome`] — one scheduled fault's injection/detection/heal
//!   timeline and the `detect_us`/`mttr_us` SLO metrics derived from it;
//! * [`ResilienceStats`] — the aggregate `resilience` block of
//!   results.json (restarts, downtime, poison quarantine).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::config::FaultSpec;
use crate::util::json::Json;

/// At most this many quarantined payloads are carried verbatim into the
/// dead-letter sample of results.json (per run, merged across tasks).
pub const DEAD_LETTER_SAMPLE_CAP: usize = 8;

/// Heartbeat/hang state shared between the task threads of one engine
/// incarnation and the supervising watchdog.  All operations are lock-free
/// loads/stores — the beat sits on the poll loop's hot path.
pub struct TaskMonitor {
    /// Last heartbeat per task, clock µs; 0 = no beat yet (still
    /// compiling / restoring — the watchdog must not count it as stale).
    beats: Vec<AtomicU64>,
    /// Injected hang deadline per task, clock µs; a task seeing a future
    /// deadline stalls (no polls, no beats) until it passes.
    hang_until: Vec<AtomicU64>,
    /// Tasks that exited their drive loop (gracefully, killed, or with an
    /// error).  Done tasks are exempt from staleness checks so a drained
    /// task is never declared hung.
    done: Vec<AtomicBool>,
}

impl TaskMonitor {
    pub fn new(parallelism: u32) -> Self {
        Self {
            beats: (0..parallelism).map(|_| AtomicU64::new(0)).collect(),
            hang_until: (0..parallelism).map(|_| AtomicU64::new(0)).collect(),
            done: (0..parallelism).map(|_| AtomicBool::new(false)).collect(),
        }
    }

    pub fn parallelism(&self) -> u32 {
        self.beats.len() as u32
    }

    /// Publish a heartbeat (called by task `id` every poll iteration).
    pub fn beat(&self, id: u32, now: u64) {
        self.beats[id as usize].store(now, Ordering::Relaxed);
    }

    pub fn last_beat(&self, id: u32) -> u64 {
        self.beats[id as usize].load(Ordering::Relaxed)
    }

    /// Inject a hang: task `id` stalls until `until` (clock µs).
    pub fn inject_hang(&self, id: u32, until: u64) {
        self.hang_until[id as usize].store(until, Ordering::SeqCst);
    }

    /// The hang deadline task `id` must respect (0 = none injected).
    pub fn hang_deadline(&self, id: u32) -> u64 {
        self.hang_until[id as usize].load(Ordering::Relaxed)
    }

    /// Mark task `id` as exited (any path out of the drive loop).
    pub fn mark_done(&self, id: u32) {
        self.done[id as usize].store(true, Ordering::SeqCst);
    }

    /// The first live task whose last heartbeat is older than `timeout`
    /// at `now`.  Tasks that never beat (still compiling) and tasks that
    /// exited are exempt.
    pub fn stale_task(&self, now: u64, timeout: u64) -> Option<u32> {
        for (id, beat) in self.beats.iter().enumerate() {
            if self.done[id].load(Ordering::SeqCst) {
                continue;
            }
            let last = beat.load(Ordering::Relaxed);
            if last > 0 && now.saturating_sub(last) > timeout {
                return Some(id as u32);
            }
        }
        None
    }
}

/// Exponential supervisor backoff: `base * 2^restart_index`, saturating
/// (the shift is capped so a long fault storm cannot overflow).
pub fn backoff_micros(base: u64, restart_index: u32) -> u64 {
    base.saturating_mul(1u64 << restart_index.min(16))
}

/// One scheduled fault's runtime timeline.  Timestamps are clock µs;
/// `None` means the phase never happened (fault scheduled past the end of
/// the run, or degradation without detection).
#[derive(Clone, Debug)]
pub struct FaultOutcome {
    pub spec: FaultSpec,
    pub injected_at: Option<u64>,
    pub detected_at: Option<u64>,
    pub healed_at: Option<u64>,
}

impl FaultOutcome {
    pub fn new(spec: FaultSpec) -> Self {
        Self {
            spec,
            injected_at: None,
            detected_at: None,
            healed_at: None,
        }
    }

    /// Injection → detection, µs (0 until both happened).
    pub fn detect_micros(&self) -> u64 {
        match (self.injected_at, self.detected_at) {
            (Some(i), Some(d)) => d.saturating_sub(i),
            _ => 0,
        }
    }

    /// Injection → healed (mean time to repair), µs (0 until healed).
    pub fn mttr_micros(&self) -> u64 {
        match (self.injected_at, self.healed_at) {
            (Some(i), Some(h)) => h.saturating_sub(i),
            _ => 0,
        }
    }

    /// The per-fault entry of the results.json `faults[]` list.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", Json::Str(self.spec.kind.name().to_string()));
        j.set("target", Json::Str(self.spec.kind.target()));
        j.set("at_us", Json::Int(self.spec.at_micros as i64));
        j.set("duration_us", Json::Int(self.spec.duration_micros as i64));
        j.set("injected", Json::Bool(self.injected_at.is_some()));
        j.set("detected", Json::Bool(self.detected_at.is_some()));
        j.set("healed", Json::Bool(self.healed_at.is_some()));
        j.set("detect_us", Json::Int(self.detect_micros() as i64));
        j.set("mttr_us", Json::Int(self.mttr_micros() as i64));
        j
    }
}

/// The aggregate `resilience` block of results.json.
#[derive(Clone, Debug, Default)]
pub struct ResilienceStats {
    /// Faults actually injected (scheduled past the run's end never are).
    pub injected: u64,
    /// Faults the supervisor noticed (death observed / heartbeat stale /
    /// stall tracked).
    pub detected: u64,
    /// Faults fully healed (engine back to all-ready, or stall released).
    pub healed: u64,
    /// Supervised engine restarts performed.
    pub restart_count: u64,
    /// Restarts that found no usable checkpoint and went cold.
    pub cold_starts: u64,
    /// Total wall time with the engine down across restarts, µs
    /// (injection → back-to-all-ready, summed over restart faults).
    pub downtime_micros: u64,
    /// Mean injection→detection over detected restart faults, µs.
    pub detect_micros: u64,
    /// Mean injection→healed over healed restart faults, µs.
    pub mttr_micros: u64,
    /// Malformed records quarantined on the parse path.
    pub poison_records: u64,
    /// Sample of quarantined payloads (lossy UTF-8, capped at
    /// [`DEAD_LETTER_SAMPLE_CAP`]).
    pub dead_letters: Vec<String>,
}

impl ResilienceStats {
    /// Fold the per-fault timelines into the aggregate block.
    pub fn from_outcomes(
        outcomes: &[FaultOutcome],
        restart_count: u64,
        cold_starts: u64,
        poison_records: u64,
        dead_letters: Vec<String>,
    ) -> Self {
        let injected = outcomes.iter().filter(|o| o.injected_at.is_some()).count() as u64;
        let detected = outcomes.iter().filter(|o| o.detected_at.is_some()).count() as u64;
        let healed = outcomes.iter().filter(|o| o.healed_at.is_some()).count() as u64;
        let restart_outcomes: Vec<&FaultOutcome> =
            outcomes.iter().filter(|o| o.spec.needs_restart()).collect();
        let downtime_micros = restart_outcomes.iter().map(|o| o.mttr_micros()).sum();
        let mean = |vals: Vec<u64>| -> u64 {
            if vals.is_empty() {
                0
            } else {
                vals.iter().sum::<u64>() / vals.len() as u64
            }
        };
        let detect_micros = mean(
            restart_outcomes
                .iter()
                .filter(|o| o.detected_at.is_some())
                .map(|o| o.detect_micros())
                .collect(),
        );
        let mttr_micros = mean(
            restart_outcomes
                .iter()
                .filter(|o| o.healed_at.is_some())
                .map(|o| o.mttr_micros())
                .collect(),
        );
        Self {
            injected,
            detected,
            healed,
            restart_count,
            cold_starts,
            downtime_micros,
            detect_micros,
            mttr_micros,
            poison_records,
            dead_letters,
        }
    }

    /// The `resilience` block of results.json.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("injected", Json::Int(self.injected as i64));
        j.set("detected", Json::Int(self.detected as i64));
        j.set("healed", Json::Int(self.healed as i64));
        j.set("restart_count", Json::Int(self.restart_count as i64));
        j.set("cold_starts", Json::Int(self.cold_starts as i64));
        j.set("downtime_us", Json::Int(self.downtime_micros as i64));
        j.set("detect_us", Json::Int(self.detect_micros as i64));
        j.set("mttr_us", Json::Int(self.mttr_micros as i64));
        j.set("poison_records", Json::Int(self.poison_records as i64));
        j.set(
            "dead_letter_sample",
            Json::Arr(
                self.dead_letters
                    .iter()
                    .map(|s| Json::Str(s.clone()))
                    .collect(),
            ),
        );
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FaultKind;

    fn spec(kind: FaultKind, at: u64) -> FaultSpec {
        FaultSpec {
            kind,
            at_micros: at,
            duration_micros: 0,
            seed: 0,
        }
    }

    #[test]
    fn monitor_flags_only_live_stale_tasks() {
        let m = TaskMonitor::new(3);
        // No beats yet: nobody is stale (compile/restore grace).
        assert_eq!(m.stale_task(10_000_000, 100), None);
        m.beat(0, 1_000_000);
        m.beat(1, 1_000_000);
        m.beat(2, 1_000_000);
        assert_eq!(m.stale_task(1_000_050, 100), None, "within deadline");
        assert_eq!(m.stale_task(1_000_200, 100), Some(0), "first stale task");
        m.beat(0, 1_000_200);
        assert_eq!(m.stale_task(1_000_200, 100), Some(1));
        // A done task is never hung, even silent.
        m.mark_done(1);
        m.mark_done(2);
        assert_eq!(m.stale_task(2_000_000, 100), None);
    }

    #[test]
    fn hang_deadline_roundtrips() {
        let m = TaskMonitor::new(2);
        assert_eq!(m.hang_deadline(1), 0);
        m.inject_hang(1, 5_000_000);
        assert_eq!(m.hang_deadline(1), 5_000_000);
        assert_eq!(m.hang_deadline(0), 0, "per-task isolation");
    }

    #[test]
    fn backoff_doubles_and_saturates() {
        assert_eq!(backoff_micros(50_000, 0), 50_000);
        assert_eq!(backoff_micros(50_000, 1), 100_000);
        assert_eq!(backoff_micros(50_000, 3), 400_000);
        // The shift cap keeps pathological restart storms finite.
        assert!(backoff_micros(u64::MAX, 60) == u64::MAX);
    }

    #[test]
    fn outcome_slo_metrics_derive_from_the_timeline() {
        let mut o = FaultOutcome::new(spec(FaultKind::KillTask { task: 1 }, 500_000));
        assert_eq!(o.detect_micros(), 0);
        assert_eq!(o.mttr_micros(), 0);
        o.injected_at = Some(1_000_000);
        o.detected_at = Some(1_040_000);
        o.healed_at = Some(1_250_000);
        assert_eq!(o.detect_micros(), 40_000);
        assert_eq!(o.mttr_micros(), 250_000);
        let j = o.to_json();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("kill_task"));
        assert_eq!(j.get("detect_us").and_then(|v| v.as_i64()), Some(40_000));
        assert_eq!(j.get("mttr_us").and_then(|v| v.as_i64()), Some(250_000));
        assert_eq!(j.get("healed").and_then(|v| v.as_bool()), Some(true));
    }

    #[test]
    fn resilience_aggregates_restart_faults_only() {
        let mut kill = FaultOutcome::new(spec(FaultKind::KillTask { task: 0 }, 0));
        kill.injected_at = Some(100);
        kill.detected_at = Some(150);
        kill.healed_at = Some(300);
        let mut hang = FaultOutcome::new(spec(FaultKind::HangTask { task: 1 }, 0));
        hang.injected_at = Some(1_000);
        hang.detected_at = Some(1_100);
        hang.healed_at = Some(1_400);
        // A stall degrades in place: injected+healed but adds no downtime.
        let mut stall = FaultOutcome::new(spec(FaultKind::StallPartition { partition: 0 }, 0));
        stall.injected_at = Some(2_000);
        stall.detected_at = Some(2_000);
        stall.healed_at = Some(2_500);
        let r = ResilienceStats::from_outcomes(
            &[kill, hang, stall],
            2,
            1,
            7,
            vec!["bad".into()],
        );
        assert_eq!(r.injected, 3);
        assert_eq!(r.detected, 3);
        assert_eq!(r.healed, 3);
        assert_eq!(r.restart_count, 2);
        assert_eq!(r.cold_starts, 1);
        assert_eq!(r.downtime_micros, 200 + 400, "stall adds no downtime");
        assert_eq!(r.detect_micros, (50 + 100) / 2);
        assert_eq!(r.mttr_micros, (200 + 400) / 2);
        assert_eq!(r.poison_records, 7);
        let j = r.to_json();
        assert_eq!(j.get("downtime_us").and_then(|v| v.as_i64()), Some(600));
        assert_eq!(j.get("restart_count").and_then(|v| v.as_i64()), Some(2));
        assert_eq!(
            j.get("dead_letter_sample").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(1)
        );
    }
}
