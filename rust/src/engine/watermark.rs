//! Watermark tracking for event-time processing.
//!
//! A watermark is the engine's claim that no record with a generation
//! timestamp below it is still expected.  The suite uses the classic
//! bounded-disorder heuristic (Karimov et al.): the watermark trails the
//! maximum observed event timestamp by a fixed bound chosen from the
//! workload's disorder model, advancing once per processed [`RowBatch`]
//! (never per record — watermark math stays off the per-record hot path).
//!
//! [`RowBatch`]: crate::pipelines::RowBatch

/// Bounded-disorder watermark: `watermark = max(gen_ts seen) - bound`,
/// monotonically non-decreasing.
#[derive(Clone, Debug)]
pub struct WatermarkTracker {
    bound_micros: u64,
    max_ts: u64,
    watermark: u64,
    seen: bool,
}

impl WatermarkTracker {
    /// `bound_micros` is the disorder slack: how far behind the observed
    /// frontier the watermark trails.  Bound it at or above the stream's
    /// real maximum lateness and no in-bound record is ever late.
    pub fn new(bound_micros: u64) -> Self {
        Self {
            bound_micros,
            max_ts: 0,
            watermark: 0,
            seen: false,
        }
    }

    pub fn bound_micros(&self) -> u64 {
        self.bound_micros
    }

    /// Observe one record's generation timestamp.
    #[inline]
    pub fn observe(&mut self, gen_ts_micros: u64) {
        self.seen = true;
        if gen_ts_micros > self.max_ts {
            self.max_ts = gen_ts_micros;
        }
    }

    /// Observe a batch of generation timestamps.
    pub fn observe_batch(&mut self, gen_ts: &[u64]) {
        for &t in gen_ts {
            self.observe(t);
        }
    }

    /// Advance and return the watermark (called once per batch).
    pub fn advance(&mut self) -> u64 {
        if self.seen {
            let w = self.max_ts.saturating_sub(self.bound_micros);
            if w > self.watermark {
                self.watermark = w;
            }
        }
        self.watermark
    }

    /// Current watermark (0 until any record was observed).
    pub fn watermark(&self) -> u64 {
        self.watermark
    }

    /// Highest generation timestamp observed so far.
    pub fn max_ts(&self) -> u64 {
        self.max_ts
    }

    /// Watermark lag relative to processing time `now`: how far event
    /// time trails the wall — the per-operator staleness metric.  0 until
    /// any record was observed.
    pub fn lag_at(&self, now_micros: u64) -> u64 {
        if !self.seen {
            return 0;
        }
        now_micros.saturating_sub(self.watermark)
    }

    /// Export the mutable state for a checkpoint:
    /// `(max_ts, watermark, seen)`.  `bound_micros` is configuration and
    /// is re-derived on restore, not checkpointed.
    pub fn export_state(&self) -> (u64, u64, bool) {
        (self.max_ts, self.watermark, self.seen)
    }

    /// Restore state captured by [`WatermarkTracker::export_state`].
    pub fn import_state(&mut self, max_ts: u64, watermark: u64, seen: bool) {
        self.max_ts = max_ts;
        self.watermark = watermark;
        self.seen = seen;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trails_the_frontier_by_the_bound() {
        let mut w = WatermarkTracker::new(1_000);
        w.observe_batch(&[5_000, 4_200, 6_000]);
        assert_eq!(w.advance(), 5_000);
        assert_eq!(w.max_ts(), 6_000);
    }

    #[test]
    fn monotone_under_out_of_order_input() {
        let mut w = WatermarkTracker::new(500);
        w.observe(10_000);
        assert_eq!(w.advance(), 9_500);
        // Older records never regress the watermark.
        w.observe(2_000);
        assert_eq!(w.advance(), 9_500);
        w.observe(11_000);
        assert_eq!(w.advance(), 10_500);
    }

    #[test]
    fn zero_until_first_observation() {
        let mut w = WatermarkTracker::new(100);
        assert_eq!(w.advance(), 0);
        assert_eq!(w.lag_at(1_000_000), 0, "no data → no lag signal");
        w.observe(50);
        // Saturates at zero when the frontier is inside the bound.
        assert_eq!(w.advance(), 0);
        assert_eq!(w.lag_at(1_000), 1_000);
    }

    #[test]
    fn export_import_roundtrips_exactly() {
        let mut a = WatermarkTracker::new(700);
        a.observe_batch(&[3_000, 9_000, 4_000]);
        a.advance();
        let (max_ts, wm, seen) = a.export_state();
        let mut b = WatermarkTracker::new(700);
        b.import_state(max_ts, wm, seen);
        assert_eq!(b.watermark(), a.watermark());
        assert_eq!(b.max_ts(), a.max_ts());
        // Both trackers evolve identically from the restored point.
        a.observe(10_000);
        b.observe(10_000);
        assert_eq!(a.advance(), b.advance());
    }

    #[test]
    fn lag_measures_distance_to_processing_time() {
        let mut w = WatermarkTracker::new(2_000);
        w.observe(10_000);
        w.advance();
        assert_eq!(w.lag_at(12_000), 4_000); // 12k now − 8k watermark
        assert_eq!(w.lag_at(7_000), 0, "saturating");
    }
}
