//! Aligned checkpointing: versioned, CRC-validated snapshot files plus
//! the coordinator that assembles per-task state parts into one atomic
//! checkpoint per epoch.
//!
//! The fault-recovery dimension Karimov et al. treat as first-class
//! ("Benchmarking Distributed Stream Data Processing Systems") needs
//! state that survives a kill: consumer-group offsets, window panes,
//! watermark positions, exchange frontiers.  The protocol here is the
//! aligned/epoch-based family (Chandy–Lamport as used by Flink), adapted
//! to this engine's structure:
//!
//! * **Epochs** — `checkpoint.interval` divides the run into numbered
//!   epochs; every task snapshots its operator state and read offsets
//!   the first time it crosses an epoch boundary, at a batch boundary
//!   (never mid-batch), so a task part always describes a prefix of its
//!   input stream.
//! * **Alignment** — a checkpoint *commits* only when all `parallelism`
//!   task parts for the epoch have arrived; staged (exchange-connected)
//!   pipelines snapshot at drained-fabric quiesce points, where the
//!   boundary frontiers fully describe the in-flight state (see
//!   `LockstepExchange::snapshot`).
//! * **Atomicity** — the file is written to a `.tmp` sibling and
//!   renamed into place; a kill mid-write can never leave a partial
//!   file observable as "latest".
//! * **Validation** — every file carries a magic string, a format
//!   version and a CRC32 over the serialized body; truncated or
//!   bit-flipped files are rejected with a readable error and skipped
//!   by the latest-checkpoint scan (degrading to an older epoch, or to
//!   a cold start).
//! * **Exactly-once offsets** — tasks commit consumer offsets to the
//!   broker group only for epochs whose checkpoint file has committed,
//!   so log pruning (min committed across groups) always retains every
//!   record a restore could need to replay.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::{parse, Json};

/// File-format magic; the first field of every checkpoint document.
pub const CHECKPOINT_MAGIC: &str = "sprobench-checkpoint";
/// Current checkpoint format version.  Bumped on layout changes; loads
/// of other versions fail with a readable error instead of guessing.
pub const CHECKPOINT_VERSION: i64 = 1;

// --- CRC32 (IEEE 802.3, the zlib polynomial) ---------------------------------

/// CRC32 over `data` (IEEE polynomial, bitwise — checkpoint bodies are
/// small enough that a table buys nothing worth the 1 KiB).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

// --- checkpoint store --------------------------------------------------------

/// One task's contribution to a checkpoint epoch.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskPart {
    /// Next read offset per owned partition: `(partition, offset)`.
    pub offsets: Vec<(u32, u64)>,
    /// Events this task had ingested when the snapshot was taken — the
    /// baseline for computing replayed records after a kill.
    pub events_in: u64,
    /// Malformed records this task had quarantined when the snapshot was
    /// taken — absolute like `events_in`, so the supervisor can subtract
    /// re-quarantined replays and keep the distinct poison count exact
    /// across restarts.  Missing in pre-quarantine checkpoint files
    /// (reads back as 0).
    pub parse_failures: u64,
    /// Serialized operator state (`Chain::snapshot_ops` /
    /// `PipelineStep::snapshot`).
    pub state: Json,
}

impl TaskPart {
    fn to_json(&self) -> Json {
        let mut offs = Vec::with_capacity(self.offsets.len());
        for &(p, o) in &self.offsets {
            offs.push(Json::Arr(vec![Json::Int(p as i64), Json::Int(o as i64)]));
        }
        let mut j = Json::obj();
        j.set("offsets", Json::Arr(offs))
            .set("events_in", Json::Int(self.events_in as i64))
            .set("parse_failures", Json::Int(self.parse_failures as i64))
            .set("state", self.state.clone());
        j
    }

    fn from_json(j: &Json) -> Result<TaskPart, String> {
        let offs = j
            .get("offsets")
            .and_then(|v| v.as_arr())
            .ok_or("task part: missing `offsets` array")?;
        let mut offsets = Vec::with_capacity(offs.len());
        for o in offs {
            let pair = o.as_arr().ok_or("task part: offset entry is not a pair")?;
            match (pair.first().and_then(|v| v.as_i64()), pair.get(1).and_then(|v| v.as_i64())) {
                (Some(p), Some(off)) if p >= 0 && off >= 0 => {
                    offsets.push((p as u32, off as u64));
                }
                _ => return Err("task part: offset pair is not two non-negative ints".into()),
            }
        }
        let events_in = j
            .get("events_in")
            .and_then(|v| v.as_i64())
            .unwrap_or(0)
            .max(0) as u64;
        let parse_failures = j
            .get("parse_failures")
            .and_then(|v| v.as_i64())
            .unwrap_or(0)
            .max(0) as u64;
        let state = j.get("state").cloned().unwrap_or(Json::Null);
        Ok(TaskPart {
            offsets,
            events_in,
            parse_failures,
            state,
        })
    }
}

/// A fully-loaded, validated checkpoint.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub epoch: u64,
    /// One part per task, indexed by task id.
    pub tasks: Vec<TaskPart>,
}

impl Checkpoint {
    /// Total events the checkpointed state covers (sum over tasks).
    pub fn events_in(&self) -> u64 {
        self.tasks.iter().map(|t| t.events_in).sum()
    }

    /// Total quarantined records the checkpointed state covers.
    pub fn parse_failures(&self) -> u64 {
        self.tasks.iter().map(|t| t.parse_failures).sum()
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("epoch", Json::Int(self.epoch as i64)).set(
            "tasks",
            Json::Arr(self.tasks.iter().map(|t| t.to_json()).collect()),
        );
        j
    }

    fn from_json(j: &Json) -> Result<Checkpoint, String> {
        let epoch = j
            .get("epoch")
            .and_then(|v| v.as_i64())
            .ok_or("checkpoint body: missing `epoch`")?;
        if epoch < 0 {
            return Err(format!("checkpoint body: negative epoch {epoch}"));
        }
        let tasks = j
            .get("tasks")
            .and_then(|v| v.as_arr())
            .ok_or("checkpoint body: missing `tasks` array")?
            .iter()
            .map(TaskPart::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Checkpoint {
            epoch: epoch as u64,
            tasks,
        })
    }
}

/// Outcome of a latest-checkpoint scan: the newest valid checkpoint (if
/// any) plus how many newer-or-equal candidates had to be skipped as
/// corrupt — the degradation counter surfaced in results.json.
#[derive(Debug, Default)]
pub struct LatestScan {
    pub checkpoint: Option<Checkpoint>,
    /// Files that looked like checkpoints but failed validation, newest
    /// first: `(file name, readable error)`.
    pub skipped: Vec<(String, String)>,
}

/// Versioned checkpoint files in one directory: `ckpt-<epoch>.json`,
/// written atomically (temp + rename), CRC-validated on load.
pub struct CheckpointStore {
    dir: PathBuf,
    /// Keep at most this many committed checkpoints (older epochs are
    /// pruned after a successful write); 0 means keep everything.
    retain: usize,
}

impl CheckpointStore {
    pub fn new(dir: impl Into<PathBuf>, retain: usize) -> CheckpointStore {
        CheckpointStore {
            dir: dir.into(),
            retain,
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file_name(epoch: u64) -> String {
        format!("ckpt-{epoch:08}.json")
    }

    /// Parse `ckpt-<epoch>.json` back to its epoch.
    fn parse_epoch(name: &str) -> Option<u64> {
        name.strip_prefix("ckpt-")?
            .strip_suffix(".json")?
            .parse::<u64>()
            .ok()
    }

    /// Serialize `ckpt` into the wire document: magic + version + CRC32
    /// over the exact body bytes embedded after them.
    fn encode(ckpt: &Checkpoint) -> String {
        let body = ckpt.to_json().to_string();
        let crc = crc32(body.as_bytes());
        format!(
            "{{\"magic\":\"{CHECKPOINT_MAGIC}\",\"version\":{CHECKPOINT_VERSION},\
             \"crc32\":{crc},\"body\":{body}}}"
        )
    }

    /// Validate and decode one checkpoint document.
    pub fn decode(text: &str) -> Result<Checkpoint, String> {
        let doc = parse(text).map_err(|e| format!("checkpoint is not valid JSON: {e}"))?;
        match doc.get("magic").and_then(|v| v.as_str()) {
            Some(m) if m == CHECKPOINT_MAGIC => {}
            Some(m) => return Err(format!("not a checkpoint file (magic '{m}')")),
            None => return Err("not a checkpoint file (no magic field)".into()),
        }
        match doc.get("version").and_then(|v| v.as_i64()) {
            Some(v) if v == CHECKPOINT_VERSION => {}
            Some(v) => {
                return Err(format!(
                    "unsupported checkpoint version {v} (this build reads version \
                     {CHECKPOINT_VERSION})"
                ))
            }
            None => return Err("checkpoint has no version field".into()),
        }
        let stored = doc
            .get("crc32")
            .and_then(|v| v.as_i64())
            .ok_or("checkpoint has no crc32 field")? as u32;
        let body = doc.get("body").ok_or("checkpoint has no body")?;
        let actual = crc32(body.to_string().as_bytes());
        if actual != stored {
            return Err(format!(
                "checkpoint CRC mismatch: stored {stored:#010x}, computed {actual:#010x} — \
                 the file is corrupt"
            ));
        }
        Checkpoint::from_json(body)
    }

    /// Write one checkpoint atomically; returns its size in bytes.
    /// The document goes to `<name>.tmp` first and is renamed into place
    /// only when fully flushed, so a kill mid-write leaves at most a
    /// `.tmp` orphan the latest-scan never considers.
    pub fn write(&self, ckpt: &Checkpoint) -> Result<u64, String> {
        std::fs::create_dir_all(&self.dir)
            .map_err(|e| format!("checkpoint dir {:?}: {e}", self.dir))?;
        let text = Self::encode(ckpt);
        let final_path = self.dir.join(Self::file_name(ckpt.epoch));
        let tmp_path = self.dir.join(format!("{}.tmp", Self::file_name(ckpt.epoch)));
        std::fs::write(&tmp_path, &text).map_err(|e| format!("write {tmp_path:?}: {e}"))?;
        std::fs::rename(&tmp_path, &final_path)
            .map_err(|e| format!("commit {final_path:?}: {e}"))?;
        self.prune(ckpt.epoch);
        Ok(text.len() as u64)
    }

    /// Drop committed checkpoints older than the retention window.
    fn prune(&self, newest_epoch: u64) {
        if self.retain == 0 {
            return;
        }
        let keep_from = newest_epoch.saturating_sub(self.retain as u64 - 1);
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                if let Some(epoch) = Self::parse_epoch(&name) {
                    if epoch < keep_from {
                        let _ = std::fs::remove_file(entry.path());
                    }
                }
            }
        }
    }

    /// Load one epoch's checkpoint.
    pub fn load(&self, epoch: u64) -> Result<Checkpoint, String> {
        let path = self.dir.join(Self::file_name(epoch));
        let text = std::fs::read_to_string(&path).map_err(|e| format!("read {path:?}: {e}"))?;
        Self::decode(&text).map_err(|e| format!("{path:?}: {e}"))
    }

    /// Find the newest valid checkpoint: candidates are tried newest
    /// first; corrupt or truncated files are skipped (and reported), so
    /// restore degrades to an older epoch — or to a cold start when no
    /// valid file remains.  `.tmp` orphans from an interrupted write are
    /// never candidates.
    pub fn latest(&self) -> LatestScan {
        let mut epochs: Vec<u64> = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                if let Some(epoch) = Self::parse_epoch(&name) {
                    epochs.push(epoch);
                }
            }
        }
        epochs.sort_unstable_by(|a, b| b.cmp(a));
        let mut scan = LatestScan::default();
        for epoch in epochs {
            match self.load(epoch) {
                Ok(ckpt) => {
                    scan.checkpoint = Some(ckpt);
                    break;
                }
                Err(e) => scan.skipped.push((Self::file_name(epoch), e)),
            }
        }
        scan
    }
}

// --- epoch coordinator -------------------------------------------------------

/// Aggregate counters for a run's checkpoint activity.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CheckpointStats {
    /// Committed checkpoint files.
    pub committed: u64,
    /// Bytes of the committed files.
    pub bytes: u64,
    /// Wall time spent assembling + writing committed files (µs).
    pub write_micros: u64,
}

struct CoordinatorInner {
    /// Parts collected for not-yet-committed epochs.
    pending: BTreeMap<u64, Vec<Option<TaskPart>>>,
    stats: CheckpointStats,
    /// First write/assembly error; fails the run at join.
    error: Option<String>,
}

/// Collects per-task state parts and commits one checkpoint file per
/// epoch once every task has contributed — the alignment barrier of the
/// protocol, minus the blocking: tasks submit and move on, and commit
/// their broker offsets only after observing `committed_epoch` advance.
pub struct CheckpointCoordinator {
    store: CheckpointStore,
    parallelism: usize,
    interval_micros: u64,
    start_micros: u64,
    committed_epoch: AtomicU64,
    inner: Mutex<CoordinatorInner>,
}

impl CheckpointCoordinator {
    pub fn new(
        store: CheckpointStore,
        parallelism: usize,
        interval_micros: u64,
        start_micros: u64,
    ) -> CheckpointCoordinator {
        assert!(interval_micros > 0, "checkpoint interval must be > 0");
        assert!(parallelism > 0, "checkpoint coordinator needs >= 1 task");
        CheckpointCoordinator {
            store,
            parallelism,
            interval_micros,
            start_micros,
            committed_epoch: AtomicU64::new(0),
            inner: Mutex::new(CoordinatorInner {
                pending: BTreeMap::new(),
                stats: CheckpointStats::default(),
                error: None,
            }),
        }
    }

    pub fn interval_micros(&self) -> u64 {
        self.interval_micros
    }

    pub fn store(&self) -> &CheckpointStore {
        &self.store
    }

    /// The epoch `now` falls into (epoch 0 is the pre-first-interval
    /// stretch, never checkpointed; epoch N covers
    /// `[start + N*interval, ...)`).
    pub fn epoch_at(&self, now_micros: u64) -> u64 {
        now_micros.saturating_sub(self.start_micros) / self.interval_micros
    }

    /// Highest epoch whose checkpoint file has committed (0 = none).
    pub fn committed_epoch(&self) -> u64 {
        self.committed_epoch.load(Ordering::SeqCst)
    }

    /// Submit task `task_id`'s part for `epoch`.  The epoch commits —
    /// file written, `committed_epoch` bumped — when the last part
    /// arrives; the committing call returns `Some(bytes written)` so the
    /// task that closed the epoch can account the file size.  Duplicate
    /// submissions for the same (epoch, task) are rejected: they indicate
    /// an epoch-tracking bug in the caller.
    pub fn submit(
        &self,
        epoch: u64,
        task_id: usize,
        part: TaskPart,
    ) -> Result<Option<u64>, String> {
        if task_id >= self.parallelism {
            return Err(format!(
                "checkpoint: task {task_id} out of range (parallelism {})",
                self.parallelism
            ));
        }
        let t0 = std::time::Instant::now();
        let mut inner = self.inner.lock().expect("checkpoint coordinator poisoned");
        let par = self.parallelism;
        let parts = inner
            .pending
            .entry(epoch)
            .or_insert_with(|| vec![None; par]);
        if parts[task_id].is_some() {
            return Err(format!(
                "checkpoint: duplicate part from task {task_id} for epoch {epoch}"
            ));
        }
        parts[task_id] = Some(part);
        if !parts.iter().all(|p| p.is_some()) {
            return Ok(None);
        }
        // Last part in: assemble and commit.
        let parts = inner.pending.remove(&epoch).expect("entry exists");
        let ckpt = Checkpoint {
            epoch,
            tasks: parts.into_iter().map(|p| p.expect("all present")).collect(),
        };
        match self.store.write(&ckpt) {
            Ok(bytes) => {
                inner.stats.committed += 1;
                inner.stats.bytes += bytes;
                inner.stats.write_micros += t0.elapsed().as_micros() as u64;
                // Stale pending epochs below the committed one can never
                // complete usefully; drop them so memory stays bounded.
                inner.pending.retain(|&e, _| e > epoch);
                drop(inner);
                self.committed_epoch.fetch_max(epoch, Ordering::SeqCst);
                Ok(Some(bytes))
            }
            Err(e) => {
                inner.error = Some(e.clone());
                Err(e)
            }
        }
    }

    pub fn stats(&self) -> CheckpointStats {
        self.inner.lock().expect("checkpoint coordinator poisoned").stats
    }

    /// First write error, if any (the run should fail loudly).
    pub fn error(&self) -> Option<String> {
        self.inner.lock().expect("checkpoint coordinator poisoned").error.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "sprobench-ckpt-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn part(off: u64, events: u64) -> TaskPart {
        let mut state = Json::obj();
        state.set("x", Json::Int(off as i64));
        TaskPart {
            offsets: vec![(0, off), (2, off + 1)],
            events_in: events,
            // One in eight records of the test streams is poison.
            parse_failures: events / 8,
            state,
        }
    }

    #[test]
    fn crc32_known_vectors() {
        // IEEE CRC32 reference values.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn write_load_roundtrip() {
        let store = CheckpointStore::new(tmp_dir("roundtrip"), 0);
        let ckpt = Checkpoint {
            epoch: 3,
            tasks: vec![part(100, 1000), part(250, 900)],
        };
        let bytes = store.write(&ckpt).unwrap();
        assert!(bytes > 0);
        let loaded = store.load(3).unwrap();
        assert_eq!(loaded.epoch, 3);
        assert_eq!(loaded.tasks.len(), 2);
        assert_eq!(loaded.tasks[0].offsets, vec![(0, 100), (2, 101)]);
        assert_eq!(loaded.tasks[1].events_in, 900);
        assert_eq!(loaded.events_in(), 1900);
        assert_eq!(loaded.parse_failures(), 1000 / 8 + 900 / 8);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn bit_flip_is_rejected_readably() {
        let store = CheckpointStore::new(tmp_dir("bitflip"), 0);
        let ckpt = Checkpoint {
            epoch: 1,
            tasks: vec![part(5, 50)],
        };
        store.write(&ckpt).unwrap();
        let path = store.dir().join("ckpt-00000001.json");
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip a bit inside the body (past the header fields).
        let i = bytes.len() - 10;
        bytes[i] ^= 0x08;
        std::fs::write(&path, &bytes).unwrap();
        let err = store.load(1).unwrap_err();
        assert!(
            err.contains("CRC mismatch") || err.contains("not valid JSON"),
            "unreadable error: {err}"
        );
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn truncation_is_rejected_readably() {
        let store = CheckpointStore::new(tmp_dir("trunc"), 0);
        let ckpt = Checkpoint {
            epoch: 2,
            tasks: vec![part(7, 70)],
        };
        store.write(&ckpt).unwrap();
        let path = store.dir().join("ckpt-00000002.json");
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        let err = store.load(2).unwrap_err();
        assert!(err.contains("not valid JSON"), "unreadable error: {err}");
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn latest_skips_corrupt_and_ignores_tmp_orphans() {
        let store = CheckpointStore::new(tmp_dir("latest"), 0);
        store
            .write(&Checkpoint { epoch: 1, tasks: vec![part(10, 100)] })
            .unwrap();
        store
            .write(&Checkpoint { epoch: 2, tasks: vec![part(20, 200)] })
            .unwrap();
        // Corrupt the newest committed file...
        let p2 = store.dir().join("ckpt-00000002.json");
        std::fs::write(&p2, "garbage").unwrap();
        // ...and leave a partial-write orphan that must never be "latest".
        std::fs::write(store.dir().join("ckpt-00000009.json.tmp"), "half a checkp").unwrap();
        let scan = store.latest();
        let ckpt = scan.checkpoint.expect("epoch 1 is still valid");
        assert_eq!(ckpt.epoch, 1, "scan must fall back past the corrupt epoch 2");
        assert_eq!(scan.skipped.len(), 1);
        assert!(scan.skipped[0].0.contains("00000002"));
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn latest_on_empty_or_missing_dir_is_cold_start() {
        let dir = tmp_dir("cold");
        let store = CheckpointStore::new(&dir, 0);
        assert!(store.latest().checkpoint.is_none());
        let _ = std::fs::remove_dir_all(&dir);
        let gone = CheckpointStore::new(dir.join("never-created"), 0);
        let scan = gone.latest();
        assert!(scan.checkpoint.is_none());
        assert!(scan.skipped.is_empty());
    }

    #[test]
    fn retention_prunes_old_epochs() {
        let store = CheckpointStore::new(tmp_dir("retain"), 2);
        for epoch in 1..=5 {
            store
                .write(&Checkpoint { epoch, tasks: vec![part(epoch, epoch * 10)] })
                .unwrap();
        }
        assert!(store.load(5).is_ok());
        assert!(store.load(4).is_ok());
        assert!(store.load(3).is_err(), "epoch 3 must be pruned at retain=2");
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn wrong_version_and_magic_are_readable() {
        let good = CheckpointStore::encode(&Checkpoint { epoch: 1, tasks: vec![] });
        let wrong_ver = good.replace("\"version\":1", "\"version\":99");
        let err = CheckpointStore::decode(&wrong_ver).unwrap_err();
        assert!(err.contains("version 99"), "{err}");
        let wrong_magic = good.replace(CHECKPOINT_MAGIC, "some-other-format");
        let err = CheckpointStore::decode(&wrong_magic).unwrap_err();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn coordinator_commits_when_all_parts_arrive() {
        let dir = tmp_dir("coord");
        let coord = CheckpointCoordinator::new(CheckpointStore::new(&dir, 0), 2, 1_000_000, 0);
        assert_eq!(coord.epoch_at(500_000), 0);
        assert_eq!(coord.epoch_at(2_500_000), 2);
        assert_eq!(coord.submit(1, 0, part(10, 100)).unwrap(), None);
        assert_eq!(coord.committed_epoch(), 0, "half the parts is no checkpoint");
        let bytes = coord.submit(1, 1, part(12, 120)).unwrap();
        assert!(bytes.is_some_and(|b| b > 0), "closing part reports file size");
        assert_eq!(coord.committed_epoch(), 1);
        let stats = coord.stats();
        assert_eq!(stats.committed, 1);
        assert!(stats.bytes > 0);
        let scan = coord.store().latest();
        assert_eq!(scan.checkpoint.unwrap().epoch, 1);
        // Duplicate part is a caller bug, not a silent overwrite.
        coord.submit(2, 0, part(20, 200)).unwrap();
        assert!(coord.submit(2, 0, part(21, 210)).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
