//! Engine lifecycle: spawn task slots, join, aggregate reports.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use super::checkpoint::{Checkpoint, CheckpointCoordinator};
use super::personality::Personality;
use super::task::{TaskHarness, TaskReport};
use crate::broker::{Broker, Topic};
use crate::config::BenchConfig;
use crate::jvm::{GcConfig, JvmHeap};
use crate::metrics::{LatencyRecorder, ThroughputRecorder};
use crate::pipelines::StepFactory;
use crate::runtime::RuntimeFactory;
use crate::util::clock::ClockRef;

/// Aggregated engine result.
#[derive(Clone, Debug, Default)]
pub struct EngineReport {
    pub tasks: Vec<TaskReport>,
    pub events_in: u64,
    pub events_out: u64,
    pub parse_failures: u64,
    pub batches: u64,
    pub elapsed_micros: u64,
    /// Processed events/second across all tasks.
    pub rate_events: f64,
    /// Per-operator stats merged across tasks by operator name, in chain
    /// order of first appearance.
    pub operators: Vec<(String, crate::pipelines::StepStats)>,
    /// Sample of quarantined payloads, merged across tasks and capped at
    /// [`super::supervisor::DEAD_LETTER_SAMPLE_CAP`].
    pub dead_letters: Vec<String>,
}

/// Recovery hooks threaded through an engine run; all default to off.
/// `checkpoint` arms periodic aligned snapshots (and defers broker offset
/// commits to checkpoint commits), `kill` is the crash switch a fault
/// plan flips mid-run, `restore_from` re-arms every task's state and
/// offsets from a loaded checkpoint before consuming, `monitor` collects
/// per-task heartbeats for the supervising watchdog.
#[derive(Default)]
pub struct RunHooks {
    pub checkpoint: Option<Arc<CheckpointCoordinator>>,
    pub kill: Option<Arc<AtomicBool>>,
    pub restore_from: Option<Arc<Checkpoint>>,
    pub monitor: Option<Arc<super::supervisor::TaskMonitor>>,
}

/// The stream engine: `parallelism` task slots over one consumer group.
pub struct Engine {
    config: BenchConfig,
    clock: ClockRef,
    throughput: Arc<ThroughputRecorder>,
    latency: Arc<LatencyRecorder>,
    /// One simulated JVM heap per task slot (registered with JMX).
    pub heaps: Vec<Arc<JvmHeap>>,
}

impl Engine {
    pub fn new(
        config: &BenchConfig,
        clock: ClockRef,
        throughput: Arc<ThroughputRecorder>,
        latency: Arc<LatencyRecorder>,
    ) -> Self {
        // Flink-style managed memory: the worker's heap is FIXED and
        // divided across task slots, so each slot's young generation
        // shrinks as parallelism grows — which is why total GC activity
        // rises with parallelism (the paper's Fig. 8c).
        let par = config.engine.parallelism.max(1) as u64;
        let young = ((256u64 << 20) / par).max(1 << 20);
        let old = ((2u64 << 30) / par).max(8 << 20);
        let heaps = (0..config.engine.parallelism)
            .map(|_| {
                Arc::new(JvmHeap::new(
                    GcConfig {
                        young_bytes: young,
                        old_bytes: old,
                        ..GcConfig::default()
                    },
                    clock.clone(),
                ))
            })
            .collect();
        Self {
            config: config.clone(),
            clock,
            throughput,
            latency,
            heaps,
        }
    }

    /// Run the engine until `duration_micros` elapses or the input topic
    /// closes.  Blocks until every task slot finished.
    ///
    /// `ready` (optional) is incremented once per task when its pipeline
    /// step is constructed — i.e. after PJRT compilation — so a caller can
    /// hold the workload until the engine is actually ready to consume.
    pub fn run(
        &self,
        broker: &Arc<Broker>,
        in_topic_name: &str,
        out_topic: &Arc<Topic>,
        stop: &Arc<AtomicBool>,
        duration_micros: u64,
        runtime_factory: Option<RuntimeFactory>,
        ready: Option<Arc<std::sync::atomic::AtomicU32>>,
    ) -> Result<EngineReport, String> {
        let factory = Arc::new(StepFactory::new(&self.config, runtime_factory));
        self.run_with_factory(broker, in_topic_name, out_topic, stop, duration_micros, factory, ready)
    }

    /// Like [`Engine::run`], but with an explicit step factory — the hook
    /// for user-defined pipelines (`StepFactory::custom`).
    #[allow(clippy::too_many_arguments)]
    pub fn run_with_factory(
        &self,
        broker: &Arc<Broker>,
        in_topic_name: &str,
        out_topic: &Arc<Topic>,
        stop: &Arc<AtomicBool>,
        duration_micros: u64,
        factory: Arc<StepFactory>,
        ready: Option<Arc<std::sync::atomic::AtomicU32>>,
    ) -> Result<EngineReport, String> {
        self.run_with_hooks(
            broker,
            in_topic_name,
            out_topic,
            stop,
            duration_micros,
            factory,
            ready,
            RunHooks::default(),
        )
    }

    /// Full-control entry point: [`Engine::run_with_factory`] plus the
    /// recovery hooks ([`RunHooks`]) the kill-and-restore driver uses.
    #[allow(clippy::too_many_arguments)]
    pub fn run_with_hooks(
        &self,
        broker: &Arc<Broker>,
        in_topic_name: &str,
        out_topic: &Arc<Topic>,
        stop: &Arc<AtomicBool>,
        duration_micros: u64,
        factory: Arc<StepFactory>,
        ready: Option<Arc<std::sync::atomic::AtomicU32>>,
        hooks: RunHooks,
    ) -> Result<EngineReport, String> {
        let parallelism = self.config.engine.parallelism;
        let personality = Personality::for_framework(
            self.config.engine.framework,
            self.config.engine.batch_size,
            self.config.engine.microbatch_micros,
        );
        let group = broker.subscribe(in_topic_name, "engine", parallelism);
        let ready = ready.unwrap_or_default();
        let start = self.clock.now_micros();
        let deadline = start + duration_micros;

        // Keyed exchange: when the configured chain splits at a keyby
        // boundary, one engine-lifetime fabric connects the per-task
        // stage instances (see engine::exchange).
        let fabric = factory.staged_spec().map(|stages| {
            Arc::new(crate::engine::exchange::ExchangeFabric::new(
                &stages,
                crate::pipelines::StagedChain::channel_capacity(),
            ))
        });

        let kill = hooks
            .kill
            .unwrap_or_else(|| Arc::new(AtomicBool::new(false)));
        let mut handles = Vec::with_capacity(parallelism as usize);
        for id in 0..parallelism {
            let harness = TaskHarness {
                    id,
                    personality,
                    group: group.clone(),
                    out_topic: out_topic.clone(),
                    broker: broker.clone(),
                    clock: self.clock.clone(),
                    throughput: self.throughput.clone(),
                    latency: self.latency.clone(),
                    heap: self.heaps[id as usize].clone(),
                    stop: stop.clone(),
                    factory: factory.clone(),
                    exchange: fabric.clone(),
                    deadline_micros: deadline,
                    // warmup == 0 means "record everything", including
                    // events generated before the engine started.
                    measure_after_micros: if self.config.bench.warmup_micros == 0 {
                        0
                    } else {
                        start + self.config.bench.warmup_micros
                    },
                    ready: ready.clone(),
                    checkpoint: hooks.checkpoint.clone(),
                    kill: kill.clone(),
                    restore_from: hooks.restore_from.clone(),
                    monitor: hooks.monitor.clone(),
                };
            match std::thread::Builder::new()
                .name(format!("engine-task-{id}"))
                .spawn(move || harness.run())
            {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // A mid-fleet spawn failure (thread exhaustion under a
                    // restart storm) must surface as a task failure the
                    // supervisor can count, not a panic: stop the tasks
                    // already running and report.
                    stop.store(true, std::sync::atomic::Ordering::SeqCst);
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(format!("spawn engine task {id}: {e}"));
                }
            }
        }

        let mut report = EngineReport::default();
        for h in handles {
            let task = h.join().map_err(|_| "engine task panicked")??;
            report.events_in += task.events_in;
            report.events_out += task.events_out;
            report.parse_failures += task.parse_failures;
            report.batches += task.batches;
            // Merge positionally: every task runs the same chain, so op i
            // of task j is the same operator instance slot.  (Merging by
            // name would collapse chains that repeat an operator, e.g.
            // two filters.)
            for (i, (name, stats)) in task.op_stats.iter().enumerate() {
                match report.operators.get_mut(i) {
                    Some((n, merged)) if n == name => merged.merge(stats),
                    _ => report.operators.push((name.clone(), *stats)),
                }
            }
            for dl in &task.dead_letters {
                if report.dead_letters.len() >= super::supervisor::DEAD_LETTER_SAMPLE_CAP {
                    break;
                }
                report.dead_letters.push(dl.clone());
            }
            report.tasks.push(task);
        }
        report.elapsed_micros = self.clock.now_micros().saturating_sub(start).max(1);
        report.rate_events = report.events_in as f64 * 1e6 / report.elapsed_micros as f64;
        // A killed incarnation's consumer group is dead: its frozen
        // committed offsets must not pin the broker log while the
        // restarted engine (a fresh group) works through the backlog.
        if kill.load(std::sync::atomic::Ordering::SeqCst) {
            group.leave();
        }
        // A checkpoint write failure must fail the run loudly, not
        // silently degrade exactly-once to at-most-once.
        if let Some(coord) = &hooks.checkpoint {
            if let Some(e) = coord.error() {
                return Err(format!("checkpointing failed: {e}"));
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::{BrokerConfig, Record};
    use crate::config::{Framework, PipelineKind};
    use crate::util::clock;
    use crate::wgen::{EventFormat, SensorEvent};
    use std::sync::atomic::Ordering;

    fn make_config(parallelism: u32, pipeline: PipelineKind, framework: Framework) -> BenchConfig {
        let mut cfg = BenchConfig::default();
        cfg.bench.warmup_micros = 0; // tests measure everything
        cfg.engine.parallelism = parallelism;
        cfg.engine.pipeline = pipeline;
        cfg.engine.framework = framework;
        cfg.engine.use_hlo = false; // unit tests run native; HLO covered elsewhere
        cfg.engine.batch_size = 128;
        cfg.workload.sensors = 64;
        cfg
    }

    fn seed_topic(broker: &Arc<Broker>, topic: &Arc<Topic>, n: u32, clock: &ClockRef) {
        let mut buf = Vec::new();
        let records: Vec<Record> = (0..n)
            .map(|i| {
                let ev = SensorEvent {
                    ts_micros: clock.now_micros(),
                    sensor_id: i % 64,
                    temp_c: (i % 100) as f32,
                };
                ev.serialize_into(EventFormat::Csv, 27, &mut buf);
                Record::new(ev.sensor_id, buf.as_slice(), ev.ts_micros)
            })
            .collect();
        broker.produce_batch(topic, records).unwrap();
    }

    fn run_engine(
        cfg: &BenchConfig,
        events: u32,
    ) -> (EngineReport, Arc<ThroughputRecorder>, Arc<LatencyRecorder>) {
        let clk = clock::wall();
        let broker = Broker::new(BrokerConfig::default(), clk.clone());
        let in_topic = broker.create_topic("in");
        let out_topic = broker.create_topic("out");
        // Drain the out topic so capacity never binds.
        let drain = broker.subscribe("out", "drain", 1);
        let drainer = std::thread::spawn(move || {
            let mut n = 0u64;
            loop {
                match drain.poll(0, 4096) {
                    Ok(Some(b)) => {
                        n += b.record_count() as u64;
                        drain.commit(b.partition, b.next_offset);
                    }
                    Ok(None) => std::thread::sleep(std::time::Duration::from_millis(1)),
                    Err(_) => return n,
                }
            }
        });
        seed_topic(&broker, &in_topic, events, &clk);
        let tp = Arc::new(ThroughputRecorder::new());
        let lat = Arc::new(LatencyRecorder::new());
        let engine = Engine::new(cfg, clk.clone(), tp.clone(), lat.clone());
        let stop = Arc::new(AtomicBool::new(false));
        // Close the input once seeded so tasks drain and exit.
        in_topic.close();
        let report = engine
            .run(&broker, "in", &out_topic, &stop, 30_000_000, None, None)
            .unwrap();
        broker.shutdown();
        let drained = drainer.join().unwrap();
        assert_eq!(drained, report.events_out, "egestion count mismatch");
        (report, tp, lat)
    }

    #[test]
    fn passthrough_forwards_every_event() {
        let cfg = make_config(2, PipelineKind::PassThrough, Framework::Flink);
        let (report, tp, _) = run_engine(&cfg, 1000);
        assert_eq!(report.events_in, 1000);
        assert_eq!(report.events_out, 1000);
        assert_eq!(report.parse_failures, 0);
        use crate::metrics::MeasurementPoint as P;
        assert_eq!(tp.events_at(P::ProcIn), 1000);
        assert_eq!(tp.events_at(P::ProcOut), 1000);
        assert_eq!(tp.events_at(P::BrokerOut), 1000);
    }

    #[test]
    fn cpu_pipeline_transforms_every_event() {
        let cfg = make_config(4, PipelineKind::CpuIntensive, Framework::Flink);
        let (report, _, lat) = run_engine(&cfg, 2000);
        assert_eq!(report.events_in, 2000);
        assert_eq!(report.events_out, 2000);
        let alerts: u64 = report.tasks.iter().map(|t| t.step.alerts).sum();
        // temps 0..99 °C → °F range 32..210; threshold 80°F ≈ 26.7°C.
        assert!(alerts > 0, "some events must alert");
        assert!(alerts < 2000, "not all events alert");
        use crate::metrics::MeasurementPoint as P;
        assert!(lat.merged(P::EndToEnd).count() == 2000);
        assert!(lat.merged(P::ProcOut).count() == 2000);
    }

    #[test]
    fn mem_pipeline_emits_window_aggregates() {
        let mut cfg = make_config(2, PipelineKind::MemIntensive, Framework::Flink);
        cfg.engine.window_micros = 200_000;
        cfg.engine.slide_micros = 100_000;
        let (report, _, _) = run_engine(&cfg, 1000);
        assert_eq!(report.events_in, 1000);
        // Finish-flush guarantees at least one emission per task.
        assert!(report.events_out > 0, "no window aggregates emitted");
        let emits: u64 = report.tasks.iter().map(|t| t.step.window_emits).sum();
        assert!(emits >= 2);
    }

    #[test]
    fn per_operator_stats_are_merged_across_tasks() {
        let cfg = make_config(4, PipelineKind::CpuIntensive, Framework::Flink);
        let (report, _, _) = run_engine(&cfg, 2000);
        let names: Vec<&str> = report.operators.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["cpu_transform", "emit_events"]);
        let cpu = &report.operators[0].1;
        assert_eq!(cpu.events_in, 2000, "intake summed across tasks");
        let alerts_from_tasks: u64 = report.tasks.iter().map(|t| t.step.alerts).sum();
        assert_eq!(cpu.alerts, alerts_from_tasks);
        assert_eq!(report.operators[1].1.events_out, 2000);
    }

    #[test]
    fn repeated_operators_keep_distinct_stat_entries() {
        use crate::config::{CmpOp, OpSpec, PipelineSpec};
        let mut cfg = make_config(2, PipelineKind::PassThrough, Framework::Flink);
        cfg.engine.pipeline_spec = Some(PipelineSpec {
            ops: vec![
                OpSpec::Filter {
                    cmp: CmpOp::Ge,
                    value: 0.0,
                },
                OpSpec::Filter {
                    cmp: CmpOp::Lt,
                    value: 50.0,
                },
                OpSpec::EmitEvents,
            ],
        });
        let (report, _, _) = run_engine(&cfg, 500);
        let names: Vec<&str> = report.operators.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec!["filter", "filter", "emit_events"],
            "positional merge must not collapse repeated ops"
        );
        // Chain-position semantics: second filter's intake is the first
        // filter's output.
        assert_eq!(report.operators[0].1.events_out, report.operators[1].1.events_in);
    }

    #[test]
    fn every_framework_personality_completes() {
        for fw in [Framework::Flink, Framework::Spark, Framework::KStreams] {
            let mut cfg = make_config(2, PipelineKind::CpuIntensive, fw);
            cfg.engine.microbatch_micros = 20_000;
            let (report, _, _) = run_engine(&cfg, 500);
            assert_eq!(report.events_in, 500, "{fw:?} lost events");
            assert_eq!(report.events_out, 500, "{fw:?} lost outputs");
        }
    }

    #[test]
    fn parallelism_splits_work_across_tasks() {
        let cfg = make_config(4, PipelineKind::PassThrough, Framework::Flink);
        let (report, _, _) = run_engine(&cfg, 4000);
        let active = report.tasks.iter().filter(|t| t.events_in > 0).count();
        assert!(active >= 2, "work stuck on {active} task(s)");
        assert_eq!(report.tasks.len(), 4);
    }

    #[test]
    fn gc_activity_scales_with_load() {
        let cfg = make_config(1, PipelineKind::CpuIntensive, Framework::Flink);
        let clk = clock::wall();
        let broker = Broker::new(BrokerConfig::default(), clk.clone());
        let in_topic = broker.create_topic("in");
        let out_topic = broker.create_topic("out");
        let drain = broker.subscribe("out", "drain", 1);
        std::thread::spawn(move || loop {
            match drain.poll(0, 4096) {
                Ok(Some(b)) => drain.commit(b.partition, b.next_offset),
                Ok(None) => std::thread::sleep(std::time::Duration::from_millis(1)),
                Err(_) => return,
            }
        });
        seed_topic(&broker, &in_topic, 5000, &clk);
        in_topic.close();
        let tp = Arc::new(ThroughputRecorder::new());
        let lat = Arc::new(LatencyRecorder::new());
        let engine = Engine::new(&cfg, clk.clone(), tp, lat);
        let stop = Arc::new(AtomicBool::new(false));
        engine
            .run(&broker, "in", &out_topic, &stop, 30_000_000, None, None)
            .unwrap();
        broker.shutdown();
        let allocated = engine.heaps[0].stats().allocated_bytes;
        assert!(
            allocated >= 5000 * 120,
            "allocation model under-counts: {allocated}"
        );
        let _ = stop.load(Ordering::Relaxed);
    }
}
