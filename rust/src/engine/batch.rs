//! Parsed event batches: broker records → structure-of-arrays, ready for
//! tensor marshalling.

use crate::broker::{Record, RecordBatch};
use crate::wgen::SensorEvent;

/// A batch of parsed sensor events in structure-of-arrays layout (the
/// layout the HLO artifacts consume directly).
#[derive(Clone, Debug, Default)]
pub struct EventBatch {
    pub ids: Vec<u32>,
    pub temps: Vec<f32>,
    /// Generation timestamps (end-to-end latency anchors).
    pub gen_ts: Vec<u64>,
    /// Broker append timestamps (processing-latency anchors).
    pub append_ts: Vec<u64>,
    /// Total payload bytes represented by this batch.
    pub payload_bytes: u64,
}

impl EventBatch {
    pub fn with_capacity(n: usize) -> Self {
        Self {
            ids: Vec::with_capacity(n),
            temps: Vec::with_capacity(n),
            gen_ts: Vec::with_capacity(n),
            append_ts: Vec::with_capacity(n),
            payload_bytes: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn clear(&mut self) {
        self.ids.clear();
        self.temps.clear();
        self.gen_ts.clear();
        self.append_ts.clear();
        self.payload_bytes = 0;
    }

    /// Parse and append `records`; returns the number of parse failures
    /// (malformed payloads are counted and skipped, never crash the task).
    pub fn extend_from_records(&mut self, records: &[Record]) -> usize {
        let mut failures = 0;
        for r in records {
            match SensorEvent::parse(r.payload()) {
                Some(ev) => {
                    self.ids.push(ev.sensor_id);
                    self.temps.push(ev.temp_c);
                    self.gen_ts.push(ev.ts_micros);
                    self.append_ts.push(r.append_ts_micros);
                    self.payload_bytes += r.len() as u64;
                }
                None => failures += 1,
            }
        }
        failures
    }

    /// Parse and append one [`RecordBatch`] by iterating its payload
    /// views — no `Record` materialization, no refcount traffic.  The
    /// batch's shared append stamp fans out to every parsed event.
    /// Returns the number of parse failures.
    pub fn extend_from_record_batch(&mut self, rb: &RecordBatch) -> usize {
        let mut failures = 0;
        let append_ts = rb.append_ts_micros;
        for i in 0..rb.len() {
            let payload = rb.payload(i);
            match SensorEvent::parse(payload) {
                Some(ev) => {
                    self.ids.push(ev.sensor_id);
                    self.temps.push(ev.temp_c);
                    self.gen_ts.push(ev.ts_micros);
                    self.append_ts.push(append_ts);
                    self.payload_bytes += payload.len() as u64;
                }
                None => failures += 1,
            }
        }
        failures
    }

    /// Parse and append a run of [`RecordBatch`]es (one poll's worth).
    pub fn extend_from_batches(&mut self, batches: &[RecordBatch]) -> usize {
        batches
            .iter()
            .map(|rb| self.extend_from_record_batch(rb))
            .sum()
    }

    /// Oldest generation timestamp in the batch (worst-case latency anchor).
    pub fn oldest_gen_ts(&self) -> Option<u64> {
        self.gen_ts.iter().copied().min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wgen::EventFormat;

    fn record(id: u32, temp: f32, ts: u64) -> Record {
        let ev = SensorEvent {
            ts_micros: ts,
            sensor_id: id,
            temp_c: temp,
        };
        let mut buf = Vec::new();
        ev.serialize_into(EventFormat::Json, 64, &mut buf);
        let mut r = Record::new(id, buf.as_slice(), ts);
        r.append_ts_micros = ts + 5;
        r
    }

    #[test]
    fn parses_records_into_soa() {
        let mut b = EventBatch::with_capacity(4);
        let records = vec![record(1, 20.5, 100), record(2, -3.25, 200)];
        assert_eq!(b.extend_from_records(&records), 0);
        assert_eq!(b.len(), 2);
        assert_eq!(b.ids, vec![1, 2]);
        assert!((b.temps[1] + 3.25).abs() < 0.01);
        assert_eq!(b.gen_ts, vec![100, 200]);
        assert_eq!(b.append_ts, vec![105, 205]);
        assert_eq!(b.payload_bytes, 128);
        assert_eq!(b.oldest_gen_ts(), Some(100));
    }

    #[test]
    fn parses_record_batches_with_shared_append_stamp() {
        use crate::broker::RecordBatchBuilder;
        let mut builder = RecordBatchBuilder::new();
        let mut buf = Vec::new();
        for (id, temp, ts) in [(1u32, 20.5f32, 100u64), (2, -3.25, 200)] {
            let ev = SensorEvent {
                ts_micros: ts,
                sensor_id: id,
                temp_c: temp,
            };
            ev.serialize_into(EventFormat::Json, 64, &mut buf);
            builder.push(id, &buf, ts);
        }
        let mut rb = builder.build();
        rb.append_ts_micros = 305;
        let mut b = EventBatch::with_capacity(4);
        assert_eq!(b.extend_from_batches(std::slice::from_ref(&rb)), 0);
        assert_eq!(b.len(), 2);
        assert_eq!(b.ids, vec![1, 2]);
        assert_eq!(b.gen_ts, vec![100, 200]);
        // One stamp per batch fans out to every event.
        assert_eq!(b.append_ts, vec![305, 305]);
        assert_eq!(b.payload_bytes, 128);
    }

    #[test]
    fn malformed_payloads_in_batches_are_counted_not_fatal() {
        use crate::broker::RecordBatchBuilder;
        let mut builder = RecordBatchBuilder::new();
        let ev = SensorEvent {
            ts_micros: 1,
            sensor_id: 1,
            temp_c: 1.0,
        };
        let mut buf = Vec::new();
        ev.serialize_into(EventFormat::Csv, 27, &mut buf);
        builder.push(1, &buf, 1);
        builder.push(0, b"garbage!!", 2);
        let rb = builder.build();
        let mut b = EventBatch::default();
        assert_eq!(b.extend_from_record_batch(&rb), 1);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn malformed_payloads_are_counted_not_fatal() {
        let mut b = EventBatch::default();
        let bad = Record::new(0, b"garbage!!".as_slice(), 0);
        let records = vec![record(1, 1.0, 1), bad, record(2, 2.0, 2)];
        assert_eq!(b.extend_from_records(&records), 1);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn clear_resets_everything() {
        let mut b = EventBatch::default();
        b.extend_from_records(&[record(1, 1.0, 1)]);
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.payload_bytes, 0);
        assert_eq!(b.oldest_gen_ts(), None);
    }
}
