//! Parsed event batches: broker records → structure-of-arrays, ready for
//! tensor marshalling.

use crate::broker::Record;
use crate::wgen::SensorEvent;

/// A batch of parsed sensor events in structure-of-arrays layout (the
/// layout the HLO artifacts consume directly).
#[derive(Clone, Debug, Default)]
pub struct EventBatch {
    pub ids: Vec<u32>,
    pub temps: Vec<f32>,
    /// Generation timestamps (end-to-end latency anchors).
    pub gen_ts: Vec<u64>,
    /// Broker append timestamps (processing-latency anchors).
    pub append_ts: Vec<u64>,
    /// Total payload bytes represented by this batch.
    pub payload_bytes: u64,
}

impl EventBatch {
    pub fn with_capacity(n: usize) -> Self {
        Self {
            ids: Vec::with_capacity(n),
            temps: Vec::with_capacity(n),
            gen_ts: Vec::with_capacity(n),
            append_ts: Vec::with_capacity(n),
            payload_bytes: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn clear(&mut self) {
        self.ids.clear();
        self.temps.clear();
        self.gen_ts.clear();
        self.append_ts.clear();
        self.payload_bytes = 0;
    }

    /// Parse and append `records`; returns the number of parse failures
    /// (malformed payloads are counted and skipped, never crash the task).
    pub fn extend_from_records(&mut self, records: &[Record]) -> usize {
        let mut failures = 0;
        for r in records {
            match SensorEvent::parse(r.payload()) {
                Some(ev) => {
                    self.ids.push(ev.sensor_id);
                    self.temps.push(ev.temp_c);
                    self.gen_ts.push(ev.ts_micros);
                    self.append_ts.push(r.append_ts_micros);
                    self.payload_bytes += r.len() as u64;
                }
                None => failures += 1,
            }
        }
        failures
    }

    /// Oldest generation timestamp in the batch (worst-case latency anchor).
    pub fn oldest_gen_ts(&self) -> Option<u64> {
        self.gen_ts.iter().copied().min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wgen::EventFormat;

    fn record(id: u32, temp: f32, ts: u64) -> Record {
        let ev = SensorEvent {
            ts_micros: ts,
            sensor_id: id,
            temp_c: temp,
        };
        let mut buf = Vec::new();
        ev.serialize_into(EventFormat::Json, 64, &mut buf);
        let mut r = Record::new(id, buf.as_slice(), ts);
        r.append_ts_micros = ts + 5;
        r
    }

    #[test]
    fn parses_records_into_soa() {
        let mut b = EventBatch::with_capacity(4);
        let records = vec![record(1, 20.5, 100), record(2, -3.25, 200)];
        assert_eq!(b.extend_from_records(&records), 0);
        assert_eq!(b.len(), 2);
        assert_eq!(b.ids, vec![1, 2]);
        assert!((b.temps[1] + 3.25).abs() < 0.01);
        assert_eq!(b.gen_ts, vec![100, 200]);
        assert_eq!(b.append_ts, vec![105, 205]);
        assert_eq!(b.payload_bytes, 128);
        assert_eq!(b.oldest_gen_ts(), Some(100));
    }

    #[test]
    fn malformed_payloads_are_counted_not_fatal() {
        let mut b = EventBatch::default();
        let bad = Record::new(0, b"garbage!!".as_slice(), 0);
        let records = vec![record(1, 1.0, 1), bad, record(2, 2.0, 2)];
        assert_eq!(b.extend_from_records(&records), 1);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn clear_resets_everything() {
        let mut b = EventBatch::default();
        b.extend_from_records(&[record(1, 1.0, 1)]);
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.payload_bytes, 0);
        assert_eq!(b.oldest_gen_ts(), None);
    }
}
