//! One engine task slot: the poll → parse → process → produce → commit loop.
//!
//! A task owns one consumer-group membership on the ingestion topic and a
//! producer role on the egestion topic.  Its behaviour between those two
//! points is shaped by the framework [`Personality`] (batching/commit
//! discipline) and the configured pipeline step.  Every step is metered:
//!
//! * `ProcIn` — events/bytes polled, latency broker-append → poll,
//! * `ProcOut` — events processed, latency broker-append → processed,
//! * `BrokerOut` — records produced to the egestion topic,
//! * `EndToEnd` — latency generation → egestion append.
//!
//! The loop is batch-first: polls hand back [`RecordBatch`] views that are
//! parsed by iterating payload slices (no `Record` clones), broker-anchored
//! latency collapses to one `(latency, count)` group per batch (every
//! record in a batch shares its append stamp), and the `EventBatch` /
//! emit buffers are reused across polls.  Per-record `Record`s are only
//! materialized for steps that forward raw records (pass-through).
//!
//! JVM accounting: parsing and processing allocate on a simulated heap;
//! GC pauses stall the task exactly where a real JVM would.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::batch::EventBatch;
use super::checkpoint::{Checkpoint, CheckpointCoordinator, TaskPart};
use super::personality::Personality;
use crate::broker::{Broker, ConsumerGroup, Record, RecordBatch, Topic};
use crate::jvm::JvmHeap;
use crate::metrics::{LatencyRecorder, MeasurementPoint, ThroughputRecorder};
use crate::pipelines::{StepFactory, StepStats};
use crate::util::clock::ClockRef;

/// Estimated JVM allocation per parsed event (object headers, boxed tuple
/// fields, char[] — what a JVM engine would churn per record).
const ALLOC_PER_EVENT_BYTES: u64 = 120;

/// Fixed allocation per processed batch (dispatch buffers, iterator
/// wrappers, network envelopes).  Smaller batches at higher parallelism
/// mean more batches and therefore more of this churn — the second
/// driver of Fig. 8c's GC growth.
const ALLOC_PER_BATCH_BYTES: u64 = 192 << 10;

/// Everything a task thread needs; `Send`, the pipeline step is built
/// inside the thread (PJRT runtimes are thread-confined).
pub struct TaskHarness {
    pub id: u32,
    pub personality: Personality,
    pub group: Arc<ConsumerGroup>,
    pub out_topic: Arc<Topic>,
    pub broker: Arc<Broker>,
    pub clock: ClockRef,
    pub throughput: Arc<ThroughputRecorder>,
    pub latency: Arc<LatencyRecorder>,
    pub heap: Arc<JvmHeap>,
    pub stop: Arc<AtomicBool>,
    pub factory: Arc<StepFactory>,
    /// Shared keyed-exchange fabric when the configured chain stages at a
    /// `keyby` boundary; `None` runs the classic fused chain.
    pub exchange: Option<Arc<crate::engine::exchange::ExchangeFabric>>,
    /// Hard deadline; the task drains and exits at this time even if the
    /// input topic stays open.
    pub deadline_micros: u64,
    /// Latency samples earlier than this are warmup (PJRT compile, queue
    /// fill) and are not recorded; 0 = record everything.
    pub measure_after_micros: u64,
    /// Incremented once this task's pipeline step is built (PJRT compile
    /// done); the coordinator holds the generator fleet until every task
    /// signalled so compile time never pollutes measured latency.
    pub ready: std::sync::Arc<std::sync::atomic::AtomicU32>,
    /// Aligned-checkpoint coordinator; `None` when checkpointing is off.
    /// When set, broker offset commits are deferred until the covering
    /// epoch's checkpoint file has durably committed (exactly-once state).
    pub checkpoint: Option<Arc<CheckpointCoordinator>>,
    /// Crash switch — distinct from `stop`, which is a graceful shutdown
    /// that flushes windows and commits offsets.  When `kill` flips, the
    /// task abandons buffered batches, open windows, and uncommitted
    /// offsets exactly where they stand, modeling a process kill.
    pub kill: Arc<AtomicBool>,
    /// Restore source: this task re-arms its operator state from
    /// `tasks[id]` and seeks its partitions back to the recorded offsets
    /// before consuming, replaying everything after the snapshot.
    pub restore_from: Option<Arc<Checkpoint>>,
    /// Supervision channel: the task publishes a heartbeat here every
    /// poll iteration and honours injected hang deadlines; the watchdog
    /// reads staleness off it.  `None` runs unsupervised.
    pub monitor: Option<Arc<super::supervisor::TaskMonitor>>,
}

/// Per-task result.
#[derive(Clone, Debug, Default)]
pub struct TaskReport {
    pub events_in: u64,
    pub events_out: u64,
    pub batches: u64,
    pub parse_failures: u64,
    pub step: StepStats,
    /// Per-operator stats in chain order (one entry for monolithic steps).
    pub op_stats: Vec<(String, StepStats)>,
    /// Sample of quarantined (unparseable) payloads, lossy UTF-8, capped
    /// at [`super::supervisor::DEAD_LETTER_SAMPLE_CAP`] per task.
    pub dead_letters: Vec<String>,
}

/// Reusable per-task buffers, refilled every processed batch so the steady
/// state allocates nothing on the hot path.
struct TaskBuffers {
    /// Polled-but-unprocessed batch views.
    pending: Vec<RecordBatch>,
    /// Record count across `pending` (so size checks don't re-sum).
    pending_records: usize,
    /// Uncommitted `(partition, next_offset)` pairs covering `pending`.
    commits: Vec<(u32, u64)>,
    /// Parsed structure-of-arrays view.
    parsed: EventBatch,
    /// Materialized records — only for steps that forward raw records.
    compat: Vec<Record>,
    /// Step outputs bound for the egestion topic.
    out: Vec<Record>,
}

/// Per-task checkpoint bookkeeping: deferred offsets and epoch tracking.
struct CkptState {
    coord: Arc<CheckpointCoordinator>,
    /// Last epoch this task snapshotted (0 = none yet; epoch 0 is the
    /// pre-first-interval stretch and is never checkpointed).
    last_epoch: u64,
    /// Latest processed next-offset per owned partition.  These are NOT
    /// committed to the broker group as they accrue — they ride in the
    /// next snapshot and commit only once its file is durable, so the
    /// log always retains every record a restore could replay.
    offsets: Vec<(u32, u64)>,
    /// Offsets awaiting their epoch's durable commit: `(epoch, offsets)`.
    queued: Vec<(u64, Vec<(u32, u64)>)>,
    /// Stream position already covered by the restore source, so
    /// checkpointed `events_in` counts stay absolute across any number of
    /// supervised restarts (the task's own report is incarnation-local).
    base_events: u64,
    /// Quarantined-record count already covered by the restore source —
    /// the same absolute-count trick for `parse_failures`.
    base_parse: u64,
    /// Snapshots this task contributed.
    snapshots: u64,
    /// Bytes of checkpoint files whose commit this task's submit closed.
    bytes: u64,
    /// Time spent snapshotting (and, for the closing task, writing), µs.
    micros: u64,
}

impl CkptState {
    /// Fold a processed batch's `(partition, next_offset)` into the
    /// deferred positions (latest per partition).
    fn absorb(&mut self, partition: u32, next_offset: u64) {
        match self.offsets.iter_mut().find(|(p, _)| *p == partition) {
            Some((_, off)) => *off = (*off).max(next_offset),
            None => self.offsets.push((partition, next_offset)),
        }
    }
}

impl TaskHarness {
    pub fn run(self) -> Result<TaskReport, String> {
        let mut step = match &self.exchange {
            Some(fabric) => self
                .factory
                .create_staged(self.id, fabric, self.clock.now_micros())?,
            None => self.factory.create(self.clock.now_micros())?,
        };
        if let Some(ckpt) = &self.restore_from {
            let part = ckpt.tasks.get(self.id as usize).ok_or_else(|| {
                format!(
                    "restore: checkpoint epoch {} has {} task parts, no part for task {} — \
                     it was taken at a different parallelism",
                    ckpt.epoch,
                    ckpt.tasks.len(),
                    self.id
                )
            })?;
            step.restore(&part.state)
                .map_err(|e| format!("restore task {}: {e}", self.id))?;
            for &(p, off) in &part.offsets {
                self.group.seek(p, off);
            }
        }
        self.ready.fetch_add(1, Ordering::SeqCst);
        let res = self.drive(&mut *step);
        // Whatever the exit path — graceful drain, kill, or error — the
        // watchdog must stop expecting heartbeats from this slot.
        if let Some(mon) = &self.monitor {
            mon.mark_done(self.id);
        }
        if res.is_err() {
            // Release anything sibling tasks are waiting on (exchange
            // boundaries) so their finish drains terminate and the
            // engine join surfaces this error instead of hanging.
            step.abort();
        }
        res
    }

    fn drive(&self, step: &mut dyn crate::pipelines::PipelineStep) -> Result<TaskReport, String> {
        let needs_parse = step.needs_parse();
        let shard = self.id as usize;

        let mut report = TaskReport::default();
        let mut bufs = TaskBuffers {
            pending: Vec::new(),
            pending_records: 0,
            commits: Vec::new(),
            parsed: EventBatch::with_capacity(self.personality.process_batch),
            compat: Vec::new(),
            out: Vec::new(),
        };
        let mut batch_started = self.clock.now_micros();
        let mut ckpt = self.checkpoint.as_ref().map(|coord| CkptState {
            coord: coord.clone(),
            last_epoch: 0,
            // A restored task re-arms its deferred positions at the
            // checkpoint's offsets so even a data-free run re-commits them
            // on its graceful finish.
            offsets: self
                .restore_from
                .as_ref()
                .and_then(|c| c.tasks.get(self.id as usize))
                .map(|p| p.offsets.clone())
                .unwrap_or_default(),
            queued: Vec::new(),
            base_events: self
                .restore_from
                .as_ref()
                .and_then(|c| c.tasks.get(self.id as usize))
                .map(|p| p.events_in)
                .unwrap_or(0),
            base_parse: self
                .restore_from
                .as_ref()
                .and_then(|c| c.tasks.get(self.id as usize))
                .map(|p| p.parse_failures)
                .unwrap_or(0),
            snapshots: 0,
            bytes: 0,
            micros: 0,
        });

        let interval = self.personality.batch_interval_micros;
        loop {
            if let Some(mon) = &self.monitor {
                // An injected hang: stop polling AND stop heartbeating
                // until the deadline passes, so only the watchdog's
                // heartbeat timeout can notice.  The kill switch still
                // breaks the stall — it models a SIGKILL, which even a
                // wedged task obeys.
                while self.clock.now_micros() < mon.hang_deadline(self.id)
                    && !self.kill.load(Ordering::Relaxed)
                {
                    self.clock.sleep_micros(1_000);
                }
                mon.beat(self.id, self.clock.now_micros());
            }
            if self.kill.load(Ordering::Relaxed) {
                // Crash, not a stop: no finish flush, no offset commit —
                // buffered batches, open windows, and deferred offsets are
                // lost exactly where they stand.  Exchange peers are
                // released so the fleet's join returns.
                step.abort();
                return Ok(report);
            }
            let now = self.clock.now_micros();
            let stop_now = self.stop.load(Ordering::Relaxed) || now >= self.deadline_micros;
            let mut closed = false;

            if !stop_now {
                match self.group.poll(self.id, self.personality.poll_batch) {
                    Ok(Some(polled)) => {
                        let n = polled.record_count() as u64;
                        let bytes = polled.payload_bytes();
                        self.throughput
                            .record_events(MeasurementPoint::ProcIn, n, bytes);
                        // Broker residency: append → poll.  One (latency,
                        // count) group per batch under a single shard lock
                        // — records share their batch's append stamp.
                        if now >= self.measure_after_micros {
                            self.latency.record_groups(
                                MeasurementPoint::ProcIn,
                                shard,
                                polled.batches.iter().map(|b| {
                                    (now.saturating_sub(b.append_ts_micros), b.len() as u64)
                                }),
                            );
                        }
                        bufs.pending_records += n as usize;
                        bufs.pending.extend(polled.batches);
                        bufs.commits.push((polled.partition, polled.next_offset));
                    }
                    Ok(None) => {
                        // Idle: if we hold a partial batch past the interval
                        // (or have no interval), flush it; else tick the
                        // step (exchange-staged chains drain their inbound
                        // boundaries and keep frontiers moving) and back
                        // off.
                        if bufs.pending.is_empty() {
                            bufs.out.clear();
                            step.idle(now, &mut bufs.out)?;
                            if !bufs.out.is_empty() {
                                self.emit(&mut bufs.out, &mut report)?;
                            }
                            // Idle tasks still contribute epoch snapshots;
                            // without this a quiet partition would stall
                            // the alignment barrier for the whole fleet.
                            if let Some(cs) = ckpt.as_mut() {
                                self.maybe_checkpoint(
                                    &mut *step,
                                    cs,
                                    self.clock.now_micros(),
                                    report.events_in,
                                    report.parse_failures,
                                )?;
                            }
                            self.clock.sleep_micros(200);
                            continue;
                        }
                    }
                    Err(_) => closed = true,
                }
            }

            let now = self.clock.now_micros();
            let interval_elapsed = interval == 0 || now.saturating_sub(batch_started) >= interval;
            let size_reached = bufs.pending_records >= self.personality.process_batch;
            let must_flush = closed || stop_now;

            if !bufs.pending.is_empty() && (must_flush || size_reached || interval_elapsed) {
                self.process_pending(&mut *step, needs_parse, &mut bufs, &mut report, ckpt.as_mut())?;
                // Snapshots happen at batch boundaries only, so a task
                // part always describes a prefix of its input stream.
                if let Some(cs) = ckpt.as_mut() {
                    self.maybe_checkpoint(
                        &mut *step,
                        cs,
                        self.clock.now_micros(),
                        report.events_in,
                        report.parse_failures,
                    )?;
                }
                batch_started = self.clock.now_micros();
            }

            if must_flush {
                let mut tail = Vec::new();
                step.finish(self.clock.now_micros(), &mut tail)?;
                if !tail.is_empty() {
                    self.emit(&mut tail, &mut report)?;
                }
                report.step = step.stats();
                report.op_stats = step.operator_stats();
                if let Some(cs) = &ckpt {
                    // Graceful stop: the stream is over, so the final read
                    // positions commit directly (they supersede anything
                    // still queued — offsets only grow).
                    for &(p, off) in &cs.offsets {
                        self.group.commit(p, off);
                    }
                    report.step.checkpoints = cs.snapshots;
                    report.step.checkpoint_bytes = cs.bytes;
                    report.step.checkpoint_time_micros = cs.micros;
                }
                return Ok(report);
            }
        }
    }

    /// Snapshot when the epoch advanced, then commit any deferred offsets
    /// whose covering epoch (or a later one — later checkpoints strictly
    /// cover earlier offsets) has a durable file.
    fn maybe_checkpoint(
        &self,
        step: &mut dyn crate::pipelines::PipelineStep,
        cs: &mut CkptState,
        now: u64,
        events_in: u64,
        parse_failures: u64,
    ) -> Result<(), String> {
        let epoch = cs.coord.epoch_at(now);
        if epoch > cs.last_epoch {
            let t0 = std::time::Instant::now();
            let state = step.snapshot()?;
            let part = TaskPart {
                offsets: cs.offsets.clone(),
                events_in: cs.base_events + events_in,
                parse_failures: cs.base_parse + parse_failures,
                state,
            };
            let written = cs.coord.submit(epoch, self.id as usize, part)?;
            cs.queued.push((epoch, cs.offsets.clone()));
            cs.last_epoch = epoch;
            cs.snapshots += 1;
            cs.bytes += written.unwrap_or(0);
            cs.micros += t0.elapsed().as_micros() as u64;
        }
        let committed = cs.coord.committed_epoch();
        let mut i = 0;
        while i < cs.queued.len() {
            if cs.queued[i].0 <= committed {
                let (_, offs) = cs.queued.remove(i);
                for (p, off) in offs {
                    self.group.commit(p, off);
                }
            } else {
                i += 1;
            }
        }
        Ok(())
    }

    fn process_pending(
        &self,
        step: &mut dyn crate::pipelines::PipelineStep,
        needs_parse: bool,
        bufs: &mut TaskBuffers,
        report: &mut TaskReport,
        ckpt: Option<&mut CkptState>,
    ) -> Result<(), String> {
        let shard = self.id as usize;
        let n = bufs.pending_records as u64;
        let bytes: u64 = bufs.pending.iter().map(|b| b.payload_bytes()).sum();

        // Framework dispatch overhead (what makes tiny batches costly).
        self.burn(self.personality.per_batch_overhead_micros);

        bufs.parsed.clear();
        bufs.compat.clear();
        if needs_parse {
            let quarantined = bufs.parsed.extend_from_batches(&bufs.pending) as u64;
            if quarantined > 0 {
                report.parse_failures += quarantined;
                self.sample_dead_letters(&bufs.pending, report);
            }
        } else {
            // Per-record compatibility view for steps that forward raw
            // records (pass-through); payload arenas are shared, not
            // copied.
            for rb in &bufs.pending {
                for i in 0..rb.len() {
                    bufs.compat.push(rb.record(i));
                }
            }
        }
        let now = self.clock.now_micros();
        bufs.out.clear();
        step.process(now, &bufs.compat, &bufs.parsed, &mut bufs.out)?;

        // JVM allocation model: parse tuples + output records + per-batch
        // framework churn.
        let out_bytes: u64 = bufs.out.iter().map(|r| r.len() as u64).sum();
        self.heap
            .alloc(n * ALLOC_PER_EVENT_BYTES + bytes + out_bytes + ALLOC_PER_BATCH_BYTES);

        let done = self.clock.now_micros();
        self.throughput
            .record_events(MeasurementPoint::ProcOut, n, bytes);
        // Processing latency: broker append → processing complete; again
        // one group per batch.
        if done >= self.measure_after_micros {
            self.latency.record_groups(
                MeasurementPoint::ProcOut,
                shard,
                bufs.pending
                    .iter()
                    .map(|b| (done.saturating_sub(b.append_ts_micros), b.len() as u64)),
            );
        }
        report.events_in += n;
        report.batches += 1;

        self.emit(&mut bufs.out, report)?;

        let egest = self.clock.now_micros();
        // End-to-end: only events *generated* after warmup count, so the
        // compile-era queue backlog cannot poison the tail.  Generation
        // stamps stay per-record (they are the anchor being measured);
        // the entries are read straight from the batch views.
        self.latency.record_batch(
            MeasurementPoint::EndToEnd,
            shard,
            bufs.pending
                .iter()
                .flat_map(|rb| (0..rb.len()).map(move |i| rb.entry(i).gen_ts_micros))
                .filter(|&g| g >= self.measure_after_micros)
                .map(|g| egest.saturating_sub(g)),
        );
        bufs.pending.clear();
        bufs.pending_records = 0;

        // Commit the offsets covering the processed records.  Under eager
        // commit (Flink/KStreams) this fires per processed poll-batch;
        // under micro-batching (Spark) it fires once per micro-batch —
        // the cadence difference the personalities model.  With
        // checkpointing on, offsets are deferred instead: they ride in
        // the next snapshot and reach the broker group only once its file
        // is durable, so the log retains everything a restore replays.
        match ckpt {
            Some(cs) => {
                for (p, off) in bufs.commits.drain(..) {
                    cs.absorb(p, off);
                }
            }
            None => {
                for (p, off) in bufs.commits.drain(..) {
                    self.group.commit(p, off);
                }
            }
        }
        Ok(())
    }

    /// Quarantine bookkeeping for a poll batch that contained malformed
    /// payloads: re-scan the raw batches (cold path, failures only) and
    /// keep up to the dead-letter cap of them verbatim so results.json
    /// can show *what* was poisoned, not just how many.
    fn sample_dead_letters(&self, pending: &[RecordBatch], report: &mut TaskReport) {
        let cap = super::supervisor::DEAD_LETTER_SAMPLE_CAP;
        if report.dead_letters.len() >= cap {
            return;
        }
        for rb in pending {
            for i in 0..rb.len() {
                let payload = rb.payload(i);
                if crate::wgen::SensorEvent::parse(payload).is_none() {
                    report
                        .dead_letters
                        .push(String::from_utf8_lossy(payload).into_owned());
                    if report.dead_letters.len() >= cap {
                        return;
                    }
                }
            }
        }
    }

    /// Produce processed records to the egestion topic.  The buffer is
    /// drained in place so its allocation survives across batches.
    fn emit(&self, out: &mut Vec<Record>, report: &mut TaskReport) -> Result<(), String> {
        if out.is_empty() {
            return Ok(());
        }
        let n = out.len() as u64;
        let bytes: u64 = out.iter().map(|r| r.len() as u64).sum();
        self.broker
            .produce_records(&self.out_topic, out)
            .map_err(|_| "egestion topic closed".to_string())?;
        self.throughput
            .record_events(MeasurementPoint::BrokerOut, n, bytes);
        report.events_out += n;
        Ok(())
    }

    /// Busy-burn (wall) or advance (sim) the per-batch overhead.
    fn burn(&self, micros: u64) {
        if micros == 0 {
            return;
        }
        if self.clock.is_virtual() {
            self.clock.sleep_micros(micros);
        } else {
            let start = std::time::Instant::now();
            while start.elapsed().as_micros() < micros as u128 {
                std::hint::spin_loop();
            }
        }
    }
}
