//! Keyed exchange (shuffle) fabric: hash-routed inter-task row channels.
//!
//! ShuffleBench (Henning et al.) isolates the data-shuffling step between
//! re-keying and keyed state as the place distributed stream frameworks
//! win or lose at scale; without it, a `keyby` that only rewrites keys
//! leaves every derived key group split across task slots and per-key
//! results silently change with `engine.parallelism` — the
//! task-sensitivity bug class Karimov et al. warn benchmark harnesses
//! against.  The fabric fixes that: an operator chain is split into
//! [`StageSpec`](crate::config::StageSpec)s at each `keyby` boundary, and
//! every boundary owns one bounded channel per downstream instance.
//! Rows are routed with [`crate::broker::fib_slot`] — the same Fibonacci
//! hash the broker partitions with — so a key's exchange route stays
//! consistent with broker partitioning.
//!
//! Besides rows, a boundary carries **frontiers**: each upstream instance
//! publishes a monotone event-time (or window-end) frontier, and the
//! downstream side reads the **minimum over live upstreams** as its safe
//! frontier.  That min-merge is what makes event-time watermarks correct
//! across the exchange (no instance's watermark can outrun a slower
//! upstream still holding older rows) and lets a global top-k stage wait
//! until every upstream window instance has emitted through a window end
//! before selecting.
//!
//! The fabric is engine-lifetime shared state; each task interacts with
//! it through its thread-confined
//! [`StagedChain`](crate::pipelines::StagedChain).

use std::sync::Arc;

use crate::config::StageSpec;
use crate::net::transport::{LocalTransport, Transport, TransportStats};
use crate::pipelines::RowBatch;

/// Serialized row footprint on the exchange wire: key (4) + value (4) +
/// timestamp (8) + count (8) — what a real shuffle would move per row.
pub const ROW_WIRE_BYTES: u64 = 24;

/// One routed slice of rows, stamped at send time so the drain side can
/// meter queue residency.
pub struct ExchangePacket {
    pub rows: RowBatch,
    pub sent_micros: u64,
}

/// One stage boundary: `upstreams` sending instances, one channel per
/// downstream instance, per-upstream frontier/done cells.
///
/// The boundary is a thin veneer over a [`Transport`]: in-process runs
/// get a [`LocalTransport`] (bounded channels + atomics, the original
/// shared-memory fast path); distributed runs plug in a
/// [`TcpTransport`](crate::net::transport::TcpTransport) via
/// [`Boundary::over`] without any caller noticing — the
/// try_send/drain/frontier semantics are the trait contract.
pub struct Boundary {
    link: Arc<dyn Transport<ExchangePacket>>,
}

impl Boundary {
    fn new(upstreams: u32, downstreams: u32, capacity: usize) -> Boundary {
        Boundary::over(Arc::new(LocalTransport::new(upstreams, downstreams, capacity)))
    }

    /// Build a boundary over an arbitrary transport (TCP in distributed
    /// runs, local otherwise).
    pub fn over(link: Arc<dyn Transport<ExchangePacket>>) -> Boundary {
        Boundary { link }
    }

    pub fn downstreams(&self) -> u32 {
        self.link.downstreams()
    }

    pub fn upstreams(&self) -> u32 {
        self.link.upstreams()
    }

    /// Non-blocking route: hands the packet back when the destination
    /// queue is full (or closed), so the caller can relieve its own
    /// inbound queues and retry instead of parking.  There is
    /// deliberately no blocking variant: every fabric participant also
    /// *receives*, and a sender parked on a full queue cannot drain its
    /// own inbound channels — two tasks parked on each other would
    /// deadlock (see `StagedChain::send_with_relief` for the retry
    /// discipline).
    pub fn try_send(&self, dest: u32, packet: ExchangePacket) -> Result<(), ExchangePacket> {
        self.link.try_send(dest, packet)
    }

    /// Drain pending packets for downstream instance `dest` without
    /// blocking; returns how many packets were moved into `buf`.
    pub fn drain(&self, dest: u32, buf: &mut Vec<ExchangePacket>, max: usize) -> usize {
        self.link.drain(dest, buf, max)
    }

    /// True when downstream instance `dest` has no queued packets.
    pub fn is_drained(&self, dest: u32) -> bool {
        self.link.is_drained(dest)
    }

    /// Publish upstream instance `upstream`'s frontier (monotone max).
    pub fn publish_frontier(&self, upstream: u32, frontier_micros: u64) {
        self.link.publish_frontier(upstream, frontier_micros);
    }

    /// Mark upstream instance `upstream` finished; its frontier stops
    /// constraining the safe frontier.
    pub fn finish_upstream(&self, upstream: u32) {
        self.link.finish_upstream(upstream);
    }

    /// The min-merged safe frontier: no live upstream will send a row (or
    /// window emission) with a timestamp at or below it that it has not
    /// already sent.  `u64::MAX` once every upstream finished.
    pub fn safe_frontier(&self) -> u64 {
        let mut safe = u64::MAX;
        for u in 0..self.link.upstreams() {
            if !self.link.upstream_done(u) {
                safe = safe.min(self.link.frontier(u));
            }
        }
        safe
    }

    /// True once every upstream instance marked itself finished.
    pub fn all_done(&self) -> bool {
        (0..self.link.upstreams()).all(|u| self.link.upstream_done(u))
    }

    /// The published frontier of every upstream instance, in instance
    /// order — the checkpoint coordinator snapshots these so a restored
    /// fabric resumes from the aligned frontiers instead of zero.
    /// (`publish_frontier` is monotone, so re-publishing a snapshot is
    /// always safe.)
    pub fn frontiers(&self) -> Vec<u64> {
        (0..self.link.upstreams())
            .map(|u| self.link.frontier(u))
            .collect()
    }

    /// Total rows routed through this boundary (all upstreams).
    pub fn records(&self) -> u64 {
        self.link.stats().records
    }

    /// Total bytes routed through this boundary.
    pub fn bytes(&self) -> u64 {
        self.link.stats().bytes
    }

    /// Wire-level counters of the underlying transport.
    pub fn transport_stats(&self) -> TransportStats {
        self.link.stats()
    }
}

/// The engine-lifetime exchange: one [`Boundary`] between each pair of
/// adjacent stages.
pub struct ExchangeFabric {
    boundaries: Vec<Boundary>,
}

impl ExchangeFabric {
    /// Build the fabric for a staged spec.  Boundary `b` connects stage
    /// `b` (its `parallelism` instances are the upstreams) to stage
    /// `b + 1` (whose instances own the channels).
    pub fn new(stages: &[StageSpec], capacity: usize) -> ExchangeFabric {
        let boundaries = stages
            .windows(2)
            .map(|w| Boundary::new(w[0].parallelism, w[1].parallelism, capacity))
            .collect();
        ExchangeFabric { boundaries }
    }

    pub fn boundary(&self, b: usize) -> &Boundary {
        &self.boundaries[b]
    }

    pub fn boundary_count(&self) -> usize {
        self.boundaries.len()
    }

    /// Total rows routed across every boundary.
    pub fn total_records(&self) -> u64 {
        self.boundaries.iter().map(|b| b.records()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OpSpec, PipelineSpec};
    use crate::engine::window::AggKind;

    fn staged() -> Vec<StageSpec> {
        PipelineSpec {
            ops: vec![
                OpSpec::KeyBy {
                    modulo: 16,
                    parallelism: 0,
                },
                OpSpec::window(AggKind::Mean, 1_000_000, 500_000),
                OpSpec::TopK {
                    k: 4,
                    parallelism: 0,
                },
                OpSpec::EmitAggregates,
            ],
        }
        .split_stages(4)
    }

    fn packet(n: usize, ts0: u64, sent: u64) -> ExchangePacket {
        let mut rows = RowBatch::default();
        for i in 0..n {
            rows.push(i as u32, 1.0, ts0 + i as u64, 1);
        }
        ExchangePacket {
            rows,
            sent_micros: sent,
        }
    }

    #[test]
    fn fabric_shapes_follow_the_staged_spec() {
        let stages = staged();
        assert_eq!(stages.len(), 3);
        let fabric = ExchangeFabric::new(&stages, 64);
        assert_eq!(fabric.boundary_count(), 2);
        assert_eq!(fabric.boundary(0).upstreams(), 4);
        assert_eq!(fabric.boundary(0).downstreams(), 4);
        assert_eq!(fabric.boundary(1).upstreams(), 4);
        assert_eq!(fabric.boundary(1).downstreams(), 1, "global top-k");
    }

    #[test]
    fn send_drain_accounts_records_and_bytes() {
        let fabric = ExchangeFabric::new(&staged(), 64);
        let b = fabric.boundary(0);
        assert!(b.try_send(2, packet(5, 100, 42)).is_ok());
        assert!(b.try_send(2, packet(3, 200, 43)).is_ok());
        assert_eq!(b.records(), 8);
        assert_eq!(b.bytes(), 8 * ROW_WIRE_BYTES);
        let mut buf = Vec::new();
        assert_eq!(b.drain(2, &mut buf, 16), 2);
        assert_eq!(buf[0].rows.len(), 5);
        assert_eq!(buf[0].sent_micros, 42);
        assert!(b.is_drained(2));
        assert_eq!(b.drain(2, &mut buf, 16), 0);
    }

    #[test]
    fn try_send_hands_the_packet_back_when_full() {
        let fabric = ExchangeFabric::new(&staged(), 2);
        let b = fabric.boundary(0);
        assert!(b.try_send(0, packet(1, 0, 1)).is_ok());
        assert!(b.try_send(0, packet(1, 10, 2)).is_ok());
        // Queue depth 2: the third packet comes back intact, uncounted.
        let refused = b.try_send(0, packet(3, 20, 3)).unwrap_err();
        assert_eq!(refused.rows.len(), 3);
        assert_eq!(refused.sent_micros, 3);
        assert_eq!(b.records(), 2, "refused packets are not counted");
        // Draining frees capacity; the retry succeeds and is counted.
        let mut buf = Vec::new();
        assert_eq!(b.drain(0, &mut buf, 1), 1);
        assert!(b.try_send(0, refused).is_ok());
        assert_eq!(b.records(), 5);
    }

    #[test]
    fn safe_frontier_is_min_over_live_upstreams() {
        let fabric = ExchangeFabric::new(&staged(), 64);
        let b = fabric.boundary(0);
        assert_eq!(b.safe_frontier(), 0, "nothing published yet");
        b.publish_frontier(0, 1_000);
        b.publish_frontier(1, 5_000);
        b.publish_frontier(2, 3_000);
        b.publish_frontier(3, 9_000);
        assert_eq!(b.safe_frontier(), 1_000, "the slowest upstream gates");
        // Frontiers are monotone: an older publish never regresses.
        b.publish_frontier(0, 500);
        assert_eq!(b.safe_frontier(), 1_000);
        b.publish_frontier(0, 4_000);
        assert_eq!(b.safe_frontier(), 3_000);
        assert_eq!(
            b.frontiers(),
            vec![4_000, 5_000, 3_000, 9_000],
            "per-upstream snapshot view"
        );
        // Finished upstreams stop constraining.
        b.finish_upstream(2);
        assert_eq!(b.safe_frontier(), 4_000);
        for u in [0, 1, 3] {
            b.finish_upstream(u);
        }
        assert!(b.all_done());
        assert_eq!(b.safe_frontier(), u64::MAX);
    }
}
