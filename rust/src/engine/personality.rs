//! Framework personalities: the execution disciplines of the three DSP
//! frameworks the paper integrates (Sec. 3: Apache Flink, Apache Spark
//! Streaming, Apache Kafka Streams).
//!
//! The same pipeline logic runs under all three; what differs is *when*
//! work is batched and committed — which is what separates the frameworks
//! in the paper's throughput/latency comparisons.

use crate::config::Framework;

/// Batching/commit discipline of one framework personality.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Personality {
    pub framework: Framework,
    /// Max records per broker poll.
    pub poll_batch: usize,
    /// Accumulate polls until this many records before processing
    /// (1 poll's worth for record-pipelined engines).
    pub process_batch: usize,
    /// Accumulate for this long before processing (Spark micro-batching);
    /// 0 = process as soon as `process_batch` is reached or input idles.
    pub batch_interval_micros: u64,
    /// Commit after every processed batch (true) or on an interval-aligned
    /// cadence (false → commit when a micro-batch completes).
    pub eager_commit: bool,
    /// Per-batch framework overhead (task dispatch, barriers), microseconds
    /// of busy work — what makes small batches expensive on real engines.
    pub per_batch_overhead_micros: u64,
}

impl Personality {
    /// Build the personality for `framework` with the engine batch size.
    pub fn for_framework(
        framework: Framework,
        batch_size: usize,
        microbatch_micros: u64,
    ) -> Personality {
        match framework {
            // Flink: record-pipelined; polls feed processing directly.
            Framework::Flink => Personality {
                framework,
                poll_batch: batch_size,
                process_batch: batch_size,
                batch_interval_micros: 0,
                eager_commit: true,
                per_batch_overhead_micros: 15,
            },
            // Spark Streaming: micro-batches on an interval; bigger slices,
            // scheduler overhead per micro-batch, commits per micro-batch.
            Framework::Spark => Personality {
                framework,
                poll_batch: batch_size,
                process_batch: batch_size * 4,
                batch_interval_micros: microbatch_micros,
                eager_commit: false,
                per_batch_overhead_micros: 120,
            },
            // Kafka Streams: per-partition loop, small polls, eager commits.
            Framework::KStreams => Personality {
                framework,
                poll_batch: (batch_size / 4).max(64),
                process_batch: (batch_size / 4).max(64),
                batch_interval_micros: 0,
                eager_commit: true,
                per_batch_overhead_micros: 8,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flink_is_record_pipelined() {
        let p = Personality::for_framework(Framework::Flink, 1024, 100_000);
        assert_eq!(p.process_batch, 1024);
        assert_eq!(p.batch_interval_micros, 0);
        assert!(p.eager_commit);
    }

    #[test]
    fn spark_micro_batches() {
        let p = Personality::for_framework(Framework::Spark, 1024, 100_000);
        assert_eq!(p.process_batch, 4096);
        assert_eq!(p.batch_interval_micros, 100_000);
        assert!(!p.eager_commit);
        assert!(
            p.per_batch_overhead_micros
                > Personality::for_framework(Framework::Flink, 1024, 0).per_batch_overhead_micros
        );
    }

    #[test]
    fn kstreams_polls_small() {
        let p = Personality::for_framework(Framework::KStreams, 1024, 0);
        assert_eq!(p.poll_batch, 256);
        assert!(p.eager_commit);
    }

    #[test]
    fn kstreams_small_batch_floor() {
        let p = Personality::for_framework(Framework::KStreams, 100, 0);
        assert_eq!(p.poll_batch, 64);
    }
}
