//! Keyed sliding-window pane state with pluggable aggregators.
//!
//! The paper's memory-intensive pipeline keys the stream by sensor ID and
//! maintains a sliding-window mean temperature per key as operator state
//! (Sec. 3.3).  Standard pane decomposition: the window (length `W`,
//! slide `S`, `S | W`) is covered by `W/S` contiguous panes; each pane
//! accumulates `(sum, cnt)` per key — that accumulation is exactly what
//! the `mem_pipeline_step` HLO artifact computes — and on every slide
//! boundary the live panes merge into one window emission.
//!
//! The aggregation applied at merge time is pluggable ([`AggKind`]):
//! mean, sum and count all reduce over the same `(sum, cnt)` pane state
//! (and therefore stay HLO-compatible); min and max additionally track
//! per-pane extrema and are native-only.

use std::collections::VecDeque;

/// Per-key aggregation function applied when a window closes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggKind {
    Mean,
    Sum,
    Min,
    Max,
    Count,
}

impl AggKind {
    pub fn name(self) -> &'static str {
        match self {
            AggKind::Mean => "mean",
            AggKind::Sum => "sum",
            AggKind::Min => "min",
            AggKind::Max => "max",
            AggKind::Count => "count",
        }
    }

    pub fn from_name(s: &str) -> Option<AggKind> {
        match s {
            "mean" | "avg" => Some(AggKind::Mean),
            "sum" => Some(AggKind::Sum),
            "min" => Some(AggKind::Min),
            "max" => Some(AggKind::Max),
            "count" | "cnt" => Some(AggKind::Count),
            _ => None,
        }
    }

    /// True when the aggregate reduces over the `(sum, cnt)` pane tensors
    /// alone — the state shape the `mem_pipeline_step` HLO artifact
    /// updates.  Min/max need per-pane extrema and run native-only.
    pub fn uses_sum_cnt(self) -> bool {
        !matches!(self, AggKind::Min | AggKind::Max)
    }

    /// JSON field name carrying the aggregate value in emitted records
    /// (`avg` for mean keeps the paper pipeline's wire format stable).
    pub fn field(self) -> &'static str {
        match self {
            AggKind::Mean => "avg",
            AggKind::Sum => "sum",
            AggKind::Min => "min",
            AggKind::Max => "max",
            AggKind::Count => "cnt",
        }
    }
}

/// One pane's keyed accumulator (the tensors the HLO kernel updates).
#[derive(Clone, Debug)]
pub struct Pane {
    pub start_micros: u64,
    pub sum: Vec<f32>,
    pub cnt: Vec<f32>,
    /// Per-key extrema; empty unless the window's aggregator needs them.
    pub min: Vec<f32>,
    pub max: Vec<f32>,
}

impl Pane {
    fn new(start_micros: u64, k: usize, extrema: bool) -> Self {
        Self {
            start_micros,
            sum: vec![0.0; k],
            cnt: vec![0.0; k],
            min: if extrema { vec![f32::INFINITY; k] } else { Vec::new() },
            max: if extrema { vec![f32::NEG_INFINITY; k] } else { Vec::new() },
        }
    }

    pub fn events(&self) -> f64 {
        self.cnt.iter().map(|&c| c as f64).sum()
    }
}

/// One emitted window aggregate.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowEmit {
    /// Window end time (the slide boundary that triggered the emission).
    pub end_micros: u64,
    /// `(key, value, count)` for every key observed in the window; the
    /// value is the window's [`AggKind`] applied to that key's events.
    pub aggregates: Vec<(u32, f32, u64)>,
}

/// Keyed sliding window over processing time.
pub struct SlidingWindow {
    k: usize,
    window_micros: u64,
    slide_micros: u64,
    agg: AggKind,
    /// Closed panes still inside the window, oldest first.
    panes: VecDeque<Pane>,
    /// The open pane the kernel currently accumulates into.
    current: Pane,
}

impl SlidingWindow {
    /// A mean-aggregating window (the paper's memory-intensive pipeline).
    pub fn new(k: usize, window_micros: u64, slide_micros: u64, start_micros: u64) -> Self {
        Self::with_agg(k, window_micros, slide_micros, start_micros, AggKind::Mean)
    }

    /// A window with an explicit aggregator.
    pub fn with_agg(
        k: usize,
        window_micros: u64,
        slide_micros: u64,
        start_micros: u64,
        agg: AggKind,
    ) -> Self {
        assert!(slide_micros > 0 && window_micros >= slide_micros);
        let aligned = start_micros - start_micros % slide_micros;
        let extrema = !agg.uses_sum_cnt();
        Self {
            k,
            window_micros,
            slide_micros,
            agg,
            panes: VecDeque::new(),
            current: Pane::new(aligned, k, extrema),
        }
    }

    pub fn key_count(&self) -> usize {
        self.k
    }

    pub fn agg(&self) -> AggKind {
        self.agg
    }

    /// The open pane (the HLO kernel reads its state in and writes the
    /// updated state back via [`SlidingWindow::store_state`]).
    pub fn current_pane(&self) -> &Pane {
        &self.current
    }

    /// Write the kernel's updated `(sum, cnt)` back into the open pane.
    /// Only valid for `sum/cnt` aggregators (mean, sum, count) — the HLO
    /// state carries no extrema.
    pub fn store_state(&mut self, sum: Vec<f32>, cnt: Vec<f32>) {
        debug_assert!(self.agg.uses_sum_cnt(), "HLO state path needs a sum/cnt aggregator");
        debug_assert_eq!(sum.len(), self.k);
        debug_assert_eq!(cnt.len(), self.k);
        self.current.sum = sum;
        self.current.cnt = cnt;
    }

    /// Native accumulation path (ablation / no-HLO mode / extrema).
    pub fn accumulate_native(&mut self, ids: &[u32], vals: &[f32]) {
        let extrema = !self.current.min.is_empty();
        for (&id, &v) in ids.iter().zip(vals) {
            let i = id as usize;
            if i < self.k {
                self.current.sum[i] += v;
                self.current.cnt[i] += 1.0;
                if extrema {
                    if v < self.current.min[i] {
                        self.current.min[i] = v;
                    }
                    if v > self.current.max[i] {
                        self.current.max[i] = v;
                    }
                }
            }
        }
    }

    /// Advance processing time to `now`; emits one window aggregate per
    /// crossed slide boundary (usually 0 or 1).  A window with no events
    /// still emits — with an empty `aggregates` list.
    pub fn advance(&mut self, now_micros: u64) -> Vec<WindowEmit> {
        let mut out = Vec::new();
        while now_micros >= self.current.start_micros + self.slide_micros {
            let boundary = self.current.start_micros + self.slide_micros;
            let extrema = !self.agg.uses_sum_cnt();
            let closed =
                std::mem::replace(&mut self.current, Pane::new(boundary, self.k, extrema));
            self.panes.push_back(closed);
            // Retain panes with start >= boundary - window (the window
            // ending at `boundary` covers [boundary - W, boundary)).
            while let Some(front) = self.panes.front() {
                if front.start_micros + self.window_micros < boundary {
                    self.panes.pop_front();
                } else {
                    break;
                }
            }
            out.push(self.merge(boundary));
        }
        out
    }

    /// Merge all live panes into one aggregate.
    fn merge(&self, end_micros: u64) -> WindowEmit {
        let mut sum = vec![0.0f64; self.k];
        let mut cnt = vec![0.0f64; self.k];
        let mut min = vec![f32::INFINITY; if self.agg == AggKind::Min { self.k } else { 0 }];
        let mut max = vec![f32::NEG_INFINITY; if self.agg == AggKind::Max { self.k } else { 0 }];
        for pane in &self.panes {
            for k in 0..self.k {
                sum[k] += pane.sum[k] as f64;
                cnt[k] += pane.cnt[k] as f64;
            }
            if self.agg == AggKind::Min {
                for k in 0..self.k {
                    if pane.min[k] < min[k] {
                        min[k] = pane.min[k];
                    }
                }
            }
            if self.agg == AggKind::Max {
                for k in 0..self.k {
                    if pane.max[k] > max[k] {
                        max[k] = pane.max[k];
                    }
                }
            }
        }
        let aggregates = (0..self.k)
            .filter(|&k| cnt[k] > 0.0)
            .map(|k| {
                let value = match self.agg {
                    AggKind::Mean => (sum[k] / cnt[k]) as f32,
                    AggKind::Sum => sum[k] as f32,
                    AggKind::Count => cnt[k] as f32,
                    AggKind::Min => min[k],
                    AggKind::Max => max[k],
                };
                (k as u32, value, cnt[k] as u64)
            })
            .collect();
        WindowEmit {
            end_micros,
            aggregates,
        }
    }

    /// End-of-stream flush: force the open pane closed and emit the final
    /// window even if wall time never reached the next slide boundary.
    /// No-op when the open pane is empty (nothing new to report).
    pub fn flush(&mut self) -> Vec<WindowEmit> {
        if self.current.events() == 0.0 {
            return Vec::new();
        }
        let boundary = self.current.start_micros + self.slide_micros;
        self.advance(boundary)
    }

    /// Number of closed panes currently held (state-size metric).
    pub fn live_panes(&self) -> usize {
        self.panes.len()
    }

    /// Approximate state footprint in bytes (keyed state metric).
    pub fn state_bytes(&self) -> u64 {
        let per_key = if self.agg.uses_sum_cnt() { 8 } else { 16 };
        ((self.panes.len() + 1) * self.k * per_key) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w() -> SlidingWindow {
        // window 10s, slide 2s → 5 panes.
        SlidingWindow::new(8, 10_000_000, 2_000_000, 0)
    }

    #[test]
    fn no_emission_before_first_boundary() {
        let mut sw = w();
        sw.accumulate_native(&[1], &[10.0]);
        assert!(sw.advance(1_999_999).is_empty());
    }

    #[test]
    fn emission_at_each_slide_boundary() {
        let mut sw = w();
        sw.accumulate_native(&[1, 1, 2], &[10.0, 20.0, 5.0]);
        let emits = sw.advance(2_000_000);
        assert_eq!(emits.len(), 1);
        let e = &emits[0];
        assert_eq!(e.end_micros, 2_000_000);
        assert_eq!(e.aggregates.len(), 2);
        assert_eq!(e.aggregates[0], (1, 15.0, 2));
        assert_eq!(e.aggregates[1], (2, 5.0, 1));
    }

    #[test]
    fn window_retains_w_over_s_panes() {
        let mut sw = w();
        // Pane 0: key 0 = 100. Advance 5 slides; pane 0 leaves the window
        // after boundary 12s (pane [0,2s) + 10s window ≤ 12s).
        sw.accumulate_native(&[0], &[100.0]);
        let e = sw.advance(2_000_000);
        assert_eq!(e[0].aggregates, vec![(0, 100.0, 1)]);
        for boundary in [4_000_000u64, 6_000_000, 8_000_000, 10_000_000] {
            let e = sw.advance(boundary);
            assert_eq!(e.len(), 1);
            assert_eq!(
                e[0].aggregates,
                vec![(0, 100.0, 1)],
                "boundary {boundary}: pane should still be live"
            );
        }
        let e = sw.advance(12_000_000);
        assert!(e[0].aggregates.is_empty(), "pane 0 must have expired");
        assert!(sw.live_panes() <= 5);
    }

    #[test]
    fn multiple_boundaries_in_one_advance() {
        let mut sw = w();
        sw.accumulate_native(&[3], &[1.0]);
        let emits = sw.advance(6_500_000); // crosses 2s, 4s, 6s
        assert_eq!(emits.len(), 3);
        assert_eq!(emits[0].end_micros, 2_000_000);
        assert_eq!(emits[2].end_micros, 6_000_000);
        // The single event stays visible in all three windows.
        for e in &emits {
            assert_eq!(e.aggregates, vec![(3, 1.0, 1)]);
        }
    }

    #[test]
    fn store_state_roundtrip_matches_native() {
        let mut a = w();
        let mut b = w();
        let ids = [0u32, 1, 1, 7, 7, 7];
        let temps = [1.0f32, 2.0, 4.0, 9.0, 9.0, 9.0];
        a.accumulate_native(&ids, &temps);
        // Simulate the HLO path: read state, update outside, store back.
        let pane = b.current_pane();
        let mut sum = pane.sum.clone();
        let mut cnt = pane.cnt.clone();
        for (&id, &t) in ids.iter().zip(&temps) {
            sum[id as usize] += t;
            cnt[id as usize] += 1.0;
        }
        b.store_state(sum, cnt);
        let (ea, eb) = (a.advance(2_000_000), b.advance(2_000_000));
        assert_eq!(ea[0].aggregates, eb[0].aggregates);
    }

    #[test]
    fn out_of_range_keys_are_dropped_natively() {
        let mut sw = w();
        sw.accumulate_native(&[100], &[5.0]); // k = 8
        let e = sw.advance(2_000_000);
        assert!(e[0].aggregates.is_empty());
    }

    #[test]
    fn unaligned_start_is_aligned_down() {
        let sw = SlidingWindow::new(4, 10_000_000, 2_000_000, 3_500_000);
        assert_eq!(sw.current_pane().start_micros, 2_000_000);
    }

    #[test]
    fn state_bytes_grows_with_panes() {
        let mut sw = w();
        let s0 = sw.state_bytes();
        sw.advance(2_000_000);
        assert!(sw.state_bytes() > s0);
    }

    // --- satellite: edge cases + pluggable aggregators -------------------

    #[test]
    fn non_aligned_start_event_lands_in_the_aligned_pane() {
        // start 3.5s aligns down to pane [2s, 4s); an event accumulated
        // before the first boundary must emit in the window ending at 4s.
        let mut sw = SlidingWindow::new(4, 4_000_000, 2_000_000, 3_500_000);
        sw.accumulate_native(&[2], &[7.0]);
        assert!(sw.advance(3_999_999).is_empty(), "boundary is 4s, not 3.5s+slide");
        let e = sw.advance(4_000_000);
        assert_eq!(e.len(), 1);
        assert_eq!(e[0].end_micros, 4_000_000);
        assert_eq!(e[0].aggregates, vec![(2, 7.0, 1)]);
    }

    #[test]
    fn slide_equal_to_window_is_tumbling() {
        // slide == window → one pane per window; events never carry over.
        let mut sw = SlidingWindow::new(4, 2_000_000, 2_000_000, 0);
        sw.accumulate_native(&[1], &[10.0]);
        let e = sw.advance(2_000_000);
        assert_eq!(e[0].aggregates, vec![(1, 10.0, 1)]);
        let e = sw.advance(4_000_000);
        assert!(
            e[0].aggregates.is_empty(),
            "tumbling window must not re-emit the previous window's events"
        );
        assert!(sw.live_panes() <= 1);
    }

    #[test]
    fn empty_windows_emit_zero_aggregates() {
        let mut sw = w();
        let emits = sw.advance(6_000_000); // three boundaries, no data at all
        assert_eq!(emits.len(), 3);
        for e in &emits {
            assert!(e.aggregates.is_empty(), "no data → no aggregates at {}", e.end_micros);
        }
        // flush() after pure-empty advance is also a no-op.
        assert!(sw.flush().is_empty());
    }

    #[test]
    fn sum_min_max_count_aggregators() {
        let cases: [(AggKind, f32); 4] = [
            (AggKind::Sum, 36.0),
            (AggKind::Min, 2.0),
            (AggKind::Max, 30.0),
            (AggKind::Count, 3.0),
        ];
        for (agg, expect) in cases {
            let mut sw = SlidingWindow::with_agg(4, 4_000_000, 2_000_000, 0, agg);
            sw.accumulate_native(&[1, 1, 1], &[4.0, 2.0, 30.0]);
            let e = sw.advance(2_000_000);
            assert_eq!(e[0].aggregates, vec![(1, expect, 3)], "{agg:?}");
        }
    }

    #[test]
    fn extrema_survive_pane_merges() {
        // Min lives in pane 0, max in pane 1; the merged window must see both.
        let mut min_w = SlidingWindow::with_agg(4, 4_000_000, 2_000_000, 0, AggKind::Min);
        let mut max_w = SlidingWindow::with_agg(4, 4_000_000, 2_000_000, 0, AggKind::Max);
        for sw in [&mut min_w, &mut max_w] {
            sw.accumulate_native(&[0], &[-5.0]);
            sw.advance(2_000_000);
            sw.accumulate_native(&[0], &[50.0]);
        }
        let e = min_w.advance(4_000_000);
        assert_eq!(e[0].aggregates, vec![(0, -5.0, 2)]);
        let e = max_w.advance(4_000_000);
        assert_eq!(e[0].aggregates, vec![(0, 50.0, 2)]);
    }

    #[test]
    fn agg_names_roundtrip() {
        for agg in [AggKind::Mean, AggKind::Sum, AggKind::Min, AggKind::Max, AggKind::Count] {
            assert_eq!(AggKind::from_name(agg.name()), Some(agg));
        }
        assert_eq!(AggKind::from_name("avg"), Some(AggKind::Mean));
        assert_eq!(AggKind::from_name("median"), None);
        assert_eq!(AggKind::Mean.field(), "avg");
    }

    #[test]
    fn store_state_roundtrip_for_sum_aggregator() {
        let mut sw = SlidingWindow::with_agg(4, 2_000_000, 1_000_000, 0, AggKind::Sum);
        let pane = sw.current_pane();
        let (mut sum, mut cnt) = (pane.sum.clone(), pane.cnt.clone());
        sum[3] = 12.5;
        cnt[3] = 5.0;
        sw.store_state(sum, cnt);
        let e = sw.advance(1_000_000);
        assert_eq!(e[0].aggregates, vec![(3, 12.5, 5)]);
    }
}
