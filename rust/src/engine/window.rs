//! Sliding-window pane state for the memory-intensive pipeline.
//!
//! The paper's memory-intensive pipeline keys the stream by sensor ID and
//! maintains a sliding-window mean temperature per key as operator state
//! (Sec. 3.3).  Standard pane decomposition: the window (length `W`,
//! slide `S`, `S | W`) is covered by `W/S` contiguous panes; each pane
//! accumulates `(sum, cnt)` per key — that accumulation is exactly what
//! the `mem_pipeline_step` HLO artifact computes — and on every slide
//! boundary the live panes merge into one window emission.

use std::collections::VecDeque;

/// One pane's keyed accumulator (the tensors the HLO kernel updates).
#[derive(Clone, Debug)]
pub struct Pane {
    pub start_micros: u64,
    pub sum: Vec<f32>,
    pub cnt: Vec<f32>,
}

impl Pane {
    fn new(start_micros: u64, k: usize) -> Self {
        Self {
            start_micros,
            sum: vec![0.0; k],
            cnt: vec![0.0; k],
        }
    }

    pub fn events(&self) -> f64 {
        self.cnt.iter().map(|&c| c as f64).sum()
    }
}

/// One emitted window aggregate.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowEmit {
    /// Window end time (the slide boundary that triggered the emission).
    pub end_micros: u64,
    /// `(key, mean, count)` for every key observed in the window.
    pub aggregates: Vec<(u32, f32, u64)>,
}

/// Keyed sliding window over processing time.
pub struct SlidingWindow {
    k: usize,
    window_micros: u64,
    slide_micros: u64,
    /// Closed panes still inside the window, oldest first.
    panes: VecDeque<Pane>,
    /// The open pane the kernel currently accumulates into.
    current: Pane,
}

impl SlidingWindow {
    pub fn new(k: usize, window_micros: u64, slide_micros: u64, start_micros: u64) -> Self {
        assert!(slide_micros > 0 && window_micros >= slide_micros);
        let aligned = start_micros - start_micros % slide_micros;
        Self {
            k,
            window_micros,
            slide_micros,
            panes: VecDeque::new(),
            current: Pane::new(aligned, k),
        }
    }

    pub fn key_count(&self) -> usize {
        self.k
    }

    /// The open pane (the HLO kernel reads its state in and writes the
    /// updated state back via [`SlidingWindow::store_state`]).
    pub fn current_pane(&self) -> &Pane {
        &self.current
    }

    /// Write the kernel's updated `(sum, cnt)` back into the open pane.
    pub fn store_state(&mut self, sum: Vec<f32>, cnt: Vec<f32>) {
        debug_assert_eq!(sum.len(), self.k);
        debug_assert_eq!(cnt.len(), self.k);
        self.current.sum = sum;
        self.current.cnt = cnt;
    }

    /// Native accumulation path (ablation / no-HLO mode).
    pub fn accumulate_native(&mut self, ids: &[u32], temps: &[f32]) {
        for (&id, &t) in ids.iter().zip(temps) {
            if (id as usize) < self.k {
                self.current.sum[id as usize] += t;
                self.current.cnt[id as usize] += 1.0;
            }
        }
    }

    /// Advance processing time to `now`; emits one window aggregate per
    /// crossed slide boundary (usually 0 or 1).
    pub fn advance(&mut self, now_micros: u64) -> Vec<WindowEmit> {
        let mut out = Vec::new();
        while now_micros >= self.current.start_micros + self.slide_micros {
            let boundary = self.current.start_micros + self.slide_micros;
            let closed = std::mem::replace(&mut self.current, Pane::new(boundary, self.k));
            self.panes.push_back(closed);
            // Retain panes with start >= boundary - window (the window
            // ending at `boundary` covers [boundary - W, boundary)).
            while let Some(front) = self.panes.front() {
                if front.start_micros + self.window_micros < boundary {
                    self.panes.pop_front();
                } else {
                    break;
                }
            }
            out.push(self.merge(boundary));
        }
        out
    }

    /// Merge all live panes into one aggregate.
    fn merge(&self, end_micros: u64) -> WindowEmit {
        let mut sum = vec![0.0f64; self.k];
        let mut cnt = vec![0.0f64; self.k];
        for pane in &self.panes {
            for k in 0..self.k {
                sum[k] += pane.sum[k] as f64;
                cnt[k] += pane.cnt[k] as f64;
            }
        }
        let aggregates = (0..self.k)
            .filter(|&k| cnt[k] > 0.0)
            .map(|k| (k as u32, (sum[k] / cnt[k]) as f32, cnt[k] as u64))
            .collect();
        WindowEmit {
            end_micros,
            aggregates,
        }
    }

    /// End-of-stream flush: force the open pane closed and emit the final
    /// window even if wall time never reached the next slide boundary.
    /// No-op when the open pane is empty (nothing new to report).
    pub fn flush(&mut self) -> Vec<WindowEmit> {
        if self.current.events() == 0.0 {
            return Vec::new();
        }
        let boundary = self.current.start_micros + self.slide_micros;
        self.advance(boundary)
    }

    /// Number of closed panes currently held (state-size metric).
    pub fn live_panes(&self) -> usize {
        self.panes.len()
    }

    /// Approximate state footprint in bytes (keyed state metric).
    pub fn state_bytes(&self) -> u64 {
        ((self.panes.len() + 1) * self.k * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w() -> SlidingWindow {
        // window 10s, slide 2s → 5 panes.
        SlidingWindow::new(8, 10_000_000, 2_000_000, 0)
    }

    #[test]
    fn no_emission_before_first_boundary() {
        let mut sw = w();
        sw.accumulate_native(&[1], &[10.0]);
        assert!(sw.advance(1_999_999).is_empty());
    }

    #[test]
    fn emission_at_each_slide_boundary() {
        let mut sw = w();
        sw.accumulate_native(&[1, 1, 2], &[10.0, 20.0, 5.0]);
        let emits = sw.advance(2_000_000);
        assert_eq!(emits.len(), 1);
        let e = &emits[0];
        assert_eq!(e.end_micros, 2_000_000);
        assert_eq!(e.aggregates.len(), 2);
        assert_eq!(e.aggregates[0], (1, 15.0, 2));
        assert_eq!(e.aggregates[1], (2, 5.0, 1));
    }

    #[test]
    fn window_retains_w_over_s_panes() {
        let mut sw = w();
        // Pane 0: key 0 = 100. Advance 5 slides; pane 0 leaves the window
        // after boundary 12s (pane [0,2s) + 10s window ≤ 12s).
        sw.accumulate_native(&[0], &[100.0]);
        let e = sw.advance(2_000_000);
        assert_eq!(e[0].aggregates, vec![(0, 100.0, 1)]);
        for boundary in [4_000_000u64, 6_000_000, 8_000_000, 10_000_000] {
            let e = sw.advance(boundary);
            assert_eq!(e.len(), 1);
            assert_eq!(
                e[0].aggregates,
                vec![(0, 100.0, 1)],
                "boundary {boundary}: pane should still be live"
            );
        }
        let e = sw.advance(12_000_000);
        assert!(e[0].aggregates.is_empty(), "pane 0 must have expired");
        assert!(sw.live_panes() <= 5);
    }

    #[test]
    fn multiple_boundaries_in_one_advance() {
        let mut sw = w();
        sw.accumulate_native(&[3], &[1.0]);
        let emits = sw.advance(6_500_000); // crosses 2s, 4s, 6s
        assert_eq!(emits.len(), 3);
        assert_eq!(emits[0].end_micros, 2_000_000);
        assert_eq!(emits[2].end_micros, 6_000_000);
        // The single event stays visible in all three windows.
        for e in &emits {
            assert_eq!(e.aggregates, vec![(3, 1.0, 1)]);
        }
    }

    #[test]
    fn store_state_roundtrip_matches_native() {
        let mut a = w();
        let mut b = w();
        let ids = [0u32, 1, 1, 7, 7, 7];
        let temps = [1.0f32, 2.0, 4.0, 9.0, 9.0, 9.0];
        a.accumulate_native(&ids, &temps);
        // Simulate the HLO path: read state, update outside, store back.
        let pane = b.current_pane();
        let mut sum = pane.sum.clone();
        let mut cnt = pane.cnt.clone();
        for (&id, &t) in ids.iter().zip(&temps) {
            sum[id as usize] += t;
            cnt[id as usize] += 1.0;
        }
        b.store_state(sum, cnt);
        let (ea, eb) = (a.advance(2_000_000), b.advance(2_000_000));
        assert_eq!(ea[0].aggregates, eb[0].aggregates);
    }

    #[test]
    fn out_of_range_keys_are_dropped_natively() {
        let mut sw = w();
        sw.accumulate_native(&[100], &[5.0]); // k = 8
        let e = sw.advance(2_000_000);
        assert!(e[0].aggregates.is_empty());
    }

    #[test]
    fn unaligned_start_is_aligned_down() {
        let sw = SlidingWindow::new(4, 10_000_000, 2_000_000, 3_500_000);
        assert_eq!(sw.current_pane().start_micros, 2_000_000);
    }

    #[test]
    fn state_bytes_grows_with_panes() {
        let mut sw = w();
        let s0 = sw.state_bytes();
        sw.advance(2_000_000);
        assert!(sw.state_bytes() > s0);
    }
}
