//! Keyed sliding-window pane state with pluggable aggregators, in both
//! processing-time and event-time flavours.
//!
//! The paper's memory-intensive pipeline keys the stream by sensor ID and
//! maintains a sliding-window mean temperature per key as operator state
//! (Sec. 3.3).  Standard pane decomposition: the window (length `W`,
//! slide `S`, `S | W`) is covered by `W/S` contiguous panes; each pane
//! accumulates `(sum, cnt)` per key — that accumulation is exactly what
//! the `mem_pipeline_step` HLO artifact computes — and on every slide
//! boundary the live panes merge into one window emission.
//!
//! Two time domains (following Karimov et al., "Benchmarking Distributed
//! Stream Data Processing Systems"):
//!
//! * [`SlidingWindow`] — **processing time**: records land in the pane
//!   that is open when they are processed; windows close on wall/virtual
//!   clock boundaries.
//! * [`EventTimeWindow`] — **event time**: records are assigned to panes
//!   by their generation timestamp (`gen_ts`), windows stay open until a
//!   watermark (see [`super::watermark::WatermarkTracker`]) passes
//!   `end + allowed_lateness`, and records arriving behind the watermark
//!   are routed through a [`LatePolicy`].
//!
//! The aggregation applied at merge time is pluggable ([`AggKind`]):
//! mean, sum and count all reduce over the same `(sum, cnt)` pane state
//! (and therefore stay HLO-compatible); min and max additionally track
//! per-pane extrema and are native-only.  Event-time windows accumulate
//! natively — pane assignment is data-dependent per record, which the
//! single-state `mem_pipeline_step` artifact cannot express.

use std::collections::{BTreeMap, VecDeque};

/// Per-key aggregation function applied when a window closes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggKind {
    Mean,
    Sum,
    Min,
    Max,
    Count,
}

impl AggKind {
    pub fn name(self) -> &'static str {
        match self {
            AggKind::Mean => "mean",
            AggKind::Sum => "sum",
            AggKind::Min => "min",
            AggKind::Max => "max",
            AggKind::Count => "count",
        }
    }

    pub fn from_name(s: &str) -> Option<AggKind> {
        match s {
            "mean" | "avg" => Some(AggKind::Mean),
            "sum" => Some(AggKind::Sum),
            "min" => Some(AggKind::Min),
            "max" => Some(AggKind::Max),
            "count" | "cnt" => Some(AggKind::Count),
            _ => None,
        }
    }

    /// True when the aggregate reduces over the `(sum, cnt)` pane tensors
    /// alone — the state shape the `mem_pipeline_step` HLO artifact
    /// updates.  Min/max need per-pane extrema and run native-only.
    pub fn uses_sum_cnt(self) -> bool {
        !matches!(self, AggKind::Min | AggKind::Max)
    }

    /// JSON field name carrying the aggregate value in emitted records
    /// (`avg` for mean keeps the paper pipeline's wire format stable).
    pub fn field(self) -> &'static str {
        match self {
            AggKind::Mean => "avg",
            AggKind::Sum => "sum",
            AggKind::Min => "min",
            AggKind::Max => "max",
            AggKind::Count => "cnt",
        }
    }
}

/// Which clock assigns records to window panes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WindowTime {
    /// Panes follow the processing clock (arrival order) — the default.
    #[default]
    Processing,
    /// Panes follow the record's generation timestamp; windows close on
    /// watermark progress.
    Event,
}

impl WindowTime {
    pub fn name(self) -> &'static str {
        match self {
            WindowTime::Processing => "processing",
            WindowTime::Event => "event",
        }
    }

    pub fn from_name(s: &str) -> Option<WindowTime> {
        match s {
            "processing" | "proc" | "wall" => Some(WindowTime::Processing),
            "event" | "event_time" | "event-time" => Some(WindowTime::Event),
            _ => None,
        }
    }
}

/// What an event-time window does with a record that arrives behind the
/// watermark while at least one window covering it is still open.
/// Records whose every covering window has already been finalized are
/// always dropped (and counted), whatever the policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LatePolicy {
    /// Discard late records (counted as dropped).
    #[default]
    Drop,
    /// Discard late records from aggregation but account for them in the
    /// side channel (`late_events`).
    SideCount,
    /// Merge late records into their pane when the covering window is
    /// still open — with a watermark bound at or above the stream's real
    /// disorder this reproduces the in-order aggregates exactly.
    MergeIfOpen,
}

impl LatePolicy {
    pub fn name(self) -> &'static str {
        match self {
            LatePolicy::Drop => "drop",
            LatePolicy::SideCount => "side_count",
            LatePolicy::MergeIfOpen => "merge_if_open",
        }
    }

    pub fn from_name(s: &str) -> Option<LatePolicy> {
        match s {
            "drop" => Some(LatePolicy::Drop),
            "side_count" | "side-count" | "side" => Some(LatePolicy::SideCount),
            "merge_if_open" | "merge-if-open" | "merge" => Some(LatePolicy::MergeIfOpen),
            _ => None,
        }
    }
}

/// One pane's keyed accumulator (the tensors the HLO kernel updates).
#[derive(Clone, Debug)]
pub struct Pane {
    pub start_micros: u64,
    pub sum: Vec<f32>,
    pub cnt: Vec<f32>,
    /// Per-key extrema; empty unless the window's aggregator needs them.
    pub min: Vec<f32>,
    pub max: Vec<f32>,
}

impl Pane {
    fn new(start_micros: u64, k: usize, extrema: bool) -> Self {
        Self {
            start_micros,
            sum: vec![0.0; k],
            cnt: vec![0.0; k],
            min: if extrema { vec![f32::INFINITY; k] } else { Vec::new() },
            max: if extrema { vec![f32::NEG_INFINITY; k] } else { Vec::new() },
        }
    }

    /// Record one `(key index, value)` event — the single definition of
    /// the per-record pane update, shared by the processing-time and
    /// event-time accumulation paths (the merge side is shared the same
    /// way via `merge_panes`).
    #[inline]
    fn record(&mut self, i: usize, v: f32) {
        self.sum[i] += v;
        self.cnt[i] += 1.0;
        if !self.min.is_empty() {
            if v < self.min[i] {
                self.min[i] = v;
            }
            if v > self.max[i] {
                self.max[i] = v;
            }
        }
    }

    pub fn events(&self) -> f64 {
        self.cnt.iter().map(|&c| c as f64).sum()
    }
}

/// One emitted window aggregate.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowEmit {
    /// Window end time (the slide boundary that triggered the emission).
    pub end_micros: u64,
    /// `(key, value, count)` for every key observed in the window; the
    /// value is the window's [`AggKind`] applied to that key's events.
    pub aggregates: Vec<(u32, f32, u64)>,
}

/// Keyed sliding window over processing time.
pub struct SlidingWindow {
    k: usize,
    window_micros: u64,
    slide_micros: u64,
    agg: AggKind,
    /// Closed panes still inside the window, oldest first.
    panes: VecDeque<Pane>,
    /// The open pane the kernel currently accumulates into.
    current: Pane,
}

impl SlidingWindow {
    /// A mean-aggregating window (the paper's memory-intensive pipeline).
    pub fn new(k: usize, window_micros: u64, slide_micros: u64, start_micros: u64) -> Self {
        Self::with_agg(k, window_micros, slide_micros, start_micros, AggKind::Mean)
    }

    /// A window with an explicit aggregator.
    pub fn with_agg(
        k: usize,
        window_micros: u64,
        slide_micros: u64,
        start_micros: u64,
        agg: AggKind,
    ) -> Self {
        // Backstop only: config validation rejects non-divisible specs
        // with a readable error before any window is constructed.
        assert!(
            slide_micros > 0 && window_micros >= slide_micros && window_micros % slide_micros == 0,
            "window ({window_micros}µs) must be a whole multiple of slide ({slide_micros}µs)"
        );
        let aligned = start_micros - start_micros % slide_micros;
        let extrema = !agg.uses_sum_cnt();
        Self {
            k,
            window_micros,
            slide_micros,
            agg,
            panes: VecDeque::new(),
            current: Pane::new(aligned, k, extrema),
        }
    }

    pub fn key_count(&self) -> usize {
        self.k
    }

    pub fn agg(&self) -> AggKind {
        self.agg
    }

    /// The open pane (the HLO kernel reads its state in and writes the
    /// updated state back via [`SlidingWindow::store_state`]).
    pub fn current_pane(&self) -> &Pane {
        &self.current
    }

    /// Write the kernel's updated `(sum, cnt)` back into the open pane.
    /// Only valid for `sum/cnt` aggregators (mean, sum, count) — the HLO
    /// state carries no extrema.
    pub fn store_state(&mut self, sum: Vec<f32>, cnt: Vec<f32>) {
        debug_assert!(self.agg.uses_sum_cnt(), "HLO state path needs a sum/cnt aggregator");
        debug_assert_eq!(sum.len(), self.k);
        debug_assert_eq!(cnt.len(), self.k);
        self.current.sum = sum;
        self.current.cnt = cnt;
    }

    /// Native accumulation path (ablation / no-HLO mode / extrema).
    pub fn accumulate_native(&mut self, ids: &[u32], vals: &[f32]) {
        for (&id, &v) in ids.iter().zip(vals) {
            let i = id as usize;
            if i < self.k {
                self.current.record(i, v);
            }
        }
    }

    /// Advance processing time to `now`; emits one window aggregate per
    /// crossed slide boundary (usually 0 or 1).  A window with no events
    /// still emits — with an empty `aggregates` list.
    pub fn advance(&mut self, now_micros: u64) -> Vec<WindowEmit> {
        let mut out = Vec::new();
        while now_micros >= self.current.start_micros + self.slide_micros {
            let boundary = self.current.start_micros + self.slide_micros;
            let extrema = !self.agg.uses_sum_cnt();
            let closed =
                std::mem::replace(&mut self.current, Pane::new(boundary, self.k, extrema));
            self.panes.push_back(closed);
            // Retain panes with start >= boundary - window (the window
            // ending at `boundary` covers [boundary - W, boundary)).
            while let Some(front) = self.panes.front() {
                if front.start_micros + self.window_micros < boundary {
                    self.panes.pop_front();
                } else {
                    break;
                }
            }
            out.push(self.merge(boundary));
        }
        out
    }

    /// Merge all live panes into one aggregate.
    fn merge(&self, end_micros: u64) -> WindowEmit {
        merge_panes(self.panes.iter(), self.k, self.agg, end_micros)
    }

    /// End-of-stream flush: force the open pane closed and emit the final
    /// window even if wall time never reached the next slide boundary.
    /// No-op when the open pane is empty (nothing new to report).
    pub fn flush(&mut self) -> Vec<WindowEmit> {
        if self.current.events() == 0.0 {
            return Vec::new();
        }
        let boundary = self.current.start_micros + self.slide_micros;
        self.advance(boundary)
    }

    /// Number of closed panes currently held (state-size metric).
    pub fn live_panes(&self) -> usize {
        self.panes.len()
    }

    /// Approximate state footprint in bytes (keyed state metric).
    pub fn state_bytes(&self) -> u64 {
        let per_key = if self.agg.uses_sum_cnt() { 8 } else { 16 };
        ((self.panes.len() + 1) * self.k * per_key) as u64
    }

    /// Export the mutable pane state for a checkpoint: the closed panes
    /// (oldest first) and the open pane.  Window/slide/agg are
    /// configuration and are re-derived on restore.
    pub fn export_state(&self) -> (Vec<Pane>, Pane) {
        (self.panes.iter().cloned().collect(), self.current.clone())
    }

    /// Restore state captured by [`SlidingWindow::export_state`].  Pane
    /// key widths must match this window's `k` — a mismatch means the
    /// checkpoint was taken under a different configuration.
    pub fn import_state(&mut self, closed: Vec<Pane>, current: Pane) -> Result<(), String> {
        for p in closed.iter().chain(std::iter::once(&current)) {
            if p.sum.len() != self.k || p.cnt.len() != self.k {
                return Err(format!(
                    "window restore: pane has {} keys, this window expects {}",
                    p.sum.len(),
                    self.k
                ));
            }
        }
        self.panes = closed.into();
        self.current = current;
        Ok(())
    }
}

/// Merge a run of panes into one window aggregate: deterministic key
/// order (ascending), keys with no events omitted.  Shared by the
/// processing-time and event-time windows.
fn merge_panes<'a>(
    panes: impl Iterator<Item = &'a Pane>,
    k: usize,
    agg: AggKind,
    end_micros: u64,
) -> WindowEmit {
    let mut sum = vec![0.0f64; k];
    let mut cnt = vec![0.0f64; k];
    let mut min = vec![f32::INFINITY; if agg == AggKind::Min { k } else { 0 }];
    let mut max = vec![f32::NEG_INFINITY; if agg == AggKind::Max { k } else { 0 }];
    for pane in panes {
        for i in 0..k {
            sum[i] += pane.sum[i] as f64;
            cnt[i] += pane.cnt[i] as f64;
        }
        if agg == AggKind::Min {
            for i in 0..k {
                if pane.min[i] < min[i] {
                    min[i] = pane.min[i];
                }
            }
        }
        if agg == AggKind::Max {
            for i in 0..k {
                if pane.max[i] > max[i] {
                    max[i] = pane.max[i];
                }
            }
        }
    }
    let aggregates = (0..k)
        .filter(|&i| cnt[i] > 0.0)
        .map(|i| {
            let value = match agg {
                AggKind::Mean => (sum[i] / cnt[i]) as f32,
                AggKind::Sum => sum[i] as f32,
                AggKind::Count => cnt[i] as f32,
                AggKind::Min => min[i],
                AggKind::Max => max[i],
            };
            (i as u32, value, cnt[i] as u64)
        })
        .collect();
    WindowEmit {
        end_micros,
        aggregates,
    }
}

/// Keyed sliding window over **event time**.
///
/// Records land in the pane covering their generation timestamp; the
/// window ending at `E` (covering `[E - W, E)`) is finalized — merged and
/// emitted — once the caller-supplied watermark reaches
/// `E + allowed_lateness`.  Records arriving behind the watermark:
///
/// * every covering window already finalized → dropped (counted in
///   [`EventTimeWindow::dropped_events`]), whatever the policy;
/// * some covering window still open → routed through the [`LatePolicy`]
///   (merge into the pane, count to the side, or drop).
///
/// Emission order is deterministic: window ends advance monotonically and
/// aggregates list keys ascending, so two streams carrying the same
/// `(key, value, gen_ts)` multiset produce byte-identical emissions as
/// long as no record is dropped.
///
/// Pane state is **sparse** (a `BTreeMap` keyed by pane start): panes
/// exist only where records landed, so a single corrupted far-future
/// timestamp costs one pane, not a contiguous run of allocations — and
/// finalization fast-forwards across stretches no retained pane touches,
/// bounding the work of [`EventTimeWindow::advance`] by the number of
/// data-bearing windows rather than by raw watermark distance.
pub struct EventTimeWindow {
    k: usize,
    window_micros: u64,
    slide_micros: u64,
    agg: AggKind,
    allowed_lateness_micros: u64,
    policy: LatePolicy,
    extrema: bool,
    /// Sparse panes keyed by their start (a multiple of the slide).
    panes: BTreeMap<u64, Pane>,
    /// Next window end boundary to finalize (multiple of the slide).
    next_end: u64,
    /// Highest watermark observed via [`EventTimeWindow::advance`].
    watermark: u64,
    late_events: u64,
    dropped_events: u64,
}

impl EventTimeWindow {
    pub fn new(
        k: usize,
        window_micros: u64,
        slide_micros: u64,
        start_micros: u64,
        agg: AggKind,
        allowed_lateness_micros: u64,
        policy: LatePolicy,
    ) -> Self {
        assert!(
            slide_micros > 0 && window_micros >= slide_micros && window_micros % slide_micros == 0,
            "window ({window_micros}µs) must be a whole multiple of slide ({slide_micros}µs)"
        );
        let aligned = start_micros - start_micros % slide_micros;
        Self {
            k,
            window_micros,
            slide_micros,
            agg,
            allowed_lateness_micros,
            policy,
            extrema: !agg.uses_sum_cnt(),
            panes: BTreeMap::new(),
            next_end: aligned + slide_micros,
            watermark: 0,
            late_events: 0,
            dropped_events: 0,
        }
    }

    pub fn agg(&self) -> AggKind {
        self.agg
    }

    /// Records merged (or side-counted) after arriving behind the watermark.
    pub fn late_events(&self) -> u64 {
        self.late_events
    }

    /// Records discarded: too late for every covering window, or late
    /// under the `drop` policy.
    pub fn dropped_events(&self) -> u64 {
        self.dropped_events
    }

    /// Number of retained panes (state-size metric).
    pub fn live_panes(&self) -> usize {
        self.panes.len()
    }

    /// The last window end this instance has finalized (0 before any
    /// finalization) — the frontier a downstream exchange stage gates on:
    /// every aggregate with `end <= emitted_through()` has been emitted.
    pub fn emitted_through(&self) -> u64 {
        self.next_end.saturating_sub(self.slide_micros)
    }

    /// Accumulate one batch of `(id, value, gen_ts)` rows.  Out-of-range
    /// keys are skipped like in [`SlidingWindow::accumulate_native`].
    pub fn accumulate(&mut self, ids: &[u32], vals: &[f32], ts: &[u64]) {
        for ((&id, &v), &t) in ids.iter().zip(vals).zip(ts) {
            let i = id as usize;
            if i >= self.k {
                continue;
            }
            let pane_start = t - t % self.slide_micros;
            // The last window covering `t` ends at pane_start + W; once
            // that is finalized the record has nowhere left to go.
            if pane_start + self.window_micros < self.next_end {
                self.dropped_events += 1;
                continue;
            }
            if t < self.watermark {
                match self.policy {
                    LatePolicy::Drop => {
                        self.dropped_events += 1;
                        continue;
                    }
                    LatePolicy::SideCount => {
                        self.late_events += 1;
                        continue;
                    }
                    LatePolicy::MergeIfOpen => self.late_events += 1,
                }
            }
            let (kk, extrema) = (self.k, self.extrema);
            self.panes
                .entry(pane_start)
                .or_insert_with(|| Pane::new(pane_start, kk, extrema))
                .record(i, v);
        }
    }

    fn merge_window(&self, end_micros: u64) -> WindowEmit {
        let lo = end_micros.saturating_sub(self.window_micros);
        merge_panes(
            self.panes.range(lo..end_micros).map(|(_, p)| p),
            self.k,
            self.agg,
            end_micros,
        )
    }

    fn prune(&mut self) {
        // Keep panes some unfinalized window still covers.
        let min_keep = self.next_end.saturating_sub(self.window_micros);
        self.panes = self.panes.split_off(&min_keep);
    }

    /// Skip boundaries no retained pane's first covering window reaches
    /// (capped at `last`): every pane holds at least one record, so the
    /// skipped windows are empty and emitting them would carry no data.
    fn fast_forward(&mut self, last: u64) {
        match self.panes.keys().next() {
            Some(&first) => {
                // Pane starts are multiples of the slide, so the first
                // window containing pane `first` ends at first + slide.
                let first_end = first + self.slide_micros;
                if first_end > self.next_end {
                    self.next_end = first_end.min(last);
                }
            }
            None => self.next_end = last,
        }
    }

    /// Advance the watermark; finalizes (merges + emits) every
    /// data-bearing window whose `end + allowed_lateness` the watermark
    /// has passed.  Empty stretches are fast-forwarded (at most one
    /// trailing empty emission marks the jump), so a corrupted far-future
    /// timestamp cannot spin this loop for eons.
    pub fn advance(&mut self, watermark: u64) -> Vec<WindowEmit> {
        self.watermark = self.watermark.max(watermark);
        let mut out = Vec::new();
        let Some(horizon) = self.watermark.checked_sub(self.allowed_lateness_micros) else {
            return out;
        };
        // Last finalizable window end on the slide grid.
        let last = horizon - horizon % self.slide_micros;
        while self.next_end <= last {
            self.fast_forward(last);
            out.push(self.merge_window(self.next_end));
            self.next_end += self.slide_micros;
            self.prune();
        }
        out
    }

    /// End-of-stream flush: finalize windows until every pane holding
    /// events has been emitted at least once (one boundary past the last
    /// retained pane).  No-op when no events are pending.
    pub fn flush(&mut self) -> Vec<WindowEmit> {
        let Some(&last_pane) = self.panes.keys().next_back() else {
            return Vec::new();
        };
        let final_end = last_pane + self.slide_micros;
        let mut out = Vec::new();
        while self.next_end <= final_end {
            self.fast_forward(final_end);
            out.push(self.merge_window(self.next_end));
            self.next_end += self.slide_micros;
            self.prune();
        }
        out
    }

    /// Export the mutable state for a checkpoint: retained panes, the
    /// next window end to finalize, the observed watermark and the
    /// late/dropped counters.  Configuration (k, window, slide, agg,
    /// lateness, policy) is re-derived on restore.
    pub fn export_state(&self) -> (Vec<Pane>, u64, u64, u64, u64) {
        (
            self.panes.values().cloned().collect(),
            self.next_end,
            self.watermark,
            self.late_events,
            self.dropped_events,
        )
    }

    /// Restore state captured by [`EventTimeWindow::export_state`].
    pub fn import_state(
        &mut self,
        panes: Vec<Pane>,
        next_end: u64,
        watermark: u64,
        late_events: u64,
        dropped_events: u64,
    ) -> Result<(), String> {
        let mut map = BTreeMap::new();
        for p in panes {
            if p.sum.len() != self.k || p.cnt.len() != self.k {
                return Err(format!(
                    "event-time window restore: pane has {} keys, this window expects {}",
                    p.sum.len(),
                    self.k
                ));
            }
            map.insert(p.start_micros, p);
        }
        self.panes = map;
        self.next_end = next_end;
        self.watermark = watermark;
        self.late_events = late_events;
        self.dropped_events = dropped_events;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w() -> SlidingWindow {
        // window 10s, slide 2s → 5 panes.
        SlidingWindow::new(8, 10_000_000, 2_000_000, 0)
    }

    #[test]
    fn no_emission_before_first_boundary() {
        let mut sw = w();
        sw.accumulate_native(&[1], &[10.0]);
        assert!(sw.advance(1_999_999).is_empty());
    }

    #[test]
    fn emission_at_each_slide_boundary() {
        let mut sw = w();
        sw.accumulate_native(&[1, 1, 2], &[10.0, 20.0, 5.0]);
        let emits = sw.advance(2_000_000);
        assert_eq!(emits.len(), 1);
        let e = &emits[0];
        assert_eq!(e.end_micros, 2_000_000);
        assert_eq!(e.aggregates.len(), 2);
        assert_eq!(e.aggregates[0], (1, 15.0, 2));
        assert_eq!(e.aggregates[1], (2, 5.0, 1));
    }

    #[test]
    fn window_retains_w_over_s_panes() {
        let mut sw = w();
        // Pane 0: key 0 = 100. Advance 5 slides; pane 0 leaves the window
        // after boundary 12s (pane [0,2s) + 10s window ≤ 12s).
        sw.accumulate_native(&[0], &[100.0]);
        let e = sw.advance(2_000_000);
        assert_eq!(e[0].aggregates, vec![(0, 100.0, 1)]);
        for boundary in [4_000_000u64, 6_000_000, 8_000_000, 10_000_000] {
            let e = sw.advance(boundary);
            assert_eq!(e.len(), 1);
            assert_eq!(
                e[0].aggregates,
                vec![(0, 100.0, 1)],
                "boundary {boundary}: pane should still be live"
            );
        }
        let e = sw.advance(12_000_000);
        assert!(e[0].aggregates.is_empty(), "pane 0 must have expired");
        assert!(sw.live_panes() <= 5);
    }

    #[test]
    fn multiple_boundaries_in_one_advance() {
        let mut sw = w();
        sw.accumulate_native(&[3], &[1.0]);
        let emits = sw.advance(6_500_000); // crosses 2s, 4s, 6s
        assert_eq!(emits.len(), 3);
        assert_eq!(emits[0].end_micros, 2_000_000);
        assert_eq!(emits[2].end_micros, 6_000_000);
        // The single event stays visible in all three windows.
        for e in &emits {
            assert_eq!(e.aggregates, vec![(3, 1.0, 1)]);
        }
    }

    #[test]
    fn store_state_roundtrip_matches_native() {
        let mut a = w();
        let mut b = w();
        let ids = [0u32, 1, 1, 7, 7, 7];
        let temps = [1.0f32, 2.0, 4.0, 9.0, 9.0, 9.0];
        a.accumulate_native(&ids, &temps);
        // Simulate the HLO path: read state, update outside, store back.
        let pane = b.current_pane();
        let mut sum = pane.sum.clone();
        let mut cnt = pane.cnt.clone();
        for (&id, &t) in ids.iter().zip(&temps) {
            sum[id as usize] += t;
            cnt[id as usize] += 1.0;
        }
        b.store_state(sum, cnt);
        let (ea, eb) = (a.advance(2_000_000), b.advance(2_000_000));
        assert_eq!(ea[0].aggregates, eb[0].aggregates);
    }

    #[test]
    fn out_of_range_keys_are_dropped_natively() {
        let mut sw = w();
        sw.accumulate_native(&[100], &[5.0]); // k = 8
        let e = sw.advance(2_000_000);
        assert!(e[0].aggregates.is_empty());
    }

    #[test]
    fn unaligned_start_is_aligned_down() {
        let sw = SlidingWindow::new(4, 10_000_000, 2_000_000, 3_500_000);
        assert_eq!(sw.current_pane().start_micros, 2_000_000);
    }

    #[test]
    fn state_bytes_grows_with_panes() {
        let mut sw = w();
        let s0 = sw.state_bytes();
        sw.advance(2_000_000);
        assert!(sw.state_bytes() > s0);
    }

    // --- satellite: edge cases + pluggable aggregators -------------------

    #[test]
    fn non_aligned_start_event_lands_in_the_aligned_pane() {
        // start 3.5s aligns down to pane [2s, 4s); an event accumulated
        // before the first boundary must emit in the window ending at 4s.
        let mut sw = SlidingWindow::new(4, 4_000_000, 2_000_000, 3_500_000);
        sw.accumulate_native(&[2], &[7.0]);
        assert!(sw.advance(3_999_999).is_empty(), "boundary is 4s, not 3.5s+slide");
        let e = sw.advance(4_000_000);
        assert_eq!(e.len(), 1);
        assert_eq!(e[0].end_micros, 4_000_000);
        assert_eq!(e[0].aggregates, vec![(2, 7.0, 1)]);
    }

    #[test]
    fn slide_equal_to_window_is_tumbling() {
        // slide == window → one pane per window; events never carry over.
        let mut sw = SlidingWindow::new(4, 2_000_000, 2_000_000, 0);
        sw.accumulate_native(&[1], &[10.0]);
        let e = sw.advance(2_000_000);
        assert_eq!(e[0].aggregates, vec![(1, 10.0, 1)]);
        let e = sw.advance(4_000_000);
        assert!(
            e[0].aggregates.is_empty(),
            "tumbling window must not re-emit the previous window's events"
        );
        assert!(sw.live_panes() <= 1);
    }

    #[test]
    fn empty_windows_emit_zero_aggregates() {
        let mut sw = w();
        let emits = sw.advance(6_000_000); // three boundaries, no data at all
        assert_eq!(emits.len(), 3);
        for e in &emits {
            assert!(e.aggregates.is_empty(), "no data → no aggregates at {}", e.end_micros);
        }
        // flush() after pure-empty advance is also a no-op.
        assert!(sw.flush().is_empty());
    }

    #[test]
    fn sum_min_max_count_aggregators() {
        let cases: [(AggKind, f32); 4] = [
            (AggKind::Sum, 36.0),
            (AggKind::Min, 2.0),
            (AggKind::Max, 30.0),
            (AggKind::Count, 3.0),
        ];
        for (agg, expect) in cases {
            let mut sw = SlidingWindow::with_agg(4, 4_000_000, 2_000_000, 0, agg);
            sw.accumulate_native(&[1, 1, 1], &[4.0, 2.0, 30.0]);
            let e = sw.advance(2_000_000);
            assert_eq!(e[0].aggregates, vec![(1, expect, 3)], "{agg:?}");
        }
    }

    #[test]
    fn extrema_survive_pane_merges() {
        // Min lives in pane 0, max in pane 1; the merged window must see both.
        let mut min_w = SlidingWindow::with_agg(4, 4_000_000, 2_000_000, 0, AggKind::Min);
        let mut max_w = SlidingWindow::with_agg(4, 4_000_000, 2_000_000, 0, AggKind::Max);
        for sw in [&mut min_w, &mut max_w] {
            sw.accumulate_native(&[0], &[-5.0]);
            sw.advance(2_000_000);
            sw.accumulate_native(&[0], &[50.0]);
        }
        let e = min_w.advance(4_000_000);
        assert_eq!(e[0].aggregates, vec![(0, -5.0, 2)]);
        let e = max_w.advance(4_000_000);
        assert_eq!(e[0].aggregates, vec![(0, 50.0, 2)]);
    }

    #[test]
    fn agg_names_roundtrip() {
        for agg in [AggKind::Mean, AggKind::Sum, AggKind::Min, AggKind::Max, AggKind::Count] {
            assert_eq!(AggKind::from_name(agg.name()), Some(agg));
        }
        assert_eq!(AggKind::from_name("avg"), Some(AggKind::Mean));
        assert_eq!(AggKind::from_name("median"), None);
        assert_eq!(AggKind::Mean.field(), "avg");
    }

    #[test]
    fn store_state_roundtrip_for_sum_aggregator() {
        let mut sw = SlidingWindow::with_agg(4, 2_000_000, 1_000_000, 0, AggKind::Sum);
        let pane = sw.current_pane();
        let (mut sum, mut cnt) = (pane.sum.clone(), pane.cnt.clone());
        sum[3] = 12.5;
        cnt[3] = 5.0;
        sw.store_state(sum, cnt);
        let e = sw.advance(1_000_000);
        assert_eq!(e[0].aggregates, vec![(3, 12.5, 5)]);
    }

    #[test]
    #[should_panic(expected = "whole multiple")]
    fn non_divisible_pane_spec_panics_as_backstop() {
        // Config validation rejects this first; the constructor assert is
        // the last line of defence against silent W/S truncation.
        SlidingWindow::new(4, 10_000_000, 3_000_000, 0);
    }

    // --- event-time windows ----------------------------------------------

    fn etw(policy: LatePolicy) -> EventTimeWindow {
        // window 4s, slide 2s, no allowed lateness.
        EventTimeWindow::new(8, 4_000_000, 2_000_000, 0, AggKind::Mean, 0, policy)
    }

    #[test]
    fn event_time_assigns_by_gen_ts_not_arrival() {
        let mut w = etw(LatePolicy::Drop);
        // Two records with event times in pane [0,2s) and one in [2s,4s),
        // presented in scrambled arrival order.
        w.accumulate(&[1, 2, 1], &[10.0, 7.0, 20.0], &[1_900_000, 2_100_000, 100_000]);
        let emits = w.advance(4_000_000); // watermark past ends 2s and 4s
        assert_eq!(emits.len(), 2);
        assert_eq!(emits[0].end_micros, 2_000_000);
        assert_eq!(emits[0].aggregates, vec![(1, 15.0, 2)]);
        assert_eq!(emits[1].end_micros, 4_000_000);
        // Window [0,4s) sees all three records.
        assert_eq!(emits[1].aggregates, vec![(1, 15.0, 2), (2, 7.0, 1)]);
    }

    #[test]
    fn window_held_open_until_watermark_passes_lateness() {
        let mut w =
            EventTimeWindow::new(4, 2_000_000, 2_000_000, 0, AggKind::Sum, 500_000, LatePolicy::Drop);
        w.accumulate(&[0], &[5.0], &[100]);
        assert!(w.advance(2_000_000).is_empty(), "end reached but lateness not");
        assert!(w.advance(2_400_000).is_empty());
        let e = w.advance(2_500_000);
        assert_eq!(e.len(), 1);
        assert_eq!(e[0].aggregates, vec![(0, 5.0, 1)]);
    }

    #[test]
    fn late_policies_route_stragglers() {
        // Tumbling 2s windows; watermark at 3s finalizes window [0,2s).
        for (policy, expect_in_window, late, dropped) in [
            (LatePolicy::Drop, false, 0u64, 1u64),
            (LatePolicy::SideCount, false, 1, 0),
            (LatePolicy::MergeIfOpen, true, 1, 0),
        ] {
            let mut w =
                EventTimeWindow::new(4, 4_000_000, 2_000_000, 0, AggKind::Sum, 0, policy);
            w.accumulate(&[0], &[1.0], &[3_000_000]);
            let e = w.advance(3_000_000); // finalizes end 2s only
            assert_eq!(e.len(), 1, "{policy:?}");
            // A record at 1.5s is behind the watermark (3s) but its last
            // covering window [0,4s) is still open.
            w.accumulate(&[1], &[9.0], &[1_500_000]);
            assert_eq!(w.late_events(), late, "{policy:?}");
            assert_eq!(w.dropped_events(), dropped, "{policy:?}");
            let e = w.advance(4_000_000); // finalizes end 4s
            assert_eq!(e.len(), 1);
            let has_key1 = e[0].aggregates.iter().any(|&(k, ..)| k == 1);
            assert_eq!(has_key1, expect_in_window, "{policy:?}");
        }
    }

    #[test]
    fn too_late_for_every_window_is_always_dropped() {
        let mut w = etw(LatePolicy::MergeIfOpen);
        w.accumulate(&[0], &[1.0], &[9_000_000]);
        w.advance(9_000_000); // finalizes ends 2s..8s; next_end = 10s
        // Last window covering t=3s ends at 2s+4s=6s < 10s: gone entirely.
        w.accumulate(&[0], &[1.0], &[3_000_000]);
        assert_eq!(w.dropped_events(), 1);
        assert_eq!(w.late_events(), 0);
    }

    #[test]
    fn event_time_flush_emits_pending_panes_once() {
        let mut w = etw(LatePolicy::Drop);
        w.accumulate(&[2], &[4.0], &[500_000]);
        assert!(w.advance(500_000).is_empty(), "watermark behind first end");
        let e = w.flush();
        assert_eq!(e.len(), 1);
        assert_eq!(e[0].end_micros, 2_000_000);
        assert_eq!(e[0].aggregates, vec![(2, 4.0, 1)]);
        assert!(w.flush().is_empty(), "second flush has nothing new");
    }

    #[test]
    fn sliding_export_import_resumes_identically() {
        let mut a = w();
        a.accumulate_native(&[1, 2, 1], &[10.0, 20.0, 5.0]);
        a.advance(2_000_000);
        a.accumulate_native(&[3], &[7.0]);
        let (closed, current) = a.export_state();
        let mut b = w();
        b.import_state(closed, current).unwrap();
        a.accumulate_native(&[1], &[2.0]);
        b.accumulate_native(&[1], &[2.0]);
        assert_eq!(a.advance(4_000_000), b.advance(4_000_000));
        assert_eq!(a.flush(), b.flush());
        // Key-width mismatch is a readable error, not corruption.
        let (closed, current) = a.export_state();
        let mut narrow = SlidingWindow::new(4, 10_000_000, 2_000_000, 0);
        assert!(narrow.import_state(closed, current).is_err());
    }

    #[test]
    fn event_time_export_import_resumes_identically() {
        let mut a = etw(LatePolicy::MergeIfOpen);
        a.accumulate(&[1, 2], &[10.0, 7.0], &[1_900_000, 2_100_000]);
        a.advance(2_500_000);
        // Snapshot taken with an open pane and a live watermark.
        let (panes, next_end, wm, late, dropped) = a.export_state();
        let mut b = etw(LatePolicy::MergeIfOpen);
        b.import_state(panes, next_end, wm, late, dropped).unwrap();
        assert_eq!(b.emitted_through(), a.emitted_through());
        a.accumulate(&[1], &[20.0], &[3_000_000]);
        b.accumulate(&[1], &[20.0], &[3_000_000]);
        assert_eq!(a.advance(6_000_000), b.advance(6_000_000));
        assert_eq!(a.flush(), b.flush());
        assert_eq!(a.late_events(), b.late_events());
        assert_eq!(a.dropped_events(), b.dropped_events());
    }

    #[test]
    fn event_time_equivalence_under_bounded_disorder() {
        // The same (key, value, ts) multiset fed in order and in a
        // disordered permutation must emit identical aggregates under
        // merge_if_open with a watermark that respects the disorder bound.
        let events: Vec<(u32, f32, u64)> = (0..400u64)
            .map(|i| ((i % 7) as u32, (i % 13) as f32, i * 10_000))
            .collect();
        let mut shuffled = events.clone();
        // Bounded disorder: reverse within blocks of 16 (max displacement
        // 15 events = 150ms < the 200ms watermark bound the caller uses).
        for chunk in shuffled.chunks_mut(16) {
            chunk.reverse();
        }
        let bound = 200_000u64;
        let run = |stream: &[(u32, f32, u64)]| -> Vec<WindowEmit> {
            let mut w = EventTimeWindow::new(
                8,
                1_000_000,
                500_000,
                0,
                AggKind::Mean,
                0,
                LatePolicy::MergeIfOpen,
            );
            let mut out = Vec::new();
            let mut max_ts = 0u64;
            for batch in stream.chunks(13) {
                let ids: Vec<u32> = batch.iter().map(|e| e.0).collect();
                let vals: Vec<f32> = batch.iter().map(|e| e.1).collect();
                let ts: Vec<u64> = batch.iter().map(|e| e.2).collect();
                max_ts = max_ts.max(ts.iter().copied().max().unwrap());
                w.accumulate(&ids, &vals, &ts);
                out.extend(w.advance(max_ts.saturating_sub(bound)));
            }
            out.extend(w.advance(max_ts.saturating_sub(bound)));
            out.extend(w.flush());
            assert_eq!(w.dropped_events(), 0, "bounded disorder must not drop");
            out
        };
        let ordered = run(&events);
        let disordered = run(&shuffled);
        assert_eq!(ordered, disordered);
        assert!(!ordered.is_empty());
    }
}
