//! Workload generator (paper Sec. 3.2).
//!
//! Produces synthetic sensor-data streams: each event carries a timestamp,
//! sensor ID and temperature value, serialized as JSON (or the compact CSV
//! wire format that reaches the paper's 27-byte minimum event size).
//!
//! * [`event`] — event model + serializer/parser with exact-size padding.
//! * [`pattern`] — constant / random / burst generation schedules.
//! * [`disorder`] — out-of-order arrival model (lateness sampling,
//!   stragglers, shuffle window) for event-time scenarios.
//! * [`ratelimit`] — token-bucket rate control.
//! * [`generator`] — generator instances + the auto-scaling fleet
//!   ("automatically adjusts the number of generators based on the
//!   requested total load").

pub mod disorder;
pub mod event;
pub mod generator;
pub mod pattern;
pub mod ratelimit;

pub use disorder::DisorderState;
pub use event::{EventFormat, EventSerializer, SensorEvent};
pub use generator::{Fleet, FleetReport, GeneratorConfig};
pub use pattern::{KeyDist, Pattern, PatternState, Tick};
pub use ratelimit::TokenBucket;
