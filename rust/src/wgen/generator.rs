//! Generator instances and the auto-scaling fleet.
//!
//! One instance is a thread emitting serialized sensor events into the
//! ingestion topic at its share of the configured load, paced by a token
//! bucket and shaped by the configured pattern.  The fleet auto-scales the
//! instance count from the requested total rate and per-instance capacity
//! (paper Sec. 3.2: single instance ≈ 500 K ev/s; "multiple workload
//! generators can operate in parallel" and the count is adjusted
//! automatically).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::event::{EventFormat, SensorEvent};
use super::pattern::{Pattern, PatternState};
use super::ratelimit::TokenBucket;
use crate::broker::{Broker, PartitionedBatchBuilder, Topic};
use crate::metrics::{LatencyRecorder, MeasurementPoint, ThroughputRecorder};
use crate::util::clock::ClockRef;
use crate::util::rng::{Pcg32, Zipf};

/// Per-fleet generation parameters (derived from the master config).
#[derive(Clone, Debug)]
pub struct GeneratorConfig {
    pub total_rate: u64,
    pub instance_capacity: u64,
    pub max_instances: u32,
    pub event_bytes: usize,
    pub format: EventFormat,
    pub sensors: u32,
    /// Zipf exponent for key skew; 0 = uniform sensor ids.
    pub key_skew: f64,
    pub seed: u64,
    /// Produce-batch size (records per broker append).
    pub produce_batch: usize,
}

impl GeneratorConfig {
    pub fn from_config(cfg: &crate::config::BenchConfig) -> Self {
        Self {
            total_rate: cfg.workload.rate,
            instance_capacity: cfg.generators.instance_capacity,
            max_instances: cfg.generators.max_instances,
            event_bytes: cfg.workload.event_bytes,
            format: if cfg.workload.event_bytes < 40 {
                EventFormat::Csv
            } else {
                EventFormat::Json
            },
            sensors: cfg.workload.sensors,
            key_skew: cfg.workload.key_skew,
            seed: cfg.bench.seed,
            produce_batch: 512,
        }
    }

    /// Auto-scaled instance count.
    pub fn instances(&self) -> u32 {
        let n = (self.total_rate + self.instance_capacity - 1) / self.instance_capacity;
        (n as u32).clamp(1, self.max_instances)
    }
}

/// Result of a fleet run.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetReport {
    pub instances: u32,
    pub events: u64,
    pub bytes: u64,
    pub elapsed_micros: u64,
    /// Achieved offered load, events/second.
    pub rate_events: f64,
    pub rate_bytes: f64,
}

/// The auto-scaling generator fleet.
pub struct Fleet {
    config: GeneratorConfig,
    clock: ClockRef,
    throughput: Arc<ThroughputRecorder>,
    latency: Arc<LatencyRecorder>,
}

impl Fleet {
    pub fn new(
        config: GeneratorConfig,
        clock: ClockRef,
        throughput: Arc<ThroughputRecorder>,
        latency: Arc<LatencyRecorder>,
    ) -> Self {
        Self {
            config,
            clock,
            throughput,
            latency,
        }
    }

    /// Run the fleet for `duration_micros` against `topic`, blocking until
    /// all instances finish.  `pattern_of` builds each instance's schedule
    /// from its load share.
    pub fn run(
        &self,
        broker: &Arc<Broker>,
        topic: &Arc<Topic>,
        duration_micros: u64,
        stop: &Arc<AtomicBool>,
        pattern_of: impl Fn(u64) -> Pattern,
    ) -> FleetReport {
        let n = self.config.instances();
        let share = self.config.total_rate / n as u64;
        let remainder = self.config.total_rate - share * n as u64;
        let start = self.clock.now_micros();

        let handles: Vec<_> = (0..n)
            .map(|i| {
                // First instance absorbs the division remainder.
                let my_rate = if i == 0 { share + remainder } else { share };
                let pattern = pattern_of(my_rate);
                let worker = InstanceWorker {
                    id: i,
                    config: self.config.clone(),
                    pattern,
                    rate: my_rate,
                    clock: self.clock.clone(),
                    throughput: self.throughput.clone(),
                    latency: self.latency.clone(),
                    broker: broker.clone(),
                    topic: topic.clone(),
                    stop: stop.clone(),
                };
                let deadline = start + duration_micros;
                std::thread::Builder::new()
                    .name(format!("wgen-{i}"))
                    .spawn(move || worker.run(deadline))
                    .expect("spawn generator")
            })
            .collect();

        let mut events = 0;
        let mut bytes = 0;
        for h in handles {
            let (e, b) = h.join().expect("generator panicked");
            events += e;
            bytes += b;
        }
        let elapsed = self.clock.now_micros().saturating_sub(start).max(1);
        FleetReport {
            instances: n,
            events,
            bytes,
            elapsed_micros: elapsed,
            rate_events: events as f64 * 1e6 / elapsed as f64,
            rate_bytes: bytes as f64 * 1e6 / elapsed as f64,
        }
    }
}

struct InstanceWorker {
    id: u32,
    config: GeneratorConfig,
    pattern: Pattern,
    rate: u64,
    clock: ClockRef,
    throughput: Arc<ThroughputRecorder>,
    latency: Arc<LatencyRecorder>,
    broker: Arc<Broker>,
    topic: Arc<Topic>,
    stop: Arc<AtomicBool>,
}

impl InstanceWorker {
    fn run(self, deadline_micros: u64) -> (u64, u64) {
        let mut rng = Pcg32::from_master(self.config.seed, self.id as u64);
        let zipf = (self.config.key_skew > 0.0)
            .then(|| Zipf::new(self.config.sensors as usize, self.config.key_skew));
        let mut schedule = PatternState::new(
            self.pattern.clone(),
            Pcg32::from_master(self.config.seed ^ 0xDADA, self.id as u64),
        );
        // Pace at the instance share, never beyond rated capacity.
        let paced_rate = self.rate.min(self.config.instance_capacity).max(1);
        let mut bucket = TokenBucket::new(
            self.clock.clone(),
            paced_rate,
            (paced_rate / 50).max(self.config.produce_batch as u64 * 2),
        );

        let mut total_events = 0u64;
        let mut total_bytes = 0u64;
        let mut wire = Vec::with_capacity(self.config.event_bytes + 32);
        let mut serializer =
            super::event::EventSerializer::new(self.config.format, self.config.event_bytes);
        let partitions = self.topic.partition_count();

        'outer: while self.clock.now_micros() < deadline_micros
            && !self.stop.load(Ordering::Relaxed)
        {
            let tick = schedule.next_tick();
            let mut remaining = tick.events;
            if remaining == 0 {
                self.clock.sleep_micros(tick.duration_micros);
                continue;
            }
            while remaining > 0 {
                let chunk = remaining.min(self.config.produce_batch as u64);
                bucket.acquire(chunk);
                let now = self.clock.now_micros();
                // Batch-first path: serialize the whole chunk straight
                // into per-partition RecordBatch arenas — no intermediate
                // Vec<Record>, one Arc and one partition-lock acquisition
                // per (partition, chunk) instead of one per event.
                let mut pb = PartitionedBatchBuilder::new(partitions);
                for _ in 0..chunk {
                    let sensor_id = match &zipf {
                        Some(z) => z.sample(&mut rng) as u32,
                        None => rng.below(self.config.sensors),
                    };
                    let ev = SensorEvent {
                        ts_micros: now,
                        sensor_id,
                        temp_c: 20.0 + rng.normal() as f32 * 15.0,
                    };
                    let n = serializer.serialize(&ev, &mut wire);
                    total_bytes += n as u64;
                    pb.push(
                        self.topic.partition_for_key(sensor_id),
                        sensor_id,
                        &wire,
                        now,
                    );
                }
                let appended = pb.total_records() as u64;
                // Acked produce: generation → network thread → append →
                // ack, so the recorded BrokerIn latency sees broker-side
                // queueing as load approaches broker capacity.
                if self
                    .broker
                    .produce_batches_acked(&self.topic, pb.finish())
                    .is_err()
                {
                    break 'outer; // broker shut down
                }
                total_events += appended;
                self.throughput.record_events(
                    MeasurementPoint::DriverOut,
                    appended,
                    appended * self.config.event_bytes as u64,
                );
                self.throughput.record_events(
                    MeasurementPoint::BrokerIn,
                    appended,
                    appended * self.config.event_bytes as u64,
                );
                // Broker-ingest latency: generation → append completion.
                let lat = self.clock.now_micros().saturating_sub(now);
                self.latency
                    .record_n(MeasurementPoint::BrokerIn, self.id as usize, lat, appended);
                remaining -= chunk;
                if self.clock.now_micros() >= deadline_micros {
                    break 'outer;
                }
            }
        }
        (total_events, total_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::BrokerConfig;
    use crate::util::clock;

    fn config(rate: u64) -> GeneratorConfig {
        GeneratorConfig {
            total_rate: rate,
            instance_capacity: 500_000,
            max_instances: 64,
            event_bytes: 27,
            format: EventFormat::Csv,
            sensors: 256,
            key_skew: 0.0,
            seed: 42,
            produce_batch: 256,
        }
    }

    #[test]
    fn autoscaling_matches_paper_rule() {
        assert_eq!(config(100_000).instances(), 1);
        assert_eq!(config(500_000).instances(), 1);
        assert_eq!(config(500_001).instances(), 2);
        assert_eq!(config(2_000_000).instances(), 4);
        assert_eq!(config(8_000_000).instances(), 16);
    }

    #[test]
    fn fleet_hits_constant_rate_within_tolerance() {
        let clk = clock::wall();
        let broker = Broker::new(BrokerConfig::default(), clk.clone());
        let topic = broker.create_topic("in");
        // Consume in the background so backpressure never binds.
        let group = broker.subscribe("in", "sink", 1);
        let consumer = {
            let group = group.clone();
            std::thread::spawn(move || {
                let mut n = 0u64;
                loop {
                    match group.poll(0, 1024) {
                        Ok(Some(b)) => {
                            n += b.record_count() as u64;
                            group.commit(b.partition, b.next_offset);
                        }
                        Ok(None) => std::thread::sleep(std::time::Duration::from_millis(1)),
                        Err(_) => return n,
                    }
                }
            })
        };
        let tp = Arc::new(ThroughputRecorder::new());
        let lat = Arc::new(LatencyRecorder::new());
        let fleet = Fleet::new(config(200_000), clk, tp.clone(), lat);
        let stop = Arc::new(AtomicBool::new(false));
        let report = fleet.run(&broker, &topic, 1_000_000, &stop, |r| Pattern::Constant {
            rate: r,
        });
        broker.shutdown();
        let consumed = consumer.join().unwrap();
        assert_eq!(report.instances, 1);
        // 200K ev/s for 1s ± scheduler noise.
        assert!(
            (150_000.0..250_000.0).contains(&report.rate_events),
            "rate={}",
            report.rate_events
        );
        assert_eq!(report.events, consumed);
        assert_eq!(tp.events_at(MeasurementPoint::DriverOut), report.events);
        // 27-byte events: bytes metric consistent.
        assert_eq!(report.bytes, report.events * 27);
    }

    #[test]
    fn stop_flag_halts_fleet_early() {
        let clk = clock::wall();
        let broker = Broker::new(BrokerConfig::default(), clk.clone());
        let topic = broker.create_topic("in");
        let _g = broker.subscribe("in", "sink", 1);
        let tp = Arc::new(ThroughputRecorder::new());
        let lat = Arc::new(LatencyRecorder::new());
        let fleet = Fleet::new(config(100_000), clk.clone(), tp, lat);
        let stop = Arc::new(AtomicBool::new(false));
        let stopper = {
            let stop = stop.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(50));
                stop.store(true, Ordering::SeqCst);
            })
        };
        let t0 = std::time::Instant::now();
        fleet.run(&broker, &topic, 60_000_000, &stop, |r| Pattern::Constant { rate: r });
        assert!(t0.elapsed().as_secs() < 10, "stop flag ignored");
        stopper.join().unwrap();
    }

    #[test]
    fn zipf_skew_produces_hot_keys() {
        let clk = clock::wall();
        let broker = Broker::new(BrokerConfig::default(), clk.clone());
        let topic = broker.create_topic("in");
        let group = broker.subscribe("in", "sink", 1);
        let mut cfg = config(50_000);
        cfg.key_skew = 1.2;
        let tp = Arc::new(ThroughputRecorder::new());
        let lat = Arc::new(LatencyRecorder::new());
        let fleet = Fleet::new(cfg, clk, tp, lat);
        let stop = Arc::new(AtomicBool::new(false));
        fleet.run(&broker, &topic, 400_000, &stop, |r| Pattern::Constant { rate: r });
        broker.shutdown();
        let mut counts = vec![0u64; 256];
        loop {
            match group.poll(0, 4096) {
                Ok(Some(b)) => {
                    for r in b.iter() {
                        counts[r.key as usize] += 1;
                    }
                    group.commit(b.partition, b.next_offset);
                }
                Ok(None) => continue,
                Err(_) => break,
            }
        }
        let hot: u64 = counts[..8].iter().sum();
        let cold: u64 = counts[248..].iter().sum();
        assert!(hot > cold * 3, "hot={hot} cold={cold}");
    }
}
