//! Generator instances and the auto-scaling fleet.
//!
//! One instance is a thread emitting serialized sensor events into the
//! ingestion topic at its share of the configured load, paced by a token
//! bucket and shaped by the configured pattern.  The fleet auto-scales the
//! instance count from the requested total rate and per-instance capacity
//! (paper Sec. 3.2: single instance ≈ 500 K ev/s; "multiple workload
//! generators can operate in parallel" and the count is adjusted
//! automatically).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::disorder::DisorderState;
use super::event::{EventFormat, SensorEvent};
use super::pattern::{KeyDist, Pattern, PatternState};
use super::ratelimit::TokenBucket;
use crate::broker::{Broker, PartitionedBatchBuilder, Topic};
use crate::config::{DisorderSection, FaultKind, FaultSpec};
use crate::metrics::{LatencyRecorder, MeasurementPoint, ThroughputRecorder};
use crate::util::clock::ClockRef;
use crate::util::rng::Pcg32;

/// Per-fleet generation parameters (derived from the master config).
#[derive(Clone, Debug)]
pub struct GeneratorConfig {
    pub total_rate: u64,
    pub instance_capacity: u64,
    pub max_instances: u32,
    pub event_bytes: usize,
    pub format: EventFormat,
    pub sensors: u32,
    /// Zipf exponent for key skew; 0 = uniform sensor ids.
    pub key_skew: f64,
    /// Concentrated hot set: `hot_fraction` of events land on sensor ids
    /// `[0, hot_keys)` (see [`KeyDist`]); 0/0.0 disables.
    pub hot_keys: u32,
    pub hot_fraction: f64,
    pub seed: u64,
    /// Produce-batch size (records per broker append).
    pub produce_batch: usize,
    /// Out-of-order arrival model (`workload.disorder`); identity when
    /// disabled.
    pub disorder: DisorderSection,
    /// Poison-record fault windows (`fault.schedule: poison_records`):
    /// while a window is active a seeded fraction of serialized payloads
    /// is corrupted in place.  Empty when no poison fault is planned.
    pub poison: Vec<FaultSpec>,
    /// Count-bound deterministic generation (`workload.events`): when
    /// non-zero the fleet emits exactly this many events with synthetic
    /// evenly spaced timestamps and quarter-degree temperatures instead
    /// of pacing against the wall clock for the run span.  0 = off.
    pub events: u64,
}

impl GeneratorConfig {
    pub fn from_config(cfg: &crate::config::BenchConfig) -> Self {
        Self {
            total_rate: cfg.workload.rate,
            instance_capacity: cfg.generators.instance_capacity,
            max_instances: cfg.generators.max_instances,
            event_bytes: cfg.workload.event_bytes,
            format: if cfg.workload.event_bytes < 40 {
                EventFormat::Csv
            } else {
                EventFormat::Json
            },
            sensors: cfg.workload.sensors,
            key_skew: cfg.workload.key_skew,
            hot_keys: cfg.workload.hot_keys,
            hot_fraction: cfg.workload.hot_fraction,
            seed: cfg.bench.seed,
            produce_batch: 512,
            disorder: cfg.workload.disorder.clone(),
            poison: cfg.fault.poison_plan(),
            events: cfg.workload.events,
        }
    }

    /// Auto-scaled instance count.
    pub fn instances(&self) -> u32 {
        let n = (self.total_rate + self.instance_capacity - 1) / self.instance_capacity;
        (n as u32).clamp(1, self.max_instances)
    }
}

/// Result of a fleet run.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetReport {
    pub instances: u32,
    pub events: u64,
    pub bytes: u64,
    pub elapsed_micros: u64,
    /// Achieved offered load, events/second.
    pub rate_events: f64,
    pub rate_bytes: f64,
}

/// The auto-scaling generator fleet.
pub struct Fleet {
    config: GeneratorConfig,
    clock: ClockRef,
    throughput: Arc<ThroughputRecorder>,
    latency: Arc<LatencyRecorder>,
}

impl Fleet {
    pub fn new(
        config: GeneratorConfig,
        clock: ClockRef,
        throughput: Arc<ThroughputRecorder>,
        latency: Arc<LatencyRecorder>,
    ) -> Self {
        Self {
            config,
            clock,
            throughput,
            latency,
        }
    }

    /// Run the fleet for `duration_micros` against `topic`, blocking until
    /// all instances finish.  `pattern_of` builds each instance's schedule
    /// from its load share.
    pub fn run(
        &self,
        broker: &Arc<Broker>,
        topic: &Arc<Topic>,
        duration_micros: u64,
        stop: &Arc<AtomicBool>,
        pattern_of: impl Fn(u64) -> Pattern,
    ) -> FleetReport {
        let n = self.config.instances();
        let share = self.config.total_rate / n as u64;
        let remainder = self.config.total_rate - share * n as u64;
        let eshare = self.config.events / n as u64;
        let eremainder = self.config.events - eshare * n as u64;
        let start = self.clock.now_micros();

        let handles: Vec<_> = (0..n)
            .map(|i| {
                // First instance absorbs the division remainder.
                let my_rate = if i == 0 { share + remainder } else { share };
                let my_events = if i == 0 { eshare + eremainder } else { eshare };
                let pattern = pattern_of(my_rate);
                let worker = InstanceWorker {
                    id: i,
                    config: self.config.clone(),
                    pattern,
                    rate: my_rate,
                    events: my_events,
                    clock: self.clock.clone(),
                    throughput: self.throughput.clone(),
                    latency: self.latency.clone(),
                    broker: broker.clone(),
                    topic: topic.clone(),
                    stop: stop.clone(),
                };
                let deadline = start + duration_micros;
                std::thread::Builder::new()
                    .name(format!("wgen-{i}"))
                    .spawn(move || worker.run(deadline))
                    .expect("spawn generator")
            })
            .collect();

        let mut events = 0;
        let mut bytes = 0;
        for h in handles {
            let (e, b) = h.join().expect("generator panicked");
            events += e;
            bytes += b;
        }
        let elapsed = self.clock.now_micros().saturating_sub(start).max(1);
        FleetReport {
            instances: n,
            events,
            bytes,
            elapsed_micros: elapsed,
            rate_events: events as f64 * 1e6 / elapsed as f64,
            rate_bytes: bytes as f64 * 1e6 / elapsed as f64,
        }
    }
}

/// Live poison-fault state for one generator instance: each configured
/// window corrupts a seeded `fraction` of payloads while
/// `[at, at + duration)` is active (`duration` 0 = the whole run).
/// Corrupted payloads keep their serialized length — byte accounting and
/// event conservation are untouched; only downstream parsing fails, which
/// the engine quarantines and counts.
struct PoisonState {
    windows: Vec<PoisonWindow>,
}

struct PoisonWindow {
    from_micros: u64,
    until_micros: u64,
    fraction: f64,
    rng: Pcg32,
}

impl PoisonState {
    fn new(plan: &[FaultSpec], master_seed: u64, instance: u32, run_start_micros: u64) -> Self {
        let windows = plan
            .iter()
            .filter_map(|f| match f.kind {
                FaultKind::PoisonRecords { fraction } => {
                    let seed = if f.seed != 0 { f.seed } else { master_seed };
                    Some(PoisonWindow {
                        from_micros: run_start_micros + f.at_micros,
                        until_micros: if f.duration_micros == 0 {
                            u64::MAX
                        } else {
                            run_start_micros + f.at_micros + f.duration_micros
                        },
                        fraction,
                        // Seeded per instance like the schedule (0xDADA) and
                        // disorder (0xD150) streams, so poison runs replay
                        // exactly.
                        rng: Pcg32::from_master(seed ^ 0xBAD0, instance as u64),
                    })
                }
                _ => None,
            })
            .collect();
        Self { windows }
    }

    /// Whether the payload assembled at `now` should be corrupted.
    fn sample(&mut self, now_micros: u64) -> bool {
        self.windows.iter_mut().any(|w| {
            now_micros >= w.from_micros && now_micros < w.until_micros && w.rng.f64() < w.fraction
        })
    }
}

struct InstanceWorker {
    id: u32,
    config: GeneratorConfig,
    pattern: Pattern,
    rate: u64,
    /// Exact event budget in count-bound mode (0 = duration-bound).
    events: u64,
    clock: ClockRef,
    throughput: Arc<ThroughputRecorder>,
    latency: Arc<LatencyRecorder>,
    broker: Arc<Broker>,
    topic: Arc<Topic>,
    stop: Arc<AtomicBool>,
}

impl InstanceWorker {
    fn run(self, deadline_micros: u64) -> (u64, u64) {
        if self.events > 0 {
            return self.run_counted();
        }
        let mut rng = Pcg32::from_master(self.config.seed, self.id as u64);
        let keys = KeyDist::new(
            self.config.sensors,
            self.config.key_skew,
            self.config.hot_keys,
            self.config.hot_fraction,
        );
        let mut schedule = PatternState::new(
            self.pattern.clone(),
            Pcg32::from_master(self.config.seed ^ 0xDADA, self.id as u64),
        );
        // Disorder model: seeded per instance, so disordered runs are
        // exactly reproducible.
        let mut disorder = self.config.disorder.enabled().then(|| {
            DisorderState::new(
                self.config.disorder.clone(),
                Pcg32::from_master(self.config.seed ^ 0xD150, self.id as u64),
            )
        });
        let mut poison = (!self.config.poison.is_empty()).then(|| {
            PoisonState::new(
                &self.config.poison,
                self.config.seed,
                self.id,
                self.clock.now_micros(),
            )
        });
        // Pace at the instance share, never beyond rated capacity.
        let paced_rate = self.rate.min(self.config.instance_capacity).max(1);
        let mut bucket = TokenBucket::new(
            self.clock.clone(),
            paced_rate,
            (paced_rate / 50).max(self.config.produce_batch as u64 * 2),
        );

        let mut total_events = 0u64;
        let mut total_bytes = 0u64;
        let mut wire = Vec::with_capacity(self.config.event_bytes + 32);
        let mut serializer =
            super::event::EventSerializer::new(self.config.format, self.config.event_bytes);
        let partitions = self.topic.partition_count();

        'outer: while self.clock.now_micros() < deadline_micros
            && !self.stop.load(Ordering::Relaxed)
        {
            let tick = schedule.next_tick();
            let mut remaining = tick.events;
            if remaining == 0 {
                self.clock.sleep_micros(tick.duration_micros);
                continue;
            }
            while remaining > 0 {
                let chunk = remaining.min(self.config.produce_batch as u64);
                bucket.acquire(chunk);
                let now = self.clock.now_micros();
                // Batch-first path: serialize the whole chunk straight
                // into per-partition RecordBatch arenas — no intermediate
                // Vec<Record>, one Arc and one partition-lock acquisition
                // per (partition, chunk) instead of one per event.
                let mut pb = PartitionedBatchBuilder::new(partitions);
                for _ in 0..chunk {
                    let sensor_id = keys.sample(&mut rng);
                    let ev = SensorEvent {
                        ts_micros: now,
                        sensor_id,
                        temp_c: 20.0 + rng.normal() as f32 * 15.0,
                    };
                    // Disorder: backdate the generation stamp and/or hold
                    // the event in the shuffle window.  The perturbed
                    // stamp lands in both the wire payload and the batch
                    // entry, so the whole downstream plane sees it.
                    let ev = match &mut disorder {
                        Some(d) => match d.admit(ev) {
                            Some(e) => e,
                            None => continue, // buffered; emitted later
                        },
                        None => ev,
                    };
                    let n = serializer.serialize(&ev, &mut wire);
                    total_bytes += n as u64;
                    if let Some(p) = &mut poison {
                        if p.sample(now) {
                            // `#` defeats both wire parsers; length (and
                            // therefore all byte accounting) is preserved.
                            wire.fill(b'#');
                        }
                    }
                    pb.push(
                        self.topic.partition_for_key(ev.sensor_id),
                        ev.sensor_id,
                        &wire,
                        ev.ts_micros,
                    );
                }
                // Acked produce: generation → network thread → append →
                // ack, so the recorded BrokerIn latency sees broker-side
                // queueing as load approaches broker capacity.
                if !self.append_and_account(pb, now, &mut total_events) {
                    break 'outer; // broker shut down
                }
                remaining -= chunk;
                if self.clock.now_micros() >= deadline_micros {
                    break 'outer;
                }
            }
        }
        // Drain the shuffle window so every generated event reaches the
        // broker (conservation: rate accounting and the engine's intake
        // stay consistent with the disabled-disorder path).
        if let Some(d) = &mut disorder {
            let mut pb = PartitionedBatchBuilder::new(partitions);
            let now = self.clock.now_micros();
            while let Some(ev) = d.flush_one() {
                let n = serializer.serialize(&ev, &mut wire);
                total_bytes += n as u64;
                if let Some(p) = &mut poison {
                    if p.sample(now) {
                        wire.fill(b'#');
                    }
                }
                pb.push(
                    self.topic.partition_for_key(ev.sensor_id),
                    ev.sensor_id,
                    &wire,
                    ev.ts_micros,
                );
            }
            self.append_and_account(pb, now, &mut total_events);
        }
        (total_events, total_bytes)
    }

    /// Count-bound deterministic generation (`workload.events > 0`): the
    /// instance emits exactly its share of the budget — no token bucket,
    /// no wall-clock deadline — with synthetic generation timestamps
    /// spaced evenly at the instance rate from a fixed base, and
    /// temperatures quantized to 0.25 °C multiples.  The serialized
    /// stream is then a pure function of the seed, and quarter-degree
    /// addends make window sums exact in f32 (order-independent), so two
    /// topologies of the same spec — in-process vs. multi-process TCP —
    /// produce byte-identical final aggregates (the distributed
    /// equivalence suite's foundation; same methodology as
    /// `rust/tests/shuffle_equivalence.rs`).
    fn run_counted(self) -> (u64, u64) {
        const TS_BASE_MICROS: u64 = 1_700_000_000_000_000;
        let mut rng = Pcg32::from_master(self.config.seed, self.id as u64);
        let keys = KeyDist::new(
            self.config.sensors,
            self.config.key_skew,
            self.config.hot_keys,
            self.config.hot_fraction,
        );
        let mut disorder = self.config.disorder.enabled().then(|| {
            DisorderState::new(
                self.config.disorder.clone(),
                Pcg32::from_master(self.config.seed ^ 0xD150, self.id as u64),
            )
        });
        let paced_rate = self.rate.min(self.config.instance_capacity).max(1);

        let mut total_events = 0u64;
        let mut total_bytes = 0u64;
        let mut wire = Vec::with_capacity(self.config.event_bytes + 32);
        let mut serializer =
            super::event::EventSerializer::new(self.config.format, self.config.event_bytes);
        let partitions = self.topic.partition_count();

        let mut k = 0u64;
        while k < self.events && !self.stop.load(Ordering::Relaxed) {
            let chunk = (self.events - k).min(self.config.produce_batch as u64);
            let mut pb = PartitionedBatchBuilder::new(partitions);
            for _ in 0..chunk {
                let sensor_id = keys.sample(&mut rng);
                // Integer spacing: deterministic across platforms.
                let ts = TS_BASE_MICROS + k * 1_000_000 / paced_rate;
                k += 1;
                let temp = ((20.0f32 + rng.normal() as f32 * 15.0) * 4.0).round() / 4.0;
                let ev = SensorEvent {
                    ts_micros: ts,
                    sensor_id,
                    temp_c: temp,
                };
                let ev = match &mut disorder {
                    Some(d) => match d.admit(ev) {
                        Some(e) => e,
                        None => continue, // buffered; emitted later
                    },
                    None => ev,
                };
                let n = serializer.serialize(&ev, &mut wire);
                total_bytes += n as u64;
                pb.push(
                    self.topic.partition_for_key(ev.sensor_id),
                    ev.sensor_id,
                    &wire,
                    ev.ts_micros,
                );
            }
            if !self.append_and_account(pb, self.clock.now_micros(), &mut total_events) {
                return (total_events, total_bytes); // broker shut down
            }
        }
        // Drain the disorder shuffle window: conservation, exactly like
        // the duration-bound path.
        if let Some(d) = &mut disorder {
            let mut pb = PartitionedBatchBuilder::new(partitions);
            while let Some(ev) = d.flush_one() {
                let n = serializer.serialize(&ev, &mut wire);
                total_bytes += n as u64;
                pb.push(
                    self.topic.partition_for_key(ev.sensor_id),
                    ev.sensor_id,
                    &wire,
                    ev.ts_micros,
                );
            }
            self.append_and_account(pb, self.clock.now_micros(), &mut total_events);
        }
        (total_events, total_bytes)
    }

    /// Append a finished builder and record the produce-side metrics
    /// (DriverOut/BrokerIn throughput + broker-ingest latency anchored at
    /// `gen_now`, the batch-assembly time).  Note the anchor semantics
    /// under disorder: shuffle-window residence happens *before* assembly
    /// and is deliberately excluded — BrokerIn measures broker-side
    /// produce/queueing cost, while the reservoir delay is workload
    /// disorder and shows up (with the backdating) in the end-to-end
    /// `gen_ts`-anchored latency instead.  Returns `false` when the
    /// broker has shut down; no-op for an empty builder.
    fn append_and_account(
        &self,
        pb: PartitionedBatchBuilder,
        gen_now: u64,
        total_events: &mut u64,
    ) -> bool {
        let appended = pb.total_records() as u64;
        if appended == 0 {
            return true;
        }
        if self
            .broker
            .produce_batches_acked(&self.topic, pb.finish())
            .is_err()
        {
            return false;
        }
        *total_events += appended;
        let bytes = appended * self.config.event_bytes as u64;
        self.throughput
            .record_events(MeasurementPoint::DriverOut, appended, bytes);
        self.throughput
            .record_events(MeasurementPoint::BrokerIn, appended, bytes);
        // Broker-ingest latency: batch assembly → append completion.
        let lat = self.clock.now_micros().saturating_sub(gen_now);
        self.latency
            .record_n(MeasurementPoint::BrokerIn, self.id as usize, lat, appended);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::BrokerConfig;
    use crate::util::clock;

    fn config(rate: u64) -> GeneratorConfig {
        GeneratorConfig {
            total_rate: rate,
            instance_capacity: 500_000,
            max_instances: 64,
            event_bytes: 27,
            format: EventFormat::Csv,
            sensors: 256,
            key_skew: 0.0,
            hot_keys: 0,
            hot_fraction: 0.0,
            seed: 42,
            produce_batch: 256,
            disorder: DisorderSection::default(),
            poison: Vec::new(),
            events: 0,
        }
    }

    #[test]
    fn autoscaling_matches_paper_rule() {
        assert_eq!(config(100_000).instances(), 1);
        assert_eq!(config(500_000).instances(), 1);
        assert_eq!(config(500_001).instances(), 2);
        assert_eq!(config(2_000_000).instances(), 4);
        assert_eq!(config(8_000_000).instances(), 16);
    }

    #[test]
    fn fleet_hits_constant_rate_within_tolerance() {
        let clk = clock::wall();
        let broker = Broker::new(BrokerConfig::default(), clk.clone());
        let topic = broker.create_topic("in");
        // Consume in the background so backpressure never binds.
        let group = broker.subscribe("in", "sink", 1);
        let consumer = {
            let group = group.clone();
            std::thread::spawn(move || {
                let mut n = 0u64;
                loop {
                    match group.poll(0, 1024) {
                        Ok(Some(b)) => {
                            n += b.record_count() as u64;
                            group.commit(b.partition, b.next_offset);
                        }
                        Ok(None) => std::thread::sleep(std::time::Duration::from_millis(1)),
                        Err(_) => return n,
                    }
                }
            })
        };
        let tp = Arc::new(ThroughputRecorder::new());
        let lat = Arc::new(LatencyRecorder::new());
        let fleet = Fleet::new(config(200_000), clk, tp.clone(), lat);
        let stop = Arc::new(AtomicBool::new(false));
        let report = fleet.run(&broker, &topic, 1_000_000, &stop, |r| Pattern::Constant {
            rate: r,
        });
        broker.shutdown();
        let consumed = consumer.join().unwrap();
        assert_eq!(report.instances, 1);
        // 200K ev/s for 1s ± scheduler noise.
        assert!(
            (150_000.0..250_000.0).contains(&report.rate_events),
            "rate={}",
            report.rate_events
        );
        assert_eq!(report.events, consumed);
        assert_eq!(tp.events_at(MeasurementPoint::DriverOut), report.events);
        // 27-byte events: bytes metric consistent.
        assert_eq!(report.bytes, report.events * 27);
    }

    #[test]
    fn stop_flag_halts_fleet_early() {
        let clk = clock::wall();
        let broker = Broker::new(BrokerConfig::default(), clk.clone());
        let topic = broker.create_topic("in");
        let _g = broker.subscribe("in", "sink", 1);
        let tp = Arc::new(ThroughputRecorder::new());
        let lat = Arc::new(LatencyRecorder::new());
        let fleet = Fleet::new(config(100_000), clk.clone(), tp, lat);
        let stop = Arc::new(AtomicBool::new(false));
        let stopper = {
            let stop = stop.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(50));
                stop.store(true, Ordering::SeqCst);
            })
        };
        let t0 = std::time::Instant::now();
        fleet.run(&broker, &topic, 60_000_000, &stop, |r| Pattern::Constant { rate: r });
        assert!(t0.elapsed().as_secs() < 10, "stop flag ignored");
        stopper.join().unwrap();
    }

    #[test]
    fn disordered_fleet_conserves_events_and_perturbs_gen_ts() {
        let clk = clock::wall();
        let broker = Broker::new(BrokerConfig::default(), clk.clone());
        let topic = broker.create_topic("in");
        let group = broker.subscribe("in", "sink", 1);
        let mut cfg = config(60_000);
        cfg.disorder = DisorderSection {
            lateness_micros: 50_000,
            late_fraction: 0.5,
            straggler_fraction: 0.0,
            straggler_micros: 0,
            shuffle_window: 64,
        };
        let tp = Arc::new(ThroughputRecorder::new());
        let lat = Arc::new(LatencyRecorder::new());
        let fleet = Fleet::new(cfg, clk, tp.clone(), lat);
        let stop = Arc::new(AtomicBool::new(false));
        let report = fleet.run(&broker, &topic, 500_000, &stop, |r| Pattern::Constant {
            rate: r,
        });
        broker.shutdown();
        // Every generated event reaches the broker (shuffle window drained).
        assert_eq!(tp.events_at(MeasurementPoint::DriverOut), report.events);
        // Count ts regressions *within* each record batch: one batch is
        // one instance's emission order, so without disorder every entry
        // would carry the same chunk stamp (zero regressions).
        let mut regressions = 0u64;
        let mut consumed = 0u64;
        loop {
            match group.poll(0, 4096) {
                Ok(Some(b)) => {
                    for rb in &b.batches {
                        let mut prev_ts = 0u64;
                        for i in 0..rb.len() {
                            let ts = rb.entry(i).gen_ts_micros;
                            if ts < prev_ts {
                                regressions += 1;
                            }
                            prev_ts = ts;
                            consumed += 1;
                        }
                    }
                    group.commit(b.partition, b.next_offset);
                }
                Ok(None) => continue,
                Err(_) => break,
            }
        }
        assert_eq!(consumed, report.events, "conservation through the broker");
        assert!(
            regressions > report.events / 50,
            "disorder must produce out-of-order gen_ts: {regressions} of {consumed}"
        );
    }

    #[test]
    fn poison_windows_corrupt_a_seeded_fraction_without_losing_events() {
        let clk = clock::wall();
        let broker = Broker::new(BrokerConfig::default(), clk.clone());
        let topic = broker.create_topic("in");
        let group = broker.subscribe("in", "sink", 1);
        let mut cfg = config(60_000);
        cfg.poison = vec![FaultSpec {
            kind: FaultKind::PoisonRecords { fraction: 0.2 },
            at_micros: 0,
            duration_micros: 0, // whole run
            seed: 0,            // inherit the bench seed
        }];
        let tp = Arc::new(ThroughputRecorder::new());
        let lat = Arc::new(LatencyRecorder::new());
        let fleet = Fleet::new(cfg, clk, tp, lat);
        let stop = Arc::new(AtomicBool::new(false));
        let report = fleet.run(&broker, &topic, 500_000, &stop, |r| Pattern::Constant {
            rate: r,
        });
        broker.shutdown();
        let mut bad = 0u64;
        let mut consumed = 0u64;
        loop {
            match group.poll(0, 4096) {
                Ok(Some(b)) => {
                    for rb in &b.batches {
                        for i in 0..rb.len() {
                            if SensorEvent::parse(rb.payload(i)).is_none() {
                                bad += 1;
                            }
                            consumed += 1;
                        }
                    }
                    group.commit(b.partition, b.next_offset);
                }
                Ok(None) => continue,
                Err(_) => break,
            }
        }
        // Conservation: poison corrupts payloads, it never drops events.
        assert_eq!(consumed, report.events);
        let frac = bad as f64 / consumed.max(1) as f64;
        assert!(
            (0.1..0.35).contains(&frac),
            "poison fraction off target: {bad}/{consumed}"
        );
    }

    #[test]
    fn count_bound_mode_emits_exactly_the_budget_deterministically() {
        let run = || {
            let clk = clock::wall();
            let broker = Broker::new(BrokerConfig::default(), clk.clone());
            let topic = broker.create_topic("in");
            let group = broker.subscribe("in", "sink", 1);
            let mut cfg = config(1_000_000); // 2 instances
            cfg.events = 10_000;
            let tp = Arc::new(ThroughputRecorder::new());
            let lat = Arc::new(LatencyRecorder::new());
            let fleet = Fleet::new(cfg, clk, tp, lat);
            let stop = Arc::new(AtomicBool::new(false));
            let report =
                fleet.run(&broker, &topic, 60_000_000, &stop, |r| Pattern::Constant { rate: r });
            broker.shutdown();
            let mut lines: Vec<String> = Vec::new();
            loop {
                match group.poll(0, 4096) {
                    Ok(Some(b)) => {
                        for rb in &b.batches {
                            for i in 0..rb.len() {
                                let e = rb.entry(i);
                                let ev = SensorEvent::parse(rb.payload(i)).unwrap();
                                // Synthetic stamps, quarter-degree temps.
                                assert!(e.gen_ts_micros >= 1_700_000_000_000_000);
                                assert_eq!(ev.temp_c * 4.0, (ev.temp_c * 4.0).round());
                                lines.push(format!("{},{}", e.gen_ts_micros, e.key));
                            }
                        }
                        group.commit(b.partition, b.next_offset);
                    }
                    Ok(None) => continue,
                    Err(_) => break,
                }
            }
            lines.sort_unstable();
            (report.events, lines)
        };
        let (n1, s1) = run();
        let (n2, s2) = run();
        assert_eq!(n1, 10_000, "exact budget, not a wall-clock race");
        assert_eq!(n1, n2);
        assert_eq!(s1, s2, "the stream is a pure function of the seed");
    }

    #[test]
    fn zipf_skew_produces_hot_keys() {
        let clk = clock::wall();
        let broker = Broker::new(BrokerConfig::default(), clk.clone());
        let topic = broker.create_topic("in");
        let group = broker.subscribe("in", "sink", 1);
        let mut cfg = config(50_000);
        cfg.key_skew = 1.2;
        let tp = Arc::new(ThroughputRecorder::new());
        let lat = Arc::new(LatencyRecorder::new());
        let fleet = Fleet::new(cfg, clk, tp, lat);
        let stop = Arc::new(AtomicBool::new(false));
        fleet.run(&broker, &topic, 400_000, &stop, |r| Pattern::Constant { rate: r });
        broker.shutdown();
        let mut counts = vec![0u64; 256];
        loop {
            match group.poll(0, 4096) {
                Ok(Some(b)) => {
                    for r in b.iter() {
                        counts[r.key as usize] += 1;
                    }
                    group.commit(b.partition, b.next_offset);
                }
                Ok(None) => continue,
                Err(_) => break,
            }
        }
        let hot: u64 = counts[..8].iter().sum();
        let cold: u64 = counts[248..].iter().sum();
        assert!(hot > cold * 3, "hot={hot} cold={cold}");
    }
}
