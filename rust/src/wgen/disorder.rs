//! Out-of-order workload model: perturbs generation timestamps relative
//! to emission order.
//!
//! Real HPC ingest paths deliver disordered streams — network fan-in,
//! per-shard buffering, retried sends.  The model composes three
//! mechanisms (all off by default, see
//! [`DisorderSection`](crate::config::schema::DisorderSection)):
//!
//! * **lateness sampling** — a configured fraction of events is backdated
//!   by uniform(0, lateness]: they *arrive* on time but *happened*
//!   earlier, exactly what an event-time window must reconcile;
//! * **stragglers** — a (typically tiny) fraction is backdated beyond the
//!   lateness bound, producing records a correctly-bounded watermark has
//!   already passed — the droppable "too-late" class;
//! * **shuffle window** — a reorder buffer of `K` pending events; each
//!   emission slot releases a uniformly random one, so even unperturbed
//!   timestamps leave in shuffled order (bounded only probabilistically).
//!
//! The generator applies the model between event synthesis and
//! serialization; the perturbed timestamp lands both in the wire payload
//! and in the broker batch entry, so the entire downstream plane sees the
//! disordered stream.
//!
//! Cost note: per-event timestamps defeat the serializer's shared-prefix
//! cache (`EventSerializer` renders the `…ts…` prefix once per chunk when
//! all events share the chunk stamp, a documented ~1.9× win).  That is
//! the honest price of carrying real event-time stamps on the wire —
//! budget generator headroom accordingly (lower `workload.rate` or more
//! instances) when disorder is enabled, or the sustainability verdict
//! measures the generator instead of the engine.

use super::event::SensorEvent;
use crate::config::schema::DisorderSection;
use crate::util::rng::Pcg32;

/// Stateful disorder applicator, one per generator instance (seeded from
/// the instance id, so runs are reproducible).
pub struct DisorderState {
    spec: DisorderSection,
    rng: Pcg32,
    /// Reorder buffer (shuffle window); empty when `shuffle_window == 0`.
    pending: Vec<SensorEvent>,
}

impl DisorderState {
    pub fn new(spec: DisorderSection, rng: Pcg32) -> Self {
        let cap = spec.shuffle_window;
        Self {
            spec,
            rng,
            pending: Vec::with_capacity(cap),
        }
    }

    /// Sampled backdating delay for one event, µs.
    fn sample_delay(&mut self) -> u64 {
        let r = self.rng.f64();
        if r < self.spec.straggler_fraction {
            self.spec.lateness_micros + self.rng.range_u64(1, self.spec.straggler_micros.max(1))
        } else if r < self.spec.straggler_fraction + self.spec.late_fraction
            && self.spec.lateness_micros > 0
        {
            self.rng.range_u64(1, self.spec.lateness_micros)
        } else {
            0
        }
    }

    /// Admit one freshly generated event; returns the event to emit *now*
    /// (possibly an older buffered one), or `None` while the shuffle
    /// window is still filling.
    pub fn admit(&mut self, mut ev: SensorEvent) -> Option<SensorEvent> {
        ev.ts_micros = ev.ts_micros.saturating_sub(self.sample_delay());
        if self.spec.shuffle_window == 0 {
            return Some(ev);
        }
        self.pending.push(ev);
        if self.pending.len() <= self.spec.shuffle_window {
            return None;
        }
        let i = self.rng.below(self.pending.len() as u32) as usize;
        Some(self.pending.swap_remove(i))
    }

    /// Drain one buffered event (end-of-stream flush), in random order.
    pub fn flush_one(&mut self) -> Option<SensorEvent> {
        if self.pending.is_empty() {
            return None;
        }
        let i = self.rng.below(self.pending.len() as u32) as usize;
        Some(self.pending.swap_remove(i))
    }

    /// Events currently held in the shuffle window.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ts: u64) -> SensorEvent {
        SensorEvent {
            ts_micros: ts,
            sensor_id: 1,
            temp_c: 20.0,
        }
    }

    fn state(spec: DisorderSection) -> DisorderState {
        DisorderState::new(spec, Pcg32::from_master(7, 1))
    }

    #[test]
    fn disabled_model_is_identity() {
        let mut d = state(DisorderSection::default());
        for t in [0u64, 5, 1_000_000] {
            let out = d.admit(ev(t)).expect("no shuffle window → immediate");
            assert_eq!(out.ts_micros, t);
        }
        assert!(d.flush_one().is_none());
    }

    #[test]
    fn lateness_backdates_within_the_bound() {
        let mut d = state(DisorderSection {
            lateness_micros: 10_000,
            late_fraction: 1.0,
            ..DisorderSection::default()
        });
        let mut delayed = 0;
        for i in 0..500u64 {
            let now = 1_000_000 + i;
            let out = d.admit(ev(now)).unwrap();
            assert!(out.ts_micros <= now);
            assert!(now - out.ts_micros <= 10_000, "delay beyond bound");
            if out.ts_micros < now {
                delayed += 1;
            }
        }
        assert!(delayed > 450, "late_fraction 1.0 must delay nearly all: {delayed}");
    }

    #[test]
    fn stragglers_exceed_the_lateness_bound() {
        let mut d = state(DisorderSection {
            lateness_micros: 1_000,
            late_fraction: 0.0,
            straggler_fraction: 1.0,
            straggler_micros: 5_000,
            ..DisorderSection::default()
        });
        for i in 0..100u64 {
            let now = 1_000_000 + i;
            let out = d.admit(ev(now)).unwrap();
            let delay = now - out.ts_micros;
            assert!(delay > 1_000 && delay <= 6_000, "straggler delay {delay}");
        }
    }

    #[test]
    fn timestamps_never_underflow() {
        let mut d = state(DisorderSection {
            lateness_micros: 1_000_000,
            late_fraction: 1.0,
            ..DisorderSection::default()
        });
        let out = d.admit(ev(5)).unwrap();
        // Saturates at zero instead of wrapping.
        assert!(out.ts_micros <= 5);
    }

    #[test]
    fn shuffle_window_reorders_but_conserves_events() {
        let mut d = state(DisorderSection {
            shuffle_window: 16,
            ..DisorderSection::default()
        });
        let mut out = Vec::new();
        for t in 0..200u64 {
            if let Some(e) = d.admit(ev(t)) {
                out.push(e.ts_micros);
            }
        }
        assert_eq!(d.pending(), 16, "window stays full in steady state");
        while let Some(e) = d.flush_one() {
            out.push(e.ts_micros);
        }
        assert_eq!(out.len(), 200, "no event lost or duplicated");
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..200).collect::<Vec<_>>());
        assert_ne!(out, sorted, "a 16-slot reservoir must actually shuffle");
        // Displacement is concentrated: an event can only be overtaken
        // while it sits in the reservoir.
        let mut max_disp = 0i64;
        for (pos, &t) in out.iter().enumerate() {
            max_disp = max_disp.max((pos as i64 - t as i64).abs());
        }
        assert!(max_disp >= 1);
    }

    #[test]
    fn deterministic_for_a_fixed_seed() {
        let spec = DisorderSection {
            lateness_micros: 5_000,
            late_fraction: 0.5,
            shuffle_window: 8,
            ..DisorderSection::default()
        };
        let run = || {
            let mut d = DisorderState::new(spec.clone(), Pcg32::from_master(42, 3));
            let mut out = Vec::new();
            for t in 0..100u64 {
                if let Some(e) = d.admit(ev(1_000 + t * 10)) {
                    out.push(e.ts_micros);
                }
            }
            while let Some(e) = d.flush_one() {
                out.push(e.ts_micros);
            }
            out
        };
        assert_eq!(run(), run());
    }
}
