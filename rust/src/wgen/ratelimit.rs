//! Token-bucket rate limiter over an abstract clock.
//!
//! Generator instances pace their emission with this: `acquire(n)` blocks
//! (wall) or advances virtual time (sim) until `n` tokens are available.
//! Burst capacity defaults to one tick's worth so short stalls don't cause
//! permanent rate deficits, but sustained overdraw is impossible.

use crate::util::clock::ClockRef;

pub struct TokenBucket {
    clock: ClockRef,
    /// Tokens per microsecond.
    rate_per_micro: f64,
    /// Maximum accumulated tokens.
    burst: f64,
    tokens: f64,
    last_micros: u64,
}

impl TokenBucket {
    /// `rate` tokens/second, bursting up to `burst` tokens.
    pub fn new(clock: ClockRef, rate: u64, burst: u64) -> Self {
        let last_micros = clock.now_micros();
        Self {
            clock,
            rate_per_micro: rate as f64 / 1e6,
            burst: burst.max(1) as f64,
            tokens: 0.0,
            last_micros,
        }
    }

    fn refill(&mut self) {
        let now = self.clock.now_micros();
        let dt = now.saturating_sub(self.last_micros);
        self.last_micros = now;
        self.tokens = (self.tokens + dt as f64 * self.rate_per_micro).min(self.burst);
    }

    /// Take `n` tokens, sleeping until available.
    pub fn acquire(&mut self, n: u64) {
        debug_assert!(n as f64 <= self.burst, "acquire larger than burst");
        loop {
            self.refill();
            if self.tokens >= n as f64 {
                self.tokens -= n as f64;
                return;
            }
            let missing = n as f64 - self.tokens;
            let wait = (missing / self.rate_per_micro).ceil() as u64;
            self.clock.sleep_micros(wait.max(1));
        }
    }

    /// Non-blocking attempt; true when the tokens were taken.
    pub fn try_acquire(&mut self, n: u64) -> bool {
        self.refill();
        if self.tokens >= n as f64 {
            self.tokens -= n as f64;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock;

    #[test]
    fn sim_clock_paces_exactly() {
        let c = clock::sim();
        let mut tb = TokenBucket::new(c.clone(), 1_000_000, 10_000); // 1 ev/us
        for _ in 0..100 {
            tb.acquire(1_000);
        }
        // 100k tokens at 1/us -> 100k us.
        let t = c.now_micros();
        assert!((99_000..=101_000 * 2).contains(&t), "t={t}");
    }

    #[test]
    fn burst_capacity_is_bounded() {
        let c = clock::sim();
        let mut tb = TokenBucket::new(c.clone(), 1_000, 100);
        c.sleep_micros(10_000_000); // long idle: refill caps at burst
        assert!(tb.try_acquire(100));
        assert!(!tb.try_acquire(50), "bucket must not exceed burst");
    }

    #[test]
    fn wall_clock_rate_is_respected() {
        let c = clock::wall();
        let mut tb = TokenBucket::new(c.clone(), 100_000, 1_000);
        let t0 = c.now_micros();
        // 20k tokens at 100k/s should take ~200ms.
        for _ in 0..20 {
            tb.acquire(1_000);
        }
        let dt = c.now_micros() - t0;
        assert!(dt >= 150_000, "finished too fast: {dt}us");
        assert!(dt < 600_000, "finished too slow: {dt}us");
    }

    #[test]
    fn try_acquire_fails_then_succeeds() {
        let c = clock::sim();
        let mut tb = TokenBucket::new(c.clone(), 1_000_000, 1_000);
        assert!(!tb.try_acquire(500), "empty bucket");
        c.sleep_micros(500);
        assert!(tb.try_acquire(500));
    }
}
