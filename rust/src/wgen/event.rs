//! Sensor event model and wire formats.
//!
//! Default workload: synthetic sensor stream; every event has a timestamp,
//! sensor ID, and temperature (paper Sec. 3.2).  Two wire formats:
//!
//! * `Json` — `{"ts":…,"id":…,"t":…}` (+ `"p"` padding to the target size),
//! * `Csv`  — `ts,id,temp` + space padding; this is the compact form whose
//!   floor is the paper's 27-byte minimum event size.
//!
//! The serializer writes into a caller-provided buffer (no allocation on
//! the hot path) and always produces *exactly* `target_bytes` when the
//! target is at or above the format's floor for the given values.

/// One sensor reading.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SensorEvent {
    /// Generation timestamp, microseconds.
    pub ts_micros: u64,
    /// Sensor id in `[0, sensors)` — the stream key.
    pub sensor_id: u32,
    /// Temperature, °C, two decimals of precision on the wire.
    pub temp_c: f32,
}

/// Wire format for serialized events.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventFormat {
    Json,
    Csv,
}

impl SensorEvent {
    /// Serialize into `buf` (cleared first), padding to exactly
    /// `target_bytes` when possible. Returns the serialized length.
    pub fn serialize_into(&self, format: EventFormat, target_bytes: usize, buf: &mut Vec<u8>) -> usize {
        buf.clear();
        match format {
            EventFormat::Json => {
                buf.extend_from_slice(b"{\"ts\":");
                write_u64(buf, self.ts_micros);
                buf.extend_from_slice(b",\"id\":");
                write_u64(buf, self.sensor_id as u64);
                buf.extend_from_slice(b",\"t\":");
                write_temp(buf, self.temp_c);
                // Pad with a filler field to hit the exact target size:
                // `,"p":"xxxx"}` costs 8 + padlen bytes.
                let base = buf.len() + 1; // closing brace
                if target_bytes >= base + 7 {
                    let pad = target_bytes - base - 7;
                    buf.extend_from_slice(b",\"p\":\"");
                    buf.resize(buf.len() + pad, b'x');
                    buf.extend_from_slice(b"\"}");
                } else {
                    buf.push(b'}');
                }
            }
            EventFormat::Csv => {
                write_u64(buf, self.ts_micros);
                buf.push(b',');
                write_u64(buf, self.sensor_id as u64);
                buf.push(b',');
                write_temp(buf, self.temp_c);
                if target_bytes > buf.len() {
                    buf.resize(target_bytes, b' ');
                }
            }
        }
        buf.len()
    }

    /// Parse either wire format (sniffs the first byte).
    pub fn parse(bytes: &[u8]) -> Option<SensorEvent> {
        if bytes.first() == Some(&b'{') {
            Self::parse_json(bytes)
        } else {
            Self::parse_csv(bytes)
        }
    }

    /// Fast-path JSON parse for the exact shape the generator emits.
    /// Falls back to the general parser for reordered/foreign documents.
    fn parse_json(bytes: &[u8]) -> Option<SensorEvent> {
        let ts = field_u64(bytes, b"\"ts\":")?;
        let id = field_u64(bytes, b"\"id\":")?;
        let t = field_f32(bytes, b"\"t\":")?;
        Some(SensorEvent {
            ts_micros: ts,
            sensor_id: id as u32,
            temp_c: t,
        })
    }

    /// Byte-level CSV parse (perf pass: the engine decodes every event on
    /// the hot path — no UTF-8 validation, no float machinery for the
    /// fixed two-decimal wire format).
    fn parse_csv(bytes: &[u8]) -> Option<SensorEvent> {
        let mut i = 0;
        let ts = parse_u64_until(bytes, &mut i, b',')?;
        let id = parse_u64_until(bytes, &mut i, b',')?;
        if id > u32::MAX as u64 {
            return None;
        }
        // Temperature: [-]INT[.FRAC] followed by padding spaces/EOL.
        let neg = bytes.get(i) == Some(&b'-');
        if neg {
            i += 1;
        }
        let mut int_part: u64 = 0;
        let mut any = false;
        while let Some(&b) = bytes.get(i) {
            if b.is_ascii_digit() {
                int_part = int_part * 10 + (b - b'0') as u64;
                any = true;
                i += 1;
            } else {
                break;
            }
        }
        if !any {
            return None;
        }
        let mut frac: u64 = 0;
        let mut scale: f32 = 1.0;
        if bytes.get(i) == Some(&b'.') {
            i += 1;
            while let Some(&b) = bytes.get(i) {
                if b.is_ascii_digit() && scale < 1e6 {
                    frac = frac * 10 + (b - b'0') as u64;
                    scale *= 10.0;
                    i += 1;
                } else {
                    break;
                }
            }
        }
        // Remainder must be padding.
        while let Some(&b) = bytes.get(i) {
            if b == b' ' || b == b'\n' || b == b'\r' {
                i += 1;
            } else {
                return None;
            }
        }
        let mut t = int_part as f32;
        if scale > 1.0 {
            t += frac as f32 / scale;
        }
        if neg {
            t = -t;
        }
        Some(SensorEvent {
            ts_micros: ts,
            sensor_id: id as u32,
            temp_c: t,
        })
    }
}

/// Prefix-caching serializer (perf pass): events inside one produce chunk
/// share their timestamp, and the timestamp is the longest field on the
/// wire — so the `…ts…` prefix is rendered once per chunk and reused
/// until the timestamp changes.  ~1.9× over [`SensorEvent::serialize_into`]
/// in the generator inner loop (EXPERIMENTS.md §Perf).
pub struct EventSerializer {
    format: EventFormat,
    target_bytes: usize,
    prefix: Vec<u8>,
    prefix_ts: u64,
}

impl EventSerializer {
    pub fn new(format: EventFormat, target_bytes: usize) -> Self {
        Self {
            format,
            target_bytes,
            prefix: Vec::with_capacity(32),
            prefix_ts: u64::MAX,
        }
    }

    #[inline]
    fn rebuild_prefix(&mut self, ts: u64) {
        self.prefix.clear();
        match self.format {
            EventFormat::Json => {
                self.prefix.extend_from_slice(b"{\"ts\":");
                write_u64(&mut self.prefix, ts);
                self.prefix.extend_from_slice(b",\"id\":");
            }
            EventFormat::Csv => {
                write_u64(&mut self.prefix, ts);
                self.prefix.push(b',');
            }
        }
        self.prefix_ts = ts;
    }

    /// Serialize into `buf` (cleared), padded to the exact target size
    /// when reachable.  Bit-identical to `SensorEvent::serialize_into`.
    #[inline]
    pub fn serialize(&mut self, ev: &SensorEvent, buf: &mut Vec<u8>) -> usize {
        if ev.ts_micros != self.prefix_ts {
            self.rebuild_prefix(ev.ts_micros);
        }
        buf.clear();
        buf.extend_from_slice(&self.prefix);
        match self.format {
            EventFormat::Json => {
                write_u64(buf, ev.sensor_id as u64);
                buf.extend_from_slice(b",\"t\":");
                write_temp(buf, ev.temp_c);
                let base = buf.len() + 1;
                if self.target_bytes >= base + 7 {
                    let pad = self.target_bytes - base - 7;
                    buf.extend_from_slice(b",\"p\":\"");
                    buf.resize(buf.len() + pad, b'x');
                    buf.extend_from_slice(b"\"}");
                } else {
                    buf.push(b'}');
                }
            }
            EventFormat::Csv => {
                write_u64(buf, ev.sensor_id as u64);
                buf.push(b',');
                write_temp(buf, ev.temp_c);
                if self.target_bytes > buf.len() {
                    buf.resize(self.target_bytes, b' ');
                }
            }
        }
        buf.len()
    }
}

/// Parse digits into u64 until `stop` (consumed) — hot-path helper.
#[inline]
fn parse_u64_until(bytes: &[u8], i: &mut usize, stop: u8) -> Option<u64> {
    let mut v: u64 = 0;
    let mut any = false;
    while let Some(&b) = bytes.get(*i) {
        if b.is_ascii_digit() {
            v = v.wrapping_mul(10).wrapping_add((b - b'0') as u64);
            any = true;
            *i += 1;
        } else if b == stop {
            *i += 1;
            return any.then_some(v);
        } else {
            return None;
        }
    }
    None
}

#[inline]
fn write_u64(buf: &mut Vec<u8>, mut v: u64) {
    let mut tmp = [0u8; 20];
    let mut i = tmp.len();
    loop {
        i -= 1;
        tmp[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    buf.extend_from_slice(&tmp[i..]);
}

/// Write a temperature with exactly two decimals (no float formatting
/// machinery on the hot path).
#[inline]
fn write_temp(buf: &mut Vec<u8>, t: f32) {
    let neg = t < 0.0;
    // Round to centi-degrees in integer space.
    let cents = (t.abs() as f64 * 100.0).round() as u64;
    if neg && cents > 0 {
        buf.push(b'-');
    }
    write_u64(buf, cents / 100);
    buf.push(b'.');
    let frac = cents % 100;
    buf.push(b'0' + (frac / 10) as u8);
    buf.push(b'0' + (frac % 10) as u8);
}

/// Find `pat` in `hay` and parse the u64 right after it.
#[inline]
fn field_u64(hay: &[u8], pat: &[u8]) -> Option<u64> {
    let pos = find(hay, pat)?;
    let mut v: u64 = 0;
    let mut any = false;
    for &b in &hay[pos + pat.len()..] {
        if b.is_ascii_digit() {
            v = v * 10 + (b - b'0') as u64;
            any = true;
        } else {
            break;
        }
    }
    any.then_some(v)
}

#[inline]
fn field_f32(hay: &[u8], pat: &[u8]) -> Option<f32> {
    let pos = find(hay, pat)?;
    let rest = &hay[pos + pat.len()..];
    let end = rest
        .iter()
        .position(|&b| !(b.is_ascii_digit() || b == b'-' || b == b'.'))
        .unwrap_or(rest.len());
    std::str::from_utf8(&rest[..end]).ok()?.parse().ok()
}

#[inline]
fn find(hay: &[u8], pat: &[u8]) -> Option<usize> {
    hay.windows(pat.len()).position(|w| w == pat)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev() -> SensorEvent {
        SensorEvent {
            ts_micros: 1_714_329_600_123_456,
            sensor_id: 17,
            temp_c: 21.5,
        }
    }

    #[test]
    fn json_roundtrip() {
        let mut buf = Vec::new();
        ev().serialize_into(EventFormat::Json, 64, &mut buf);
        let parsed = SensorEvent::parse(&buf).unwrap();
        assert_eq!(parsed.ts_micros, ev().ts_micros);
        assert_eq!(parsed.sensor_id, 17);
        assert!((parsed.temp_c - 21.5).abs() < 0.005);
    }

    #[test]
    fn csv_roundtrip_at_27_bytes() {
        let e = SensorEvent {
            ts_micros: 1_714_329_600_123_456,
            sensor_id: 3,
            temp_c: -7.25,
        };
        let mut buf = Vec::new();
        let n = e.serialize_into(EventFormat::Csv, 27, &mut buf);
        assert_eq!(n, 27, "csv floor must reach the paper's 27-byte minimum");
        let parsed = SensorEvent::parse(&buf).unwrap();
        assert_eq!(parsed.sensor_id, 3);
        assert!((parsed.temp_c + 7.25).abs() < 0.005);
    }

    #[test]
    fn exact_target_size_json() {
        let mut buf = Vec::new();
        for target in [64usize, 100, 256, 1024] {
            let n = ev().serialize_into(EventFormat::Json, target, &mut buf);
            assert_eq!(n, target, "target={target}");
            assert!(SensorEvent::parse(&buf).is_some());
        }
    }

    #[test]
    fn exact_target_size_csv() {
        let mut buf = Vec::new();
        for target in [27usize, 32, 64, 512] {
            let n = ev().serialize_into(EventFormat::Csv, target, &mut buf);
            assert_eq!(n, target);
            assert!(SensorEvent::parse(&buf).is_some());
        }
    }

    #[test]
    fn undersized_target_keeps_base_encoding() {
        let mut buf = Vec::new();
        let n = ev().serialize_into(EventFormat::Json, 10, &mut buf);
        assert!(n > 10, "cannot shrink below the natural encoding");
        assert!(SensorEvent::parse(&buf).is_some());
    }

    #[test]
    fn negative_and_zero_temps() {
        for t in [-40.0f32, -0.004, 0.0, 0.005, 99.99] {
            let e = SensorEvent {
                ts_micros: 1,
                sensor_id: 0,
                temp_c: t,
            };
            let mut buf = Vec::new();
            e.serialize_into(EventFormat::Json, 48, &mut buf);
            let p = SensorEvent::parse(&buf).unwrap();
            assert!((p.temp_c - t).abs() < 0.006, "t={t} p={}", p.temp_c);
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(SensorEvent::parse(b"{\"nope\":1}").is_none());
        assert!(SensorEvent::parse(b"not,an").is_none());
        assert!(SensorEvent::parse(b"").is_none());
    }

    #[test]
    fn event_serializer_matches_serialize_into() {
        // The cached-prefix serializer must be bit-identical, across ts
        // changes and both formats.
        for format in [EventFormat::Csv, EventFormat::Json] {
            for target in [27usize, 64, 200] {
                let mut cached = EventSerializer::new(format, target);
                let (mut a, mut b) = (Vec::new(), Vec::new());
                for i in 0..50u64 {
                    let e = SensorEvent {
                        ts_micros: 1_700_000_000_000_000 + (i / 7), // repeats
                        sensor_id: (i * 13 % 1024) as u32,
                        temp_c: i as f32 * 3.3 - 40.0,
                    };
                    e.serialize_into(format, target, &mut a);
                    cached.serialize(&e, &mut b);
                    assert_eq!(a, b, "format={format:?} target={target} i={i}");
                }
            }
        }
    }

    #[test]
    fn temp_two_decimals_on_wire() {
        let e = SensorEvent {
            ts_micros: 1,
            sensor_id: 2,
            temp_c: 21.456,
        };
        let mut buf = Vec::new();
        e.serialize_into(EventFormat::Csv, 0, &mut buf);
        let s = String::from_utf8(buf).unwrap();
        assert!(s.ends_with("21.46"), "{s}");
    }
}
