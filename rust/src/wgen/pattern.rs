//! Generation patterns (paper Sec. 3.2): constant, random, burst.
//!
//! A pattern is a schedule of *ticks*: each tick says how many events to
//! emit and how long the tick spans.  The paper defines:
//!
//! * **constant** — fixed frequency;
//! * **random** — variable rate bounded by min/max frequency, with random
//!   pauses bounded by min/max pause;
//! * **burst** — "a special case of the random interval generation, where
//!   the minimum and maximum pauses … are the same, and the data
//!   generation frequency is constant".

use crate::util::rng::{Pcg32, Zipf};

/// Tick granularity: rate control operates on 10ms slices, fine enough
/// that per-second rates look smooth and coarse enough that the schedule
/// itself costs nothing.
pub const TICK_MICROS: u64 = 10_000;

/// Generation pattern parameters (rates are events/second).
#[derive(Clone, Debug, PartialEq)]
pub enum Pattern {
    Constant {
        rate: u64,
    },
    Random {
        min_rate: u64,
        max_rate: u64,
        min_pause_micros: u64,
        max_pause_micros: u64,
    },
    Burst {
        interval_micros: u64,
        burst_rate: u64,
    },
}

impl Pattern {
    /// Build from the workload section for one generator instance emitting
    /// `share` of the total configured load.
    pub fn from_config(w: &crate::config::schema::WorkloadSection, share: u64) -> Pattern {
        use crate::config::schema::Pattern as P;
        match w.pattern {
            P::Constant => Pattern::Constant { rate: share },
            P::Random => Pattern::Random {
                // Scale the bounds by the same instance share ratio.
                min_rate: scale(w.random.min_rate, share, w.rate),
                max_rate: scale(w.random.max_rate, share, w.rate).max(1),
                min_pause_micros: w.random.min_pause_micros,
                max_pause_micros: w.random.max_pause_micros,
            },
            P::Burst => Pattern::Burst {
                interval_micros: w.burst.interval_micros,
                burst_rate: scale(w.burst.burst_rate, share, w.rate).max(1),
            },
        }
    }

    /// Long-run average rate (events/second) this pattern converges to.
    pub fn mean_rate(&self) -> f64 {
        match *self {
            Pattern::Constant { rate } => rate as f64,
            Pattern::Random {
                min_rate,
                max_rate,
                min_pause_micros,
                max_pause_micros,
            } => {
                // Alternates active ticks at uniform(min,max) rate with
                // uniform(min,max) pauses: duty cycle = tick/(tick+pause).
                let mean_rate = (min_rate + max_rate) as f64 / 2.0;
                let mean_pause = (min_pause_micros + max_pause_micros) as f64 / 2.0;
                let duty = TICK_MICROS as f64 / (TICK_MICROS as f64 + mean_pause);
                mean_rate * duty
            }
            Pattern::Burst {
                interval_micros,
                burst_rate,
            } => {
                // One burst tick of TICK_MICROS at burst_rate per interval.
                let events = burst_rate as f64 * TICK_MICROS as f64 / 1e6;
                events / (interval_micros.max(TICK_MICROS) as f64 / 1e6)
            }
        }
    }
}

fn scale(v: u64, share: u64, total: u64) -> u64 {
    if total == 0 {
        return v;
    }
    ((v as u128 * share as u128) / total as u128) as u64
}

/// Sensor-id (key) distribution for generated events: uniform by default,
/// a Zipf tail under `workload.key_skew`, and a concentrated hot set
/// under `workload.hot_keys`/`hot_fraction` — the skewed-key regimes the
/// keyed exchange is benchmarked against (ShuffleBench's hot-key
/// scenarios).  The three compose: `hot_fraction` of the stream hits the
/// hot set uniformly, the remainder follows the Zipf (or uniform) body.
#[derive(Clone, Debug)]
pub struct KeyDist {
    sensors: u32,
    zipf: Option<Zipf>,
    hot_keys: u32,
    hot_fraction: f64,
}

impl KeyDist {
    pub fn new(sensors: u32, key_skew: f64, hot_keys: u32, hot_fraction: f64) -> KeyDist {
        KeyDist {
            sensors: sensors.max(1),
            zipf: (key_skew > 0.0).then(|| Zipf::new(sensors.max(1) as usize, key_skew)),
            hot_keys: hot_keys.min(sensors.max(1)),
            hot_fraction,
        }
    }

    /// Build from the workload section of the master config.
    pub fn from_workload(w: &crate::config::schema::WorkloadSection) -> KeyDist {
        KeyDist::new(w.sensors, w.key_skew, w.hot_keys, w.hot_fraction)
    }

    /// True when any non-uniform mechanism is active.
    pub fn skewed(&self) -> bool {
        self.zipf.is_some() || (self.hot_fraction > 0.0 && self.hot_keys > 0)
    }

    /// Sample one sensor id.
    pub fn sample(&self, rng: &mut Pcg32) -> u32 {
        if self.hot_fraction > 0.0 && self.hot_keys > 0 && rng.f64() < self.hot_fraction {
            return rng.below(self.hot_keys);
        }
        match &self.zipf {
            Some(z) => z.sample(rng) as u32,
            None => rng.below(self.sensors),
        }
    }
}

/// One scheduling step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tick {
    /// Events to emit during this tick.
    pub events: u64,
    /// Tick span in microseconds (emit + any pause).
    pub duration_micros: u64,
}

/// Stateful tick generator for a pattern.
pub struct PatternState {
    pattern: Pattern,
    rng: Pcg32,
    /// Fractional-event carry so integer ticks hit the exact mean rate.
    carry: f64,
    /// For burst: time left until the next burst fires.
    until_burst_micros: u64,
}

impl PatternState {
    pub fn new(pattern: Pattern, rng: Pcg32) -> Self {
        Self {
            pattern,
            rng,
            carry: 0.0,
            until_burst_micros: 0,
        }
    }

    /// Produce the next tick of the schedule.
    pub fn next_tick(&mut self) -> Tick {
        match self.pattern {
            Pattern::Constant { rate } => {
                let want = rate as f64 * TICK_MICROS as f64 / 1e6 + self.carry;
                let events = want.floor() as u64;
                self.carry = want - events as f64;
                Tick {
                    events,
                    duration_micros: TICK_MICROS,
                }
            }
            Pattern::Random {
                min_rate,
                max_rate,
                min_pause_micros,
                max_pause_micros,
            } => {
                let rate = self.rng.range_u64(min_rate, max_rate.max(min_rate));
                let pause = self
                    .rng
                    .range_u64(min_pause_micros, max_pause_micros.max(min_pause_micros));
                let want = rate as f64 * TICK_MICROS as f64 / 1e6 + self.carry;
                let events = want.floor() as u64;
                self.carry = want - events as f64;
                Tick {
                    events,
                    duration_micros: TICK_MICROS + pause,
                }
            }
            Pattern::Burst {
                interval_micros,
                burst_rate,
            } => {
                if self.until_burst_micros >= TICK_MICROS {
                    // Quiet period between bursts.
                    let quiet = self.until_burst_micros;
                    self.until_burst_micros = 0;
                    return Tick {
                        events: 0,
                        duration_micros: quiet,
                    };
                }
                let want = burst_rate as f64 * TICK_MICROS as f64 / 1e6 + self.carry;
                let events = want.floor() as u64;
                self.carry = want - events as f64;
                self.until_burst_micros = interval_micros.saturating_sub(TICK_MICROS);
                Tick {
                    events,
                    duration_micros: TICK_MICROS,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config as PtConfig};

    fn run_for(pattern: Pattern, total_micros: u64) -> (u64, u64) {
        let mut st = PatternState::new(pattern, Pcg32::new(1, 1));
        let mut t = 0;
        let mut events = 0;
        while t < total_micros {
            let tick = st.next_tick();
            events += tick.events;
            t += tick.duration_micros;
        }
        (events, t)
    }

    #[test]
    fn constant_hits_exact_rate() {
        let (events, t) = run_for(Pattern::Constant { rate: 123_456 }, 10_000_000);
        let rate = events as f64 * 1e6 / t as f64;
        assert!((rate - 123_456.0).abs() < 200.0, "rate={rate}");
    }

    #[test]
    fn constant_low_rate_carry_accumulates() {
        // 7 events/sec over 10s must produce ~70 events, not 0.
        let (events, _) = run_for(Pattern::Constant { rate: 7 }, 10_000_000);
        assert!((60..=80).contains(&events), "events={events}");
    }

    #[test]
    fn random_respects_mean_rate_model() {
        let p = Pattern::Random {
            min_rate: 50_000,
            max_rate: 150_000,
            min_pause_micros: 0,
            max_pause_micros: 10_000,
        };
        let expect = p.mean_rate();
        let (events, t) = run_for(p, 20_000_000);
        let rate = events as f64 * 1e6 / t as f64;
        assert!(
            (rate - expect).abs() / expect < 0.10,
            "rate={rate} expect={expect}"
        );
    }

    #[test]
    fn burst_is_quiet_between_bursts() {
        let mut st = PatternState::new(
            Pattern::Burst {
                interval_micros: 1_000_000,
                burst_rate: 1_000_000,
            },
            Pcg32::new(2, 2),
        );
        let first = st.next_tick();
        assert!(first.events > 0);
        let quiet = st.next_tick();
        assert_eq!(quiet.events, 0);
        assert_eq!(quiet.duration_micros, 1_000_000 - TICK_MICROS);
        let second = st.next_tick();
        assert!(second.events > 0);
    }

    #[test]
    fn burst_mean_rate_matches_model() {
        let p = Pattern::Burst {
            interval_micros: 500_000,
            burst_rate: 2_000_000,
        };
        let expect = p.mean_rate();
        let (events, t) = run_for(p, 30_000_000);
        let rate = events as f64 * 1e6 / t as f64;
        assert!(
            (rate - expect).abs() / expect < 0.05,
            "rate={rate} expect={expect}"
        );
    }

    #[test]
    fn prop_constant_rate_conservation() {
        check(PtConfig::default().cases(40), "constant-conservation", |g| {
            let rate = g.u64(1..2_000_000);
            let (events, t) = run_for(Pattern::Constant { rate }, 2_000_000);
            let got = events as f64 * 1e6 / t as f64;
            let tol = (rate as f64 * 0.01).max(60.0);
            if (got - rate as f64).abs() > tol {
                return Err(format!("rate {rate}: got {got}"));
            }
            Ok(())
        });
    }

    #[test]
    fn key_dist_uniform_covers_the_keyspace() {
        let d = KeyDist::new(64, 0.0, 0, 0.0);
        assert!(!d.skewed());
        let mut rng = Pcg32::new(7, 7);
        let mut counts = [0u64; 64];
        for _ in 0..64_000 {
            counts[d.sample(&mut rng) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 500), "uniform draw: {counts:?}");
    }

    #[test]
    fn key_dist_hot_set_concentrates_traffic() {
        // Half the stream on 4 hot keys over a 256-key space.
        let d = KeyDist::new(256, 0.0, 4, 0.5);
        assert!(d.skewed());
        let mut rng = Pcg32::new(9, 9);
        let mut hot = 0u64;
        let n = 100_000;
        for _ in 0..n {
            if d.sample(&mut rng) < 4 {
                hot += 1;
            }
        }
        // hot_fraction 0.5 + the uniform body's 4/256 sliver.
        let frac = hot as f64 / n as f64;
        assert!((0.45..0.60).contains(&frac), "hot fraction {frac}");
    }

    #[test]
    fn key_dist_zipf_and_hot_set_compose() {
        let d = KeyDist::new(256, 1.2, 8, 0.25);
        let mut rng = Pcg32::new(11, 11);
        let mut counts = vec![0u64; 256];
        for _ in 0..100_000 {
            counts[d.sample(&mut rng) as usize] += 1;
        }
        let head: u64 = counts[..8].iter().sum();
        let tail: u64 = counts[248..].iter().sum();
        assert!(head > tail * 5, "head {head} vs tail {tail}");
    }

    #[test]
    fn from_config_scales_share() {
        let w = crate::config::BenchConfig::default().workload;
        // Default rate 100K; an instance carrying half the load.
        let p = Pattern::from_config(&w, 50_000);
        assert_eq!(p, Pattern::Constant { rate: 50_000 });
    }
}
