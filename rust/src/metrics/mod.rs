//! Metric collection (paper Sec. 3.4).
//!
//! Throughput and latency are measured at several locations along the
//! pipeline (paper Fig. 5) so bottlenecks can be localised; process
//! metrics (GC, heap) come from [`crate::jvm`], system metrics (CPU,
//! membw, energy) from [`crate::sysmon`].  Everything lands in a central
//! [`store::MetricStore`] which post-processing aggregates.
//!
//! * [`point`] — the measurement points along the pipeline.
//! * [`recorder`] — lock-cheap throughput counters + latency histograms.
//! * [`store`] — central time-series storage with CSV/JSON export; the
//!   max-capacity sustainability predicate ([`crate::experiment`]) reads
//!   its latency timeline to detect drift.

pub mod point;
pub mod recorder;
pub mod store;

pub use point::MeasurementPoint;
pub use recorder::{LatencyRecorder, ThroughputRecorder, ThroughputSnapshot};
pub use store::{MetricStore, Series};
