//! Central metric storage (paper Fig. 5: "the monitoring layer transmits
//! all metrics to a central storage").
//!
//! A [`MetricStore`] holds named time series of `(t_micros, value)` points
//! appended by the samplers (throughput/latency interval sampler, JMX, Pika,
//! MetricQ equivalents).  Post-processing reads it back, aggregates, and
//! exports CSV/JSON for the report generators.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;

/// One named time series.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Series {
    pub points: Vec<(u64, f64)>,
}

impl Series {
    pub fn push(&mut self, t_micros: u64, value: f64) {
        self.points.push((t_micros, value));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn values(&self) -> impl Iterator<Item = f64> + '_ {
        self.points.iter().map(|&(_, v)| v)
    }

    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.values().sum::<f64>() / self.points.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.values().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn last(&self) -> Option<(u64, f64)> {
        self.points.last().copied()
    }

    /// Restrict to `t >= from` (drop warmup samples).
    pub fn after(&self, from_micros: u64) -> Series {
        Series {
            points: self
                .points
                .iter()
                .copied()
                .filter(|&(t, _)| t >= from_micros)
                .collect(),
        }
    }

    /// Normalize timestamps to [0,1] over the series span (Fig. 8's
    /// "normalized runtime" x-axis).
    pub fn normalized(&self) -> Vec<(f64, f64)> {
        if self.points.is_empty() {
            return vec![];
        }
        let t0 = self.points.first().expect("nonempty").0 as f64;
        let t1 = self.points.last().expect("nonempty").0 as f64;
        let span = (t1 - t0).max(1.0);
        self.points
            .iter()
            .map(|&(t, v)| ((t as f64 - t0) / span, v))
            .collect()
    }
}

/// Thread-safe map of named series.
#[derive(Default)]
pub struct MetricStore {
    series: Mutex<BTreeMap<String, Series>>,
}

impl MetricStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn append(&self, name: &str, t_micros: u64, value: f64) {
        let mut m = self.series.lock().expect("metric store");
        m.entry(name.to_string()).or_default().push(t_micros, value);
    }

    pub fn get(&self, name: &str) -> Option<Series> {
        self.series.lock().expect("metric store").get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        self.series.lock().expect("metric store").keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.series.lock().expect("metric store").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Export every series as JSON: `{name: [[t, v], ...], ...}`.
    pub fn to_json(&self) -> Json {
        let m = self.series.lock().expect("metric store");
        let mut obj = Json::obj();
        for (name, series) in m.iter() {
            let arr = series
                .points
                .iter()
                .map(|&(t, v)| Json::Arr(vec![Json::Int(t as i64), Json::Num(v)]))
                .collect();
            obj.set(name, Json::Arr(arr));
        }
        obj
    }

    /// Export one series as CSV (`t_micros,value` lines with header).
    pub fn to_csv(&self, name: &str) -> Option<String> {
        let s = self.get(name)?;
        let mut out = String::from("t_micros,value\n");
        for (t, v) in &s.points {
            out.push_str(&format!("{t},{v}\n"));
        }
        Some(out)
    }

    /// Export all series into a wide CSV keyed by sample index (for series
    /// with aligned sampling intervals, e.g. the Fig. 8 timeline).
    pub fn to_wide_csv(&self, names: &[&str]) -> String {
        let m = self.series.lock().expect("metric store");
        let cols: Vec<&Series> = names.iter().filter_map(|n| m.get(*n)).collect();
        let rows = cols.iter().map(|s| s.len()).max().unwrap_or(0);
        let mut out = String::from("idx");
        for n in names {
            out.push(',');
            out.push_str(n);
        }
        out.push('\n');
        for i in 0..rows {
            out.push_str(&i.to_string());
            for c in &cols {
                out.push(',');
                match c.points.get(i) {
                    Some((_, v)) => out.push_str(&format!("{v}")),
                    None => out.push_str(""),
                }
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_read_back() {
        let store = MetricStore::new();
        store.append("throughput.broker_in", 0, 100.0);
        store.append("throughput.broker_in", 1_000_000, 200.0);
        let s = store.get("throughput.broker_in").unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.mean(), 150.0);
        assert_eq!(s.max(), 200.0);
        assert_eq!(s.last(), Some((1_000_000, 200.0)));
    }

    #[test]
    fn after_drops_warmup() {
        let store = MetricStore::new();
        for t in 0..10u64 {
            store.append("x", t * 1_000_000, t as f64);
        }
        let s = store.get("x").unwrap().after(5_000_000);
        assert_eq!(s.len(), 5);
        assert_eq!(s.points[0].1, 5.0);
    }

    #[test]
    fn normalized_runtime_spans_unit_interval() {
        let store = MetricStore::new();
        for t in [10u64, 20, 30, 40] {
            store.append("n", t, t as f64);
        }
        let n = store.get("n").unwrap().normalized();
        assert_eq!(n.first().unwrap().0, 0.0);
        assert_eq!(n.last().unwrap().0, 1.0);
    }

    #[test]
    fn json_export_roundtrips() {
        let store = MetricStore::new();
        store.append("a", 1, 2.5);
        store.append("b", 2, 3.0);
        let j = store.to_json();
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.get("a").unwrap().as_arr().unwrap()[0].as_arr().unwrap()[1].as_f64(),
            Some(2.5)
        );
    }

    #[test]
    fn csv_export() {
        let store = MetricStore::new();
        store.append("lat", 1000, 42.0);
        let csv = store.to_csv("lat").unwrap();
        assert!(csv.starts_with("t_micros,value\n"));
        assert!(csv.contains("1000,42"));
        assert!(store.to_csv("missing").is_none());
    }

    #[test]
    fn wide_csv_handles_ragged_series() {
        let store = MetricStore::new();
        store.append("a", 0, 1.0);
        store.append("a", 1, 2.0);
        store.append("b", 0, 9.0);
        let csv = store.to_wide_csv(&["a", "b"]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "idx,a,b");
        assert_eq!(lines[1], "0,1,9");
        assert_eq!(lines[2], "1,2,");
    }
}
