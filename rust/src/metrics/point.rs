//! Measurement points along the processing pipeline (paper Fig. 5).
//!
//! Latencies measured at different locations decompose the end-to-end
//! latency into benchmark-driver, broker, and processing components,
//! "which in turn facilitates the identification of bottlenecks in each
//! pipeline" (Sec. 3.4).

/// Where along the pipeline a throughput/latency sample was taken.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MeasurementPoint {
    /// Generator output (offered load).
    DriverOut,
    /// Ingestion broker append (producer → broker).
    BrokerIn,
    /// Engine source operator (broker → engine).
    ProcIn,
    /// Engine sink operator (engine → broker).
    ProcOut,
    /// Egestion broker append (processed stream received).
    BrokerOut,
    /// Full path: generation timestamp → egestion append.
    EndToEnd,
}

impl MeasurementPoint {
    pub const ALL: [MeasurementPoint; 6] = [
        MeasurementPoint::DriverOut,
        MeasurementPoint::BrokerIn,
        MeasurementPoint::ProcIn,
        MeasurementPoint::ProcOut,
        MeasurementPoint::BrokerOut,
        MeasurementPoint::EndToEnd,
    ];

    pub fn name(self) -> &'static str {
        match self {
            MeasurementPoint::DriverOut => "driver_out",
            MeasurementPoint::BrokerIn => "broker_in",
            MeasurementPoint::ProcIn => "proc_in",
            MeasurementPoint::ProcOut => "proc_out",
            MeasurementPoint::BrokerOut => "broker_out",
            MeasurementPoint::EndToEnd => "end_to_end",
        }
    }

    pub fn index(self) -> usize {
        match self {
            MeasurementPoint::DriverOut => 0,
            MeasurementPoint::BrokerIn => 1,
            MeasurementPoint::ProcIn => 2,
            MeasurementPoint::ProcOut => 3,
            MeasurementPoint::BrokerOut => 4,
            MeasurementPoint::EndToEnd => 5,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_are_dense_and_unique() {
        let mut seen = [false; 6];
        for p in MeasurementPoint::ALL {
            assert!(!seen[p.index()]);
            seen[p.index()] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn names_are_unique() {
        let mut names: Vec<_> = MeasurementPoint::ALL.iter().map(|p| p.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 6);
    }
}
