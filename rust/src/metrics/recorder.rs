//! Hot-path metric recorders.
//!
//! Throughput: per-point atomic event/byte counters — `record_events` is a
//! pair of relaxed fetch-adds, cheap enough for the per-batch path.
//! Latency: per-point sharded histograms (one shard per recording thread
//! bucket) merged at snapshot time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::point::MeasurementPoint;
use crate::util::histogram::{Histogram, HistogramSummary};

const POINTS: usize = 6;
/// Latency shards per point; threads hash into shards to avoid contention.
const SHARDS: usize = 8;

/// Monotonic event/byte counters for every measurement point.
#[derive(Default)]
pub struct ThroughputRecorder {
    events: [AtomicU64; POINTS],
    bytes: [AtomicU64; POINTS],
}

/// A point-in-time view of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ThroughputSnapshot {
    pub events: [u64; POINTS],
    pub bytes: [u64; POINTS],
}

impl ThroughputRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn record_events(&self, point: MeasurementPoint, events: u64, bytes: u64) {
        self.events[point.index()].fetch_add(events, Ordering::Relaxed);
        self.bytes[point.index()].fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ThroughputSnapshot {
        let mut s = ThroughputSnapshot::default();
        for i in 0..POINTS {
            s.events[i] = self.events[i].load(Ordering::Relaxed);
            s.bytes[i] = self.bytes[i].load(Ordering::Relaxed);
        }
        s
    }

    pub fn events_at(&self, point: MeasurementPoint) -> u64 {
        self.events[point.index()].load(Ordering::Relaxed)
    }

    pub fn bytes_at(&self, point: MeasurementPoint) -> u64 {
        self.bytes[point.index()].load(Ordering::Relaxed)
    }
}

impl ThroughputSnapshot {
    /// Events/sec between two snapshots `dt_micros` apart.
    pub fn rate_events(&self, earlier: &ThroughputSnapshot, point: MeasurementPoint, dt_micros: u64) -> f64 {
        if dt_micros == 0 {
            return 0.0;
        }
        let d = self.events[point.index()].saturating_sub(earlier.events[point.index()]);
        d as f64 * 1e6 / dt_micros as f64
    }

    /// Bytes/sec between two snapshots.
    pub fn rate_bytes(&self, earlier: &ThroughputSnapshot, point: MeasurementPoint, dt_micros: u64) -> f64 {
        if dt_micros == 0 {
            return 0.0;
        }
        let d = self.bytes[point.index()].saturating_sub(earlier.bytes[point.index()]);
        d as f64 * 1e6 / dt_micros as f64
    }
}

/// Sharded latency histograms per measurement point (microseconds).
pub struct LatencyRecorder {
    shards: Vec<Mutex<Histogram>>, // POINTS * SHARDS
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self {
            shards: (0..POINTS * SHARDS).map(|_| Mutex::new(Histogram::new())).collect(),
        }
    }

    /// Record one latency sample. `shard_hint` (e.g. task index) spreads
    /// threads across shards; any value works.
    #[inline]
    pub fn record(&self, point: MeasurementPoint, shard_hint: usize, micros: u64) {
        let idx = point.index() * SHARDS + (shard_hint % SHARDS);
        self.shards[idx].lock().expect("latency shard").record(micros);
    }

    /// Record `n` samples of the same value (batch completion).
    #[inline]
    pub fn record_n(&self, point: MeasurementPoint, shard_hint: usize, micros: u64, n: u64) {
        let idx = point.index() * SHARDS + (shard_hint % SHARDS);
        self.shards[idx].lock().expect("latency shard").record_n(micros, n);
    }

    /// Record `(micros, count)` groups under a single lock acquisition —
    /// the batch-first hot path: every record in a [`RecordBatch`] shares
    /// one append stamp, so a poll's latency collapses to one group per
    /// batch instead of one sample per event.
    ///
    /// [`RecordBatch`]: crate::broker::RecordBatch
    pub fn record_groups(
        &self,
        point: MeasurementPoint,
        shard_hint: usize,
        groups: impl Iterator<Item = (u64, u64)>,
    ) {
        let idx = point.index() * SHARDS + (shard_hint % SHARDS);
        let mut h = self.shards[idx].lock().expect("latency shard");
        for (micros, n) in groups {
            h.record_n(micros, n);
        }
    }

    /// Record many distinct samples under a single lock acquisition
    /// (per-event latencies of one processed batch).
    pub fn record_batch(
        &self,
        point: MeasurementPoint,
        shard_hint: usize,
        samples: impl Iterator<Item = u64>,
    ) {
        let idx = point.index() * SHARDS + (shard_hint % SHARDS);
        let mut h = self.shards[idx].lock().expect("latency shard");
        for s in samples {
            h.record(s);
        }
    }

    /// Merge all shards of a point into one histogram.
    pub fn merged(&self, point: MeasurementPoint) -> Histogram {
        let mut out = Histogram::new();
        for s in 0..SHARDS {
            let shard = self.shards[point.index() * SHARDS + s].lock().expect("latency shard");
            out.merge(&shard);
        }
        out
    }

    pub fn summary(&self, point: MeasurementPoint) -> HistogramSummary {
        self.merged(point).summary()
    }

    /// Drain-and-reset: returns the merged histogram and clears all shards
    /// (used for per-interval timeline sampling in Fig. 8).
    pub fn drain(&self, point: MeasurementPoint) -> Histogram {
        let mut out = Histogram::new();
        for s in 0..SHARDS {
            let mut shard = self.shards[point.index() * SHARDS + s].lock().expect("latency shard");
            out.merge(&shard);
            shard.reset();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn throughput_rates() {
        let r = ThroughputRecorder::new();
        let t0 = r.snapshot();
        r.record_events(MeasurementPoint::BrokerIn, 1000, 27_000);
        let t1 = r.snapshot();
        let ev = t1.rate_events(&t0, MeasurementPoint::BrokerIn, 1_000_000);
        let by = t1.rate_bytes(&t0, MeasurementPoint::BrokerIn, 1_000_000);
        assert_eq!(ev, 1000.0);
        assert_eq!(by, 27_000.0);
        // Other points untouched.
        assert_eq!(t1.rate_events(&t0, MeasurementPoint::ProcIn, 1_000_000), 0.0);
    }

    #[test]
    fn zero_dt_is_zero_rate() {
        let r = ThroughputRecorder::new();
        let s = r.snapshot();
        assert_eq!(s.rate_events(&s, MeasurementPoint::BrokerIn, 0), 0.0);
    }

    #[test]
    fn latency_merge_across_shards() {
        let r = LatencyRecorder::new();
        for shard in 0..16 {
            r.record(MeasurementPoint::EndToEnd, shard, 100 * (shard as u64 + 1));
        }
        let h = r.merged(MeasurementPoint::EndToEnd);
        assert_eq!(h.count(), 16);
        assert!(h.max() >= 1500);
    }

    #[test]
    fn record_groups_bulk_records_per_batch_stamps() {
        let r = LatencyRecorder::new();
        // Three polled batches: (latency, record count) per batch.
        r.record_groups(
            MeasurementPoint::ProcIn,
            3,
            [(100u64, 512u64), (250, 512), (400, 76)].into_iter(),
        );
        let h = r.merged(MeasurementPoint::ProcIn);
        assert_eq!(h.count(), 1100);
        assert!(h.max() >= 400);
    }

    #[test]
    fn drain_resets() {
        let r = LatencyRecorder::new();
        r.record(MeasurementPoint::ProcIn, 0, 50);
        assert_eq!(r.drain(MeasurementPoint::ProcIn).count(), 1);
        assert_eq!(r.merged(MeasurementPoint::ProcIn).count(), 0);
    }

    #[test]
    fn concurrent_recording_is_lossless() {
        let r = Arc::new(ThroughputRecorder::new());
        let lat = Arc::new(LatencyRecorder::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let r = r.clone();
                let lat = lat.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        r.record_events(MeasurementPoint::DriverOut, 1, 27);
                        if i % 100 == 0 {
                            lat.record(MeasurementPoint::DriverOut, t, i);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.events_at(MeasurementPoint::DriverOut), 80_000);
        assert_eq!(r.bytes_at(MeasurementPoint::DriverOut), 80_000 * 27);
        assert_eq!(lat.merged(MeasurementPoint::DriverOut).count(), 800);
    }
}
