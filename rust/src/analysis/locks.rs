//! Pass `locks` — static lock-order audit.
//!
//! Builds an acquisition graph over the concurrency-bearing files
//! ([`SCOPE`]): nodes are lock classes (`Mutex` fields, named
//! `<file>.<field>`, plus the `util::chan` internal queue lock as
//! `chan.queue`), and an edge `A → B` is recorded whenever `B` is
//! acquired while a guard of `A` is statically held.  Cycles in that
//! graph are the classic deadlock recipe and fail the run, as does the
//! sharper local hazard: a *blocking* channel op (`send`/`recv`) under
//! a held `Mutex` guard — the parked thread keeps the lock, and
//! whoever must wake it may need that lock (exactly the invariant "no
//! sender ever parks while holding engine state" the exchange fabric
//! relies on).
//!
//! Guard liveness is approximated lexically: a `let`-bound (or
//! `match`/`for`-scrutinee) guard is held to the end of its enclosing
//! block, an un-bound temporary only for its own statement, a chain
//! that projects a value out of the guard
//! (`….lock()….is_some()`) binds the value and not the guard, and
//! `drop(guard)` releases early.  Condvar `wait(guard)` atomically
//! releases, so it is deliberately not an acquisition.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::{Finding, SourceFile, Workspace};

const PASS: &str = "locks";

/// Files whose lock sites enter the graph.
const SCOPE: &[&str] = &[
    "rust/src/util/chan.rs",
    "rust/src/engine/exchange.rs",
    "rust/src/engine/supervisor.rs",
    "rust/src/net/transport.rs",
    "rust/src/coordinator/mod.rs",
];

/// Channel ops that can park the calling thread.
const BLOCKING_OPS: &[&str] = &[".send(", ".recv(", ".recv_timeout("];
/// Channel ops that take the queue lock but never park.
const MOMENTARY_OPS: &[&str] = &[".try_send(", ".drain_into(", ".close("];

/// The class every `util::chan` operation acquires.
const CHAN_CLASS: &str = "chan.queue";

struct Guard {
    class: String,
    var: Option<String>,
    depth: usize,
}

#[derive(Default)]
struct Graph {
    /// edge → first provenance (file, line).
    edges: BTreeMap<(String, String), (String, usize)>,
    classes: BTreeSet<String>,
    sites: usize,
}

impl Graph {
    fn add_edge(&mut self, from: &str, to: &str, file: &str, line: usize) {
        self.edges
            .entry((from.to_string(), to.to_string()))
            .or_insert((file.to_string(), line));
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Class key for a file: stem, or the parent directory for `mod.rs`.
fn file_key(rel: &str) -> String {
    let mut parts = rel.rsplit('/');
    let stem = parts
        .next()
        .unwrap_or(rel)
        .trim_end_matches(".rs")
        .to_string();
    if stem == "mod" {
        parts.next().unwrap_or("mod").to_string()
    } else {
        stem
    }
}

/// Last path segment of the receiver ending just before `dot_at`
/// (e.g. `self.inner.queue` → `queue`).  Multi-line method chains
/// (`shared\n.error\n.lock()`) are followed through the whitespace.
fn receiver_field(code: &str, dot_at: usize) -> String {
    let bytes = code.as_bytes();
    let chain = |b: u8| is_ident(b) || b == b'.' || b == b':';
    let mut start = dot_at;
    while start > 0 {
        let b = bytes[start - 1];
        if chain(b) {
            start -= 1;
        } else if (b as char).is_whitespace() {
            // Step over the whitespace run only if it splices two
            // pieces of the same chain.
            let mut k = start - 1;
            while k > 0 && (bytes[k - 1] as char).is_whitespace() {
                k -= 1;
            }
            if k > 0 && chain(bytes[k - 1]) {
                start = k;
            } else {
                break;
            }
        } else {
            break;
        }
    }
    let path: String = code[start..dot_at]
        .chars()
        .filter(|c| !c.is_whitespace())
        .collect();
    let path = path.replace("::", ".");
    path.rsplit('.')
        .find(|s| !s.is_empty())
        .unwrap_or("unknown")
        .to_string()
}

/// If an adapter that still denotes the guard (`.unwrap()`,
/// `.expect(…)`, `.unwrap_or_else(…)`) starts at `at`, return the
/// offset just past it.
fn adapter_end(code: &str, at: usize) -> Option<usize> {
    let rest = &code[at..];
    if rest.starts_with(".unwrap()") {
        return Some(at + ".unwrap()".len());
    }
    for pat in [".expect(", ".unwrap_or_else("] {
        if rest.starts_with(pat) {
            let bytes = code.as_bytes();
            let mut depth = 0usize;
            let mut k = at + pat.len() - 1;
            while k < bytes.len() {
                match bytes[k] {
                    b'(' => depth += 1,
                    b')' => {
                        depth -= 1;
                        if depth == 0 {
                            return Some(k + 1);
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            return Some(bytes.len());
        }
    }
    None
}

/// Does the chain continue past the guard with a *projection*
/// (`.is_some()`, `.len()`, indexing)?  Then the statement binds the
/// projected value, the guard itself is a temporary that dies at the
/// end of the statement — not a held lock.
fn projects_past_guard(code: &str, mut i: usize) -> bool {
    let bytes = code.as_bytes();
    loop {
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        match adapter_end(code, i) {
            Some(end) => i = end,
            None => break,
        }
    }
    i < bytes.len() && (bytes[i] == b'.' || bytes[i] == b'[')
}

/// Text from the start of the current statement to `at` (for binding
/// detection): everything after the nearest `;`, `{` or `}`.
fn statement_prefix(code: &str, at: usize) -> &str {
    let bytes = code.as_bytes();
    let mut start = at;
    while start > 0 {
        match bytes[start - 1] {
            b';' | b'{' | b'}' => break,
            _ => start -= 1,
        }
    }
    &code[start..at]
}

/// If the statement binds its value (`let g = …`, `match …`, `for …`),
/// return the bound variable name when it is a simple `let` ident.
fn binding_of(prefix: &str) -> Option<Option<String>> {
    let has = |kw: &str| {
        let mut from = 0;
        while let Some(pos) = prefix[from..].find(kw) {
            let at = from + pos;
            let left_ok = at == 0 || !is_ident(prefix.as_bytes()[at - 1]);
            if left_ok {
                return Some(at);
            }
            from = at + 1;
        }
        None
    };
    if let Some(at) = has("let ") {
        let rest = prefix[at + 4..].trim_start();
        let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
        let name: String = rest
            .bytes()
            .take_while(|&b| is_ident(b))
            .map(|b| b as char)
            .collect();
        let var = if name.is_empty() { None } else { Some(name) };
        return Some(var);
    }
    if has("match ").is_some() || has("for ").is_some() || has("while ").is_some() {
        return Some(None);
    }
    None
}

/// Walk one file, adding acquisition edges and emitting
/// blocking-op-under-lock findings.
fn walk(file: &SourceFile, graph: &mut Graph, findings: &mut Vec<Finding>) {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let key = file_key(&file.rel);
    let mut held: Vec<Guard> = Vec::new();
    let mut depth: usize = 0;
    let mut i = 0;

    while i < bytes.len() {
        // Skip #[cfg(test)] regions wholesale.
        if let Some(end) = file
            .test_ranges
            .iter()
            .find(|&&(s, e)| i >= s && i < e)
            .map(|&(_, e)| e)
        {
            i = end;
            continue;
        }
        match bytes[i] {
            b'{' => {
                depth += 1;
                i += 1;
                continue;
            }
            b'}' => {
                depth = depth.saturating_sub(1);
                held.retain(|g| g.depth <= depth);
                i += 1;
                continue;
            }
            _ => {}
        }

        // Early release: drop(guard).
        if code[i..].starts_with("drop(") && (i == 0 || !is_ident(bytes[i - 1])) {
            let arg: String = code[i + 5..]
                .bytes()
                .take_while(|&b| is_ident(b))
                .map(|b| b as char)
                .collect();
            if let Some(pos) = held
                .iter()
                .rposition(|g| g.var.as_deref() == Some(arg.as_str()))
            {
                held.remove(pos);
            }
            i += 5;
            continue;
        }

        // Mutex acquisition.
        if code[i..].starts_with(".lock()") {
            let class = format!("{key}.{}", receiver_field(code, i));
            let line = file.scan.line_of(i);
            graph.classes.insert(class.clone());
            graph.sites += 1;
            for g in &held {
                graph.add_edge(&g.class, &class, &file.rel, line);
            }
            // A temporary (no binding) is released at end of statement
            // and never pushed; likewise when the chain projects a
            // value out of the guard (`….lock()….is_some()`).
            if !projects_past_guard(code, i + ".lock()".len()) {
                if let Some(var) = binding_of(statement_prefix(code, i)) {
                    held.push(Guard { class, var, depth });
                }
            }
            i += ".lock()".len();
            continue;
        }

        // util::chan operations.
        let mut matched = false;
        for &op in BLOCKING_OPS.iter().chain(MOMENTARY_OPS) {
            if code[i..].starts_with(op) {
                let line = file.scan.line_of(i);
                graph.classes.insert(CHAN_CLASS.to_string());
                graph.sites += 1;
                for g in &held {
                    graph.add_edge(&g.class, CHAN_CLASS, &file.rel, line);
                }
                if BLOCKING_OPS.contains(&op) && !held.is_empty() {
                    let holding: Vec<&str> =
                        held.iter().map(|g| g.class.as_str()).collect();
                    findings.push(Finding::error(
                        PASS,
                        &file.rel,
                        line,
                        format!(
                            "blocking channel op `{}` while holding lock guard(s) \
                             [{}] — a parked thread keeps the lock and risks \
                             deadlock with whoever must wake it",
                            op.trim_start_matches('.').trim_end_matches('('),
                            holding.join(", ")
                        ),
                    ));
                }
                i += op.len();
                matched = true;
                break;
            }
        }
        if matched {
            continue;
        }

        i += 1;
    }
}

/// Strongly connected components of the acquisition graph (Tarjan).
/// A deadlock-capable cycle exists iff some SCC has more than one node
/// (self-edges are reported separately), so SCC detection is exact
/// where naive cycle enumeration can miss cycles.
fn sccs(adj: &BTreeMap<String, BTreeSet<String>>) -> Vec<Vec<String>> {
    struct Tarjan<'a> {
        adj: &'a BTreeMap<String, BTreeSet<String>>,
        next_index: usize,
        index: BTreeMap<String, usize>,
        low: BTreeMap<String, usize>,
        stack: Vec<String>,
        on_stack: BTreeSet<String>,
        out: Vec<Vec<String>>,
    }
    fn strong(t: &mut Tarjan<'_>, v: &str) {
        t.index.insert(v.to_string(), t.next_index);
        t.low.insert(v.to_string(), t.next_index);
        t.next_index += 1;
        t.stack.push(v.to_string());
        t.on_stack.insert(v.to_string());
        let nexts: Vec<String> = t
            .adj
            .get(v)
            .map(|s| s.iter().cloned().collect())
            .unwrap_or_default();
        for w in nexts {
            if !t.index.contains_key(&w) {
                strong(t, &w);
                let low_w = t.low.get(&w).copied().unwrap_or(usize::MAX);
                let low_v = t.low.get(v).copied().unwrap_or(usize::MAX);
                if low_w < low_v {
                    t.low.insert(v.to_string(), low_w);
                }
            } else if t.on_stack.contains(&w) {
                let idx_w = t.index.get(&w).copied().unwrap_or(usize::MAX);
                let low_v = t.low.get(v).copied().unwrap_or(usize::MAX);
                if idx_w < low_v {
                    t.low.insert(v.to_string(), idx_w);
                }
            }
        }
        if t.low.get(v) == t.index.get(v) {
            let mut comp = Vec::new();
            while let Some(w) = t.stack.pop() {
                t.on_stack.remove(&w);
                let done = w == v;
                comp.push(w);
                if done {
                    break;
                }
            }
            comp.sort();
            t.out.push(comp);
        }
    }

    let mut nodes: BTreeSet<String> = adj.keys().cloned().collect();
    for targets in adj.values() {
        nodes.extend(targets.iter().cloned());
    }
    let mut t = Tarjan {
        adj,
        next_index: 0,
        index: BTreeMap::new(),
        low: BTreeMap::new(),
        stack: Vec::new(),
        on_stack: BTreeSet::new(),
        out: Vec::new(),
    };
    for n in &nodes {
        if !t.index.contains_key(n) {
            strong(&mut t, n);
        }
    }
    t.out.into_iter().filter(|c| c.len() > 1).collect()
}

pub fn run(ws: &Workspace) -> Vec<Finding> {
    let mut graph = Graph::default();
    let mut findings = Vec::new();
    for file in &ws.src {
        if SCOPE.contains(&file.rel.as_str()) {
            walk(file, &mut graph, &mut findings);
        }
    }

    let mut adj: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for (from, to) in graph.edges.keys() {
        adj.entry(from.clone()).or_default().insert(to.clone());
    }

    for ((from, to), (file, line)) in &graph.edges {
        if from == to {
            findings.push(Finding::error(
                PASS,
                file,
                *line,
                format!(
                    "re-entrant acquisition: lock class `{from}` acquired while \
                     already held — std::sync::Mutex self-deadlocks"
                ),
            ));
        }
    }

    for component in sccs(&adj) {
        // Every edge internal to the component is part of some cycle:
        // list them all with provenance.
        let legs: Vec<String> = graph
            .edges
            .iter()
            .filter(|((from, to), _)| component.contains(from) && component.contains(to))
            .map(|((from, to), (f, l))| format!("{from} → {to} ({f}:{l})"))
            .collect();
        let (file, line) = graph
            .edges
            .iter()
            .find(|((from, to), _)| component.contains(from) && component.contains(to))
            .map(|(_, (f, l))| (f.clone(), *l))
            .unwrap_or((String::new(), 0));
        findings.push(Finding::error(
            PASS,
            &file,
            line,
            format!(
                "lock-order cycle among [{}]: {} — two threads taking these locks \
                 in opposite order deadlock",
                component.join(", "),
                legs.join(", ")
            ),
        ));
    }

    for ((from, to), (file, line)) in &graph.edges {
        findings.push(Finding::note(
            PASS,
            file,
            *line,
            format!("acquisition edge: {from} → {to}"),
        ));
    }
    findings.push(Finding::note(
        PASS,
        "rust/src",
        0,
        format!(
            "{} lock class(es), {} acquisition site(s), {} edge(s) across {} scoped file(s)",
            graph.classes.len(),
            graph.sites,
            graph.edges.len(),
            SCOPE.len()
        ),
    ));
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{find_test_ranges, lexer};

    fn file(rel: &str, src: &str) -> SourceFile {
        let scan = lexer::scan(src);
        let test_ranges = find_test_ranges(&scan.code);
        SourceFile {
            rel: rel.to_string(),
            scan,
            test_ranges,
        }
    }

    fn run_on(files: &[(&str, &str)]) -> (Graph, Vec<Finding>) {
        let mut graph = Graph::default();
        let mut findings = Vec::new();
        for (rel, src) in files {
            walk(&file(rel, src), &mut graph, &mut findings);
        }
        (graph, findings)
    }

    #[test]
    fn nested_acquisition_makes_an_edge() {
        let (graph, _) = run_on(&[(
            "rust/src/util/chan.rs",
            "fn f(&self) { let g = self.a.lock().expect(\"p\"); \
             self.b.lock().expect(\"p\").push(1); }",
        )]);
        assert!(graph
            .edges
            .contains_key(&("chan.a".to_string(), "chan.b".to_string())));
    }

    #[test]
    fn temporary_guard_does_not_stay_held() {
        let (graph, _) = run_on(&[(
            "rust/src/util/chan.rs",
            "fn f(&self) { self.a.lock().expect(\"p\").push(1); \
             self.b.lock().expect(\"p\").push(2); }",
        )]);
        assert!(graph.edges.is_empty());
    }

    #[test]
    fn drop_releases_guard() {
        let (graph, _) = run_on(&[(
            "rust/src/util/chan.rs",
            "fn f(&self) { let st = self.a.lock().expect(\"p\"); drop(st); \
             self.b.lock().expect(\"p\").push(1); }",
        )]);
        assert!(graph.edges.is_empty());
    }

    #[test]
    fn guard_released_at_block_end() {
        let (graph, _) = run_on(&[(
            "rust/src/util/chan.rs",
            "fn f(&self) { { let g = self.a.lock().expect(\"p\"); } \
             self.b.lock().expect(\"p\").push(1); }",
        )]);
        assert!(graph.edges.is_empty());
    }

    #[test]
    fn opposite_orders_cycle() {
        let (graph, _findings) = run_on(&[(
            "rust/src/net/transport.rs",
            "fn f(&self) { let g = self.a.lock().expect(\"p\"); \
             let h = self.b.lock().expect(\"p\"); }\n\
             fn g(&self) { let g = self.b.lock().expect(\"p\"); \
             let h = self.a.lock().expect(\"p\"); }",
        )]);
        let mut adj: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
        for (from, to) in graph.edges.keys() {
            adj.entry(from.clone()).or_default().insert(to.clone());
        }
        let components = sccs(&adj);
        assert_eq!(components.len(), 1, "{components:?}");
        assert_eq!(
            components[0],
            vec!["transport.a".to_string(), "transport.b".to_string()]
        );
    }

    #[test]
    fn blocking_send_under_lock_flagged() {
        let (_, findings) = run_on(&[(
            "rust/src/engine/exchange.rs",
            "fn f(&self) { let g = self.state.lock().expect(\"p\"); \
             self.tx.send(1); }",
        )]);
        assert!(findings
            .iter()
            .any(|f| f.message.contains("blocking channel op")));
    }

    #[test]
    fn try_send_under_lock_is_edge_not_error() {
        let (graph, findings) = run_on(&[(
            "rust/src/engine/exchange.rs",
            "fn f(&self) { let g = self.state.lock().expect(\"p\"); \
             let _ = self.tx.try_send(1); }",
        )]);
        assert!(findings.is_empty(), "{findings:?}");
        assert!(graph
            .edges
            .contains_key(&("exchange.state".to_string(), CHAN_CLASS.to_string())));
    }

    #[test]
    fn multiline_chain_names_the_class() {
        let (graph, _) = run_on(&[(
            "rust/src/net/transport.rs",
            "fn f(&self) { let g = self.state.lock().expect(\"p\"); \
             let h = shared\n        .error\n        .lock()\n        \
             .unwrap_or_else(PoisonError::into_inner); }",
        )]);
        assert!(
            graph.classes.contains("transport.error"),
            "{:?}",
            graph.classes
        );
        assert!(graph
            .edges
            .contains_key(&("transport.state".to_string(), "transport.error".to_string())));
    }

    #[test]
    fn projected_value_is_not_a_held_guard() {
        // `let x = m.lock()….is_some();` binds the bool — the guard is
        // a temporary, so the later chan op runs lock-free.
        let (graph, findings) = run_on(&[(
            "rust/src/net/transport.rs",
            "fn f(&self) { let failed = self.error.lock()\n        \
             .unwrap_or_else(PoisonError::into_inner)\n        .is_some(); \
             self.tx.send(1); }",
        )]);
        assert!(findings.is_empty(), "{findings:?}");
        assert!(graph.edges.is_empty(), "{:?}", graph.edges);
    }
}
