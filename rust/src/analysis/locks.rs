//! Pass `locks` — static lock-order audit.
//!
//! Builds an acquisition graph over the concurrency-bearing files
//! ([`SCOPE`]): nodes are lock classes (`Mutex` fields, named
//! `<file>.<field>`, plus the `util::chan` internal queue lock as
//! `chan.queue`), and an edge `A → B` is recorded whenever `B` is
//! acquired while a guard of `A` is statically held.  Cycles in that
//! graph are the classic deadlock recipe and fail the run, as does the
//! sharper local hazard: a *blocking* channel op (`send`/`recv`) under
//! a held `Mutex` guard — the parked thread keeps the lock, and
//! whoever must wake it may need that lock (exactly the invariant "no
//! sender ever parks while holding engine state" the exchange fabric
//! relies on).
//!
//! Guard liveness is approximated lexically: a `let`-bound (or
//! `match`/`for`-scrutinee) guard is held to the end of its enclosing
//! block, an un-bound temporary only for its own statement, a chain
//! that projects a value out of the guard
//! (`….lock()….is_some()`) binds the value and not the guard, and
//! `drop(guard)` releases early.  Condvar `wait(guard)` atomically
//! releases, so it is deliberately not an acquisition.
//!
//! The `locks2` pass ([`run_deep`]) extends the same walk one call
//! level deep within each file: every function body is summarized
//! (which lock classes it acquires, which blocking channel ops it
//! contains), and a call to a same-file helper — bare `helper(…)` or
//! `self.helper(…)` — made while a guard is held contributes the
//! callee's acquisitions as edges and its blocking ops as errors at
//! the call site.  Only findings that need the call-mediated leg are
//! reported, so `locks` and `locks2` never duplicate each other.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::{fn_items, Finding, SourceFile, Workspace};

const PASS: &str = "locks";
const PASS2: &str = "locks2";

/// Files whose lock sites enter the graph.
const SCOPE: &[&str] = &[
    "rust/src/util/chan.rs",
    "rust/src/engine/exchange.rs",
    "rust/src/engine/supervisor.rs",
    "rust/src/net/transport.rs",
    "rust/src/coordinator/mod.rs",
];

/// Channel ops that can park the calling thread.
const BLOCKING_OPS: &[&str] = &[".send(", ".recv(", ".recv_timeout("];
/// Channel ops that take the queue lock but never park.
const MOMENTARY_OPS: &[&str] = &[".try_send(", ".drain_into(", ".close("];

/// The class every `util::chan` operation acquires.
const CHAN_CLASS: &str = "chan.queue";

struct Guard {
    class: String,
    var: Option<String>,
    depth: usize,
}

#[derive(Default)]
struct Graph {
    /// edge → first provenance (file, line).
    edges: BTreeMap<(String, String), (String, usize)>,
    classes: BTreeSet<String>,
    sites: usize,
    /// Edges that needed a call-mediated leg (locks2 only).
    call_edges: BTreeSet<(String, String)>,
}

/// Per-function summary for the one-level interprocedural extension:
/// what the body acquires and where it can park.
#[derive(Default)]
struct FnSummary {
    /// Lock classes `.lock()`ed anywhere in the body, with lines.
    acquires: Vec<(String, usize)>,
    /// Blocking channel ops anywhere in the body, with lines.
    blocking: Vec<(&'static str, usize)>,
}

/// Summaries of every non-test `fn` body in `file`, by name.  Same-name
/// overloads (trait impls on several types) merge conservatively —
/// a call resolves to the union of their effects.
fn summarize(file: &SourceFile) -> BTreeMap<String, FnSummary> {
    let code = &file.scan.code;
    let key = file_key(&file.rel);
    let mut out: BTreeMap<String, FnSummary> = BTreeMap::new();
    for item in fn_items(code) {
        if file.in_test(item.open) {
            continue;
        }
        let entry = out.entry(item.name.clone()).or_default();
        let mut from = item.open;
        while let Some(pos) = code[from..item.close].find(".lock()") {
            let at = from + pos;
            from = at + ".lock()".len();
            entry.acquires.push((
                format!("{key}.{}", receiver_field(code, at)),
                file.scan.line_of(at),
            ));
        }
        for &op in BLOCKING_OPS {
            let mut from = item.open;
            while let Some(pos) = code[from..item.close].find(op) {
                let at = from + pos;
                from = at + op.len();
                entry.blocking.push((op, file.scan.line_of(at)));
            }
        }
    }
    out
}

impl Graph {
    fn add_edge(&mut self, from: &str, to: &str, file: &str, line: usize) {
        self.edges
            .entry((from.to_string(), to.to_string()))
            .or_insert((file.to_string(), line));
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Class key for a file: stem, or the parent directory for `mod.rs`.
fn file_key(rel: &str) -> String {
    let mut parts = rel.rsplit('/');
    let stem = parts
        .next()
        .unwrap_or(rel)
        .trim_end_matches(".rs")
        .to_string();
    if stem == "mod" {
        parts.next().unwrap_or("mod").to_string()
    } else {
        stem
    }
}

/// Last path segment of the receiver ending just before `dot_at`
/// (e.g. `self.inner.queue` → `queue`).  Multi-line method chains
/// (`shared\n.error\n.lock()`) are followed through the whitespace.
fn receiver_field(code: &str, dot_at: usize) -> String {
    let bytes = code.as_bytes();
    let chain = |b: u8| is_ident(b) || b == b'.' || b == b':';
    let mut start = dot_at;
    while start > 0 {
        let b = bytes[start - 1];
        if chain(b) {
            start -= 1;
        } else if (b as char).is_whitespace() {
            // Step over the whitespace run only if it splices two
            // pieces of the same chain.
            let mut k = start - 1;
            while k > 0 && (bytes[k - 1] as char).is_whitespace() {
                k -= 1;
            }
            if k > 0 && chain(bytes[k - 1]) {
                start = k;
            } else {
                break;
            }
        } else {
            break;
        }
    }
    let path: String = code[start..dot_at]
        .chars()
        .filter(|c| !c.is_whitespace())
        .collect();
    let path = path.replace("::", ".");
    path.rsplit('.')
        .find(|s| !s.is_empty())
        .unwrap_or("unknown")
        .to_string()
}

/// If an adapter that still denotes the guard (`.unwrap()`,
/// `.expect(…)`, `.unwrap_or_else(…)`) starts at `at`, return the
/// offset just past it.
fn adapter_end(code: &str, at: usize) -> Option<usize> {
    let rest = &code[at..];
    if rest.starts_with(".unwrap()") {
        return Some(at + ".unwrap()".len());
    }
    for pat in [".expect(", ".unwrap_or_else("] {
        if rest.starts_with(pat) {
            let bytes = code.as_bytes();
            let mut depth = 0usize;
            let mut k = at + pat.len() - 1;
            while k < bytes.len() {
                match bytes[k] {
                    b'(' => depth += 1,
                    b')' => {
                        depth -= 1;
                        if depth == 0 {
                            return Some(k + 1);
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            return Some(bytes.len());
        }
    }
    None
}

/// Does the chain continue past the guard with a *projection*
/// (`.is_some()`, `.len()`, indexing)?  Then the statement binds the
/// projected value, the guard itself is a temporary that dies at the
/// end of the statement — not a held lock.
fn projects_past_guard(code: &str, mut i: usize) -> bool {
    let bytes = code.as_bytes();
    loop {
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        match adapter_end(code, i) {
            Some(end) => i = end,
            None => break,
        }
    }
    i < bytes.len() && (bytes[i] == b'.' || bytes[i] == b'[')
}

/// Text from the start of the current statement to `at` (for binding
/// detection): everything after the nearest `;`, `{` or `}`.
fn statement_prefix(code: &str, at: usize) -> &str {
    let bytes = code.as_bytes();
    let mut start = at;
    while start > 0 {
        match bytes[start - 1] {
            b';' | b'{' | b'}' => break,
            _ => start -= 1,
        }
    }
    &code[start..at]
}

/// If the statement binds its value (`let g = …`, `match …`, `for …`),
/// return the bound variable name when it is a simple `let` ident.
fn binding_of(prefix: &str) -> Option<Option<String>> {
    let has = |kw: &str| {
        let mut from = 0;
        while let Some(pos) = prefix[from..].find(kw) {
            let at = from + pos;
            let left_ok = at == 0 || !is_ident(prefix.as_bytes()[at - 1]);
            if left_ok {
                return Some(at);
            }
            from = at + 1;
        }
        None
    };
    if let Some(at) = has("let ") {
        let rest = prefix[at + 4..].trim_start();
        let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
        let name: String = rest
            .bytes()
            .take_while(|&b| is_ident(b))
            .map(|b| b as char)
            .collect();
        let var = if name.is_empty() { None } else { Some(name) };
        return Some(var);
    }
    if has("match ").is_some() || has("for ").is_some() || has("while ").is_some() {
        return Some(None);
    }
    None
}

/// Walk one file, adding acquisition edges and emitting
/// blocking-op-under-lock findings.  With `summaries` (locks2 mode)
/// the walk additionally resolves same-file helper calls made under a
/// held guard, and leaves the purely lexical blocking errors to the
/// plain `locks` pass so the two never double-report.
fn walk(
    file: &SourceFile,
    graph: &mut Graph,
    findings: &mut Vec<Finding>,
    summaries: Option<&BTreeMap<String, FnSummary>>,
) {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let key = file_key(&file.rel);
    let mut held: Vec<Guard> = Vec::new();
    let mut depth: usize = 0;
    let mut i = 0;

    while i < bytes.len() {
        // Skip #[cfg(test)] regions wholesale.
        if let Some(end) = file
            .test_ranges
            .iter()
            .find(|&&(s, e)| i >= s && i < e)
            .map(|&(_, e)| e)
        {
            i = end;
            continue;
        }
        match bytes[i] {
            b'{' => {
                depth += 1;
                i += 1;
                continue;
            }
            b'}' => {
                depth = depth.saturating_sub(1);
                held.retain(|g| g.depth <= depth);
                i += 1;
                continue;
            }
            _ => {}
        }

        // Early release: drop(guard).
        if code[i..].starts_with("drop(") && (i == 0 || !is_ident(bytes[i - 1])) {
            let arg: String = code[i + 5..]
                .bytes()
                .take_while(|&b| is_ident(b))
                .map(|b| b as char)
                .collect();
            if let Some(pos) = held
                .iter()
                .rposition(|g| g.var.as_deref() == Some(arg.as_str()))
            {
                held.remove(pos);
            }
            i += 5;
            continue;
        }

        // Mutex acquisition.
        if code[i..].starts_with(".lock()") {
            let class = format!("{key}.{}", receiver_field(code, i));
            let line = file.scan.line_of(i);
            graph.classes.insert(class.clone());
            graph.sites += 1;
            for g in &held {
                graph.add_edge(&g.class, &class, &file.rel, line);
            }
            // A temporary (no binding) is released at end of statement
            // and never pushed; likewise when the chain projects a
            // value out of the guard (`….lock()….is_some()`).
            if !projects_past_guard(code, i + ".lock()".len()) {
                if let Some(var) = binding_of(statement_prefix(code, i)) {
                    held.push(Guard { class, var, depth });
                }
            }
            i += ".lock()".len();
            continue;
        }

        // util::chan operations.
        let mut matched = false;
        for &op in BLOCKING_OPS.iter().chain(MOMENTARY_OPS) {
            if code[i..].starts_with(op) {
                let line = file.scan.line_of(i);
                graph.classes.insert(CHAN_CLASS.to_string());
                graph.sites += 1;
                for g in &held {
                    graph.add_edge(&g.class, CHAN_CLASS, &file.rel, line);
                }
                if BLOCKING_OPS.contains(&op) && !held.is_empty() && summaries.is_none() {
                    let holding: Vec<&str> =
                        held.iter().map(|g| g.class.as_str()).collect();
                    findings.push(Finding::error(
                        PASS,
                        &file.rel,
                        line,
                        format!(
                            "blocking channel op `{}` while holding lock guard(s) \
                             [{}] — a parked thread keeps the lock and risks \
                             deadlock with whoever must wake it",
                            op.trim_start_matches('.').trim_end_matches('('),
                            holding.join(", ")
                        ),
                    ));
                }
                i += op.len();
                matched = true;
                break;
            }
        }
        if matched {
            continue;
        }

        // locks2: a same-file helper call under a held guard pulls the
        // callee's summary into the caller's context.
        if let Some(summaries) = summaries {
            if !held.is_empty()
                && (bytes[i].is_ascii_alphabetic() || bytes[i] == b'_')
                && (i == 0 || !is_ident(bytes[i - 1]))
            {
                let start = i;
                let mut j = i;
                while j < bytes.len() && is_ident(bytes[j]) {
                    j += 1;
                }
                let name = &code[start..j];
                let mut k = j;
                if code[k..].starts_with("::<") {
                    let mut depth = 0usize;
                    let mut m = k + 2;
                    while m < bytes.len() {
                        match bytes[m] {
                            b'<' => depth += 1,
                            b'>' => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        m += 1;
                    }
                    k = (m + 1).min(bytes.len());
                }
                if k < bytes.len() && bytes[k] == b'(' {
                    if let Some(summary) = summaries.get(name) {
                        // Resolve only unambiguous same-file targets:
                        // a bare call that is not the definition, or a
                        // `self.…` method — `other.helper(…)` could be
                        // any type's method.
                        let dotted = start > 0 && bytes[start - 1] == b'.';
                        let resolved = if dotted {
                            receiver_field(code, start - 1) == "self"
                        } else {
                            let mut p = start;
                            while p > 0 && (bytes[p - 1] as char).is_whitespace() {
                                p -= 1;
                            }
                            !(p >= 2 && &code[p - 2..p] == "fn")
                        };
                        if resolved {
                            let line = file.scan.line_of(start);
                            for (class, _) in &summary.acquires {
                                graph.classes.insert(class.clone());
                                for g in &held {
                                    graph.add_edge(&g.class, class, &file.rel, line);
                                    graph
                                        .call_edges
                                        .insert((g.class.clone(), class.clone()));
                                }
                            }
                            if let Some((op, op_line)) = summary.blocking.first() {
                                let holding: Vec<&str> =
                                    held.iter().map(|g| g.class.as_str()).collect();
                                findings.push(Finding::error(
                                    PASS2,
                                    &file.rel,
                                    line,
                                    format!(
                                        "call to `{name}` reaches blocking channel op \
                                         `{}` ({}:{op_line}) while holding lock \
                                         guard(s) [{}] — the guard stays held across \
                                         the park",
                                        op.trim_start_matches('.').trim_end_matches('('),
                                        file.rel,
                                        holding.join(", ")
                                    ),
                                ));
                            }
                        }
                    }
                    i = j;
                    continue;
                }
            }
        }

        i += 1;
    }
}

/// Strongly connected components of the acquisition graph (Tarjan).
/// A deadlock-capable cycle exists iff some SCC has more than one node
/// (self-edges are reported separately), so SCC detection is exact
/// where naive cycle enumeration can miss cycles.
fn sccs(adj: &BTreeMap<String, BTreeSet<String>>) -> Vec<Vec<String>> {
    struct Tarjan<'a> {
        adj: &'a BTreeMap<String, BTreeSet<String>>,
        next_index: usize,
        index: BTreeMap<String, usize>,
        low: BTreeMap<String, usize>,
        stack: Vec<String>,
        on_stack: BTreeSet<String>,
        out: Vec<Vec<String>>,
    }
    fn strong(t: &mut Tarjan<'_>, v: &str) {
        t.index.insert(v.to_string(), t.next_index);
        t.low.insert(v.to_string(), t.next_index);
        t.next_index += 1;
        t.stack.push(v.to_string());
        t.on_stack.insert(v.to_string());
        let nexts: Vec<String> = t
            .adj
            .get(v)
            .map(|s| s.iter().cloned().collect())
            .unwrap_or_default();
        for w in nexts {
            if !t.index.contains_key(&w) {
                strong(t, &w);
                let low_w = t.low.get(&w).copied().unwrap_or(usize::MAX);
                let low_v = t.low.get(v).copied().unwrap_or(usize::MAX);
                if low_w < low_v {
                    t.low.insert(v.to_string(), low_w);
                }
            } else if t.on_stack.contains(&w) {
                let idx_w = t.index.get(&w).copied().unwrap_or(usize::MAX);
                let low_v = t.low.get(v).copied().unwrap_or(usize::MAX);
                if idx_w < low_v {
                    t.low.insert(v.to_string(), idx_w);
                }
            }
        }
        if t.low.get(v) == t.index.get(v) {
            let mut comp = Vec::new();
            while let Some(w) = t.stack.pop() {
                t.on_stack.remove(&w);
                let done = w == v;
                comp.push(w);
                if done {
                    break;
                }
            }
            comp.sort();
            t.out.push(comp);
        }
    }

    let mut nodes: BTreeSet<String> = adj.keys().cloned().collect();
    for targets in adj.values() {
        nodes.extend(targets.iter().cloned());
    }
    let mut t = Tarjan {
        adj,
        next_index: 0,
        index: BTreeMap::new(),
        low: BTreeMap::new(),
        stack: Vec::new(),
        on_stack: BTreeSet::new(),
        out: Vec::new(),
    };
    for n in &nodes {
        if !t.index.contains_key(n) {
            strong(&mut t, n);
        }
    }
    t.out.into_iter().filter(|c| c.len() > 1).collect()
}

pub fn run(ws: &Workspace) -> Vec<Finding> {
    let mut graph = Graph::default();
    let mut findings = Vec::new();
    for file in &ws.src {
        if SCOPE.contains(&file.rel.as_str()) {
            walk(file, &mut graph, &mut findings, None);
        }
    }

    let mut adj: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for (from, to) in graph.edges.keys() {
        adj.entry(from.clone()).or_default().insert(to.clone());
    }

    for ((from, to), (file, line)) in &graph.edges {
        if from == to {
            findings.push(Finding::error(
                PASS,
                file,
                *line,
                format!(
                    "re-entrant acquisition: lock class `{from}` acquired while \
                     already held — std::sync::Mutex self-deadlocks"
                ),
            ));
        }
    }

    for component in sccs(&adj) {
        // Every edge internal to the component is part of some cycle:
        // list them all with provenance.
        let legs: Vec<String> = graph
            .edges
            .iter()
            .filter(|((from, to), _)| component.contains(from) && component.contains(to))
            .map(|((from, to), (f, l))| format!("{from} → {to} ({f}:{l})"))
            .collect();
        let (file, line) = graph
            .edges
            .iter()
            .find(|((from, to), _)| component.contains(from) && component.contains(to))
            .map(|(_, (f, l))| (f.clone(), *l))
            .unwrap_or((String::new(), 0));
        findings.push(Finding::error(
            PASS,
            &file,
            line,
            format!(
                "lock-order cycle among [{}]: {} — two threads taking these locks \
                 in opposite order deadlock",
                component.join(", "),
                legs.join(", ")
            ),
        ));
    }

    for ((from, to), (file, line)) in &graph.edges {
        findings.push(Finding::note(
            PASS,
            file,
            *line,
            format!("acquisition edge: {from} → {to}"),
        ));
    }
    findings.push(Finding::note(
        PASS,
        "rust/src",
        0,
        format!(
            "{} lock class(es), {} acquisition site(s), {} edge(s) across {} scoped file(s)",
            graph.classes.len(),
            graph.sites,
            graph.edges.len(),
            SCOPE.len()
        ),
    ));
    findings
}

/// The `locks2` pass: the lexical walk, one call level deep.  Reports
/// only hazards that need a call-mediated leg — blocking ops reached
/// through a helper call under a guard, re-entrant acquisition via a
/// callee, and lock-order cycles at least one of whose edges crosses a
/// call — the purely lexical cases are [`run`]'s to report.
pub fn run_deep(ws: &Workspace) -> Vec<Finding> {
    let mut graph = Graph::default();
    let mut findings = Vec::new();
    let mut fn_count = 0usize;
    for file in &ws.src {
        if SCOPE.contains(&file.rel.as_str()) {
            let summaries = summarize(file);
            fn_count += summaries.len();
            walk(file, &mut graph, &mut findings, Some(&summaries));
        }
    }

    for ((from, to), (file, line)) in &graph.edges {
        if from == to && graph.call_edges.contains(&(from.clone(), to.clone())) {
            findings.push(Finding::error(
                PASS2,
                file,
                *line,
                format!(
                    "re-entrant acquisition through a helper call: lock class \
                     `{from}` acquired by the callee while already held at the \
                     call site — std::sync::Mutex self-deadlocks"
                ),
            ));
        }
    }

    let mut adj: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for (from, to) in graph.edges.keys() {
        adj.entry(from.clone()).or_default().insert(to.clone());
    }
    for component in sccs(&adj) {
        let has_call_leg = graph
            .call_edges
            .iter()
            .any(|(a, b)| component.contains(a) && component.contains(b));
        if !has_call_leg {
            continue; // fully lexical cycle: the plain pass reports it
        }
        let legs: Vec<String> = graph
            .edges
            .iter()
            .filter(|((from, to), _)| component.contains(from) && component.contains(to))
            .map(|((from, to), (f, l))| format!("{from} → {to} ({f}:{l})"))
            .collect();
        let (file, line) = graph
            .edges
            .iter()
            .find(|((from, to), _)| component.contains(from) && component.contains(to))
            .map(|(_, (f, l))| (f.clone(), *l))
            .unwrap_or((String::new(), 0));
        findings.push(Finding::error(
            PASS2,
            &file,
            line,
            format!(
                "interprocedural lock-order cycle among [{}]: {} — at least one \
                 leg crosses a helper call, invisible to the lexical pass",
                component.join(", "),
                legs.join(", ")
            ),
        ));
    }

    for ((from, to), (file, line)) in &graph.edges {
        if graph.call_edges.contains(&(from.clone(), to.clone())) {
            findings.push(Finding::note(
                PASS2,
                file,
                *line,
                format!("call-mediated acquisition edge: {from} → {to}"),
            ));
        }
    }
    findings.push(Finding::note(
        PASS2,
        "rust/src",
        0,
        format!(
            "{fn_count} function summary(ies) resolved one call level deep; {} \
             call-mediated edge(s)",
            graph.call_edges.len()
        ),
    ));
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{find_test_ranges, lexer};

    fn file(rel: &str, src: &str) -> SourceFile {
        let scan = lexer::scan(src);
        let test_ranges = find_test_ranges(&scan.code);
        SourceFile {
            rel: rel.to_string(),
            scan,
            test_ranges,
        }
    }

    fn run_on(files: &[(&str, &str)]) -> (Graph, Vec<Finding>) {
        let mut graph = Graph::default();
        let mut findings = Vec::new();
        for (rel, src) in files {
            walk(&file(rel, src), &mut graph, &mut findings, None);
        }
        (graph, findings)
    }

    fn run_deep_on(files: &[(&str, &str)]) -> (Graph, Vec<Finding>) {
        let mut graph = Graph::default();
        let mut findings = Vec::new();
        for (rel, src) in files {
            let f = file(rel, src);
            let summaries = summarize(&f);
            walk(&f, &mut graph, &mut findings, Some(&summaries));
        }
        (graph, findings)
    }

    #[test]
    fn nested_acquisition_makes_an_edge() {
        let (graph, _) = run_on(&[(
            "rust/src/util/chan.rs",
            "fn f(&self) { let g = self.a.lock().expect(\"p\"); \
             self.b.lock().expect(\"p\").push(1); }",
        )]);
        assert!(graph
            .edges
            .contains_key(&("chan.a".to_string(), "chan.b".to_string())));
    }

    #[test]
    fn temporary_guard_does_not_stay_held() {
        let (graph, _) = run_on(&[(
            "rust/src/util/chan.rs",
            "fn f(&self) { self.a.lock().expect(\"p\").push(1); \
             self.b.lock().expect(\"p\").push(2); }",
        )]);
        assert!(graph.edges.is_empty());
    }

    #[test]
    fn drop_releases_guard() {
        let (graph, _) = run_on(&[(
            "rust/src/util/chan.rs",
            "fn f(&self) { let st = self.a.lock().expect(\"p\"); drop(st); \
             self.b.lock().expect(\"p\").push(1); }",
        )]);
        assert!(graph.edges.is_empty());
    }

    #[test]
    fn guard_released_at_block_end() {
        let (graph, _) = run_on(&[(
            "rust/src/util/chan.rs",
            "fn f(&self) { { let g = self.a.lock().expect(\"p\"); } \
             self.b.lock().expect(\"p\").push(1); }",
        )]);
        assert!(graph.edges.is_empty());
    }

    #[test]
    fn opposite_orders_cycle() {
        let (graph, _findings) = run_on(&[(
            "rust/src/net/transport.rs",
            "fn f(&self) { let g = self.a.lock().expect(\"p\"); \
             let h = self.b.lock().expect(\"p\"); }\n\
             fn g(&self) { let g = self.b.lock().expect(\"p\"); \
             let h = self.a.lock().expect(\"p\"); }",
        )]);
        let mut adj: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
        for (from, to) in graph.edges.keys() {
            adj.entry(from.clone()).or_default().insert(to.clone());
        }
        let components = sccs(&adj);
        assert_eq!(components.len(), 1, "{components:?}");
        assert_eq!(
            components[0],
            vec!["transport.a".to_string(), "transport.b".to_string()]
        );
    }

    #[test]
    fn blocking_send_under_lock_flagged() {
        let (_, findings) = run_on(&[(
            "rust/src/engine/exchange.rs",
            "fn f(&self) { let g = self.state.lock().expect(\"p\"); \
             self.tx.send(1); }",
        )]);
        assert!(findings
            .iter()
            .any(|f| f.message.contains("blocking channel op")));
    }

    #[test]
    fn try_send_under_lock_is_edge_not_error() {
        let (graph, findings) = run_on(&[(
            "rust/src/engine/exchange.rs",
            "fn f(&self) { let g = self.state.lock().expect(\"p\"); \
             let _ = self.tx.try_send(1); }",
        )]);
        assert!(findings.is_empty(), "{findings:?}");
        assert!(graph
            .edges
            .contains_key(&("exchange.state".to_string(), CHAN_CLASS.to_string())));
    }

    #[test]
    fn multiline_chain_names_the_class() {
        let (graph, _) = run_on(&[(
            "rust/src/net/transport.rs",
            "fn f(&self) { let g = self.state.lock().expect(\"p\"); \
             let h = shared\n        .error\n        .lock()\n        \
             .unwrap_or_else(PoisonError::into_inner); }",
        )]);
        assert!(
            graph.classes.contains("transport.error"),
            "{:?}",
            graph.classes
        );
        assert!(graph
            .edges
            .contains_key(&("transport.state".to_string(), "transport.error".to_string())));
    }

    #[test]
    fn blocking_op_across_helper_call_flagged_by_deep_walk() {
        let src = "impl S { fn outer(&self) { let g = self.state.lock().expect(\"p\"); \
                   self.flush(); }\n\
                   fn flush(&self) { self.tx.send(1); } }";
        let (_, shallow) = run_on(&[("rust/src/engine/exchange.rs", src)]);
        assert!(shallow.is_empty(), "lexical pass is blind here: {shallow:?}");
        let (_, deep) = run_deep_on(&[("rust/src/engine/exchange.rs", src)]);
        assert_eq!(deep.len(), 1, "{deep:?}");
        assert!(deep[0].message.contains("call to `flush`"), "{}", deep[0].message);
        assert!(deep[0].message.contains("exchange.state"), "{}", deep[0].message);
    }

    #[test]
    fn reentrant_acquisition_via_callee_makes_call_edge() {
        let src = "impl S { fn outer(&self) { let g = self.state.lock().expect(\"p\"); \
                   refresh(x); }\n }\n\
                   fn refresh(x: u8) { let h = GLOBAL.state.lock().expect(\"p\"); }";
        let (graph, _) = run_deep_on(&[("rust/src/engine/supervisor.rs", src)]);
        assert!(graph.call_edges.contains(&(
            "supervisor.state".to_string(),
            "supervisor.state".to_string()
        )));
    }

    #[test]
    fn unresolved_receiver_is_not_a_call_edge() {
        // `other.flush(…)` could be any type's method — never resolved.
        let src = "impl S { fn outer(&self, other: &T) { \
                   let g = self.state.lock().expect(\"p\"); other.flush(); }\n\
                   fn flush(&self) { self.tx.send(1); } }";
        let (graph, deep) = run_deep_on(&[("rust/src/engine/exchange.rs", src)]);
        assert!(deep.is_empty(), "{deep:?}");
        assert!(graph.call_edges.is_empty());
    }

    #[test]
    fn projected_value_is_not_a_held_guard() {
        // `let x = m.lock()….is_some();` binds the bool — the guard is
        // a temporary, so the later chan op runs lock-free.
        let (graph, findings) = run_on(&[(
            "rust/src/net/transport.rs",
            "fn f(&self) { let failed = self.error.lock()\n        \
             .unwrap_or_else(PoisonError::into_inner)\n        .is_some(); \
             self.tx.send(1); }",
        )]);
        assert!(findings.is_empty(), "{findings:?}");
        assert!(graph.edges.is_empty(), "{:?}", graph.edges);
    }
}
