//! Pass `tests` — test-registration audit.
//!
//! With an explicit `[lib]`/`[[bin]]` layout (sources under `rust/`,
//! not `src/`), Cargo's target auto-discovery is off: a file in
//! `rust/tests/` with no `[[test]]` block in `Cargo.toml` silently
//! never runs.  This bit PR 3 (`chain_equivalence` landed unregistered)
//! and was guarded by an ad-hoc shell loop in CI until this pass
//! replaced it.  Checks both directions: every test file registered,
//! every registration pointing at a file that exists.

use crate::analysis::{Finding, Workspace};

const PASS: &str = "tests";

/// One `[[test]]` block of the manifest.
struct TestTarget {
    name: Option<String>,
    path: Option<String>,
    /// 1-based line of the `[[test]]` header.
    line: usize,
}

/// Parse the `[[test]]` blocks out of manifest text.  TOML subset:
/// `#` comments stripped (quote-aware), block ends at the next
/// `[`-header line.
fn test_targets(manifest: &str) -> Vec<TestTarget> {
    let mut targets: Vec<TestTarget> = Vec::new();
    let mut current: Option<TestTarget> = None;
    for (idx, raw_line) in manifest.lines().enumerate() {
        let line = strip_toml_comment(raw_line);
        let trimmed = line.trim();
        if trimmed.starts_with('[') {
            if let Some(t) = current.take() {
                targets.push(t);
            }
            if trimmed == "[[test]]" {
                current = Some(TestTarget {
                    name: None,
                    path: None,
                    line: idx + 1,
                });
            }
            continue;
        }
        if let Some(t) = current.as_mut() {
            if let Some((key, value)) = trimmed.split_once('=') {
                let key = key.trim();
                let value = value.trim().trim_matches('"').to_string();
                match key {
                    "name" => t.name = Some(value),
                    "path" => t.path = Some(value),
                    _ => {}
                }
            }
        }
    }
    if let Some(t) = current.take() {
        targets.push(t);
    }
    targets
}

/// Drop a `#` comment, ignoring `#` inside a quoted string.
fn strip_toml_comment(line: &str) -> &str {
    let bytes = line.as_bytes();
    let mut in_str = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'"' => in_str = !in_str,
            b'#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

pub fn run(ws: &Workspace) -> Vec<Finding> {
    let mut findings = Vec::new();
    if ws.cargo_toml.is_empty() {
        findings.push(Finding::error(
            PASS,
            "Cargo.toml",
            0,
            "manifest missing or unreadable — cannot audit test registration".to_string(),
        ));
        return findings;
    }
    let targets = test_targets(&ws.cargo_toml);

    for stem in &ws.test_files {
        let registered = targets
            .iter()
            .any(|t| t.name.as_deref() == Some(stem.as_str()));
        if !registered {
            findings.push(Finding::error(
                PASS,
                &format!("rust/tests/{stem}.rs"),
                0,
                format!(
                    "no [[test]] target named \"{stem}\" in Cargo.toml — \
                     with an explicit target layout this test silently never runs"
                ),
            ));
        }
    }

    for t in &targets {
        let Some(name) = &t.name else {
            findings.push(Finding::error(
                PASS,
                "Cargo.toml",
                t.line,
                "[[test]] block without a name".to_string(),
            ));
            continue;
        };
        let Some(path) = &t.path else {
            findings.push(Finding::error(
                PASS,
                "Cargo.toml",
                t.line,
                format!("[[test]] \"{name}\" has no path — target auto-discovery is off"),
            ));
            continue;
        };
        if !ws.root.join(path).is_file() {
            findings.push(Finding::error(
                PASS,
                "Cargo.toml",
                t.line,
                format!("[[test]] \"{name}\" points at missing file {path}"),
            ));
        }
    }

    findings.push(Finding::note(
        PASS,
        "Cargo.toml",
        0,
        format!(
            "{} test file(s) in rust/tests/, {} [[test]] target(s)",
            ws.test_files.len(),
            targets.len()
        ),
    ));
    findings
}
