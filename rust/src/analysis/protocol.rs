//! Pass `protocol` — control-plane state-machine conformance.
//!
//! The driver↔worker control protocol of distributed runs lives in
//! `net/control.rs` (frame sends/receives) and `net/runner.rs` (the
//! call sequences that drive them).  This pass declares that protocol
//! *once*, as an explicit state machine ([`MACHINE`]): HELLO → ASSIGN
//! → READY → START → FRAGMENT along the happy path, worker→driver
//! ERROR escapes, and the implicit EOF edge (peer closed the link).
//! It then extracts both implementations from the masked source and
//! checks them against the declaration:
//!
//! * every *send* site (`write_frame(…, kind::X, …)`) and every
//!   *receive/check* site (`f.kind != kind::X`, `== kind::X`) is
//!   attributed to the driver side (`impl ControlPlane`), the worker
//!   side (`impl WorkerLink`), or a side-neutral helper;
//! * a declared edge with no send site on its sender side, or no
//!   receive site on its receiver side — a frame kind handled on only
//!   one side — is an error with `file:line` provenance, as is a send
//!   or check of a kind the machine does not declare;
//! * the declared machine itself must be well-formed: every state
//!   reachable from INIT, every state able to reach a terminal;
//! * peer close (EOF) must be handled while awaiting a frame
//!   (`Ok(None)` arm), so a dead worker fails the run instead of
//!   hanging it.
//!
//! A second, flow-sensitive check walks every function body in the
//! scoped files and verifies the *order* of control-plane calls:
//! driver-side gather → broadcast_assign → barrier →
//! collect_fragments → merge_results, worker-side connect → ready →
//! await_start → send_fragment.  An out-of-order call (e.g.
//! `await_start` before `ready`, which would deadlock the barrier) is
//! an error at the call site.
//!
//! PING is a keepalive outside the machine and is ignored everywhere.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::{fn_items, Finding, SourceFile, Workspace};

const PASS: &str = "protocol";

/// The control-plane implementation the conformance checks read.
const CONTROL_FILE: &str = "rust/src/net/control.rs";
/// Files whose function bodies are checked for protocol call order.
const FLOW_FILES: &[&str] = &["rust/src/net/control.rs", "rust/src/net/runner.rs"];

/// Which endpoint a send/receive site belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Side {
    Driver,
    Worker,
    /// Free helpers outside both impl blocks (`read_control`,
    /// `check_error`) — they serve whichever side calls them, so a
    /// neutral site satisfies either side's obligation.
    Neutral,
}

impl Side {
    fn name(self) -> &'static str {
        match self {
            Side::Driver => "driver",
            Side::Worker => "worker",
            Side::Neutral => "shared helper",
        }
    }

    fn satisfies(self, want: Side) -> bool {
        self == want || self == Side::Neutral
    }

    fn other(self) -> Side {
        match self {
            Side::Driver => Side::Worker,
            Side::Worker => Side::Driver,
            Side::Neutral => Side::Neutral,
        }
    }
}

/// One declared transition of the control-plane state machine.
struct EdgeDecl {
    from: &'static str,
    to: &'static str,
    kind: &'static str,
    sender: Side,
}

/// The declared machine.  The diagram in `docs/ARCHITECTURE.md`
/// §Static analysis renders exactly this table — edit both together.
const MACHINE: &[EdgeDecl] = &[
    EdgeDecl { from: "INIT", to: "CONNECTED", kind: "HELLO", sender: Side::Worker },
    EdgeDecl { from: "CONNECTED", to: "ASSIGNED", kind: "ASSIGN", sender: Side::Driver },
    EdgeDecl { from: "ASSIGNED", to: "READY", kind: "READY", sender: Side::Worker },
    EdgeDecl { from: "READY", to: "RUNNING", kind: "START", sender: Side::Driver },
    EdgeDecl { from: "RUNNING", to: "DONE", kind: "FRAGMENT", sender: Side::Worker },
    // A worker may report failure instead of READY or FRAGMENT.
    EdgeDecl { from: "ASSIGNED", to: "FAILED", kind: "ERROR", sender: Side::Worker },
    EdgeDecl { from: "RUNNING", to: "FAILED", kind: "ERROR", sender: Side::Worker },
];

const INITIAL: &str = "INIT";
const TERMINALS: &[&str] = &["DONE", "FAILED"];

/// Driver-side calls in protocol order (index = position in the flow).
const DRIVER_FLOW: &[&str] = &[
    "ControlPlane::gather(",
    ".broadcast_assign(",
    ".barrier(",
    ".collect_fragments(",
    "merge_results(",
];

/// Worker-side calls in protocol order.
const WORKER_FLOW: &[&str] = &[
    "WorkerLink::connect(",
    ".ready(",
    ".await_start(",
    ".send_fragment(",
];

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// One extracted send or receive site.
struct Site {
    kind: String,
    side: Side,
    line: usize,
}

/// The span of `impl <header> { … }`, if present.
fn impl_span(code: &str, header: &str) -> Option<(usize, usize)> {
    let at = code.find(header)?;
    let bytes = code.as_bytes();
    let mut i = at;
    while i < bytes.len() && bytes[i] != b'{' {
        i += 1;
    }
    let open = i;
    let mut depth = 0usize;
    while i < bytes.len() {
        match bytes[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some((open, i + 1));
                }
            }
            _ => {}
        }
        i += 1;
    }
    Some((open, bytes.len()))
}

/// All `kind::NAME` tokens in masked code: `(offset, NAME)`.
fn kind_tokens(code: &str) -> Vec<(usize, String)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find("kind::") {
        let at = from + pos;
        from = at + 6;
        // Word boundary on the left (a path separator `:` is fine — a
        // fully qualified `frame::kind::X` still names the module).
        if at > 0 && is_ident(bytes[at - 1]) {
            continue;
        }
        let start = at + 6;
        let mut i = start;
        while i < bytes.len() && is_ident(bytes[i]) {
            i += 1;
        }
        if i > start {
            out.push((at, code[start..i].to_string()));
        }
    }
    out
}

/// Argument-list spans of every `write_frame(…)` call.
fn write_frame_spans(code: &str) -> Vec<(usize, usize)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find("write_frame") {
        let at = from + pos;
        from = at + "write_frame".len();
        if at > 0 && is_ident(bytes[at - 1]) {
            continue;
        }
        let mut i = at + "write_frame".len();
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'(' {
            continue; // the import or a doc reference, not a call
        }
        let open = i;
        let mut depth = 0usize;
        while i < bytes.len() {
            match bytes[i] {
                b'(' => depth += 1,
                b')' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        out.push((open, i.min(bytes.len())));
    }
    out
}

/// Extract every send and receive site from the control-plane file.
fn extract_sites(file: &SourceFile) -> (Vec<Site>, Vec<Site>) {
    let code = &file.scan.code;
    let driver = impl_span(code, "impl ControlPlane");
    let worker = impl_span(code, "impl WorkerLink");
    let side_of = |offset: usize| -> Side {
        if driver.map(|(s, e)| offset >= s && offset < e).unwrap_or(false) {
            Side::Driver
        } else if worker.map(|(s, e)| offset >= s && offset < e).unwrap_or(false) {
            Side::Worker
        } else {
            Side::Neutral
        }
    };

    let send_spans = write_frame_spans(code);
    let in_send = |offset: usize| send_spans.iter().any(|&(s, e)| offset >= s && offset < e);

    let mut sends = Vec::new();
    let mut recvs = Vec::new();
    for (offset, kind) in kind_tokens(code) {
        if file.in_test(offset) || kind == "PING" {
            continue;
        }
        let site = Site {
            kind,
            side: side_of(offset),
            line: file.scan.line_of(offset),
        };
        if in_send(offset) {
            sends.push(site);
        } else {
            recvs.push(site);
        }
    }
    (sends, recvs)
}

/// Well-formedness of the declared machine itself: every state must be
/// reachable from [`INITIAL`], and every state must reach a terminal.
/// Static data, but the check keeps future edits honest.
fn machine_self_check(findings: &mut Vec<Finding>) {
    let mut states: BTreeSet<&str> = BTreeSet::new();
    let mut fwd: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    let mut rev: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for e in MACHINE {
        states.insert(e.from);
        states.insert(e.to);
        fwd.entry(e.from).or_default().push(e.to);
        rev.entry(e.to).or_default().push(e.from);
    }
    let closure = |adj: &BTreeMap<&str, Vec<&str>>, seeds: &[&str]| -> BTreeSet<String> {
        let mut seen: BTreeSet<String> = seeds.iter().map(|s| s.to_string()).collect();
        let mut queue: Vec<&str> = seeds.to_vec();
        while let Some(s) = queue.pop() {
            for &n in adj.get(s).map(|v| v.as_slice()).unwrap_or(&[]) {
                if seen.insert(n.to_string()) {
                    queue.push(n);
                }
            }
        }
        seen
    };
    let reachable = closure(&fwd, &[INITIAL]);
    let reaches_end = closure(&rev, TERMINALS);
    for s in &states {
        if !reachable.contains(*s) {
            findings.push(Finding::error(
                PASS,
                CONTROL_FILE,
                0,
                format!("declared protocol state {s} is unreachable from {INITIAL}"),
            ));
        }
        if !reaches_end.contains(*s) {
            findings.push(Finding::error(
                PASS,
                CONTROL_FILE,
                0,
                format!(
                    "declared protocol state {s} cannot reach a terminal state \
                     ({}) — a run entering it would never finish",
                    TERMINALS.join("/")
                ),
            ));
        }
    }
}

/// Check extracted sites against the declared machine.
fn conformance(file: &SourceFile, sends: &[Site], recvs: &[Site], findings: &mut Vec<Finding>) {
    let declared: BTreeSet<&str> = MACHINE.iter().map(|e| e.kind).collect();

    for kind in &declared {
        let sender = MACHINE
            .iter()
            .find(|e| e.kind == *kind)
            .map(|e| e.sender)
            .unwrap_or(Side::Neutral);
        let receiver = sender.other();
        let send_hits: Vec<&Site> = sends
            .iter()
            .filter(|s| s.kind == *kind && s.side.satisfies(sender))
            .collect();
        let recv_hits: Vec<&Site> = recvs
            .iter()
            .filter(|s| s.kind == *kind && s.side.satisfies(receiver))
            .collect();

        if send_hits.is_empty() {
            let line = recvs
                .iter()
                .find(|s| s.kind == *kind)
                .map(|s| s.line)
                .unwrap_or(0);
            findings.push(Finding::error(
                PASS,
                &file.rel,
                line,
                format!(
                    "declared control frame {kind} ({} → {}) has no send site \
                     (`write_frame(…, kind::{kind}, …)`) on the {} side",
                    sender.name(),
                    receiver.name(),
                    sender.name()
                ),
            ));
        }
        if recv_hits.is_empty() {
            let line = sends
                .iter()
                .find(|s| s.kind == *kind)
                .map(|s| s.line)
                .unwrap_or(0);
            findings.push(Finding::error(
                PASS,
                &file.rel,
                line,
                format!(
                    "{kind} is sent by the {} side but never received/checked \
                     on the {} side — a frame kind handled on only one side \
                     deadlocks or drops the handshake",
                    sender.name(),
                    receiver.name()
                ),
            ));
        }
        // A send from the declared *receiver* side inverts the protocol.
        for s in sends.iter().filter(|s| s.kind == *kind && s.side == receiver) {
            findings.push(Finding::error(
                PASS,
                &file.rel,
                s.line,
                format!(
                    "{kind} is sent from the {} side here, but the declared \
                     machine names the {} as its sender",
                    receiver.name(),
                    sender.name()
                ),
            ));
        }
    }

    for s in sends.iter().filter(|s| !declared.contains(s.kind.as_str())) {
        findings.push(Finding::error(
            PASS,
            &file.rel,
            s.line,
            format!(
                "control send of frame kind {} which the declared state \
                 machine does not know — declare the transition or drop the send",
                s.kind
            ),
        ));
    }
    for r in recvs.iter().filter(|s| !declared.contains(s.kind.as_str())) {
        findings.push(Finding::error(
            PASS,
            &file.rel,
            r.line,
            format!(
                "control receive/check of frame kind {} which the declared \
                 state machine does not know",
                r.kind
            ),
        ));
    }

    // The EOF edge: peer close must be handled while awaiting a frame
    // (the `Ok(None)` arm of the read loop), otherwise a dead worker
    // hangs the driver instead of failing the run.
    let code = &file.scan.code;
    let mut eof_handled = false;
    let mut from = 0;
    while let Some(pos) = code[from..].find("Ok(None)") {
        let at = from + pos;
        from = at + 1;
        if !file.in_test(at) {
            eof_handled = true;
            break;
        }
    }
    if !eof_handled {
        findings.push(Finding::error(
            PASS,
            &file.rel,
            0,
            "peer close (EOF) is never handled while awaiting a control frame \
             (no `Ok(None)` arm) — a crashed worker would hang the driver"
                .to_string(),
        ));
    }
}

/// Pattern occurrences of `pat` inside `code[span]`, left-bounded for
/// patterns that start with an identifier (dot-patterns bound themselves).
fn flow_hits(code: &str, span: (usize, usize), pat: &str) -> Vec<usize> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut from = span.0;
    while let Some(pos) = code[from..span.1.min(code.len())].find(pat) {
        let at = from + pos;
        from = at + pat.len();
        if !pat.starts_with('.') && at > 0 && is_ident(bytes[at - 1]) {
            continue;
        }
        out.push(at);
    }
    out
}

/// Flow-order check: within each function body, calls of one flow
/// family must appear in protocol order.
fn flow_check(ws: &Workspace, findings: &mut Vec<Finding>) -> usize {
    let mut checked = 0usize;
    for rel in FLOW_FILES {
        let Some(file) = ws.src.iter().find(|f| f.rel == *rel) else {
            continue;
        };
        let code = &file.scan.code;
        for item in fn_items(code) {
            if file.in_test(item.open) {
                continue;
            }
            for flow in [DRIVER_FLOW, WORKER_FLOW] {
                let mut hits: Vec<(usize, usize)> = Vec::new(); // (offset, index)
                for (idx, pat) in flow.iter().enumerate() {
                    for off in flow_hits(code, (item.open, item.close), pat) {
                        hits.push((off, idx));
                    }
                }
                if hits.is_empty() {
                    continue;
                }
                checked += 1;
                hits.sort();
                for pair in hits.windows(2) {
                    let (prev, cur) = (pair[0], pair[1]);
                    if cur.1 < prev.1 {
                        findings.push(Finding::error(
                            PASS,
                            &file.rel,
                            file.scan.line_of(cur.0),
                            format!(
                                "control-plane call `{}` appears after `{}` in fn \
                                 `{}`, inverting the protocol order ({})",
                                flow[cur.1].trim_matches(|c| c == '.' || c == '('),
                                flow[prev.1].trim_matches(|c| c == '.' || c == '('),
                                item.name,
                                flow.iter()
                                    .map(|p| p.trim_matches(|c| c == '.' || c == '('))
                                    .collect::<Vec<_>>()
                                    .join(" → ")
                            ),
                        ));
                        break; // one report per fn per family
                    }
                }
            }
        }
    }
    checked
}

pub fn run(ws: &Workspace) -> Vec<Finding> {
    let mut findings = Vec::new();
    machine_self_check(&mut findings);

    let control = ws.src.iter().find(|f| f.rel == CONTROL_FILE);
    match control {
        Some(file) => {
            let (sends, recvs) = extract_sites(file);
            conformance(file, &sends, &recvs, &mut findings);
            findings.push(Finding::note(
                PASS,
                &file.rel,
                0,
                format!(
                    "{} send site(s), {} receive site(s) checked against {} \
                     declared transition(s)",
                    sends.len(),
                    recvs.len(),
                    MACHINE.len()
                ),
            ));
        }
        None => {
            findings.push(Finding::note(
                PASS,
                CONTROL_FILE,
                0,
                "no control-plane source in this tree — conformance checks skipped"
                    .to_string(),
            ));
        }
    }

    let flows = flow_check(ws, &mut findings);
    findings.push(Finding::note(
        PASS,
        "rust/src/net",
        0,
        format!("{flows} function flow sequence(s) order-checked"),
    ));
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{find_test_ranges, lexer};

    fn file(rel: &str, src: &str) -> SourceFile {
        let scan = lexer::scan(src);
        let test_ranges = find_test_ranges(&scan.code);
        SourceFile {
            rel: rel.to_string(),
            scan,
            test_ranges,
        }
    }

    #[test]
    fn machine_is_well_formed() {
        let mut findings = Vec::new();
        machine_self_check(&mut findings);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn kind_tokens_and_send_spans() {
        let f = file(
            "rust/src/net/control.rs",
            "fn a(s: &mut S) { write_frame(s, kind::HELLO, 0, b\"\").unwrap(); \
             if f.kind != kind::ASSIGN { return; } }",
        );
        let toks = kind_tokens(&f.scan.code);
        assert_eq!(toks.len(), 2);
        let spans = write_frame_spans(&f.scan.code);
        assert_eq!(spans.len(), 1);
        let (sends, recvs) = extract_sites(&f);
        assert_eq!(sends.len(), 1);
        assert_eq!(sends[0].kind, "HELLO");
        assert_eq!(recvs.len(), 1);
        assert_eq!(recvs[0].kind, "ASSIGN");
    }

    #[test]
    fn sides_attributed_by_impl_block() {
        let f = file(
            "rust/src/net/control.rs",
            "impl ControlPlane { fn g(&mut self) { if f.kind != kind::HELLO {} } }\n\
             impl WorkerLink { fn c(&mut self) { write_frame(s, kind::HELLO, 0, b\"\"); } }\n\
             fn free(f: &Frame) { if f.kind == kind::ERROR {} }",
        );
        let (sends, recvs) = extract_sites(&f);
        assert_eq!(sends[0].side, Side::Worker);
        assert_eq!(recvs[0].side, Side::Driver);
        assert_eq!(recvs[1].side, Side::Neutral);
    }

    #[test]
    fn out_of_order_flow_is_flagged() {
        let src = "fn worker_main(link: &mut WorkerLink) { \
                   link.await_start(1); link.ready(); }";
        let f = file("rust/src/net/runner.rs", src);
        let ws = Workspace {
            root: std::path::PathBuf::from("."),
            src: vec![f],
            benches: Vec::new(),
            cargo_toml: String::new(),
            test_files: Vec::new(),
            docs: Vec::new(),
        };
        let mut findings = Vec::new();
        flow_check(&ws, &mut findings);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("ready"), "{}", findings[0].message);
    }
}
