//! Pass `panics` — panic-path audit with a ratcheting baseline.
//!
//! Counts `.unwrap()` / `.expect(` / `panic!` / `unreachable!` /
//! `todo!` / `unimplemented!` sites in non-test `rust/src/` code
//! (masked source, so doc comments and strings never count) and
//! compares per-file counts against the committed baseline
//! (`rust/src/analysis/baseline.txt`).  New sites fail; counts below
//! baseline also fail ("stale baseline") so the ratchet can only move
//! down.  `--bless` rewrites the baseline from the current tree.
//!
//! Files under `net/` and `coordinator/`, and `engine/supervisor.rs`,
//! are flagged as critical path: a panic there takes down a
//! distributed run or the self-healing supervisor itself, so findings
//! carry an elevated marker.

use std::collections::BTreeMap;
use std::fs;

use crate::analysis::{Finding, SourceFile, Workspace};

const PASS: &str = "panics";

/// Baseline location, relative to the workspace root.
pub const BASELINE_REL: &str = "rust/src/analysis/baseline.txt";

/// Panic-class patterns matched in masked source.  The flag marks
/// macro patterns that need a left identifier boundary (so a
/// hypothetical `dont_panic!(` never counts).
const PATTERNS: &[(&str, bool)] = &[
    (".unwrap()", false),
    (".expect(", false),
    ("panic!(", true),
    ("unreachable!(", true),
    ("todo!(", true),
    ("unimplemented!(", true),
];

/// Path prefixes (and exact files) where a panic kills a distributed
/// run: elevated severity in messages.
const CRITICAL: &[&str] = &[
    "rust/src/net/",
    "rust/src/coordinator/",
    "rust/src/engine/supervisor.rs",
];

fn is_critical(rel: &str) -> bool {
    CRITICAL
        .iter()
        .any(|c| if c.ends_with('/') { rel.starts_with(c) } else { rel == *c })
}

/// One panic-class site in non-test code.
pub struct Site {
    pub line: usize,
    pub what: &'static str,
}

/// All panic-class sites of one file, test regions excluded.
pub fn sites(file: &SourceFile) -> Vec<Site> {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let mut found = Vec::new();
    for &(pat, needs_boundary) in PATTERNS {
        let mut from = 0;
        while let Some(pos) = code[from..].find(pat) {
            let at = from + pos;
            from = at + 1;
            if needs_boundary && at > 0 {
                let prev = bytes[at - 1];
                if prev.is_ascii_alphanumeric() || prev == b'_' {
                    continue;
                }
            }
            if file.in_test(at) {
                continue;
            }
            found.push(Site {
                line: file.scan.line_of(at),
                what: pat,
            });
        }
    }
    found.sort_by_key(|s| s.line);
    found
}

/// Parse baseline text: `<count> <path>` per line, `#` comments.
fn parse_baseline(text: &str) -> Result<BTreeMap<String, usize>, String> {
    let mut map = BTreeMap::new();
    for (idx, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((count, path)) = line.split_once(' ') else {
            return Err(format!("baseline line {}: expected '<count> <path>'", idx + 1));
        };
        let count: usize = count
            .parse()
            .map_err(|_| format!("baseline line {}: bad count '{count}'", idx + 1))?;
        map.insert(path.trim().to_string(), count);
    }
    Ok(map)
}

fn render_baseline(counts: &BTreeMap<String, usize>) -> String {
    let mut out = String::from(
        "# sprobench panic-path baseline: per-file count of .unwrap()/.expect(/\n\
         # panic!/unreachable!/todo!/unimplemented! sites in non-test rust/src code.\n\
         # The `panics` analysis pass fails on any count above (new panic path) or\n\
         # below (stale entry) these numbers, so panic density can only shrink.\n\
         # Regenerate with: sprobench analyze panics --bless\n",
    );
    for (path, count) in counts {
        out.push_str(&format!("{count} {path}\n"));
    }
    out
}

pub fn run(ws: &Workspace, bless: bool) -> Result<Vec<Finding>, String> {
    let mut actual: BTreeMap<String, Vec<Site>> = BTreeMap::new();
    for file in &ws.src {
        let s = sites(file);
        if !s.is_empty() {
            actual.insert(file.rel.clone(), s);
        }
    }
    let total_sites: usize = actual.values().map(|v| v.len()).sum();

    let baseline_path = ws.root.join(BASELINE_REL);
    if bless {
        let counts: BTreeMap<String, usize> = actual
            .iter()
            .map(|(path, s)| (path.clone(), s.len()))
            .collect();
        fs::write(&baseline_path, render_baseline(&counts))
            .map_err(|e| format!("write {}: {e}", baseline_path.display()))?;
        return Ok(vec![Finding::note(
            PASS,
            BASELINE_REL,
            0,
            format!(
                "baseline blessed: {} file(s), {} panic site(s)",
                counts.len(),
                total_sites
            ),
        )]);
    }

    let baseline_text = fs::read_to_string(&baseline_path)
        .map_err(|e| format!("read {} (run `analyze panics --bless` once to create it): {e}", baseline_path.display()))?;
    let baseline = parse_baseline(&baseline_text)?;

    let mut findings = Vec::new();
    for (path, file_sites) in &actual {
        let allowed = baseline.get(path).copied().unwrap_or(0);
        let n = file_sites.len();
        let crit = if is_critical(path) {
            " [critical path: a panic here kills a distributed run]"
        } else {
            ""
        };
        if n > allowed {
            let lines: Vec<String> = file_sites
                .iter()
                .map(|s| format!("{} ({})", s.line, s.what.trim_end_matches('(')))
                .collect();
            findings.push(Finding::error(
                PASS,
                path,
                file_sites[0].line,
                format!(
                    "{n} panic site(s), baseline allows {allowed}{crit} — handle the \
                     error or bless deliberately (`analyze panics --bless`); sites: {}",
                    lines.join(", ")
                ),
            ));
        } else if n < allowed {
            findings.push(Finding::error(
                PASS,
                path,
                0,
                format!(
                    "baseline is stale: allows {allowed} panic site(s) but only {n} \
                     remain — re-bless to ratchet the budget down"
                ),
            ));
        }
    }
    for (path, &allowed) in &baseline {
        if allowed > 0 && !actual.contains_key(path) {
            findings.push(Finding::error(
                PASS,
                path,
                0,
                format!(
                    "baseline is stale: allows {allowed} panic site(s) in a file with \
                     none (removed or cleaned) — re-bless to ratchet the budget down"
                ),
            ));
        }
    }

    findings.push(Finding::note(
        PASS,
        BASELINE_REL,
        0,
        format!(
            "{} panic site(s) across {} file(s), all within baseline",
            total_sites,
            actual.len()
        ),
    ));
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{find_test_ranges, lexer};

    fn file(rel: &str, src: &str) -> SourceFile {
        let scan = lexer::scan(src);
        let test_ranges = find_test_ranges(&scan.code);
        SourceFile {
            rel: rel.to_string(),
            scan,
            test_ranges,
        }
    }

    #[test]
    fn counts_non_test_sites_only() {
        let f = file(
            "rust/src/x.rs",
            "fn a() { b().unwrap(); c().expect(\"x\"); panic!(\"y\"); }\n\
             // commented .unwrap() does not count\n\
             let s = \".unwrap()\";\n\
             #[cfg(test)]\nmod tests { fn t() { z().unwrap(); } }\n",
        );
        let s = sites(&f);
        assert_eq!(s.len(), 3);
        assert!(s.iter().all(|s| s.line == 1));
    }

    #[test]
    fn unwrap_or_else_is_not_a_panic_site() {
        let f = file(
            "rust/src/x.rs",
            "fn a() { m.lock().unwrap_or_else(|p| p.into_inner()); opt.unwrap_or(0); }",
        );
        assert!(sites(&f).is_empty());
    }

    #[test]
    fn macro_boundary() {
        let f = file("rust/src/x.rs", "fn a() { my_panic!(1); panic!(\"x\"); }");
        let s = sites(&f);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn baseline_roundtrip() {
        let mut counts = BTreeMap::new();
        counts.insert("rust/src/a.rs".to_string(), 3);
        counts.insert("rust/src/b.rs".to_string(), 1);
        let parsed = parse_baseline(&render_baseline(&counts)).unwrap();
        assert_eq!(parsed, counts);
    }

    #[test]
    fn critical_paths() {
        assert!(is_critical("rust/src/net/transport.rs"));
        assert!(is_critical("rust/src/coordinator/mod.rs"));
        assert!(is_critical("rust/src/engine/supervisor.rs"));
        assert!(!is_critical("rust/src/engine/task.rs"));
    }
}
