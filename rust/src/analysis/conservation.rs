//! Pass `conservation` — counter provenance from bump to results.json.
//!
//! The PR-7 `parse_failures` bug class: a counter field faithfully
//! incremented on the hot path but dropped on the floor because the
//! fragment/summary merge never folded it, so results.json reported
//! zero forever.  This pass closes that hole structurally:
//!
//! 1. **vocabulary** — the numeric fields of the [`TRACKED`] report
//!    structs (StepStats, TransportStats, TaskReport, EngineReport,
//!    RecoveryStats, ResilienceStats, RunSummary), parsed from their
//!    defining files;
//! 2. **bump sites** — `.field += …` and `.field.fetch_add(…)` in
//!    non-test code under [`BUMP_SCOPE`], excluding `fn merge` bodies
//!    (a merge *is* the conservation step, not a new source);
//! 3. **merge reach** — a bumped field must appear in some `fn merge`
//!    body of a tracked file, or be initialized in a tracked-struct
//!    literal (aggregation constructors like `EngineReport { events_in:
//!    tasks.iter().map(…).sum(), … }` and `Self { … }` inside the
//!    struct's own impl both count);
//! 4. **key reach** — the field must feed a `.set("…")` key inside a
//!    `fn to_json` body of the schema pass's curated emitters
//!    ([`crate::analysis::schema::RESULT_EMITTERS`]), and every derived
//!    key must round-trip against [`schema::emitter_key_table`] — whose
//!    docs sync the schema pass already enforces.
//!
//! A counter bumped but never merged, or merged but never emitted, is
//! an error at the bump site with `file:line` provenance.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::{fn_items, schema, Finding, SourceFile, Workspace};

const PASS: &str = "conservation";

/// Counter-bearing report structs and their defining files.
const TRACKED: &[(&str, &str)] = &[
    ("StepStats", "rust/src/pipelines/mod.rs"),
    ("TransportStats", "rust/src/net/transport.rs"),
    ("TaskReport", "rust/src/engine/task.rs"),
    ("EngineReport", "rust/src/engine/core.rs"),
    ("RecoveryStats", "rust/src/coordinator/mod.rs"),
    ("ResilienceStats", "rust/src/engine/supervisor.rs"),
    ("RunSummary", "rust/src/coordinator/mod.rs"),
];

/// Path prefixes whose increments are audited.
const BUMP_SCOPE: &[&str] = &[
    "rust/src/engine/",
    "rust/src/broker/",
    "rust/src/pipelines/",
    "rust/src/net/",
    "rust/src/coordinator/",
];

/// Field types that count as counters.
const NUMERIC: &[&str] = &["u64", "u32", "usize", "f64"];

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Word-bounded occurrences of `word`.
fn word_occurrences(code: &str, word: &str) -> Vec<usize> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        from = at + word.len();
        let left = at == 0 || !is_ident(bytes[at - 1]);
        let end = at + word.len();
        let right = end >= bytes.len() || !is_ident(bytes[end]);
        if left && right {
            out.push(at);
        }
    }
    out
}

/// Does `.field` occur word-bounded (on the right) in `text`?
fn dotted_field(text: &str, field: &str) -> bool {
    let bytes = text.as_bytes();
    let needle = format!(".{field}");
    let mut from = 0;
    while let Some(pos) = text[from..].find(&needle) {
        let at = from + pos;
        from = at + 1;
        let end = at + needle.len();
        if end >= bytes.len() || !is_ident(bytes[end]) {
            return true;
        }
    }
    false
}

/// Numeric field names of `struct name { … }` in its defining file.
fn struct_fields(file: &SourceFile, name: &str) -> Vec<String> {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let needle = format!("struct {name}");
    let Some(at) = word_occurrences(code, &needle).first().copied() else {
        return Vec::new();
    };
    let mut i = at + needle.len();
    while i < bytes.len() && bytes[i] != b'{' && bytes[i] != b';' && bytes[i] != b'(' {
        i += 1;
    }
    if i >= bytes.len() || bytes[i] != b'{' {
        return Vec::new(); // tuple/unit struct: nothing to track
    }
    let open = i;
    let mut depth = 0usize;
    while i < bytes.len() {
        match bytes[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
        i += 1;
    }
    let body = &code[open + 1..i.min(bytes.len())];
    let mut fields = Vec::new();
    for decl in body.split(',') {
        let Some((lhs, ty)) = decl.split_once(':') else {
            continue;
        };
        let field = lhs.trim().rsplit(char::is_whitespace).next().unwrap_or("");
        let ty = ty.trim();
        if !field.is_empty()
            && field.bytes().all(is_ident)
            && NUMERIC.contains(&ty)
        {
            fields.push(field.to_string());
        }
    }
    fields
}

/// Byte spans of `impl … Name … { … }` blocks (inherent and trait
/// impls), where `Self { … }` literals construct `Name`.
fn impl_spans(code: &str, name: &str) -> Vec<(usize, usize)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    for at in word_occurrences(code, "impl") {
        let mut i = at;
        while i < bytes.len() && bytes[i] != b'{' && bytes[i] != b';' {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'{' {
            continue;
        }
        if word_occurrences(&code[at..i], name).is_empty() {
            continue;
        }
        let open = i;
        let mut depth = 0usize;
        while i < bytes.len() {
            match bytes[i] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        out.push((open, (i + 1).min(bytes.len())));
    }
    out
}

/// Field names initialized in struct-literal expressions of `name`
/// anywhere in `file` (including `Self { … }` inside the struct's own
/// impl blocks): both `field: value` inits and shorthand `field,`.
fn literal_inits(file: &SourceFile, name: &str, out: &mut BTreeSet<String>) {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let selfs = impl_spans(code, name);

    let mut starts: Vec<usize> = word_occurrences(code, name);
    for at in word_occurrences(code, "Self") {
        if selfs.iter().any(|&(s, e)| at >= s && at < e) {
            starts.push(at);
        }
    }
    for at in starts {
        let word_len = if code[at..].starts_with("Self") { 4 } else { name.len() };
        let mut i = at + word_len;
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'{' {
            continue;
        }
        // Reject declarations and function bodies: a statement prefix
        // containing `impl`/`struct`/`fn`/… means this `{` opens an
        // item, not a literal.
        let mut s = at;
        while s > 0 && !matches!(bytes[s - 1], b';' | b'{' | b'}') {
            s -= 1;
        }
        let prefix = &code[s..at];
        if ["impl", "struct", "enum", "trait", "fn", "for", "where"]
            .iter()
            .any(|kw| !word_occurrences(prefix, kw).is_empty())
        {
            continue;
        }
        // Scan the literal body for field keys at brace depth 1,
        // paren/bracket depth 0 (so `vec![a, b]` elements and call
        // arguments never read as shorthand inits).
        let open = i;
        let mut depth = 0i32;
        let mut sub = 0i32;
        let mut j = open;
        let close;
        loop {
            if j >= bytes.len() {
                close = bytes.len();
                break;
            }
            match bytes[j] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        close = j;
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let mut k = open + 1;
        depth = 1;
        while k < close {
            match bytes[k] {
                b'{' => depth += 1,
                b'}' => depth -= 1,
                b'(' | b'[' => sub += 1,
                b')' | b']' => sub -= 1,
                c if is_ident(c) && depth == 1 && sub == 0 => {
                    let start = k;
                    while k < close && is_ident(bytes[k]) {
                        k += 1;
                    }
                    let word = &code[start..k];
                    // Preceded (past whitespace) by `{` or `,`?
                    let mut p = start;
                    while p > open && (bytes[p - 1] as char).is_whitespace() {
                        p -= 1;
                    }
                    let at_field_position = p == open + 1 || matches!(bytes[p - 1], b'{' | b',');
                    if !at_field_position {
                        continue;
                    }
                    // Followed (past whitespace) by `:` (init), or by
                    // `,`/`}` (shorthand)?
                    let mut q = k;
                    while q < close && (bytes[q] as char).is_whitespace() {
                        q += 1;
                    }
                    let init = q < close && bytes[q] == b':' && !code[q..].starts_with("::");
                    let shorthand = q >= close || matches!(bytes[q], b',' | b'}');
                    if init || shorthand {
                        out.insert(word.to_string());
                    }
                    continue;
                }
                _ => {}
            }
            k += 1;
        }
    }
}

/// One audited increment site.
struct Bump {
    field: String,
    file: String,
    line: usize,
}

/// `.field += …` and `.field.fetch_add(…)` sites in non-test scope
/// code, excluding `fn merge` bodies.
fn bump_sites(file: &SourceFile, vocab: &BTreeSet<String>) -> Vec<Bump> {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let merge_spans: Vec<(usize, usize)> = fn_items(code)
        .into_iter()
        .filter(|f| f.name == "merge" || f.name == "merge_results")
        .map(|f| (f.open, f.close))
        .collect();
    let in_merge = |off: usize| merge_spans.iter().any(|&(s, e)| off >= s && off < e);

    let mut out = Vec::new();
    for field in vocab {
        let needle = format!(".{field}");
        let mut from = 0;
        while let Some(pos) = code[from..].find(&needle) {
            let at = from + pos;
            from = at + 1;
            let end = at + needle.len();
            if end < bytes.len() && is_ident(bytes[end]) {
                continue;
            }
            if file.in_test(at) || in_merge(at) {
                continue;
            }
            let rest = &code[end..];
            let trimmed = rest.trim_start();
            let bumped = trimmed.starts_with("+=") || rest.starts_with(".fetch_add(");
            if bumped {
                out.push(Bump {
                    field: field.clone(),
                    file: file.rel.clone(),
                    line: file.scan.line_of(at),
                });
            }
        }
    }
    out
}

/// Map each vocabulary field to the results.json keys whose `.set`
/// argument span reads it, inside the curated emitters' `fn to_json`
/// bodies.  Public: the flow-analysis integration tests round-trip
/// this table against [`schema::emitter_key_table`].
pub fn field_key_table(ws: &Workspace) -> BTreeMap<String, BTreeSet<String>> {
    let vocab = vocabulary(ws);
    let mut table: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for file in &ws.src {
        if !schema::RESULT_EMITTERS.contains(&file.rel.as_str()) {
            continue;
        }
        let code = &file.scan.code;
        let bytes = code.as_bytes();
        for (open, close) in schema::to_json_bodies(file) {
            let mut at = open;
            while let Some(pos) = code[at..close].find(".set(") {
                let call = at + pos;
                at = call + 5;
                // Literal keys only, exactly like the schema pass: the
                // quote must directly follow the paren (dynamic keys
                // like `set(point.name(), …)` are skipped).
                let mut q = call + 5;
                while q < bytes.len() && (bytes[q] == b' ' || bytes[q] == b'\n') {
                    q += 1;
                }
                if q >= bytes.len() || bytes[q] != b'"' {
                    continue;
                }
                let key = match file.scan.string_at_or_after(q) {
                    Some(lit) if lit.offset == q => lit.value.clone(),
                    _ => continue,
                };
                // The argument span of this `.set(…)` call.
                let popen = call + 4;
                let mut depth = 0usize;
                let mut j = popen;
                while j < bytes.len() {
                    match bytes[j] {
                        b'(' => depth += 1,
                        b')' => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                let args = &code[popen..j.min(bytes.len())];
                for (field, _) in vocab.iter() {
                    if dotted_field(args, field) {
                        table.entry(field.clone()).or_default().insert(key.clone());
                    }
                }
            }
        }
    }
    table
}

/// The tracked vocabulary: numeric field name → structs declaring it.
fn vocabulary(ws: &Workspace) -> BTreeMap<String, Vec<&'static str>> {
    let mut vocab: BTreeMap<String, Vec<&'static str>> = BTreeMap::new();
    for (name, rel) in TRACKED {
        let Some(file) = ws.src.iter().find(|f| f.rel == *rel) else {
            continue;
        };
        for field in struct_fields(file, name) {
            vocab.entry(field).or_default().push(name);
        }
    }
    vocab
}

pub fn run(ws: &Workspace) -> Vec<Finding> {
    let mut findings = Vec::new();
    let vocab = vocabulary(ws);
    if vocab.is_empty() {
        findings.push(Finding::note(
            PASS,
            "rust/src",
            0,
            "no tracked report structs in this tree — conservation checks skipped"
                .to_string(),
        ));
        return findings;
    }
    let fields: BTreeSet<String> = vocab.keys().cloned().collect();

    // Where does each field get conserved?  (a) `fn merge` bodies in
    // tracked files; (b) tracked-struct literal initializations
    // anywhere in the tree.
    let tracked_files: BTreeSet<&str> = TRACKED.iter().map(|(_, rel)| *rel).collect();
    let mut merged: BTreeSet<String> = BTreeSet::new();
    for file in &ws.src {
        if tracked_files.contains(file.rel.as_str()) {
            let code = &file.scan.code;
            for item in fn_items(code) {
                if item.name != "merge" && item.name != "merge_results" {
                    continue;
                }
                let body = &code[item.open..item.close];
                for field in &fields {
                    if dotted_field(body, field) {
                        merged.insert(field.clone());
                    }
                }
            }
        }
        for (name, _) in TRACKED {
            literal_inits(file, name, &mut merged);
        }
    }
    merged.retain(|f| fields.contains(f));

    let key_table = field_key_table(ws);
    let schema_table = schema::emitter_key_table(ws);

    let mut bumps: Vec<Bump> = Vec::new();
    for file in &ws.src {
        if BUMP_SCOPE.iter().any(|p| file.rel.starts_with(p)) {
            bumps.extend(bump_sites(file, &fields));
        }
    }

    let mut seen: BTreeSet<&str> = BTreeSet::new();
    for bump in &bumps {
        if !seen.insert(bump.field.as_str()) {
            continue; // one verdict per field, at its first bump site
        }
        let structs = vocab
            .get(&bump.field)
            .map(|v| v.join("/"))
            .unwrap_or_default();
        if !merged.contains(&bump.field) {
            findings.push(Finding::error(
                PASS,
                &bump.file,
                bump.line,
                format!(
                    "counter `{}` ({structs}) is incremented here but never folded \
                     by a `fn merge` in a tracked file nor initialized in any \
                     tracked-struct literal — it is silently lost before \
                     results.json (the `parse_failures` bug class)",
                    bump.field
                ),
            ));
            continue;
        }
        let keys = key_table.get(&bump.field);
        match keys {
            None => findings.push(Finding::error(
                PASS,
                &bump.file,
                bump.line,
                format!(
                    "counter `{}` ({structs}) is incremented and merged but never \
                     read by a `.set(\"…\")` emission in the curated results.json \
                     emitters — the merged value goes nowhere",
                    bump.field
                ),
            )),
            Some(keys) => {
                for key in keys {
                    if !schema_table.contains_key(key) {
                        findings.push(Finding::error(
                            PASS,
                            &bump.file,
                            bump.line,
                            format!(
                                "counter `{}` maps to results key \"{key}\" which the \
                                 schema pass's emitter key table does not contain — \
                                 the two passes disagree about the emitters",
                                bump.field
                            ),
                        ));
                    }
                }
            }
        }
    }

    findings.push(Finding::note(
        PASS,
        "rust/src",
        0,
        format!(
            "{} counter field(s) across {} tracked struct(s); {} bump site(s) \
             audited; {} field(s) mapped to {} results key(s)",
            fields.len(),
            TRACKED.len(),
            bumps.len(),
            key_table.len(),
            key_table.values().flatten().collect::<BTreeSet<_>>().len()
        ),
    ));
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{find_test_ranges, lexer};

    fn file(rel: &str, src: &str) -> SourceFile {
        let scan = lexer::scan(src);
        let test_ranges = find_test_ranges(&scan.code);
        SourceFile {
            rel: rel.to_string(),
            scan,
            test_ranges,
        }
    }

    #[test]
    fn numeric_fields_parsed_from_struct() {
        let f = file(
            "rust/src/pipelines/mod.rs",
            "pub struct StepStats { pub events_in: u64, pub name: String, \
             pub rate: f64, pub step: StepStats }",
        );
        let fields = struct_fields(&f, "StepStats");
        assert_eq!(fields, vec!["events_in".to_string(), "rate".to_string()]);
    }

    #[test]
    fn literal_inits_cover_shorthand_and_self() {
        let f = file(
            "rust/src/engine/supervisor.rs",
            "pub struct ResilienceStats { pub injected: u64, pub healed: u64 }\n\
             impl ResilienceStats { fn from(healed: u64) -> Self { \
             Self { injected: 1, healed } } }",
        );
        let mut inits = BTreeSet::new();
        literal_inits(&f, "ResilienceStats", &mut inits);
        assert!(inits.contains("injected"), "{inits:?}");
        assert!(inits.contains("healed"), "{inits:?}");
    }

    #[test]
    fn struct_declaration_is_not_a_literal() {
        let f = file(
            "rust/src/engine/core.rs",
            "pub struct EngineReport { pub events_in: u64 }",
        );
        let mut inits = BTreeSet::new();
        literal_inits(&f, "EngineReport", &mut inits);
        assert!(inits.is_empty(), "{inits:?}");
    }

    #[test]
    fn bump_sites_skip_merge_bodies_and_tests() {
        let f = file(
            "rust/src/engine/task.rs",
            "impl T { fn tick(&mut self) { self.events_in += 1; }\n\
             fn merge(&mut self, o: &T) { self.events_in += o.events_in; } }\n\
             #[cfg(test)] mod tests { fn t() { x.events_in += 9; } }",
        );
        let vocab: BTreeSet<String> = ["events_in".to_string()].into();
        let bumps = bump_sites(&f, &vocab);
        assert_eq!(bumps.len(), 1, "only the tick() bump counts");
        assert_eq!(bumps[0].line, 1);
    }
}
