//! Pass `schema` — results/bench schema ⇄ documentation sync.
//!
//! Direction 1 (undocumented emission): every string key the
//! results.json emitters (`fn to_json` bodies in [`RESULT_EMITTERS`])
//! and the hotpath bench writer put into a document must be mentioned,
//! word-bounded, somewhere in `README.md` or `docs/ARCHITECTURE.md`.
//! A new metric that never reaches the docs is how schema drift
//! starts.
//!
//! Direction 2 (ghost documentation): every key inside a fenced
//! ```json / ```jsonc schema block in those docs must be emitted by
//! *some* `.set("…")` site in the tree — otherwise the docs describe
//! fields that no code produces.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::{Finding, SourceFile, Workspace};

const PASS: &str = "schema";

/// Files whose `fn to_json` bodies emit results.json blocks the docs
/// must describe.  Public: the conservation pass walks the same
/// emitters to map counter fields to their output keys.
pub const RESULT_EMITTERS: &[&str] = &[
    "rust/src/coordinator/mod.rs",
    "rust/src/net/transport.rs",
    "rust/src/engine/supervisor.rs",
    "rust/src/pipelines/mod.rs",
];

/// The bench writer: every key it sets lands in BENCH_hotpath.json.
const BENCH_EMITTER: &str = "rust/benches/hotpath_micro.rs";

fn is_ident_key(s: &str) -> bool {
    !s.is_empty()
        && s.bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'_')
        && !s.as_bytes()[0].is_ascii_digit()
}

/// Literal keys of `.set("…", …)` calls inside `[from, to)` of the
/// masked code, with the line of each.  Dynamic keys (`set(point
/// .name(), …)`) are skipped — the mask has no quote right after the
/// paren there.
pub fn set_keys_in(file: &SourceFile, from: usize, to: usize) -> Vec<(String, usize)> {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let mut keys = Vec::new();
    let mut at = from;
    while let Some(pos) = code[at..to.min(code.len())].find(".set(") {
        let call = at + pos;
        at = call + 5;
        let mut q = call + 5;
        while q < bytes.len() && (bytes[q] == b' ' || bytes[q] == b'\n') {
            q += 1;
        }
        if q >= bytes.len() || bytes[q] != b'"' {
            continue; // dynamic key expression
        }
        if let Some(lit) = file.scan.string_at_or_after(q) {
            if lit.offset == q {
                keys.push((lit.value.clone(), lit.line));
            }
        }
    }
    keys
}

/// Byte ranges of `fn to_json` bodies in masked code.
pub fn to_json_bodies(file: &SourceFile) -> Vec<(usize, usize)> {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let mut bodies = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find("fn to_json") {
        let at = from + pos;
        from = at + 1;
        let mut i = at;
        while i < bytes.len() && bytes[i] != b'{' && bytes[i] != b';' {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'{' {
            continue;
        }
        let open = i;
        let mut depth = 0usize;
        while i < bytes.len() {
            match bytes[i] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        bodies.push((open, (i + 1).min(bytes.len())));
    }
    bodies
}

/// Keys inside fenced ```json / ```jsonc blocks of a doc, with lines.
fn doc_schema_keys(text: &str) -> Vec<(String, usize)> {
    let mut keys = Vec::new();
    let mut in_schema_block = false;
    for (idx, line) in text.lines().enumerate() {
        let trimmed = line.trim_start();
        if let Some(info) = trimmed.strip_prefix("```") {
            let info = info.trim();
            in_schema_block = !in_schema_block && (info == "json" || info == "jsonc");
            continue;
        }
        if !in_schema_block {
            continue;
        }
        // `"key":` occurrences, quote-aware: a colon must directly
        // follow the closing quote (so string *values* never match).
        let bytes = line.as_bytes();
        let mut i = 0;
        while i < bytes.len() {
            if bytes[i] == b'"' {
                let mut j = i + 1;
                while j < bytes.len() && bytes[j] != b'"' {
                    j += 1;
                }
                if j < bytes.len() {
                    let key = &line[i + 1..j];
                    let mut k = j + 1;
                    while k < bytes.len() && bytes[k] == b' ' {
                        k += 1;
                    }
                    if k < bytes.len() && bytes[k] == b':' && is_ident_key(key) {
                        keys.push((key.to_string(), idx + 1));
                    }
                    i = j + 1;
                    continue;
                }
            }
            i += 1;
        }
    }
    keys
}

/// The curated emitter key table: every literal `.set("…")` key inside
/// a `fn to_json` body of [`RESULT_EMITTERS`] (plus the bench writer),
/// mapped to its first emission site.  Direction 1 of this pass checks
/// the table against the docs; the conservation pass round-trips its
/// counter→key mapping against it.
pub fn emitter_key_table(ws: &Workspace) -> BTreeMap<String, (String, usize)> {
    let mut table: BTreeMap<String, (String, usize)> = BTreeMap::new();
    for file in &ws.src {
        if !RESULT_EMITTERS.contains(&file.rel.as_str()) {
            continue;
        }
        for (open, close) in to_json_bodies(file) {
            for (key, line) in set_keys_in(file, open, close) {
                table.entry(key).or_insert((file.rel.clone(), line));
            }
        }
    }
    for file in &ws.benches {
        if file.rel == BENCH_EMITTER {
            for (key, line) in set_keys_in(file, 0, file.scan.code.len()) {
                table.entry(key).or_insert((file.rel.clone(), line));
            }
        }
    }
    table
}

pub fn run(ws: &Workspace) -> Vec<Finding> {
    let mut findings = Vec::new();

    // Direction 1 inputs: curated emitter keys.
    let emitted_documentable = emitter_key_table(ws);

    // Direction 2 vocabulary: every literal `.set` key anywhere.
    let mut all_emitted: BTreeSet<String> = BTreeSet::new();
    for file in ws.src.iter().chain(ws.benches.iter()) {
        for (key, _) in set_keys_in(file, 0, file.scan.code.len()) {
            all_emitted.insert(key);
        }
    }

    for (key, (file, line)) in &emitted_documentable {
        if !ws.documented(key) {
            findings.push(Finding::error(
                PASS,
                file,
                *line,
                format!(
                    "results key \"{key}\" is emitted but never mentioned in \
                     README.md or docs/ARCHITECTURE.md — document it (schema \
                     drift starts here)"
                ),
            ));
        }
    }

    for (doc, text) in &ws.docs {
        for (key, line) in doc_schema_keys(text) {
            if !all_emitted.contains(&key) {
                findings.push(Finding::error(
                    PASS,
                    doc,
                    line,
                    format!(
                        "documented schema key \"{key}\" is not emitted by any \
                         `.set(\"…\")` site in the tree — stale docs or a typo"
                    ),
                ));
            }
        }
    }

    findings.push(Finding::note(
        PASS,
        "rust/src",
        0,
        format!(
            "{} documentable emitter key(s), {} emitted key(s) total, {} doc file(s) checked",
            emitted_documentable.len(),
            all_emitted.len(),
            ws.docs.len()
        ),
    ));
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{find_test_ranges, lexer};

    fn file(rel: &str, src: &str) -> SourceFile {
        let scan = lexer::scan(src);
        let test_ranges = find_test_ranges(&scan.code);
        SourceFile {
            rel: rel.to_string(),
            scan,
            test_ranges,
        }
    }

    #[test]
    fn set_keys_extracted_literal_only() {
        let f = file(
            "rust/src/coordinator/mod.rs",
            "impl X { pub fn to_json(&self) -> Json { let mut j = Json::obj(); \
             j.set(\"alpha\", v); j.set(point.name(), p); j.set(\"beta\", w); j } }",
        );
        let bodies = to_json_bodies(&f);
        assert_eq!(bodies.len(), 1);
        let keys: Vec<String> = set_keys_in(&f, bodies[0].0, bodies[0].1)
            .into_iter()
            .map(|(k, _)| k)
            .collect();
        assert_eq!(keys, vec!["alpha".to_string(), "beta".to_string()]);
    }

    #[test]
    fn doc_keys_from_fenced_blocks_only() {
        let text = "prose \"not_a_key\": here\n```jsonc\n{\n  \"real_key\": 1, // c\n  \
                    \"nested\": { \"inner\": \"a: b\" }\n}\n```\n```yaml\nyaml_key: 1\n```\n";
        let keys: Vec<String> = doc_schema_keys(text).into_iter().map(|(k, _)| k).collect();
        assert_eq!(
            keys,
            vec![
                "real_key".to_string(),
                "nested".to_string(),
                "inner".to_string()
            ]
        );
    }

    #[test]
    fn ellipsis_placeholders_skipped() {
        let text = "```json\n{\"op\": \"window\", \"events_in\": …, \"…\": 1}\n```\n";
        let keys: Vec<String> = doc_schema_keys(text).into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["op".to_string(), "events_in".to_string()]);
    }
}
