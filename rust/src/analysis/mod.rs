//! `sprobench analyze` — zero-dependency static analysis over the
//! repository's own sources.
//!
//! Eight PRs of structural invariants (test registration, results.json
//! schema sync, struct-literal exhaustiveness, lock ordering, panic
//! density) were checked by hand-greps until this subsystem turned
//! them into machine-checked passes.  Everything here is pure std: the
//! scanner ([`lexer`]) masks comments and string contents so the
//! passes can pattern-match source text without false positives, and
//! each pass reads the tree through one shared [`Workspace`].
//!
//! Passes (`sprobench analyze --all`, see `docs/ARCHITECTURE.md`
//! §Static analysis):
//!
//! | name      | invariant |
//! |-----------|-----------|
//! | `tests`   | every `rust/tests/*.rs` has a `[[test]]` target in `Cargo.toml` |
//! | `panics`  | `unwrap()`/`expect()`/`panic!` density in non-test `rust/src/` never grows (ratchet vs [`panics`] baseline) |
//! | `locks`   | the static `Mutex`/`util::chan` acquisition graph is cycle-free and no blocking channel op runs under a held guard |
//! | `locks2`  | the lock pass, interprocedural one level deep: guards held across calls into same-file helpers that acquire or block |
//! | `schema`  | results.json / BENCH_hotpath.json keys ⇄ README + ARCHITECTURE schema docs |
//! | `structs` | report-bearing structs are constructed field-exhaustively (no `..` functional update) |
//! | `grammar` | config keys accepted by the YAML/spec parsers ⇄ the documented grammar |
//! | `protocol` | driver/worker control-plane sends and receives conform to one declared state machine (HELLO → ASSIGN → READY → START → FRAGMENT, ERROR/EOF edges), and call order matches the flow |
//! | `channels` | static channel topology: every constructed endpoint has a drain, every blocking drain loop a finish/abort path, no capacity-zero or unbounded constructions |
//! | `conservation` | every counter field bumped in the data/control plane reaches a merge site and a results.json key |
//!
//! Findings print human-readably, serialize to `analysis_report.json`
//! (and SARIF 2.1.0 via `--sarif`), and any `error`-severity finding
//! makes the run exit nonzero — the CI `analyze` job is the standing
//! gate.  `--changed-since <rev>` demotes errors in files untouched
//! since `rev` to `[pre-existing]` notes for PR annotation.

pub mod channels;
pub mod conservation;
pub mod grammar;
pub mod lexer;
pub mod locks;
pub mod panics;
pub mod protocol;
pub mod schema;
pub mod structs;
pub mod tests_reg;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Severity of a [`Finding`].  Only `Error` findings fail the run;
/// `Note` findings are inventory (construction-site enumerations,
/// per-pass statistics) surfaced in verbose output and the JSON report.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Note,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Note => write!(f, "note"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// One analysis finding, anchored to `file:line` (line 0 means the
/// finding is about the file or the tree as a whole).
#[derive(Clone, Debug)]
pub struct Finding {
    pub pass: &'static str,
    pub severity: Severity,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn error(pass: &'static str, file: &str, line: usize, message: String) -> Finding {
        Finding {
            pass,
            severity: Severity::Error,
            file: file.to_string(),
            line,
            message,
        }
    }

    pub fn note(pass: &'static str, file: &str, line: usize, message: String) -> Finding {
        Finding {
            pass,
            severity: Severity::Note,
            file: file.to_string(),
            line,
            message,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("pass", Json::Str(self.pass.to_string()));
        j.set("severity", Json::Str(self.severity.to_string()));
        j.set("file", Json::Str(self.file.clone()));
        j.set("line", Json::Int(self.line as i64));
        j.set("message", Json::Str(self.message.clone()));
        j
    }
}

/// One scanned source file.
pub struct SourceFile {
    /// Path relative to the workspace root, forward slashes.
    pub rel: String,
    pub scan: lexer::Scan,
    /// Byte ranges of `#[cfg(test)]`-gated items (the in-file unit-test
    /// modules); passes that audit production code skip these.
    pub test_ranges: Vec<(usize, usize)>,
}

impl SourceFile {
    /// Is this byte offset inside a `#[cfg(test)]` region?
    pub fn in_test(&self, offset: usize) -> bool {
        self.test_ranges
            .iter()
            .any(|&(s, e)| offset >= s && offset < e)
    }
}

/// The analyzed tree: sources, manifest, tests listing, and docs, each
/// read once and shared by every pass.
pub struct Workspace {
    pub root: PathBuf,
    /// `rust/src/**/*.rs`, sorted by relative path.
    pub src: Vec<SourceFile>,
    /// `rust/benches/*.rs`, sorted.
    pub benches: Vec<SourceFile>,
    /// Raw `Cargo.toml` text (empty if absent — fixture trees).
    pub cargo_toml: String,
    /// File stems of `rust/tests/*.rs`, sorted.
    pub test_files: Vec<String>,
    /// Documentation files checked by the sync passes:
    /// `(relative path, raw text)`.
    pub docs: Vec<(String, String)>,
}

impl Workspace {
    /// Load a tree rooted at `root`.  Missing directories load as
    /// empty sets so pass fixtures only need the files their pass
    /// reads; a missing root is an error.
    pub fn load(root: &Path) -> Result<Workspace, String> {
        if !root.is_dir() {
            return Err(format!("analysis root {} is not a directory", root.display()));
        }
        let mut src = Vec::new();
        collect_sources(root, &root.join("rust").join("src"), &mut src)?;
        let mut benches = Vec::new();
        collect_sources(root, &root.join("rust").join("benches"), &mut benches)?;
        src.sort_by(|a, b| a.rel.cmp(&b.rel));
        benches.sort_by(|a, b| a.rel.cmp(&b.rel));

        let cargo_toml = fs::read_to_string(root.join("Cargo.toml")).unwrap_or_default();

        let mut test_files = Vec::new();
        if let Ok(entries) = fs::read_dir(root.join("rust").join("tests")) {
            for entry in entries.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                if let Some(stem) = name.strip_suffix(".rs") {
                    test_files.push(stem.to_string());
                }
            }
        }
        test_files.sort();

        let mut docs = Vec::new();
        for rel in ["README.md", "docs/ARCHITECTURE.md"] {
            if let Ok(text) = fs::read_to_string(root.join(rel)) {
                docs.push((rel.to_string(), text));
            }
        }

        Ok(Workspace {
            root: root.to_path_buf(),
            src,
            benches,
            cargo_toml,
            test_files,
            docs,
        })
    }

    /// Does `word` occur with word boundaries anywhere in the loaded
    /// documentation?  This is the "is it documented" predicate shared
    /// by the schema and grammar sync passes.
    pub fn documented(&self, word: &str) -> bool {
        self.docs.iter().any(|(_, text)| contains_word(text, word))
    }
}

/// Word-boundary containment: `needle` occurs in `hay` not flanked by
/// identifier characters (`_`, alphanumerics) — so `p50` does not
/// count as documenting `p5`, nor `send_wait_us` as `wait_us`, while a
/// dotted path like `data_plane.speedup` documents both segments.
pub fn contains_word(hay: &str, needle: &str) -> bool {
    if needle.is_empty() {
        return false;
    }
    let hb = hay.as_bytes();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let start = from + pos;
        let end = start + needle.len();
        let left_ok = start == 0 || {
            let c = hb[start - 1];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        let right_ok = end >= hb.len() || {
            let c = hb[end];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        if left_ok && right_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

fn collect_sources(root: &Path, dir: &Path, out: &mut Vec<SourceFile>) -> Result<(), String> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(()), // absent dir: empty set (fixtures)
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_sources(root, &path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            let raw = fs::read_to_string(&path)
                .map_err(|e| format!("read {}: {e}", path.display()))?;
            let scan = lexer::scan(&raw);
            let test_ranges = find_test_ranges(&scan.code);
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(SourceFile {
                rel,
                scan,
                test_ranges,
            });
        }
    }
    Ok(())
}

/// Byte ranges of items gated by `#[cfg(test)]` in masked code: from
/// the attribute to the matching close brace of the item's block.  An
/// attribute whose item has no block (hits `;` first) contributes no
/// range.
pub fn find_test_ranges(code: &str) -> Vec<(usize, usize)> {
    const ATTR: &str = "#[cfg(test)]";
    let mut ranges = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find(ATTR) {
        let attr_at = from + pos;
        let mut i = attr_at + ATTR.len();
        let bytes = code.as_bytes();
        // Find the item's opening brace; a `;` first means no block.
        let mut open = None;
        while i < bytes.len() {
            match bytes[i] {
                b'{' => {
                    open = Some(i);
                    break;
                }
                b';' => break,
                _ => i += 1,
            }
        }
        if let Some(open) = open {
            let mut depth = 0usize;
            let mut j = open;
            while j < bytes.len() {
                match bytes[j] {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            ranges.push((attr_at, (j + 1).min(bytes.len())));
            from = (j + 1).min(code.len()).max(attr_at + 1);
        } else {
            from = attr_at + ATTR.len();
        }
    }
    ranges
}

/// One `fn` item in masked code: its name, parameter-list text, and
/// the byte span of its body (offset of `{` to just past the matching
/// `}`).  Trait-method declarations without a body are skipped; nested
/// items are included.  The generic section between name and parameter
/// list is skipped angle-aware so `Fn(...)` bounds never masquerade as
/// the parameter list.  Shared by the flow-sensitive passes
/// ([`protocol`], [`channels`], [`conservation`], `locks2`).
pub struct FnItem {
    pub name: String,
    pub params: String,
    pub open: usize,
    pub close: usize,
}

/// Every `fn` item with a body in masked code, in source order.
pub fn fn_items(code: &str) -> Vec<FnItem> {
    let bytes = code.as_bytes();
    let ident = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find("fn ") {
        let at = from + pos;
        from = at + 3;
        if at > 0 && ident(bytes[at - 1]) {
            continue; // an identifier that merely ends in `fn`
        }
        let mut i = at + 3;
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        let name_start = i;
        while i < bytes.len() && ident(bytes[i]) {
            i += 1;
        }
        if i == name_start {
            continue; // `fn(...)` pointer type
        }
        let name = code[name_start..i].to_string();
        // Skip generics angle-aware; stop at the parameter list.
        let mut angle = 0usize;
        let mut popen = None;
        while i < bytes.len() {
            match bytes[i] {
                b'<' => angle += 1,
                b'>' => angle = angle.saturating_sub(1),
                b'(' if angle == 0 => {
                    popen = Some(i);
                    break;
                }
                b'{' | b';' => break,
                _ => {}
            }
            i += 1;
        }
        let Some(popen) = popen else { continue };
        let mut depth = 0usize;
        let mut j = popen;
        while j < bytes.len() {
            match bytes[j] {
                b'(' => depth += 1,
                b')' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let params = code[popen + 1..j.min(bytes.len())].to_string();
        // Body: the first `{` after the signature; `;` first = no body.
        let mut k = (j + 1).min(bytes.len());
        let mut bopen = None;
        while k < bytes.len() {
            match bytes[k] {
                b'{' => {
                    bopen = Some(k);
                    break;
                }
                b';' => break,
                _ => k += 1,
            }
        }
        let Some(bopen) = bopen else { continue };
        let mut depth = 0usize;
        let mut m = bopen;
        while m < bytes.len() {
            match bytes[m] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            m += 1;
        }
        out.push(FnItem {
            name,
            params,
            open: bopen,
            close: (m + 1).min(bytes.len()),
        });
    }
    out
}

/// What [`run`] executes and where it writes.
pub struct AnalyzeOptions {
    pub root: PathBuf,
    /// Pass names to run (subset of [`PASS_NAMES`]); empty means all.
    pub passes: Vec<String>,
    /// Regenerate the panic-path baseline instead of checking it.
    pub bless: bool,
    /// Diff-aware mode: demote errors in files unchanged since this
    /// git revision to `[pre-existing]` notes.
    pub changed_since: Option<String>,
}

/// All pass names, in execution order.
pub const PASS_NAMES: &[&str] = &[
    "tests",
    "panics",
    "locks",
    "locks2",
    "schema",
    "structs",
    "grammar",
    "protocol",
    "channels",
    "conservation",
];

/// The outcome of one analysis run.
pub struct Report {
    pub passes: Vec<String>,
    pub findings: Vec<Finding>,
    /// The revision `--changed-since` compared against, if any.
    pub changed_since: Option<String>,
}

impl Report {
    pub fn error_count(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.severity == Severity::Error)
            .count()
    }

    pub fn note_count(&self) -> usize {
        self.findings.len() - self.error_count()
    }

    /// The `analysis_report.json` document.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", Json::Str("sprobench.analysis/v1".to_string()));
        j.set(
            "passes",
            Json::Arr(self.passes.iter().map(|p| Json::Str(p.clone())).collect()),
        );
        j.set(
            "findings",
            Json::Arr(self.findings.iter().map(|f| f.to_json()).collect()),
        );
        j.set("errors", Json::Int(self.error_count() as i64));
        j.set("notes", Json::Int(self.note_count() as i64));
        if let Some(rev) = &self.changed_since {
            j.set("changed_since", Json::Str(rev.clone()));
        }
        j
    }

    /// SARIF 2.1.0 rendering (one run, one rule per pass) for GitHub
    /// code scanning.  Errors map to SARIF `error`, inventory notes to
    /// `note`; line 0 (whole-file/tree findings) clamps to 1 as the
    /// format requires a positive region.
    pub fn to_sarif(&self) -> Json {
        let mut rules = Vec::new();
        for pass in &self.passes {
            let mut rule = Json::obj();
            rule.set("id", Json::Str(pass.clone()));
            let mut name = Json::obj();
            name.set("text", Json::Str(format!("sprobench analyze pass `{pass}`")));
            rule.set("shortDescription", name);
            rules.push(rule);
        }
        let mut driver = Json::obj();
        driver.set("name", Json::Str("sprobench-analyze".to_string()));
        driver.set(
            "informationUri",
            Json::Str("https://github.com/sprobench/sprobench".to_string()),
        );
        driver.set("rules", Json::Arr(rules));
        let mut tool = Json::obj();
        tool.set("driver", driver);

        let mut results = Vec::new();
        for f in &self.findings {
            let mut message = Json::obj();
            message.set("text", Json::Str(f.message.clone()));
            let mut artifact = Json::obj();
            artifact.set("uri", Json::Str(f.file.clone()));
            let mut region = Json::obj();
            region.set("startLine", Json::Int(f.line.max(1) as i64));
            let mut physical = Json::obj();
            physical.set("artifactLocation", artifact);
            physical.set("region", region);
            let mut location = Json::obj();
            location.set("physicalLocation", physical);
            let mut result = Json::obj();
            result.set("ruleId", Json::Str(f.pass.to_string()));
            result.set(
                "level",
                Json::Str(
                    match f.severity {
                        Severity::Error => "error",
                        Severity::Note => "note",
                    }
                    .to_string(),
                ),
            );
            result.set("message", message);
            result.set("locations", Json::Arr(vec![location]));
            results.push(result);
        }

        let mut run = Json::obj();
        run.set("tool", tool);
        run.set("results", Json::Arr(results));
        let mut j = Json::obj();
        j.set(
            "$schema",
            Json::Str(
                "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/Schemata/sarif-schema-2.1.0.json"
                    .to_string(),
            ),
        );
        j.set("version", Json::Str("2.1.0".to_string()));
        j.set("runs", Json::Arr(vec![run]));
        j
    }

    /// Human-readable rendering; notes included only when `verbose`.
    pub fn render(&self, verbose: bool) -> String {
        let mut out = String::new();
        for f in &self.findings {
            if f.severity == Severity::Note && !verbose {
                continue;
            }
            let loc = if f.line > 0 {
                format!("{}:{}", f.file, f.line)
            } else {
                f.file.clone()
            };
            out.push_str(&format!("{}: [{}] {}: {}\n", f.severity, f.pass, loc, f.message));
        }
        out.push_str(&format!(
            "analyze: {} pass(es), {} error(s), {} note(s)\n",
            self.passes.len(),
            self.error_count(),
            self.note_count()
        ));
        out
    }
}

/// Run the selected passes over the tree at `opts.root`.
pub fn run(opts: &AnalyzeOptions) -> Result<Report, String> {
    let ws = Workspace::load(&opts.root)?;
    let selected: Vec<String> = if opts.passes.is_empty() {
        PASS_NAMES.iter().map(|s| s.to_string()).collect()
    } else {
        for p in &opts.passes {
            if !PASS_NAMES.contains(&p.as_str()) {
                return Err(format!(
                    "unknown analysis pass '{p}' (known: {})",
                    PASS_NAMES.join(", ")
                ));
            }
        }
        opts.passes.clone()
    };

    let mut findings = Vec::new();
    for pass in &selected {
        match pass.as_str() {
            "tests" => findings.extend(tests_reg::run(&ws)),
            "panics" => findings.extend(panics::run(&ws, opts.bless)?),
            "locks" => findings.extend(locks::run(&ws)),
            "locks2" => findings.extend(locks::run_deep(&ws)),
            "schema" => findings.extend(schema::run(&ws)),
            "structs" => findings.extend(structs::run(&ws)),
            "grammar" => findings.extend(grammar::run(&ws)),
            "protocol" => findings.extend(protocol::run(&ws)),
            "channels" => findings.extend(channels::run(&ws)),
            "conservation" => findings.extend(conservation::run(&ws)),
            _ => {}
        }
    }

    let mut report = Report {
        passes: selected,
        findings,
        changed_since: None,
    };
    if let Some(rev) = &opts.changed_since {
        let changed = git_changed_files(&opts.root, rev)?;
        apply_changed_filter(&mut report, &changed, rev);
    }
    Ok(report)
}

/// Paths changed since `rev`, as reported by `git diff --name-only`
/// (workspace-relative, forward slashes — the same shape as
/// [`Finding::file`]).  A git failure (no repo, unknown rev) is a hard
/// error: silently treating everything as unchanged would demote every
/// finding.
pub fn git_changed_files(root: &Path, rev: &str) -> Result<std::collections::BTreeSet<String>, String> {
    let out = std::process::Command::new("git")
        .arg("-C")
        .arg(root)
        .arg("diff")
        .arg("--name-only")
        .arg(rev)
        .output()
        .map_err(|e| format!("--changed-since: failed to run git: {e}"))?;
    if !out.status.success() {
        return Err(format!(
            "--changed-since: git diff --name-only {rev} failed: {}",
            String::from_utf8_lossy(&out.stderr).trim()
        ));
    }
    Ok(String::from_utf8_lossy(&out.stdout)
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .map(str::to_string)
        .collect())
}

/// Demote error findings anchored in files *not* in `changed` to
/// `[pre-existing]` notes, leaving errors in touched files fatal.
/// Tree-level findings (empty file) stay fatal — they cannot be blamed
/// on an untouched file.  Public so the filter is unit-testable
/// without a git checkout.
pub fn apply_changed_filter(
    report: &mut Report,
    changed: &std::collections::BTreeSet<String>,
    rev: &str,
) {
    let mut demoted = 0usize;
    for f in &mut report.findings {
        if f.severity == Severity::Error && !f.file.is_empty() && !changed.contains(&f.file) {
            f.severity = Severity::Note;
            f.message = format!("[pre-existing vs {rev}] {}", f.message);
            demoted += 1;
        }
    }
    report.changed_since = Some(rev.to_string());
    report.findings.push(Finding::note(
        "analyze",
        "",
        0,
        format!(
            "--changed-since {rev}: {} changed file(s), {demoted} pre-existing \
             finding(s) demoted to notes",
            changed.len()
        ),
    ));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_boundaries() {
        assert!(contains_word("the `p50` column", "p50"));
        assert!(!contains_word("send_wait_us only", "wait_us"));
        assert!(!contains_word("p50", "p5"));
        assert!(contains_word("a key_skew: 0.3 here", "key_skew"));
        assert!(contains_word("engine.parallelism", "parallelism"));
        assert!(contains_word("engine.parallelism", "engine.parallelism"));
    }

    #[test]
    fn test_ranges_cover_cfg_test_mod() {
        let code = lexer::scan(
            "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn b() { x.unwrap(); }\n}\nfn c() {}\n",
        );
        let ranges = find_test_ranges(&code.code);
        assert_eq!(ranges.len(), 1);
        let unwrap_at = code.code.find(".unwrap").unwrap();
        assert!(ranges[0].0 < unwrap_at && unwrap_at < ranges[0].1);
        let c_at = code.code.rfind("fn c").unwrap();
        assert!(c_at >= ranges[0].1);
    }

    #[test]
    fn cfg_test_on_use_item_has_no_range() {
        let code = lexer::scan("#[cfg(test)]\nuse std::fmt;\nfn main() { body(); }\n");
        assert!(find_test_ranges(&code.code).is_empty());
    }

    #[test]
    fn fn_items_parses_bodies_and_skips_declarations() {
        let src = "trait T { fn decl(&self) -> u8; }\n\
                   fn plain(a: u8, b: &str) -> u8 { helper(a) }\n\
                   fn generic<F: FnOnce() -> u8>(f: F) { f(); { nested(); } }\n";
        let items = fn_items(&lexer::scan(src).code);
        let names: Vec<&str> = items.iter().map(|i| i.name.as_str()).collect();
        assert_eq!(names, ["plain", "generic"]);
        assert_eq!(items[0].params, "a: u8, b: &str");
        assert!(src[items[1].open..items[1].close].contains("nested"));
        assert_eq!(items[1].params, "f: F");
    }

    #[test]
    fn changed_filter_demotes_untouched_files_only() {
        let mut report = Report {
            passes: vec!["locks".to_string()],
            findings: vec![
                Finding::error("locks", "rust/src/a.rs", 3, "touched".to_string()),
                Finding::error("locks", "rust/src/b.rs", 4, "untouched".to_string()),
                Finding::error("panics", "", 0, "tree-level".to_string()),
            ],
            changed_since: None,
        };
        let changed = std::collections::BTreeSet::from(["rust/src/a.rs".to_string()]);
        apply_changed_filter(&mut report, &changed, "origin/main");
        assert_eq!(report.error_count(), 2, "touched + tree-level stay fatal");
        assert!(report.findings[1].message.starts_with("[pre-existing vs origin/main]"));
        assert_eq!(report.changed_since.as_deref(), Some("origin/main"));
    }
}
