//! `sprobench analyze` — zero-dependency static analysis over the
//! repository's own sources.
//!
//! Eight PRs of structural invariants (test registration, results.json
//! schema sync, struct-literal exhaustiveness, lock ordering, panic
//! density) were checked by hand-greps until this subsystem turned
//! them into machine-checked passes.  Everything here is pure std: the
//! scanner ([`lexer`]) masks comments and string contents so the
//! passes can pattern-match source text without false positives, and
//! each pass reads the tree through one shared [`Workspace`].
//!
//! Passes (`sprobench analyze --all`, see `docs/ARCHITECTURE.md`
//! §Static analysis):
//!
//! | name      | invariant |
//! |-----------|-----------|
//! | `tests`   | every `rust/tests/*.rs` has a `[[test]]` target in `Cargo.toml` |
//! | `panics`  | `unwrap()`/`expect()`/`panic!` density in non-test `rust/src/` never grows (ratchet vs [`panics`] baseline) |
//! | `locks`   | the static `Mutex`/`util::chan` acquisition graph is cycle-free and no blocking channel op runs under a held guard |
//! | `schema`  | results.json / BENCH_hotpath.json keys ⇄ README + ARCHITECTURE schema docs |
//! | `structs` | report-bearing structs are constructed field-exhaustively (no `..` functional update) |
//! | `grammar` | config keys accepted by the YAML/spec parsers ⇄ the documented grammar |
//!
//! Findings print human-readably, serialize to `analysis_report.json`,
//! and any `error`-severity finding makes the run exit nonzero — the
//! CI `analyze` job is the standing gate.

pub mod grammar;
pub mod lexer;
pub mod locks;
pub mod panics;
pub mod schema;
pub mod structs;
pub mod tests_reg;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Severity of a [`Finding`].  Only `Error` findings fail the run;
/// `Note` findings are inventory (construction-site enumerations,
/// per-pass statistics) surfaced in verbose output and the JSON report.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Note,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Note => write!(f, "note"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// One analysis finding, anchored to `file:line` (line 0 means the
/// finding is about the file or the tree as a whole).
#[derive(Clone, Debug)]
pub struct Finding {
    pub pass: &'static str,
    pub severity: Severity,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn error(pass: &'static str, file: &str, line: usize, message: String) -> Finding {
        Finding {
            pass,
            severity: Severity::Error,
            file: file.to_string(),
            line,
            message,
        }
    }

    pub fn note(pass: &'static str, file: &str, line: usize, message: String) -> Finding {
        Finding {
            pass,
            severity: Severity::Note,
            file: file.to_string(),
            line,
            message,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("pass", Json::Str(self.pass.to_string()));
        j.set("severity", Json::Str(self.severity.to_string()));
        j.set("file", Json::Str(self.file.clone()));
        j.set("line", Json::Int(self.line as i64));
        j.set("message", Json::Str(self.message.clone()));
        j
    }
}

/// One scanned source file.
pub struct SourceFile {
    /// Path relative to the workspace root, forward slashes.
    pub rel: String,
    pub scan: lexer::Scan,
    /// Byte ranges of `#[cfg(test)]`-gated items (the in-file unit-test
    /// modules); passes that audit production code skip these.
    pub test_ranges: Vec<(usize, usize)>,
}

impl SourceFile {
    /// Is this byte offset inside a `#[cfg(test)]` region?
    pub fn in_test(&self, offset: usize) -> bool {
        self.test_ranges
            .iter()
            .any(|&(s, e)| offset >= s && offset < e)
    }
}

/// The analyzed tree: sources, manifest, tests listing, and docs, each
/// read once and shared by every pass.
pub struct Workspace {
    pub root: PathBuf,
    /// `rust/src/**/*.rs`, sorted by relative path.
    pub src: Vec<SourceFile>,
    /// `rust/benches/*.rs`, sorted.
    pub benches: Vec<SourceFile>,
    /// Raw `Cargo.toml` text (empty if absent — fixture trees).
    pub cargo_toml: String,
    /// File stems of `rust/tests/*.rs`, sorted.
    pub test_files: Vec<String>,
    /// Documentation files checked by the sync passes:
    /// `(relative path, raw text)`.
    pub docs: Vec<(String, String)>,
}

impl Workspace {
    /// Load a tree rooted at `root`.  Missing directories load as
    /// empty sets so pass fixtures only need the files their pass
    /// reads; a missing root is an error.
    pub fn load(root: &Path) -> Result<Workspace, String> {
        if !root.is_dir() {
            return Err(format!("analysis root {} is not a directory", root.display()));
        }
        let mut src = Vec::new();
        collect_sources(root, &root.join("rust").join("src"), &mut src)?;
        let mut benches = Vec::new();
        collect_sources(root, &root.join("rust").join("benches"), &mut benches)?;
        src.sort_by(|a, b| a.rel.cmp(&b.rel));
        benches.sort_by(|a, b| a.rel.cmp(&b.rel));

        let cargo_toml = fs::read_to_string(root.join("Cargo.toml")).unwrap_or_default();

        let mut test_files = Vec::new();
        if let Ok(entries) = fs::read_dir(root.join("rust").join("tests")) {
            for entry in entries.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                if let Some(stem) = name.strip_suffix(".rs") {
                    test_files.push(stem.to_string());
                }
            }
        }
        test_files.sort();

        let mut docs = Vec::new();
        for rel in ["README.md", "docs/ARCHITECTURE.md"] {
            if let Ok(text) = fs::read_to_string(root.join(rel)) {
                docs.push((rel.to_string(), text));
            }
        }

        Ok(Workspace {
            root: root.to_path_buf(),
            src,
            benches,
            cargo_toml,
            test_files,
            docs,
        })
    }

    /// Does `word` occur with word boundaries anywhere in the loaded
    /// documentation?  This is the "is it documented" predicate shared
    /// by the schema and grammar sync passes.
    pub fn documented(&self, word: &str) -> bool {
        self.docs.iter().any(|(_, text)| contains_word(text, word))
    }
}

/// Word-boundary containment: `needle` occurs in `hay` not flanked by
/// identifier characters (`_`, alphanumerics) — so `p50` does not
/// count as documenting `p5`, nor `send_wait_us` as `wait_us`, while a
/// dotted path like `data_plane.speedup` documents both segments.
pub fn contains_word(hay: &str, needle: &str) -> bool {
    if needle.is_empty() {
        return false;
    }
    let hb = hay.as_bytes();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let start = from + pos;
        let end = start + needle.len();
        let left_ok = start == 0 || {
            let c = hb[start - 1];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        let right_ok = end >= hb.len() || {
            let c = hb[end];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        if left_ok && right_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

fn collect_sources(root: &Path, dir: &Path, out: &mut Vec<SourceFile>) -> Result<(), String> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(()), // absent dir: empty set (fixtures)
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_sources(root, &path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            let raw = fs::read_to_string(&path)
                .map_err(|e| format!("read {}: {e}", path.display()))?;
            let scan = lexer::scan(&raw);
            let test_ranges = find_test_ranges(&scan.code);
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(SourceFile {
                rel,
                scan,
                test_ranges,
            });
        }
    }
    Ok(())
}

/// Byte ranges of items gated by `#[cfg(test)]` in masked code: from
/// the attribute to the matching close brace of the item's block.  An
/// attribute whose item has no block (hits `;` first) contributes no
/// range.
pub fn find_test_ranges(code: &str) -> Vec<(usize, usize)> {
    const ATTR: &str = "#[cfg(test)]";
    let mut ranges = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find(ATTR) {
        let attr_at = from + pos;
        let mut i = attr_at + ATTR.len();
        let bytes = code.as_bytes();
        // Find the item's opening brace; a `;` first means no block.
        let mut open = None;
        while i < bytes.len() {
            match bytes[i] {
                b'{' => {
                    open = Some(i);
                    break;
                }
                b';' => break,
                _ => i += 1,
            }
        }
        if let Some(open) = open {
            let mut depth = 0usize;
            let mut j = open;
            while j < bytes.len() {
                match bytes[j] {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            ranges.push((attr_at, (j + 1).min(bytes.len())));
            from = (j + 1).min(code.len()).max(attr_at + 1);
        } else {
            from = attr_at + ATTR.len();
        }
    }
    ranges
}

/// What [`run`] executes and where it writes.
pub struct AnalyzeOptions {
    pub root: PathBuf,
    /// Pass names to run (subset of [`PASS_NAMES`]); empty means all.
    pub passes: Vec<String>,
    /// Regenerate the panic-path baseline instead of checking it.
    pub bless: bool,
}

/// All pass names, in execution order.
pub const PASS_NAMES: &[&str] = &["tests", "panics", "locks", "schema", "structs", "grammar"];

/// The outcome of one analysis run.
pub struct Report {
    pub passes: Vec<String>,
    pub findings: Vec<Finding>,
}

impl Report {
    pub fn error_count(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.severity == Severity::Error)
            .count()
    }

    pub fn note_count(&self) -> usize {
        self.findings.len() - self.error_count()
    }

    /// The `analysis_report.json` document.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", Json::Str("sprobench.analysis/v1".to_string()));
        j.set(
            "passes",
            Json::Arr(self.passes.iter().map(|p| Json::Str(p.clone())).collect()),
        );
        j.set(
            "findings",
            Json::Arr(self.findings.iter().map(|f| f.to_json()).collect()),
        );
        j.set("errors", Json::Int(self.error_count() as i64));
        j.set("notes", Json::Int(self.note_count() as i64));
        j
    }

    /// Human-readable rendering; notes included only when `verbose`.
    pub fn render(&self, verbose: bool) -> String {
        let mut out = String::new();
        for f in &self.findings {
            if f.severity == Severity::Note && !verbose {
                continue;
            }
            let loc = if f.line > 0 {
                format!("{}:{}", f.file, f.line)
            } else {
                f.file.clone()
            };
            out.push_str(&format!("{}: [{}] {}: {}\n", f.severity, f.pass, loc, f.message));
        }
        out.push_str(&format!(
            "analyze: {} pass(es), {} error(s), {} note(s)\n",
            self.passes.len(),
            self.error_count(),
            self.note_count()
        ));
        out
    }
}

/// Run the selected passes over the tree at `opts.root`.
pub fn run(opts: &AnalyzeOptions) -> Result<Report, String> {
    let ws = Workspace::load(&opts.root)?;
    let selected: Vec<String> = if opts.passes.is_empty() {
        PASS_NAMES.iter().map(|s| s.to_string()).collect()
    } else {
        for p in &opts.passes {
            if !PASS_NAMES.contains(&p.as_str()) {
                return Err(format!(
                    "unknown analysis pass '{p}' (known: {})",
                    PASS_NAMES.join(", ")
                ));
            }
        }
        opts.passes.clone()
    };

    let mut findings = Vec::new();
    for pass in &selected {
        match pass.as_str() {
            "tests" => findings.extend(tests_reg::run(&ws)),
            "panics" => findings.extend(panics::run(&ws, opts.bless)?),
            "locks" => findings.extend(locks::run(&ws)),
            "schema" => findings.extend(schema::run(&ws)),
            "structs" => findings.extend(structs::run(&ws)),
            "grammar" => findings.extend(grammar::run(&ws)),
            _ => {}
        }
    }

    Ok(Report {
        passes: selected,
        findings,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_boundaries() {
        assert!(contains_word("the `p50` column", "p50"));
        assert!(!contains_word("send_wait_us only", "wait_us"));
        assert!(!contains_word("p50", "p5"));
        assert!(contains_word("a key_skew: 0.3 here", "key_skew"));
        assert!(contains_word("engine.parallelism", "parallelism"));
        assert!(contains_word("engine.parallelism", "engine.parallelism"));
    }

    #[test]
    fn test_ranges_cover_cfg_test_mod() {
        let code = lexer::scan(
            "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn b() { x.unwrap(); }\n}\nfn c() {}\n",
        );
        let ranges = find_test_ranges(&code.code);
        assert_eq!(ranges.len(), 1);
        let unwrap_at = code.code.find(".unwrap").unwrap();
        assert!(ranges[0].0 < unwrap_at && unwrap_at < ranges[0].1);
        let c_at = code.code.rfind("fn c").unwrap();
        assert!(c_at >= ranges[0].1);
    }

    #[test]
    fn cfg_test_on_use_item_has_no_range() {
        let code = lexer::scan("#[cfg(test)]\nuse std::fmt;\nfn main() { body(); }\n");
        assert!(find_test_ranges(&code.code).is_empty());
    }
}
