//! Pass `grammar` — config-grammar ⇄ documentation sync.
//!
//! Direction A (undocumented knob): every key the spec/YAML parsers
//! accept — section names fed to `section(…, "k")`, scalar keys fed to
//! the `get_*` helpers, and op-parameter `.get("k")` lookups in
//! [`PARSER_FILES`] — must be mentioned, word-bounded, in `README.md`
//! or `docs/ARCHITECTURE.md`.  A knob nobody can discover is a knob
//! nobody benchmarks with.
//!
//! Direction B (ghost documentation): every mapping key inside a
//! fenced ```yaml block of those docs must be part of the parser's
//! vocabulary (any identifier-like string literal in the parser files,
//! plus [`EXAMPLE_KEYS`] for illustrative user-defined names) —
//! otherwise the documented example silently does nothing when pasted.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::{Finding, SourceFile, Workspace};

const PASS: &str = "grammar";

/// Files implementing the config surface.
const PARSER_FILES: &[&str] = &["rust/src/config/schema.rs", "rust/src/config/mod.rs"];

/// Getter call patterns whose first string-literal argument is an
/// accepted config key.
const KEY_GETTERS: &[&str] = &[
    "section(",
    "get_str(",
    "get_u64(",
    "get_u32(",
    "get_f64(",
    "get_bool(",
    "get_bytes(",
    "get_duration(",
    ".get(",
];

/// Names that appear in documented examples as *user-chosen*
/// identifiers (custom operator names registered via
/// `OperatorRegistry`, experiment labels) rather than grammar keys.
const EXAMPLE_KEYS: &[&str] = &["alert_filter", "threshold_c"];

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn ident_like(s: &str) -> bool {
    !s.is_empty()
        && s.bytes().all(|b| is_ident(b) || b == b'.')
        && !s.as_bytes()[0].is_ascii_digit()
}

/// Accepted keys: first string literal after each getter call.
fn accepted_keys(file: &SourceFile) -> Vec<(String, usize)> {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let mut keys = Vec::new();
    for &getter in KEY_GETTERS {
        let mut from = 0;
        while let Some(pos) = code[from..].find(getter) {
            let at = from + pos;
            from = at + 1;
            // Word boundary on the left for non-method patterns, so
            // `subsection(` does not match `section(`.
            if !getter.starts_with('.') && at > 0 && is_ident(bytes[at - 1]) {
                continue;
            }
            if file.in_test(at) {
                continue;
            }
            // The key is the first string literal before the call's
            // closing paren at depth 0; in every getter signature the
            // key precedes any other string argument.
            let open = at + getter.len() - 1;
            let mut depth = 0usize;
            let mut close = open;
            while close < bytes.len() {
                match bytes[close] {
                    b'(' => depth += 1,
                    b')' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                close += 1;
            }
            if let Some(lit) = file.scan.string_at_or_after(open) {
                if lit.offset < close && ident_like(&lit.value) {
                    keys.push((lit.value.clone(), lit.line));
                }
            }
        }
    }
    keys
}

/// Every identifier-like string literal of a parser file (non-test):
/// the vocabulary for direction B.  Broader than [`accepted_keys`] on
/// purpose — op names matched by `match` arms, enum values
/// (`merge_if_open`, `tcp`), and unit suffixes all live in literals.
fn vocabulary(file: &SourceFile) -> BTreeSet<String> {
    file.scan
        .strings
        .iter()
        .filter(|lit| !file.in_test(lit.offset))
        .filter(|lit| ident_like(&lit.value))
        .map(|lit| lit.value.clone())
        .collect()
}

/// Mapping keys in fenced ```yaml blocks: `key:` or `- key:` lines,
/// comments stripped.
fn doc_yaml_keys(text: &str) -> Vec<(String, usize)> {
    let mut keys = Vec::new();
    let mut in_yaml = false;
    for (idx, line) in text.lines().enumerate() {
        let trimmed = line.trim_start();
        if let Some(info) = trimmed.strip_prefix("```") {
            in_yaml = !in_yaml && info.trim() == "yaml";
            continue;
        }
        if !in_yaml {
            continue;
        }
        let no_comment = match line.find('#') {
            Some(at) => &line[..at],
            None => line,
        };
        let mut item = no_comment.trim_start();
        while let Some(rest) = item.strip_prefix("- ") {
            item = rest.trim_start();
        }
        let Some((key, _)) = item.split_once(':') else {
            continue;
        };
        let key = key.trim();
        if ident_like(key) {
            keys.push((key.to_string(), idx + 1));
        }
    }
    keys
}

pub fn run(ws: &Workspace) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut accepted: BTreeMap<String, (String, usize)> = BTreeMap::new();
    let mut vocab: BTreeSet<String> = EXAMPLE_KEYS.iter().map(|s| s.to_string()).collect();
    for file in &ws.src {
        if !PARSER_FILES.contains(&file.rel.as_str()) {
            continue;
        }
        for (key, line) in accepted_keys(file) {
            accepted.entry(key).or_insert((file.rel.clone(), line));
        }
        vocab.extend(vocabulary(file));
    }

    for (key, (file, line)) in &accepted {
        if !ws.documented(key) {
            findings.push(Finding::error(
                PASS,
                file,
                *line,
                format!(
                    "config key \"{key}\" is accepted by the parser but never \
                     mentioned in README.md or docs/ARCHITECTURE.md — document \
                     the knob"
                ),
            ));
        }
    }

    for (doc, text) in &ws.docs {
        for (key, line) in doc_yaml_keys(text) {
            // Dotted override keys (`engine.parallelism: 4`) are valid
            // when every segment is vocabulary.
            let ok = vocab.contains(&key)
                || (key.contains('.') && key.split('.').all(|seg| vocab.contains(seg)));
            if !ok {
                findings.push(Finding::error(
                    PASS,
                    doc,
                    line,
                    format!(
                        "documented config key \"{key}\" is not part of the \
                         parser vocabulary ({}) — a pasted example would \
                         silently ignore it",
                        PARSER_FILES.join(", ")
                    ),
                ));
            }
        }
    }

    findings.push(Finding::note(
        PASS,
        "rust/src/config",
        0,
        format!(
            "{} accepted key(s), {} vocabulary literal(s)",
            accepted.len(),
            vocab.len()
        ),
    ));
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{find_test_ranges, lexer};

    fn file(rel: &str, src: &str) -> SourceFile {
        let scan = lexer::scan(src);
        let test_ranges = find_test_ranges(&scan.code);
        SourceFile {
            rel: rel.to_string(),
            scan,
            test_ranges,
        }
    }

    #[test]
    fn getter_keys_extracted() {
        let f = file(
            "rust/src/config/schema.rs",
            "fn parse(root: &Json) { let sec = section(root, \"workload\"); \
             let r = get_u64(&sec, \"rate\", 1_000); \
             let p = m.get(\"modulo\").and_then(J::as_i64); }",
        );
        let keys: Vec<String> = accepted_keys(&f).into_iter().map(|(k, _)| k).collect();
        assert_eq!(
            keys,
            vec![
                "workload".to_string(),
                "rate".to_string(),
                "modulo".to_string()
            ]
        );
    }

    #[test]
    fn default_string_is_not_the_key() {
        let f = file(
            "rust/src/config/schema.rs",
            "fn parse(sec: &Json) { let s = get_str(sec, \"mode\", \"wall\"); }",
        );
        let keys: Vec<String> = accepted_keys(&f).into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["mode".to_string()]);
    }

    #[test]
    fn yaml_doc_keys() {
        let text = "```yaml\nbenchmark:\n  name: x  # comment\n  rate: 1M\n\
                    engine.parallelism: 4\n  - emit: aggregates\n```\nprose key: no\n";
        let keys: Vec<String> = doc_yaml_keys(text).into_iter().map(|(k, _)| k).collect();
        assert_eq!(
            keys,
            vec![
                "benchmark".to_string(),
                "name".to_string(),
                "rate".to_string(),
                "engine.parallelism".to_string(),
                "emit".to_string()
            ]
        );
    }
}
