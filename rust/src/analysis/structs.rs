//! Pass `structs` — struct-literal exhaustiveness for report-bearing
//! structs.
//!
//! Extending [`RunSummary`](crate::coordinator::RunSummary) (or any
//! struct in [`WATCHED`]) means updating every literal-construction
//! site — the audit each PR used to do by hand.  This pass enumerates
//! those sites as notes (the work-list) and *fails* on functional-
//! update construction (`Struct { field, ..base }`) in non-test code:
//! a `..` site silently absorbs newly added fields, which is exactly
//! how a new metric ends up zero in one code path and populated in
//! another.  (Pattern-position `..` rests, like
//! `let Struct { x, .. } = v`, are fine — the compiler still forces a
//! decision when reading fields.)

use crate::analysis::{Finding, SourceFile, Workspace};

const PASS: &str = "structs";

/// Structs whose construction sites carry report/results data.
pub const WATCHED: &[&str] = &[
    "RunSummary",
    "RecoveryStats",
    "StepStats",
    "TaskReport",
    "EngineReport",
    "TransportStats",
    "FaultOutcome",
    "ResilienceStats",
];

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Keywords that make `Name {` a non-literal context.
const NON_LITERAL_PRECEDING: &[&str] = &[
    "struct", "enum", "union", "trait", "impl", "mod", "fn", "for",
];

/// The word immediately before byte `at` (skipping whitespace).
fn word_before(code: &str, at: usize) -> &str {
    let bytes = code.as_bytes();
    let mut end = at;
    while end > 0 && (bytes[end - 1] as char).is_whitespace() {
        end -= 1;
    }
    let mut start = end;
    while start > 0 && is_ident(bytes[start - 1]) {
        start -= 1;
    }
    &code[start..end]
}

/// One `Name { … }` occurrence.
struct LiteralSite {
    line: usize,
    /// `..` followed by a base expression inside the braces.
    functional_update: bool,
    in_test: bool,
}

fn literal_sites(file: &SourceFile, name: &str) -> Vec<LiteralSite> {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let mut sites = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find(name) {
        let at = from + pos;
        from = at + 1;
        // Word-bounded occurrence of the type name…
        if at > 0 && is_ident(bytes[at - 1]) {
            continue;
        }
        let end = at + name.len();
        if end < bytes.len() && is_ident(bytes[end]) {
            continue;
        }
        // …followed by `{` (possibly across whitespace)…
        let mut i = end;
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'{' {
            continue;
        }
        // …not preceded by an item keyword, and not a return-type
        // position (`fn f() -> Name {` opens the fn body, not a
        // literal).
        if NON_LITERAL_PRECEDING.contains(&word_before(code, at)) {
            continue;
        }
        let mut p = at;
        while p > 0 && (bytes[p - 1] as char).is_whitespace() {
            p -= 1;
        }
        if p >= 2 && &code[p - 2..p] == "->" {
            continue;
        }
        let open = i;
        let mut depth = 0usize;
        let mut functional_update = false;
        while i < bytes.len() {
            match bytes[i] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                b'.' if depth == 1 && i + 1 < bytes.len() && bytes[i + 1] == b'.' => {
                    // `..` at literal depth, in field position (right
                    // after `{` or `,` — so a range like `drain(..)`
                    // inside a field value never matches): a base
                    // expression after it is functional update; a
                    // closing brace after it is a pattern rest.
                    let mut back = i;
                    while back > open && (bytes[back - 1] as char).is_whitespace() {
                        back -= 1;
                    }
                    let field_position =
                        back > 0 && (bytes[back - 1] == b'{' || bytes[back - 1] == b',');
                    let mut k = i + 2;
                    while k < bytes.len() && (bytes[k] as char).is_whitespace() {
                        k += 1;
                    }
                    if field_position && k < bytes.len() && bytes[k] != b'}' {
                        functional_update = true;
                    }
                    i += 1; // past the second dot next loop step
                }
                _ => {}
            }
            i += 1;
        }
        sites.push(LiteralSite {
            line: file.scan.line_of(open),
            functional_update,
            in_test: file.in_test(at),
        });
    }
    sites
}

pub fn run(ws: &Workspace) -> Vec<Finding> {
    let mut findings = Vec::new();
    for name in WATCHED {
        let mut total = 0usize;
        for file in &ws.src {
            for site in literal_sites(file, name) {
                total += 1;
                if site.functional_update && !site.in_test {
                    findings.push(Finding::error(
                        PASS,
                        &file.rel,
                        site.line,
                        format!(
                            "functional-update (`..`) construction of report-bearing \
                             `{name}` — a new field would be silently absorbed here; \
                             list every field explicitly so the compiler flags \
                             extension sites"
                        ),
                    ));
                } else {
                    findings.push(Finding::note(
                        PASS,
                        &file.rel,
                        site.line,
                        format!(
                            "`{name}` construction site{}",
                            if site.in_test { " (test code)" } else { "" }
                        ),
                    ));
                }
            }
        }
        findings.push(Finding::note(
            PASS,
            "rust/src",
            0,
            format!("`{name}`: {total} literal construction site(s)"),
        ));
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{find_test_ranges, lexer};

    fn file(rel: &str, src: &str) -> SourceFile {
        let scan = lexer::scan(src);
        let test_ranges = find_test_ranges(&scan.code);
        SourceFile {
            rel: rel.to_string(),
            scan,
            test_ranges,
        }
    }

    #[test]
    fn literal_vs_item_contexts() {
        let f = file(
            "rust/src/x.rs",
            "pub struct RunSummary { pub a: u64 }\n\
             impl RunSummary { fn f() -> RunSummary { RunSummary { a: 1 } } }\n",
        );
        let sites = literal_sites(&f, "RunSummary");
        assert_eq!(sites.len(), 1);
        assert!(!sites[0].functional_update);
    }

    #[test]
    fn functional_update_detected() {
        let f = file(
            "rust/src/x.rs",
            "fn f(b: StepStats) -> StepStats { StepStats { events_in: 1, ..b } }",
        );
        let sites = literal_sites(&f, "StepStats");
        assert_eq!(sites.len(), 1);
        assert!(sites[0].functional_update);
    }

    #[test]
    fn default_spread_detected() {
        let f = file(
            "rust/src/x.rs",
            "fn f() -> StepStats { StepStats { events_in: 1, ..Default::default() } }",
        );
        assert!(literal_sites(&f, "StepStats")[0].functional_update);
    }

    #[test]
    fn pattern_rest_is_not_functional_update() {
        let f = file(
            "rust/src/x.rs",
            "fn f(v: RunSummary) { let RunSummary { name, .. } = v; let _ = name; }",
        );
        let sites = literal_sites(&f, "RunSummary");
        assert_eq!(sites.len(), 1);
        assert!(!sites[0].functional_update);
    }

    #[test]
    fn nested_braces_do_not_confuse_depth() {
        let f = file(
            "rust/src/x.rs",
            "fn f() -> TaskReport { TaskReport { stats: StepStats { events_in: 0 }, id: 1 } }",
        );
        let outer = literal_sites(&f, "TaskReport");
        assert_eq!(outer.len(), 1);
        assert!(!outer[0].functional_update);
    }

    #[test]
    fn test_code_spread_is_note_not_error() {
        let f = file(
            "rust/src/x.rs",
            "#[cfg(test)]\nmod tests { fn f(b: StepStats) -> StepStats { \
             StepStats { events_in: 1, ..b } } }",
        );
        let sites = literal_sites(&f, "StepStats");
        assert!(sites[0].functional_update && sites[0].in_test);
    }
}
