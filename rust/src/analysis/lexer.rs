//! Comment/string-aware Rust source scanner shared by every analysis
//! pass.
//!
//! [`scan`] produces a *masked* copy of the source: comment bodies and
//! string/char-literal contents are replaced by spaces while newlines
//! are preserved, so byte offsets and line numbers in the mask map 1:1
//! onto the original file.  Alongside the mask it returns the table of
//! string literals that were masked out.  Passes pattern-match on the
//! mask (so `// TODO: remove this unwrap()` or `"panic!"` cannot spoof
//! a finding) and consult the literal table when the *value* of a
//! string matters (results.json keys, config keys).
//!
//! The scanner understands line comments, nested block comments, plain
//! and raw (`r"…"`, `r#"…"#`) string literals, byte strings, char
//! literals, and tells `'a'` (char) apart from `'a` (lifetime).  It is
//! a lexer, not a parser: it never needs to understand expressions,
//! only where code stops and text begins.

/// One string literal lifted out of the source.
#[derive(Clone, Debug)]
pub struct StrLit {
    /// The literal's content, exactly as written (escapes not
    /// processed; schema/config keys never contain escapes).
    pub value: String,
    /// Byte offset of the opening quote in the original source (and in
    /// the mask — offsets are identical by construction).
    pub offset: usize,
    /// 1-based line of the opening quote.
    pub line: usize,
}

/// The result of scanning one source file.
#[derive(Debug)]
pub struct Scan {
    /// The masked source: same length as the input, comments and
    /// literal contents spaced out, quotes and newlines kept.
    pub code: String,
    /// String literals in source order.
    pub strings: Vec<StrLit>,
    line_starts: Vec<usize>,
}

impl Scan {
    /// 1-based line number of a byte offset.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// The first string literal whose opening quote sits at or after
    /// `offset` — used to read the key argument of a call found in the
    /// mask (e.g. the literal right after `.set(`).
    pub fn string_at_or_after(&self, offset: usize) -> Option<&StrLit> {
        let i = match self.strings.binary_search_by(|s| s.offset.cmp(&offset)) {
            Ok(i) => i,
            Err(i) => i,
        };
        self.strings.get(i)
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Length in bytes of the UTF-8 sequence starting at `b[i]`.
fn utf8_len(b: &[u8], i: usize) -> usize {
    let lead = b[i];
    let len = if lead < 0x80 {
        1
    } else if lead >= 0xF0 {
        4
    } else if lead >= 0xE0 {
        3
    } else {
        2
    };
    len.min(b.len() - i)
}

/// Scan `src`, producing the mask and the string-literal table.
pub fn scan(src: &str) -> Scan {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut strings = Vec::new();

    // Space out [from, to) in the mask, preserving newlines (and
    // carriage returns, so CRLF sources keep their line map).
    let mask = |out: &mut [u8], from: usize, to: usize| {
        for slot in out.iter_mut().take(to.min(n)).skip(from) {
            if *slot != b'\n' && *slot != b'\r' {
                *slot = b' ';
            }
        }
    };

    let mut i = 0;
    while i < n {
        let c = b[i];
        // Line comment (also covers `///` and `//!` doc comments).
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            mask(&mut out, i, j);
            i = j;
            continue;
        }
        // Block comment, possibly nested.
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if j + 1 < n && b[j] == b'/' && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if j + 1 < n && b[j] == b'*' && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            mask(&mut out, i, j);
            i = j;
            continue;
        }
        // Raw (and raw byte) string: r"…", r#"…"#, br"…", …  Guard on
        // the previous byte so an identifier ending in `r`/`br` never
        // starts one.
        if (c == b'r' || (c == b'b' && i + 1 < n && b[i + 1] == b'r'))
            && (i == 0 || !is_ident(b[i - 1]))
        {
            let after_r = if c == b'b' { i + 2 } else { i + 1 };
            let mut hashes = 0usize;
            let mut j = after_r;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == b'"' {
                let content_start = j + 1;
                let mut k = content_start;
                let end;
                loop {
                    if k >= n {
                        end = n;
                        break;
                    }
                    if b[k] == b'"'
                        && k + 1 + hashes <= n
                        && b[k + 1..k + 1 + hashes].iter().all(|&h| h == b'#')
                    {
                        end = k;
                        break;
                    }
                    k += 1;
                }
                strings.push((content_start - 1, src[content_start..end].to_string()));
                mask(&mut out, content_start, end);
                i = (end + 1 + hashes).min(n);
                continue;
            }
            // `r`/`br` not followed by a raw string: plain identifier.
            i += 1;
            continue;
        }
        // Plain (and byte) string literal.
        if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"' && (i == 0 || !is_ident(b[i - 1])))
        {
            let open = if c == b'b' { i + 1 } else { i };
            let mut j = open + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'"' {
                    break;
                } else {
                    j += 1;
                }
            }
            let end = j.min(n);
            strings.push((open, src[open + 1..end].to_string()));
            mask(&mut out, open + 1, end);
            i = (end + 1).min(n);
            continue;
        }
        // Char literal vs lifetime.
        if c == b'\'' {
            if i + 1 >= n {
                i += 1;
                continue;
            }
            if b[i + 1] == b'\\' {
                // Escaped char literal: the byte after the backslash is
                // the escape body (consumed unconditionally, so `'\\'`
                // and `'\''` close where they should), then any longer
                // escape tail (`\u{…}`, `\x41`) runs to the quote.
                let mut j = i + 2;
                if j < n {
                    j += utf8_len(b, j);
                }
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                mask(&mut out, i + 1, j);
                i = (j + 1).min(n);
                continue;
            }
            let ch_len = utf8_len(b, i + 1);
            if i + 1 + ch_len < n && b[i + 1 + ch_len] == b'\'' {
                // Single-char literal like 'a' or 'é'.
                mask(&mut out, i + 1, i + 1 + ch_len);
                i = i + 2 + ch_len;
            } else {
                // Lifetime ('a, 'static) — the tick stays, the
                // identifier after it is ordinary code.
                i += 1;
            }
            continue;
        }
        i += 1;
    }

    let mut line_starts = vec![0usize];
    for (pos, &byte) in b.iter().enumerate() {
        if byte == b'\n' {
            line_starts.push(pos + 1);
        }
    }

    let line_of = |offset: usize| match line_starts.binary_search(&offset) {
        Ok(i) => i + 1,
        Err(i) => i,
    };
    let strings = strings
        .into_iter()
        .map(|(offset, value)| StrLit {
            line: line_of(offset),
            value,
            offset,
        })
        .collect();
    Scan {
        code: String::from_utf8_lossy(&out).into_owned(),
        strings,
        line_starts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_masked() {
        let s = scan("let x = 1; // call unwrap() here\nx.unwrap();\n");
        assert!(!s.code[..s.code.find('\n').unwrap()].contains("unwrap"));
        assert!(s.code.contains("x.unwrap();"));
        assert_eq!(s.line_of(s.code.find("x.unwrap").unwrap()), 2);
    }

    #[test]
    fn nested_block_comments() {
        let s = scan("a /* outer /* inner */ still comment */ b");
        assert!(s.code.starts_with('a'));
        assert!(s.code.ends_with('b'));
        assert!(!s.code.contains("comment"));
    }

    #[test]
    fn string_contents_masked_but_recorded() {
        let s = scan(r#"j.set("panic!", v); x.expect("boom");"#);
        assert!(!s.code.contains("panic!"));
        assert!(!s.code.contains("boom"));
        assert_eq!(s.strings.len(), 2);
        assert_eq!(s.strings[0].value, "panic!");
        assert_eq!(s.strings[1].value, "boom");
        // The mask keeps the quotes and call shape.
        assert!(s.code.contains(".set("));
        assert!(s.code.contains(".expect(\""));
    }

    #[test]
    fn escaped_quotes_in_strings() {
        let s = scan(r#"let a = "he said \"hi\""; let b = 2;"#);
        assert_eq!(s.strings.len(), 1);
        assert_eq!(s.strings[0].value, r#"he said \"hi\""#);
        assert!(s.code.contains("let b = 2;"));
    }

    #[test]
    fn raw_strings() {
        let s = scan(r##"let a = r#"raw "quoted" panic!"#; let b = r"x"; done();"##);
        assert_eq!(s.strings.len(), 2);
        assert_eq!(s.strings[0].value, r#"raw "quoted" panic!"#);
        assert_eq!(s.strings[1].value, "x");
        assert!(!s.code.contains("panic!"));
        assert!(s.code.contains("done();"));
    }

    #[test]
    fn char_vs_lifetime() {
        let s = scan("fn f<'a>(x: &'a str) { let c = 'y'; let nl = '\\n'; if c == 'z' {} }");
        assert!(s.code.contains("<'a>"));
        assert!(s.code.contains("&'a str"));
        assert!(!s.code.contains('y'));
        assert!(!s.code.contains('z'));
        assert!(s.strings.is_empty());
    }

    #[test]
    fn escaped_backslash_and_quote_char_literals() {
        // `'\\'` must close at its own quote, not swallow following
        // code (this exact shape appears in this file).
        let s = scan("if b[j] == b'\\\\' { x.unwrap(); } if c == '\\'' { y(); }");
        assert!(s.code.contains(".unwrap()"));
        assert!(s.code.contains("y();"));
    }

    #[test]
    fn multibyte_char_literal() {
        let s = scan("let c = 'é'; let l: &'static str = \"ok\";");
        assert!(s.code.contains("'static"));
        assert_eq!(s.strings.len(), 1);
    }

    #[test]
    fn newlines_preserved_for_line_numbers() {
        let src = "a\n/* two\nlines */\nb \"s\ntr\" c\n";
        let s = scan(src);
        assert_eq!(s.code.len(), src.len());
        assert_eq!(
            s.code.matches('\n').count(),
            src.matches('\n').count(),
        );
        assert_eq!(s.line_of(s.code.find('b').unwrap()), 4);
    }

    #[test]
    fn string_lookup_after_offset() {
        let s = scan(r#"m.set("alpha", 1); m.set("beta", 2);"#);
        let second_set = s.code.rfind(".set(").unwrap();
        assert_eq!(s.string_at_or_after(second_set).unwrap().value, "beta");
    }
}
