//! Pass `channels` — static topology of `util::chan` endpoints.
//!
//! Every bounded-channel construction (`chan::bounded(cap)`) splits
//! into a sender and a receiver whose lifecycles the runtime couples:
//! a receiver nobody drains turns senders into silent back-pressure
//! walls, and a blocking drain loop whose senders never `close()`
//! parks a worker thread forever at shutdown.  This pass rebuilds that
//! topology statically from the masked source:
//!
//! * **construction sites** — word-bounded `bounded(…)` calls
//!   (turbofish `bounded::<T>(…)` included), with the capacity
//!   expression captured from the first argument and the `(tx, rx)`
//!   binding parsed from the surrounding `let` statement;
//! * **aliases** — each endpoint name is expanded one level: struct
//!   fields initialized from it (`field: rx` and shorthand) and
//!   parameters of same-file functions it is passed to;
//! * **drains** — `.recv(…)` / `.recv_timeout(…)` / `.drain_into(…)`
//!   / `.try_recv(…)` on any receiver alias (indexing like
//!   `rxs[i].drain_into(…)` is skipped over);
//! * **finish paths** — `.close()` on any sender alias.
//!
//! Errors: a receiver with used senders but no drain anywhere (a
//! `_`-prefixed receiver opts out — the explicit "intentionally
//! undrained" marker), a blocking `.recv()` drain inside a loop with
//! no `.close()` on the matching senders, a capacity-zero
//! construction (`bounded` asserts `cap > 0` at runtime — this pass
//! moves the panic to CI), and any unbounded `mpsc::channel()`
//! construction outside [`UNBOUNDED_ALLOWLIST`].

use std::collections::BTreeSet;

use crate::analysis::{fn_items, Finding, SourceFile, Workspace};

const PASS: &str = "channels";

/// Receiver-side drain operations.
const DRAIN_OPS: &[&str] = &[".recv(", ".recv_timeout(", ".drain_into(", ".try_recv("];

/// Files allowed to construct unbounded channels.  Empty today — the
/// list exists so a future exemption is a reviewed diff, not a silent
/// skip.
const UNBOUNDED_ALLOWLIST: &[&str] = &[];

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Word-bounded occurrences of `word` in `code`.
fn word_occurrences(code: &str, word: &str) -> Vec<usize> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        from = at + word.len();
        let left = at == 0 || !is_ident(bytes[at - 1]);
        let end = at + word.len();
        let right = end >= bytes.len() || !is_ident(bytes[end]);
        if left && right {
            out.push(at);
        }
    }
    out
}

/// The span of the parenthesized region starting at `open` (which must
/// be a `(`): offsets of the contents, exclusive of the parens.
fn paren_span(code: &str, open: usize) -> (usize, usize) {
    let bytes = code.as_bytes();
    let mut depth = 0usize;
    let mut i = open;
    while i < bytes.len() {
        match bytes[i] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return (open + 1, i);
                }
            }
            _ => {}
        }
        i += 1;
    }
    (open + 1, bytes.len())
}

/// Offsets of `{` tokens whose statement prefix names a loop construct
/// (`loop` / `while` / `for`), each paired with the matching `}` — the
/// loop-body spans used to classify blocking drains.
fn loop_spans(code: &str) -> Vec<(usize, usize)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    for (i, &b) in bytes.iter().enumerate() {
        if b != b'{' {
            continue;
        }
        // Walk back to the statement boundary and look for a loop keyword.
        let mut s = i;
        while s > 0 && !matches!(bytes[s - 1], b';' | b'{' | b'}') {
            s -= 1;
        }
        let prefix = &code[s..i];
        let looped = ["loop", "while", "for"]
            .iter()
            .any(|kw| !word_occurrences(prefix, kw).is_empty());
        if !looped {
            continue;
        }
        let mut depth = 0usize;
        let mut j = i;
        while j < bytes.len() {
            match bytes[j] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        out.push((i, j.min(bytes.len())));
    }
    out
}

/// One `bounded(…)` construction site.
struct Chan {
    offset: usize,
    line: usize,
    /// Capacity expression text, trimmed.
    cap: String,
    /// `(tx, rx)` binding names if the construction is destructured.
    tx: Option<String>,
    rx: Option<String>,
}

/// Parse `let (a, b) = …` out of the statement containing `offset`.
fn tuple_binding(code: &str, offset: usize) -> (Option<String>, Option<String>) {
    let bytes = code.as_bytes();
    let mut s = offset;
    while s > 0 && !matches!(bytes[s - 1], b';' | b'{' | b'}') {
        s -= 1;
    }
    let prefix = &code[s..offset];
    let Some(let_at) = word_occurrences(prefix, "let").first().copied() else {
        return (None, None);
    };
    let after = &prefix[let_at + 3..];
    let Some(open) = after.find('(') else {
        return (None, None);
    };
    let Some(close) = after[open..].find(')') else {
        return (None, None);
    };
    let names: Vec<String> = after[open + 1..open + close]
        .split(',')
        .map(|part| {
            part.trim()
                .trim_start_matches("mut ")
                .trim()
                .split(':')
                .next()
                .unwrap_or("")
                .trim()
                .to_string()
        })
        .collect();
    if names.len() == 2 && names.iter().all(|n| !n.is_empty() && n.bytes().all(is_ident)) {
        (Some(names[0].clone()), Some(names[1].clone()))
    } else {
        (None, None)
    }
}

/// Construction sites of `bounded(…)` in non-test code.
fn constructions(file: &SourceFile) -> Vec<Chan> {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    for at in word_occurrences(code, "bounded") {
        if file.in_test(at) {
            continue;
        }
        let mut i = at + "bounded".len();
        // Turbofish: `bounded::<T>(…)`.
        if code[i..].starts_with("::<") {
            let mut depth = 0usize;
            let mut j = i + 2;
            while j < bytes.len() {
                match bytes[j] {
                    b'<' => depth += 1,
                    b'>' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            i = (j + 1).min(bytes.len());
        }
        if i >= bytes.len() || bytes[i] != b'(' {
            continue; // the `fn bounded<T>(…)` definition or a doc word
        }
        let (s, e) = paren_span(code, i);
        let cap = code[s..e]
            .split(',')
            .next()
            .unwrap_or("")
            .trim()
            .to_string();
        let (tx, rx) = tuple_binding(code, at);
        out.push(Chan {
            offset: at,
            line: file.scan.line_of(at),
            cap,
            tx,
            rx,
        });
    }
    out
}

/// Expand an endpoint name one aliasing level: struct fields
/// initialized from it and same-file function parameters it is passed
/// to.
fn expand_aliases(file: &SourceFile, name: &str) -> BTreeSet<String> {
    let code = &file.scan.code;
    let bytes = code.as_bytes();
    let mut aliases: BTreeSet<String> = BTreeSet::new();
    aliases.insert(name.to_string());

    // Field alias: `field: name` in a struct literal.
    for at in word_occurrences(code, name) {
        let mut p = at;
        while p > 0 && (bytes[p - 1] as char).is_whitespace() {
            p -= 1;
        }
        if p == 0 || bytes[p - 1] != b':' || (p >= 2 && bytes[p - 2] == b':') {
            continue; // not `field: name` (`::` is a path, not an init)
        }
        let mut q = p - 1;
        while q > 0 && (bytes[q - 1] as char).is_whitespace() {
            q -= 1;
        }
        let end = q;
        while q > 0 && is_ident(bytes[q - 1]) {
            q -= 1;
        }
        if q < end {
            aliases.insert(code[q..end].to_string());
        }
    }

    // Call handoff: `helper(…, name, …)` → the helper's i-th parameter.
    for item in fn_items(code) {
        let params: Vec<String> = split_top_level(&item.params)
            .iter()
            .map(|p| {
                p.trim()
                    .trim_start_matches('&')
                    .trim_start_matches("mut ")
                    .trim()
                    .split(':')
                    .next()
                    .unwrap_or("")
                    .trim()
                    .to_string()
            })
            .collect();
        for at in word_occurrences(code, &item.name) {
            let mut i = at + item.name.len();
            if code[i..].starts_with("::<") {
                let mut depth = 0usize;
                let mut j = i + 2;
                while j < bytes.len() {
                    match bytes[j] {
                        b'<' => depth += 1,
                        b'>' => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                i = (j + 1).min(bytes.len());
            }
            if i >= bytes.len() || bytes[i] != b'(' {
                continue;
            }
            // Skip the definition itself.
            let mut p = at;
            while p > 0 && (bytes[p - 1] as char).is_whitespace() {
                p -= 1;
            }
            if p >= 2 && &code[p - 2..p] == "fn" {
                continue;
            }
            let (s, e) = paren_span(code, i);
            for (argi, arg) in split_top_level(&code[s..e]).iter().enumerate() {
                let t = arg
                    .trim()
                    .trim_start_matches('&')
                    .trim_start_matches("mut ")
                    .trim();
                if t == name {
                    if let Some(param) = params.get(argi) {
                        if !param.is_empty() {
                            aliases.insert(param.clone());
                        }
                    }
                }
            }
        }
    }
    aliases
}

/// Split on commas at bracket depth zero (over `()`, `[]`, `{}`).
fn split_top_level(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut cur = String::new();
    for c in text.chars() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            ',' if depth == 0 => {
                out.push(std::mem::take(&mut cur));
                continue;
            }
            _ => {}
        }
        cur.push(c);
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

/// Occurrences of `alias` followed (optionally across an index
/// expression `[…]`) by one of `ops`: `(op, offset)` pairs.
fn endpoint_ops<'a>(
    code: &str,
    alias: &str,
    ops: &[&'a str],
    in_test: impl Fn(usize) -> bool,
) -> Vec<(&'a str, usize)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    for at in word_occurrences(code, alias) {
        if in_test(at) {
            continue;
        }
        let mut i = at + alias.len();
        if i < bytes.len() && bytes[i] == b'[' {
            let mut depth = 0usize;
            while i < bytes.len() {
                match bytes[i] {
                    b'[' => depth += 1,
                    b']' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                i += 1;
            }
            i = (i + 1).min(bytes.len());
        }
        for op in ops {
            if code[i..].starts_with(op) {
                out.push((*op, at));
                break;
            }
        }
    }
    out
}

fn check_file(file: &SourceFile, findings: &mut Vec<Finding>) -> usize {
    let code = &file.scan.code;

    // Unbounded std channels are banned wholesale.
    for at in word_occurrences(code, "channel") {
        if file.in_test(at) {
            continue;
        }
        let prefixed = at >= 6 && &code[at - 6..at] == "mpsc::";
        let called = code[at + "channel".len()..].starts_with('(');
        if prefixed && called && !UNBOUNDED_ALLOWLIST.contains(&file.rel.as_str()) {
            findings.push(Finding::error(
                PASS,
                &file.rel,
                file.scan.line_of(at),
                "unbounded mpsc::channel() construction — use util::chan::bounded \
                 so back-pressure is explicit (allowlist in analysis/channels.rs)"
                    .to_string(),
            ));
        }
    }

    let chans = constructions(file);
    let loops = loop_spans(code);
    // A drain is "in a loop" relative to its construction site: a
    // channel built inside the same loop iteration as its single
    // blocking `.recv()` (request/ack pairs) lives and dies per
    // iteration and needs no close path.
    let in_loop_beyond = |off: usize, construction: usize| {
        loops
            .iter()
            .any(|&(s, e)| off > s && off < e && !(construction > s && construction < e))
    };

    for c in &chans {
        if c.cap == "0" {
            findings.push(Finding::error(
                PASS,
                &file.rel,
                c.line,
                "capacity-zero channel construction — util::chan::bounded asserts \
                 cap > 0 and would panic at runtime"
                    .to_string(),
            ));
        }
        let (Some(tx), Some(rx)) = (&c.tx, &c.rx) else {
            findings.push(Finding::note(
                PASS,
                &file.rel,
                c.line,
                format!(
                    "channel (cap `{}`) endpoints are not destructured into a \
                     `(tx, rx)` binding — topology untracked",
                    c.cap
                ),
            ));
            continue;
        };

        let tx_aliases = expand_aliases(file, tx);
        let rx_aliases = expand_aliases(file, rx);
        let in_test = |off: usize| file.in_test(off);

        let drains: Vec<(&str, usize)> = rx_aliases
            .iter()
            .flat_map(|a| endpoint_ops(code, a, DRAIN_OPS, in_test))
            .collect();
        let closes: Vec<(&str, usize)> = tx_aliases
            .iter()
            .flat_map(|a| endpoint_ops(code, a, &[".close("], in_test))
            .collect();
        // Senders count as used once any tx alias appears past the
        // construction statement (a move into a closure, a `.send(…)`,
        // a clone — all alias occurrences).
        let tx_used = tx_aliases.iter().any(|a| {
            word_occurrences(code, a)
                .iter()
                .any(|&at| at > c.offset && !file.in_test(at))
        });

        if drains.is_empty() && tx_used && !rx.starts_with('_') {
            findings.push(Finding::error(
                PASS,
                &file.rel,
                c.line,
                format!(
                    "channel `({tx}, {rx})` has live senders but no drain: no \
                     recv/recv_timeout/drain_into/try_recv on `{rx}` or its \
                     aliases — senders would hit the capacity wall and block \
                     forever (prefix the receiver with `_` if intentional)"
                ),
            ));
        }
        let blocking_drain = drains
            .iter()
            .find(|(op, off)| *op == ".recv(" && in_loop_beyond(*off, c.offset));
        if let Some((_, off)) = blocking_drain {
            if closes.is_empty() {
                findings.push(Finding::error(
                    PASS,
                    &file.rel,
                    file.scan.line_of(*off),
                    format!(
                        "blocking `.recv()` drain loop on `{rx}` with no finish/abort \
                         path: no `.close()` on `{tx}` or its aliases — the drain \
                         thread parks forever at shutdown"
                    ),
                ));
            }
        }
        findings.push(Finding::note(
            PASS,
            &file.rel,
            c.line,
            format!(
                "channel (cap `{}`) tx `{tx}` rx `{rx}`: {} drain site(s), {} \
                 close site(s)",
                c.cap,
                drains.len(),
                closes.len()
            ),
        ));
    }
    chans.len()
}

pub fn run(ws: &Workspace) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut total = 0usize;
    for file in &ws.src {
        total += check_file(file, &mut findings);
    }
    findings.push(Finding::note(
        PASS,
        "rust/src",
        0,
        format!("{total} channel construction site(s) mapped"),
    ));
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{find_test_ranges, lexer};

    fn file(rel: &str, src: &str) -> SourceFile {
        let scan = lexer::scan(src);
        let test_ranges = find_test_ranges(&scan.code);
        SourceFile {
            rel: rel.to_string(),
            scan,
            test_ranges,
        }
    }

    fn errors(findings: &[Finding]) -> Vec<&Finding> {
        findings
            .iter()
            .filter(|f| f.severity == crate::analysis::Severity::Error)
            .collect()
    }

    #[test]
    fn drained_and_closed_channel_is_clean() {
        let f = file(
            "rust/src/util/pool.rs",
            "fn pool() { let (tx, rx) = bounded::<Job>(4);\n\
             loop { match rx.recv_timeout(d) { _ => break } }\n\
             tx.send(1); tx.close(); }",
        );
        let mut findings = Vec::new();
        check_file(&f, &mut findings);
        assert!(errors(&findings).is_empty(), "{findings:?}");
    }

    #[test]
    fn orphaned_receiver_is_flagged() {
        let f = file(
            "rust/src/engine/exchange.rs",
            "fn leak() { let (tx, rx) = bounded(8); tx.send(1); }",
        );
        let mut findings = Vec::new();
        check_file(&f, &mut findings);
        let errs = errors(&findings);
        assert_eq!(errs.len(), 1, "{findings:?}");
        assert!(errs[0].message.contains("no drain"), "{}", errs[0].message);
        assert_eq!(errs[0].line, 1);
    }

    #[test]
    fn blocking_loop_without_close_is_flagged() {
        let f = file(
            "rust/src/engine/exchange.rs",
            "fn worker() { let (tx, rx) = bounded(8);\n\
             tx.send(1);\nloop { let _ = rx.recv(); }\n}",
        );
        let mut findings = Vec::new();
        check_file(&f, &mut findings);
        let errs = errors(&findings);
        assert_eq!(errs.len(), 1, "{findings:?}");
        assert!(errs[0].message.contains("finish/abort"), "{}", errs[0].message);
    }

    #[test]
    fn capacity_zero_and_unbounded_are_flagged() {
        let f = file(
            "rust/src/broker/core.rs",
            "fn bad() { let (tx, rx) = bounded(0); let _ = rx.recv(); tx.close();\n\
             let (a, b) = mpsc::channel(); }",
        );
        let mut findings = Vec::new();
        check_file(&f, &mut findings);
        let errs = errors(&findings);
        assert_eq!(errs.len(), 2, "{findings:?}");
    }

    #[test]
    fn drain_through_field_alias_and_index_is_seen() {
        let f = file(
            "rust/src/net/transport.rs",
            "struct S { rxs: Vec<Receiver<u8>> }\n\
             fn build() -> S { let (txs, rxs) = bounded(4); txs.send(1); \
             txs.close(); S { rxs } }\n\
             impl S { fn drain(&self) { self.rxs[0].drain_into(buf, 16); } }",
        );
        let mut findings = Vec::new();
        check_file(&f, &mut findings);
        assert!(errors(&findings).is_empty(), "{findings:?}");
    }

    #[test]
    fn handoff_to_same_file_fn_is_seen() {
        let f = file(
            "rust/src/net/transport.rs",
            "fn spawn() { let (tx, rx) = bounded(4); tx.send(1); tx.close(); \
             writer_loop::<M>(stream, rx, ping); }\n\
             fn writer_loop<M>(stream: S, out_rx: Receiver<M>, ping: u64) {\n\
             loop { match out_rx.recv_timeout(t) { _ => break } } }",
        );
        let mut findings = Vec::new();
        check_file(&f, &mut findings);
        assert!(errors(&findings).is_empty(), "{findings:?}");
    }
}
