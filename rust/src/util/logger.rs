//! Tiny leveled logger.
//!
//! The workflow manager requires every experiment step to be traceable
//! (paper Sec. 3.1: "logs every step of an experiment for traceability"),
//! so the logger supports an optional per-run log file in addition to
//! stderr, and timestamps every line.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(1); // Info
static FILE: Mutex<Option<File>> = Mutex::new(None);

/// Set the global minimum level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::SeqCst);
}

/// Mirror log lines into `path` (append). Used per experiment run.
pub fn set_file(path: &Path) -> std::io::Result<()> {
    let f = OpenOptions::new().create(true).append(true).open(path)?;
    *FILE.lock().expect("logger poisoned") = Some(f);
    Ok(())
}

/// Stop mirroring to a file.
pub fn clear_file() {
    *FILE.lock().expect("logger poisoned") = None;
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::SeqCst)
}

pub fn log(level: Level, target: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis())
        .unwrap_or(0);
    let line = format!("[{now}] {} {target}: {msg}", level.tag());
    eprintln!("{line}");
    if let Some(f) = FILE.lock().expect("logger poisoned").as_mut() {
        let _ = writeln!(f, "{line}");
    }
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn file_mirroring() {
        let dir = std::env::temp_dir().join(format!("sprobench-log-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.log");
        set_file(&path).unwrap();
        log(Level::Error, "test", "hello-file");
        clear_file();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains("hello-file"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
