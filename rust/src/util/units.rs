//! Human-friendly quantity parsing/formatting: "500K" events/s, "8M",
//! "2G" bytes, "200GB" memory, "30s"/"5m" durations.
//!
//! The paper's single configuration file expresses workloads this way
//! ("workloads of 5M and 10M events"); the config layer funnels every
//! quantity through here.

/// Parse a count with optional K/M/G/T suffix (decimal multipliers).
pub fn parse_count(s: &str) -> Result<u64, String> {
    let t = s.trim();
    if t.is_empty() {
        return Err("empty quantity".into());
    }
    let (num, mult) = split_suffix(t, &[("K", 1e3), ("M", 1e6), ("G", 1e9), ("T", 1e12)]);
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|_| format!("bad number in quantity '{s}'"))?;
    if v < 0.0 {
        return Err(format!("negative quantity '{s}'"));
    }
    Ok((v * mult).round() as u64)
}

/// Parse a byte size with optional B/KB/MB/GB/KiB/MiB/GiB suffix.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let t = s.trim();
    let pairs: &[(&str, f64)] = &[
        ("KiB", 1024.0),
        ("MiB", 1024.0 * 1024.0),
        ("GiB", 1024.0 * 1024.0 * 1024.0),
        ("KB", 1e3),
        ("MB", 1e6),
        ("GB", 1e9),
        ("TB", 1e12),
        ("B", 1.0),
    ];
    let (num, mult) = split_suffix(t, pairs);
    // Bare "K"/"M"/"G" also accepted for sizes.
    let (num, mult) = if mult == 1.0 && num == t {
        split_suffix(t, &[("K", 1e3), ("M", 1e6), ("G", 1e9)])
    } else {
        (num, mult)
    };
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|_| format!("bad number in size '{s}'"))?;
    if v < 0.0 {
        return Err(format!("negative size '{s}'"));
    }
    Ok((v * mult).round() as u64)
}

/// Parse a duration into microseconds: "500us", "10ms", "30s", "5m", "2h".
pub fn parse_duration_micros(s: &str) -> Result<u64, String> {
    let t = s.trim();
    let pairs: &[(&str, f64)] = &[
        ("us", 1.0),
        ("ms", 1e3),
        ("s", 1e6),
        ("m", 60e6),
        ("h", 3600e6),
    ];
    let (num, mult) = split_suffix(t, pairs);
    if num == t {
        // No suffix: seconds by convention.
        let v: f64 = t.parse().map_err(|_| format!("bad duration '{s}'"))?;
        return Ok((v * 1e6).round() as u64);
    }
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|_| format!("bad duration '{s}'"))?;
    if v < 0.0 {
        return Err(format!("negative duration '{s}'"));
    }
    Ok((v * mult).round() as u64)
}

fn split_suffix<'a>(s: &'a str, pairs: &[(&str, f64)]) -> (&'a str, f64) {
    for (suf, mult) in pairs {
        if s.len() > suf.len() && s.to_ascii_uppercase().ends_with(&suf.to_ascii_uppercase()) {
            return (&s[..s.len() - suf.len()], *mult);
        }
    }
    (s, 1.0)
}

/// Format an event count compactly ("1.5M", "40M", "800K").
pub fn fmt_count(v: f64) -> String {
    let (div, suf) = if v >= 1e12 {
        (1e12, "T")
    } else if v >= 1e9 {
        (1e9, "G")
    } else if v >= 1e6 {
        (1e6, "M")
    } else if v >= 1e3 {
        (1e3, "K")
    } else {
        (1.0, "")
    };
    let x = v / div;
    if x >= 100.0 || (x - x.round()).abs() < 0.05 {
        format!("{:.0}{}", x, suf)
    } else {
        format!("{:.1}{}", x, suf)
    }
}

/// Format bytes/s ("0.52 GB/s").
pub fn fmt_rate_bytes(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2} GB/s", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2} MB/s", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2} KB/s", v / 1e3)
    } else {
        format!("{:.0} B/s", v)
    }
}

/// Format microseconds adaptively ("532us", "4.2ms", "1.50s").
pub fn fmt_micros(us: u64) -> String {
    if us < 1_000 {
        format!("{us}us")
    } else if us < 1_000_000 {
        format!("{:.1}ms", us as f64 / 1e3)
    } else {
        format!("{:.2}s", us as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        assert_eq!(parse_count("500K").unwrap(), 500_000);
        assert_eq!(parse_count("8M").unwrap(), 8_000_000);
        assert_eq!(parse_count("1.5m").unwrap(), 1_500_000);
        assert_eq!(parse_count("42").unwrap(), 42);
        assert_eq!(parse_count("2G").unwrap(), 2_000_000_000);
        assert!(parse_count("abc").is_err());
        assert!(parse_count("-5K").is_err());
        assert!(parse_count("").is_err());
    }

    #[test]
    fn bytes() {
        assert_eq!(parse_bytes("27B").unwrap(), 27);
        assert_eq!(parse_bytes("2KB").unwrap(), 2_000);
        assert_eq!(parse_bytes("1KiB").unwrap(), 1_024);
        assert_eq!(parse_bytes("200GB").unwrap(), 200_000_000_000);
        assert_eq!(parse_bytes("5G").unwrap(), 5_000_000_000);
        assert_eq!(parse_bytes("64").unwrap(), 64);
    }

    #[test]
    fn durations() {
        assert_eq!(parse_duration_micros("500us").unwrap(), 500);
        assert_eq!(parse_duration_micros("10ms").unwrap(), 10_000);
        assert_eq!(parse_duration_micros("30s").unwrap(), 30_000_000);
        assert_eq!(parse_duration_micros("5m").unwrap(), 300_000_000);
        assert_eq!(parse_duration_micros("1.5").unwrap(), 1_500_000);
        assert!(parse_duration_micros("x").is_err());
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_count(40e6), "40M");
        assert_eq!(fmt_count(1_500_000.0), "1.5M");
        assert_eq!(fmt_count(800.0), "800");
        assert_eq!(fmt_rate_bytes(0.52e9), "520.00 MB/s");
        assert_eq!(fmt_rate_bytes(2.5e9), "2.50 GB/s");
        assert_eq!(fmt_micros(532), "532us");
        assert_eq!(fmt_micros(4_200), "4.2ms");
        assert_eq!(fmt_micros(1_500_000), "1.50s");
    }

    #[test]
    fn roundtrip_count_format() {
        for v in [1_000u64, 500_000, 8_000_000, 40_000_000] {
            assert_eq!(parse_count(&fmt_count(v as f64)).unwrap(), v);
        }
    }
}
